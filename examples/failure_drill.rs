//! Failure drill: push a UniLRC(42, 30) deployment to its fault-tolerance
//! edge — concurrent node failures up to d−1 = r+1 = 7, a whole-cluster
//! outage, and the first unrecoverable pattern — exercising the generic
//! decoder fallback on the live system.
//!
//! Run: `cargo run --release --example failure_drill`

use unilrc::codes::spec::{CodeFamily, Scheme};
use unilrc::experiments::{build_dss, ExpConfig};
use unilrc::prng::Prng;

fn main() -> anyhow::Result<()> {
    let cfg = ExpConfig { scheme: Scheme::S42, block_size: 64 * 1024, stripes: 1, ..Default::default() };
    let mut prng = Prng::new(5);
    let mut dss = build_dss(CodeFamily::UniLrc, &cfg);
    dss.ingest_random_stripes(1, &mut prng)?;
    let code = dss.code.clone();

    // 1. Escalating multi-failure inside one group: 1..=3 blocks down,
    //    degraded reads still served (XOR plan first, decoder fallback after).
    println!("=== escalating failures in group 0 ===");
    for wave in 1..=3usize {
        for b in 0..wave {
            dss.fail_node(dss.metadata().node_of(0, b));
        }
        let erased = dss.failed_blocks(0);
        let r = dss.degraded_read(0, 0)?;
        println!(
            "{} failed block(s) {:?}: degraded read {:.3} ms, cross bytes {}",
            erased.len(),
            erased,
            r.latency * 1e3,
            r.cross_bytes
        );
        dss.quiesce();
    }
    for b in 0..3 {
        dss.heal_node(dss.metadata().node_of(0, b));
    }

    // 2. Whole-cluster outage: fail every node of cluster 0 (one local
    //    group = 7 blocks = exactly d−1) and rebuild all of it.
    println!("\n=== whole-cluster outage ===");
    let lost_blocks: Vec<usize> =
        (0..code.n()).filter(|&b| dss.metadata().cluster_of(0, b) == 0).collect();
    let lost_nodes: Vec<usize> =
        lost_blocks.iter().map(|&b| dss.metadata().node_of(0, b)).collect();
    for &n in &lost_nodes {
        dss.fail_node(n);
    }
    println!("cluster 0 down: blocks {lost_blocks:?}");
    assert!(code.can_decode(&lost_blocks), "one-cluster failure must be decodable");
    for &b in &lost_blocks {
        let r = dss.reconstruct(0, b)?;
        println!("  rebuilt block {b:>2} in {:.3} ms", r.latency * 1e3);
        dss.quiesce();
    }
    for &n in &lost_nodes {
        dss.heal_node(n);
    }

    // 3. The edge: r+2 = 8 failures across two groups may be unrecoverable;
    //    show the decoder detecting it rather than corrupting data.
    println!("\n=== beyond tolerance ===");
    let mut pattern = code.groups()[0].members.clone(); // 7 blocks
    pattern.push(code.groups()[1].members[0]); // 8th
    match code.decode_plan(&pattern) {
        Some(_) => println!("this particular 8-pattern happens to be recoverable (d can exceed r+2)"),
        None => println!("8-failure pattern {pattern:?} correctly reported unrecoverable"),
    }
    println!("\nfailure_drill OK");
    Ok(())
}
