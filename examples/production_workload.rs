//! Production-workload scenario (paper §6 Experiment 6): serve the
//! EC-Cache object mix (82.5% 1-block, 10% 32-block, 7.5% 64-block
//! objects) from a 180-of-210 UniLRC deployment, before and after a node
//! failure, and print latency CDFs.
//!
//! Run: `cargo run --release --example production_workload`

use unilrc::client::{cdf_points, mean, percentile};
use unilrc::client::workload::{Workload, WorkloadSpec};
use unilrc::codes::spec::{CodeFamily, Scheme};
use unilrc::experiments::{build_dss, ExpConfig};
use unilrc::prng::Prng;

fn main() -> anyhow::Result<()> {
    let cfg = ExpConfig {
        scheme: Scheme::S210,
        block_size: 128 * 1024,
        stripes: 3,
        ..Default::default()
    };
    let mut prng = Prng::new(2024);
    let mut dss = build_dss(CodeFamily::UniLrc, &cfg);
    dss.ingest_random_stripes(cfg.stripes, &mut prng)?;

    let wl = Workload::place_fit(&dss, WorkloadSpec::default(), 48, &mut prng);
    println!(
        "placed {} objects ({} blocks) over {} stripes of {}",
        wl.objects.len(),
        wl.total_blocks(),
        cfg.stripes,
        dss.code.name()
    );

    // Phase 1: healthy reads.
    let mut normal = Vec::new();
    for _ in 0..300 {
        let obj = prng.gen_range(wl.objects.len());
        normal.push(wl.read_object(&mut dss, obj)?.latency * 1e3);
        dss.quiesce();
    }

    // Phase 2: degrade a node holding stripe-0 data and re-serve.
    let victim = dss.metadata().node_of(0, 0);
    dss.fail_node(victim);
    let mut degraded = Vec::new();
    for _ in 0..300 {
        let obj = prng.gen_range(wl.objects.len());
        degraded.push(wl.read_object(&mut dss, obj)?.latency * 1e3);
        dss.quiesce();
    }

    for (name, lats) in [("normal", &normal), ("degraded", &degraded)] {
        println!(
            "\n{name} reads: mean {:.3} ms   p50 {:.3}   p95 {:.3}   p99 {:.3}",
            mean(lats),
            percentile(lats, 50.0),
            percentile(lats, 95.0),
            percentile(lats, 99.0)
        );
        println!("CDF (ms, fraction):");
        for (lat, frac) in cdf_points(lats, 10) {
            println!("  {lat:>9.3}  {frac:>5.2}");
        }
    }
    println!("\nproduction_workload OK");
    Ok(())
}
