//! End-to-end driver — the full system on a real small workload, proving
//! all three layers compose:
//!
//!   Pallas GF(2^8) kernels (L1) → JAX graphs AOT-lowered to HLO (L2) →
//!   rust coordinator executing them via PJRT on the request path (L3),
//!   on a bandwidth-constrained virtual testbed.
//!
//! Workload: a 6-cluster UniLRC(42, 30) deployment and the ULRC baseline,
//! each ingesting 4 stripes (real bytes, PJRT-encoded when artifacts are
//! built), serving normal reads, degraded reads, single-block
//! reconstruction and a full-node recovery; reports the paper's headline
//! metrics side by side. Recorded in EXPERIMENTS.md §E2E.
//!
//! Run: `make artifacts && cargo run --release --example e2e_cluster`

use unilrc::codes::spec::{CodeFamily, Scheme};
use unilrc::experiments::{build_dss, ExpConfig};
use unilrc::prng::Prng;
use unilrc::runtime::Manifest;

fn main() -> anyhow::Result<()> {
    let mut cfg = ExpConfig { scheme: Scheme::S42, block_size: 256 * 1024, stripes: 4, ..Default::default() };
    match Manifest::load(Manifest::default_dir()) {
        Ok(_) => {
            cfg = cfg.with_pjrt()?;
            println!("coding backend: PJRT (AOT artifacts from python/jax/pallas)");
        }
        Err(_) => {
            println!("coding backend: native (run `make artifacts` for the PJRT path)");
        }
    }

    for fam in [CodeFamily::UniLrc, CodeFamily::Ulrc] {
        println!("\n=== {} on the virtual testbed ===", fam.name());
        let mut prng = Prng::new(99);
        let mut dss = build_dss(fam, &cfg);
        println!(
            "topology: {} clusters × {} nodes, {} placement",
            dss.topo.clusters(),
            dss.topo.max_cluster_size(),
            dss.metadata().strategy_name()
        );

        // ingest (real encode through the selected backend)
        dss.ingest_random_stripes(cfg.stripes, &mut prng)?;
        println!("ingested {} stripes × {} blocks × {} KiB", cfg.stripes, dss.code.n(), cfg.block_size / 1024);

        // normal read
        let r = dss.normal_read(0)?;
        println!(
            "normal read   : {:8.3} ms  ({:.1} MiB/s, cross-cluster bytes {})",
            r.latency * 1e3,
            r.bytes as f64 / r.latency / (1 << 20) as f64,
            r.cross_bytes
        );
        dss.quiesce();

        // degraded read of block 3
        let victim = dss.metadata().node_of(0, 3);
        dss.fail_node(victim);
        let r = dss.degraded_read(0, 3)?;
        println!(
            "degraded read : {:8.3} ms  (repair verified byte-exact, cross bytes {})",
            r.latency * 1e3,
            r.cross_bytes
        );
        dss.quiesce();

        // single-block reconstruction
        let r = dss.reconstruct(0, 3)?;
        println!(
            "reconstruction: {:8.3} ms  (cross bytes {})",
            r.latency * 1e3,
            r.cross_bytes
        );
        dss.quiesce();

        // full-node recovery
        let rec = dss.recover_node(victim)?;
        println!(
            "node recovery : {:8.3} ms for {} blocks ⇒ {:.1} MiB/s (cross bytes {})",
            rec.seconds * 1e3,
            rec.blocks,
            rec.throughput_mib_s(),
            rec.cross_bytes
        );
    }

    println!("\ne2e_cluster OK — all repairs verified against ground truth");
    Ok(())
}
