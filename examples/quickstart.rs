//! Quickstart: construct UniLRC(42, 30, 6), encode a stripe, repair every
//! kind of block with pure XOR, and survive a whole-cluster failure.
//!
//! Run: `cargo run --release --example quickstart`

use unilrc::codes::spec::{CodeFamily, Scheme};
use unilrc::codes::layout;
use unilrc::prng::Prng;

fn main() -> anyhow::Result<()> {
    // 1. Build the paper's running example: UniLRC(n=42, k=30, r=6).
    let code = Scheme::S42.build(CodeFamily::UniLrc);
    println!("{}", layout::render(&code));

    // 2. Encode a stripe of 30 random 4 KiB data blocks.
    let mut prng = Prng::new(7);
    let data: Vec<Vec<u8>> = (0..code.k()).map(|_| prng.bytes(4096)).collect();
    let drefs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
    let parities = code.encode_blocks(&drefs);
    let stripe: Vec<&[u8]> =
        drefs.iter().copied().chain(parities.iter().map(|v| v.as_slice())).collect();
    println!("encoded: {} data + {} parity blocks", code.k(), code.m());

    // 3. Single-block repair — data, global parity, local parity — all XOR.
    for &target in &[0usize, 30, 36] {
        let plan = code.repair_plan(target);
        assert!(plan.xor_only(), "UniLRC repairs are always XOR-only");
        let srcs: Vec<&[u8]> = plan.sources.iter().map(|&s| stripe[s]).collect();
        let rebuilt = plan.execute(&srcs);
        assert_eq!(rebuilt.as_slice(), stripe[target]);
        println!(
            "repaired block {target} from {} blocks ({} XOR ops/byte-lane, 0 MULs)",
            plan.sources.len(),
            plan.xor_ops()
        );
    }

    // 4. Whole-cluster failure: lose an entire local group (7 blocks) and
    //    decode it back — d = r+2 makes this exactly recoverable.
    let group = code.groups()[2].members.clone();
    let plan = code.decode_plan(&group).expect("one-cluster failure is within d-1");
    let srcs: Vec<&[u8]> = plan.sources.iter().map(|&s| stripe[s]).collect();
    let rebuilt = plan.execute(&srcs);
    for (i, &b) in plan.erased.iter().enumerate() {
        assert_eq!(rebuilt[i].as_slice(), stripe[b]);
    }
    println!("recovered a whole cluster ({} blocks) from {} survivors", group.len(), plan.read_cost());
    println!("quickstart OK");
    Ok(())
}
