//! Differential fuzz: every GF engine tier must be byte-identical to the
//! scalar `gf_mul` reference, across lengths 0–4096, odd alignments, and
//! both execution modes (serial and striped-parallel). This is the
//! correctness contract that lets the dispatcher pick any tier at startup.
//!
//! With `UNILRC_GF_KERNEL` set (the CI kernel matrix forces one tier per
//! job), exactly that tier is tested — and an unknown or unsupported
//! forced tier fails loudly, so a broken kernel can never hide behind
//! runtime dispatch quietly picking a different one.

use unilrc::gf::dispatch::{GfEngine, Kernel};
use unilrc::gf::slice::mul_acc_slice_scalar;
use unilrc::gf::tables::gf_mul;
use unilrc::gf::NibbleTables;
use unilrc::prng::Prng;

fn available() -> Vec<Kernel> {
    match Kernel::forced_from_env() {
        Some(k) => vec![k],
        None => Kernel::all().into_iter().filter(|k| k.available()).collect(),
    }
}

/// Reference: bytewise table multiply-accumulate.
fn ref_mul_acc(c: u8, src: &[u8], dst: &mut [u8]) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d ^= gf_mul(c, s);
    }
}

#[test]
fn all_tiers_match_reference_across_lengths_and_alignments() {
    let mut p = Prng::new(101);
    let kernels = available();
    // Every length 0..=300 catches all vector-width remainders (32-byte
    // AVX2 blocks + tails); the spot sizes cover page-ish lengths to 4096.
    let lengths: Vec<usize> = (0..=300)
        .chain([511, 512, 513, 1023, 1024, 1025, 2048, 4095, 4096])
        .collect();
    // Backing buffers are over-allocated so we can slice at odd offsets:
    // offset 0 (aligned), 1 (worst case), 3 (odd, crosses word boundaries).
    let max = 4096 + 8;
    let src_buf = p.bytes(max);
    let init_buf = p.bytes(max);
    for &len in &lengths {
        for offset in [0usize, 1, 3] {
            let src = &src_buf[offset..offset + len];
            let init = &init_buf[offset..offset + len];
            for c in [0u8, 1, 2, 0x1D, 0x53, 0x80, 0xFF] {
                let mut expect = init.to_vec();
                ref_mul_acc(c, src, &mut expect);
                // scalar SWAR path is itself a tier under test
                let mut got = init.to_vec();
                mul_acc_slice_scalar(c, src, &mut got);
                assert_eq!(got, expect, "scalar-fn len={len} off={offset} c={c}");
                for &k in &kernels {
                    let e = GfEngine::new(k);
                    let mut got = init.to_vec();
                    e.mul_acc(c, src, &mut got);
                    assert_eq!(got, expect, "kernel={k} len={len} off={offset} c={c}");
                }
            }
        }
    }
}

#[test]
fn all_tiers_match_reference_xor() {
    let mut p = Prng::new(102);
    let max = 4096 + 8;
    let a = p.bytes(max);
    let bb = p.bytes(max);
    for len in [0usize, 1, 15, 16, 17, 31, 32, 33, 100, 1000, 4096] {
        for offset in [0usize, 1] {
            let src = &a[offset..offset + len];
            let init = &bb[offset..offset + len];
            let expect: Vec<u8> = init.iter().zip(src).map(|(x, y)| x ^ y).collect();
            for k in available() {
                let e = GfEngine::new(k);
                let mut got = init.to_vec();
                e.xor(&mut got, src);
                assert_eq!(got, expect, "kernel={k} len={len} off={offset}");
            }
        }
    }
}

#[test]
fn fuzz_random_lengths_coefficients_all_tiers() {
    let mut p = Prng::new(103);
    let kernels = available();
    for round in 0..200 {
        let len = p.gen_range(4097);
        let c = (p.next_u64() & 0xFF) as u8;
        let src = p.bytes(len);
        let init = p.bytes(len);
        let mut expect = init.clone();
        ref_mul_acc(c, &src, &mut expect);
        for &k in &kernels {
            let e = GfEngine::new(k);
            let mut got = init.clone();
            e.mul_acc(c, &src, &mut got);
            assert_eq!(got, expect, "round={round} kernel={k} len={len} c={c}");
        }
    }
}

#[test]
fn fuzz_fused_mul_acc2_all_tiers() {
    // The fused two-source kernel must equal two chained single-source
    // ops for every tier, coefficient pair (incl. 0 and 1 special cases),
    // length remainder, and odd alignment.
    let mut p = Prng::new(106);
    let kernels = available();
    let max = 4096 + 8;
    let s1_buf = p.bytes(max);
    let s2_buf = p.bytes(max);
    let init_buf = p.bytes(max);
    for round in 0..200 {
        let len = p.gen_range(1025);
        let offset = (p.next_u64() % 4) as usize;
        let c1 = (p.next_u64() & 0xFF) as u8;
        let c2 = (p.next_u64() & 0xFF) as u8;
        let s1 = &s1_buf[offset..offset + len];
        let s2 = &s2_buf[offset..offset + len];
        let init = &init_buf[offset..offset + len];
        let mut expect = init.to_vec();
        ref_mul_acc(c1, s1, &mut expect);
        ref_mul_acc(c2, s2, &mut expect);
        let (t1, t2) = (NibbleTables::new(c1), NibbleTables::new(c2));
        for &k in &kernels {
            let e = GfEngine::new(k);
            let mut got = init.to_vec();
            e.mul_acc2_t(&t1, s1, &t2, s2, &mut got);
            assert_eq!(got, expect, "round={round} kernel={k} len={len} c1={c1} c2={c2}");
        }
    }
}

#[test]
fn parallel_striped_matches_serial_scalar_matmul() {
    let mut p = Prng::new(104);
    let block = 50_000; // forces multiple lanes incl. a short tail
    let srcs: Vec<Vec<u8>> = (0..7).map(|_| p.bytes(block)).collect();
    let refs: Vec<&[u8]> = srcs.iter().map(|v| v.as_slice()).collect();
    let coeff: Vec<Vec<u8>> = (0..4).map(|_| p.bytes(7)).collect();
    let crefs: Vec<&[u8]> = coeff.iter().map(|v| v.as_slice()).collect();

    let mut expect = vec![vec![0u8; block]; 4];
    GfEngine::scalar().matmul_blocks(&crefs, &refs, &mut expect);

    for k in available() {
        for threads in [2usize, 5] {
            let e = GfEngine::new(k).with_threads(threads).with_lane(4096).with_par_work(0);
            let mut got = vec![vec![0xEEu8; block]; 4];
            e.matmul_blocks(&crefs, &refs, &mut got);
            assert_eq!(got, expect, "kernel={k} threads={threads}");
        }
    }
}

/// Engine with the streaming-store (non-temporal) path forced **on**
/// (`with_nt(0)`) unless `UNILRC_GF_NT_KB` pins a threshold — the CI
/// kernel matrix runs these tests once per forced value, so the nt
/// selection knob itself is part of the differential contract.
fn nt_engine(k: Kernel) -> GfEngine {
    let e = GfEngine::new(k);
    let nt = std::env::var("UNILRC_GF_NT_KB")
        .ok()
        .and_then(|v| unilrc::gf::dispatch::parse_nt_kb(&v));
    e.with_nt(nt.unwrap_or(0))
}

#[test]
fn nt_fold_matches_scalar_reference() {
    // Streaming stores must be byte-identical to the regular path for
    // every source count (1 = pure copy, 2 = fused xor, 3+ = scratch
    // last-pass fusion), length remainder, and unaligned head/tail.
    let mut p = Prng::new(107);
    for len in [1usize, 31, 64, 65, 1000, 4097, 50_000] {
        let srcs: Vec<Vec<u8>> = (0..5).map(|_| p.bytes(len)).collect();
        for n in 1..=srcs.len() {
            let refs: Vec<&[u8]> = srcs[..n].iter().map(|v| v.as_slice()).collect();
            let mut expect = vec![0u8; len];
            GfEngine::scalar().fold_blocks(&mut expect, &refs);
            for k in available() {
                let e = nt_engine(k).with_threads(1);
                let mut got = vec![0xEEu8; len];
                e.fold_blocks(&mut got, &refs);
                assert_eq!(got, expect, "kernel={k} len={len} n={n}");
            }
        }
    }
}

#[test]
fn nt_matmul_matches_scalar_reference() {
    // Coefficient rows deliberately include 0s and 1s so the streaming
    // last-pass fusion hits its copy / xor special cases, plus general
    // multiplies — across serial and striped execution.
    let mut p = Prng::new(108);
    let block = 50_000;
    let srcs: Vec<Vec<u8>> = (0..6).map(|_| p.bytes(block)).collect();
    let refs: Vec<&[u8]> = srcs.iter().map(|v| v.as_slice()).collect();
    let coeff: Vec<Vec<u8>> = vec![
        p.bytes(6),
        vec![0, 1, 0, 1, 0, 1],
        vec![0, 0, 0, 0, 0, 0x1D],
        vec![1, 0, 0, 0, 0, 0],
    ];
    let crefs: Vec<&[u8]> = coeff.iter().map(|v| v.as_slice()).collect();
    let mut expect = vec![vec![0u8; block]; coeff.len()];
    GfEngine::scalar().matmul_blocks(&crefs, &refs, &mut expect);
    for k in available() {
        for threads in [1usize, 4] {
            let e = nt_engine(k).with_threads(threads).with_lane(4096).with_par_work(0);
            let mut got = vec![vec![0xEEu8; block]; coeff.len()];
            e.matmul_blocks(&crefs, &refs, &mut got);
            assert_eq!(got, expect, "kernel={k} threads={threads}");
        }
    }
}

#[test]
fn parallel_striped_matches_serial_fold() {
    let mut p = Prng::new(105);
    let block = 33_333;
    let srcs: Vec<Vec<u8>> = (0..9).map(|_| p.bytes(block)).collect();
    let refs: Vec<&[u8]> = srcs.iter().map(|v| v.as_slice()).collect();
    let mut expect = vec![0u8; block];
    GfEngine::scalar().fold_blocks(&mut expect, &refs);
    for k in available() {
        let e = GfEngine::new(k).with_threads(4).with_lane(1024).with_par_work(0);
        let mut got = vec![7u8; block];
        e.fold_blocks(&mut got, &refs);
        assert_eq!(got, expect, "kernel={k}");
    }
}
