//! Serving-plane conformance: pipelined sessions must answer strictly
//! in request order over real sockets, stale-epoch requests must be
//! redirected (and recover after a routing-table refresh) during a live
//! topology event, and the metadata epoch must survive WAL
//! crash-recovery without ever resurrecting an older value. Replayed by
//! the forced-kernel CI matrix alongside `tests/migration.rs` /
//! `tests/recovery.rs`.
//!
//! Every test body runs under a watchdog: a hung socket or a wedged
//! admission queue fails loudly in seconds instead of hanging the CI
//! job until its timeout.

use std::io::{Read, Write};
use std::time::Duration;
use unilrc::codes::spec::CodeFamily;
use unilrc::coordinator::{recover, DurabilityOptions};
use unilrc::experiments::{build_dss, ExpConfig};
use unilrc::placement::TopologyEvent;
use unilrc::prng::Prng;
use unilrc::serve::http::json_u64;
use unilrc::serve::loadgen::http_request;
use unilrc::serve::protocol::{take_frame, OpKind, Request, Response};
use unilrc::serve::{bind, run_loadgen, LoadgenConfig, ServeConfig};

/// Fail loudly if `f` exceeds the deadline; propagate its panics.
fn with_deadline<F: FnOnce() + Send + 'static>(secs: u64, f: F) {
    let (tx, rx) = std::sync::mpsc::channel();
    let h = std::thread::spawn(move || {
        f();
        tx.send(()).ok();
    });
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Ok(()) => h.join().unwrap(),
        Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => h.join().unwrap(),
        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
            panic!("test exceeded its {secs}s watchdog — serving plane hung")
        }
    }
}

fn scratch(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("unilrc-servetest-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn test_serve_config() -> ServeConfig {
    ServeConfig {
        stripes: 2,
        block_size: 4 * 1024,
        fail_nodes: 1,
        ..ServeConfig::default()
    }
}

/// Boot a server on ephemeral ports; returns (handle, data, http).
fn boot(cfg: ServeConfig) -> (unilrc::serve::ServerHandle, String, String) {
    let rt = tokio::runtime::Runtime::new().unwrap();
    let handle = rt.block_on(bind(cfg)).unwrap();
    let data = handle.data_addr().to_string();
    let http = handle.http_addr().to_string();
    (handle, data, http)
}

fn current_epoch(http: &str) -> u64 {
    let body = http_request(http, "GET", "/v1/epoch").unwrap();
    json_u64(&body, "epoch").unwrap()
}

/// Read exactly `n` response frames off a blocking client socket.
fn read_responses(stream: &mut std::net::TcpStream, n: usize) -> Vec<Response> {
    let mut out = Vec::with_capacity(n);
    let mut acc: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    while out.len() < n {
        loop {
            match take_frame(&acc).unwrap() {
                Some((payload, used)) => {
                    out.push(Response::decode(payload).unwrap());
                    acc.drain(..used);
                    if out.len() == n {
                        break;
                    }
                }
                None => {
                    let got = stream.read(&mut chunk).unwrap();
                    assert!(got > 0, "server closed mid-batch");
                    acc.extend_from_slice(&chunk[..got]);
                }
            }
        }
    }
    out
}

#[test]
fn pipelined_session_answers_in_order_under_concurrent_repair() {
    with_deadline(60, || {
        let (handle, data, http) = boot(test_serve_config());
        let epoch = current_epoch(&http);

        // A second session hammers background repairs on the failed
        // block throughout, so the ordered foreground batch below is
        // admitted *around* yielding repair traffic.
        let route = http_request(&http, "GET", "/v1/route").unwrap();
        let failed = unilrc::serve::http::json_pairs(&route, "failed_blocks");
        assert!(!failed.is_empty(), "boot must leave a failed block to repair");
        let (fs, fb) = failed[0];
        let data2 = data.clone();
        let repair_thread = std::thread::spawn(move || {
            let mut s = std::net::TcpStream::connect(&data2).unwrap();
            for id in 0..8u64 {
                let req = Request {
                    id,
                    tenant: 1,
                    op: OpKind::Repair,
                    epoch,
                    stripe: fs,
                    block: fb,
                };
                s.write_all(&req.encode()).unwrap();
            }
            let resps = read_responses(&mut s, 8);
            resps.iter().all(|r| matches!(r, Response::Ok { .. }))
        });

        // One pipelined batch of 32 foreground requests in a single
        // coalesced write; responses must come back 0..32 in order.
        let mut s = std::net::TcpStream::connect(&data).unwrap();
        let mut wire = Vec::new();
        for id in 0..32u64 {
            let op = if id % 5 == 4 { OpKind::DegradedRead } else { OpKind::Get };
            let (stripe, block) = if op == OpKind::DegradedRead {
                (fs, fb)
            } else {
                ((id % 2) as u32, 1 + (id % 3) as u32)
            };
            wire.extend_from_slice(
                &Request { id, tenant: 0, op, epoch, stripe, block }.encode(),
            );
        }
        s.write_all(&wire).unwrap();
        let resps = read_responses(&mut s, 32);
        for (i, r) in resps.iter().enumerate() {
            assert_eq!(r.id(), i as u64, "response {i} out of order: {r:?}");
            assert!(
                matches!(r, Response::Ok { .. }),
                "foreground request {i} failed: {r:?}"
            );
        }
        assert!(repair_thread.join().unwrap(), "background repairs must succeed");
        handle.shutdown();
    });
}

#[test]
fn stale_epoch_redirects_and_recovers_during_live_migration() {
    with_deadline(120, || {
        let (handle, data, http) = boot(test_serve_config());
        let old_epoch = current_epoch(&http);

        // Admit a topology event: the epoch bumps immediately and a
        // background pump starts the migration wave.
        let reply = http_request(&http, "POST", "/v1/topology?event=add_node&cluster=0").unwrap();
        let bumped = json_u64(&reply, "epoch").unwrap();
        assert!(bumped > old_epoch, "admission must bump the epoch");

        // A request stamped with the pre-event epoch is redirected, not
        // served.
        let mut s = std::net::TcpStream::connect(&data).unwrap();
        let stale =
            Request { id: 1, tenant: 0, op: OpKind::Get, epoch: old_epoch, stripe: 0, block: 1 };
        s.write_all(&stale.encode()).unwrap();
        let resp = &read_responses(&mut s, 1)[0];
        let current = match resp {
            Response::StaleEpoch { id: 1, current } => *current,
            other => panic!("expected StaleEpoch, got {other:?}"),
        };
        assert!(current >= bumped);

        // The client protocol: refresh the table, retry with the fresh
        // epoch — mid-wave, the retry must succeed.
        let fresh = current_epoch(&http);
        let retry =
            Request { id: 2, tenant: 0, op: OpKind::Get, epoch: fresh, stripe: 0, block: 1 };
        s.write_all(&retry.encode()).unwrap();
        match &read_responses(&mut s, 1)[0] {
            Response::Ok { id: 2, .. } => {}
            Response::StaleEpoch { .. } => {
                // The wave committed a move between refresh and retry;
                // one more refresh must land (bounded, not a loop).
                let fresh2 = current_epoch(&http);
                let retry2 = Request {
                    id: 3,
                    tenant: 0,
                    op: OpKind::Get,
                    epoch: fresh2,
                    stripe: 0,
                    block: 1,
                };
                s.write_all(&retry2.encode()).unwrap();
                assert!(
                    matches!(&read_responses(&mut s, 1)[0], Response::Ok { id: 3, .. }),
                    "retry with a refreshed epoch must eventually succeed"
                );
            }
            other => panic!("retry failed: {other:?}"),
        }

        // The wave drains; the server stays serviceable afterwards.
        for _ in 0..600 {
            let stats = http_request(&http, "GET", "/v1/stats").unwrap();
            if json_u64(&stats, "online_in_flight") == Some(0) {
                break;
            }
            std::thread::sleep(Duration::from_millis(50));
        }
        let stats = http_request(&http, "GET", "/v1/stats").unwrap();
        assert_eq!(json_u64(&stats, "online_in_flight"), Some(0), "wave must drain");
        assert!(json_u64(&stats, "stale_redirects").unwrap() >= 1);
        handle.shutdown();
    });
}

#[test]
fn closed_loop_loadgen_survives_a_topology_event() {
    with_deadline(120, || {
        let (handle, data, http) = boot(test_serve_config());
        let report = run_loadgen(&LoadgenConfig {
            data_addr: data,
            http_addr: http,
            sessions: 3,
            duration: Duration::from_secs(3),
            pipeline: 8,
            seed: 7,
            topology_event_at: Some(Duration::from_millis(600)),
        })
        .unwrap();
        assert!(report.ok > 0, "closed loop must complete operations");
        assert_eq!(report.protocol_errors, 0, "{report:?}");
        assert_eq!(report.op_errors, 0, "{report:?}");
        assert_eq!(report.in_order_violations, 0, "{report:?}");
        assert_eq!(report.unrecovered_redirects, 0, "{report:?}");
        assert!(
            report.stale_redirects > 0,
            "the mid-run topology event must be observed as StaleEpoch redirects: {report:?}"
        );
        assert!(report.p99_ms > 0.0);
        handle.shutdown();
    });
}

#[test]
fn loadgen_against_dead_server_fails_loudly() {
    // Reserve an ephemeral port, then drop the listener so nothing serves
    // it. The old behavior reported p99 = 0.0 ms for the zero completed
    // operations, letting `--assert-p99-ms` CI gates pass against a dead
    // server; the report must now be an error instead.
    with_deadline(60, || {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap().to_string();
        drop(l);
        let err = run_loadgen(&LoadgenConfig {
            data_addr: addr.clone(),
            http_addr: addr,
            sessions: 2,
            duration: Duration::from_millis(200),
            pipeline: 4,
            seed: 1,
            topology_event_at: None,
        })
        .expect_err("zero completed operations must not produce a report");
        assert!(err.contains("zero successful operations"), "unexpected error: {err}");
    });
}

// ---------------------------------------------------------------- epoch
// Durability of the metadata epoch across crash-recovery (Dss level).

fn tiny() -> ExpConfig {
    ExpConfig { block_size: 4 * 1024, stripes: 2, time_compute: false, ..Default::default() }
}

#[test]
fn epoch_survives_recovery_and_restart_resumes_greater() {
    let dir = scratch("epoch-rt");
    let mut dss = build_dss(CodeFamily::UniLrc, &tiny());
    dss.enable_durability(&dir, DurabilityOptions::default()).unwrap();
    let mut prng = Prng::new(42);
    dss.ingest_random_stripes(2, &mut prng).unwrap();
    let victim = dss.metadata().node_of(0, 0);
    dss.fail_node(victim);
    dss.heal_node(victim);
    dss.apply_topology_event(TopologyEvent::AddNode { cluster: 0 }).unwrap();
    let live = dss.epoch();
    assert!(live > 1, "the scenario must have bumped the epoch");
    drop(dss);

    let rec = recover(&dir).unwrap();
    assert_eq!(rec.epoch, live, "recovery must reproduce the live epoch exactly");

    // Restart discipline: a restored coordinator resumes *greater* than
    // the recovered epoch, so no post-restart table can collide with a
    // pre-crash one.
    let mut fresh = build_dss(CodeFamily::UniLrc, &tiny());
    fresh.set_epoch(rec.epoch + 1);
    assert_eq!(fresh.epoch(), rec.epoch + 1);
    let mut prng = Prng::new(43);
    fresh.ingest_random_stripes(1, &mut prng).unwrap();
    let v = fresh.metadata().node_of(0, 0);
    fresh.fail_node(v);
    assert!(fresh.epoch() > rec.epoch + 1, "mutations keep bumping after restore");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn wal_cut_sweep_never_resurrects_an_older_epoch() {
    use unilrc::coordinator::wal::list_segments;

    let dir = scratch("epoch-cut");
    let mut dss = build_dss(CodeFamily::UniLrc, &tiny());
    dss.enable_durability(&dir, DurabilityOptions::default()).unwrap();
    let mut prng = Prng::new(42);
    dss.ingest_random_stripes(2, &mut prng).unwrap();
    let victim = dss.metadata().node_of(0, 0);
    dss.fail_node(victim);
    dss.apply_topology_event(TopologyEvent::AddNode { cluster: 0 }).unwrap();
    dss.heal_node(victim);
    let live = dss.epoch();
    let seg_name = {
        let (_, path) = list_segments(&dir).unwrap().last().unwrap().clone();
        path.file_name().unwrap().to_string_lossy().into_owned()
    };
    let manifest_epoch = {
        // The manifest floor: even a fully-truncated WAL must recover at
        // least the snapshot's epoch.
        let rec_dir = scratch("epoch-cut-floor");
        copy_dir(&dir, &rec_dir);
        std::fs::write(rec_dir.join(&seg_name), b"").unwrap();
        let rec = recover(&rec_dir).unwrap();
        let _ = std::fs::remove_dir_all(&rec_dir);
        rec.epoch
    };
    drop(dss);

    // Exp9-style cut sweep: truncate the newest WAL segment at every
    // stride; the recovered epoch must be monotone in the cut position,
    // bounded by [manifest_epoch, live], and exactly `live` uncut.
    let full = std::fs::read(dir.join(&seg_name)).unwrap();
    let mut last_epoch = 0u64;
    let mut cut = 0usize;
    while cut <= full.len() {
        let rec_dir = scratch(&format!("epoch-cut-{cut}"));
        copy_dir(&dir, &rec_dir);
        std::fs::write(rec_dir.join(&seg_name), &full[..cut]).unwrap();
        let rec = recover(&rec_dir).unwrap_or_else(|e| {
            panic!("cut at {cut}/{} bytes must still recover: {e:?}", full.len())
        });
        assert!(
            rec.epoch >= manifest_epoch && rec.epoch <= live,
            "cut {cut}: epoch {} outside [{manifest_epoch}, {live}]",
            rec.epoch
        );
        assert!(
            rec.epoch >= last_epoch,
            "cut {cut}: epoch regressed {last_epoch} -> {} — an older epoch resurrected",
            rec.epoch
        );
        last_epoch = rec.epoch;
        let _ = std::fs::remove_dir_all(&rec_dir);
        cut += 37; // prime stride: lands inside records, headers, and CRCs
    }
    let rec = recover(&dir).unwrap();
    assert_eq!(rec.epoch, live, "the uncut journal must recover the exact live epoch");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn online_migration_lifecycle_keeps_bumping_the_epoch() {
    let mut dss = build_dss(CodeFamily::UniLrc, &tiny());
    let mut prng = Prng::new(42);
    dss.ingest_random_stripes(2, &mut prng).unwrap();
    let e0 = dss.epoch();
    dss.submit_topology_event(TopologyEvent::AddNode { cluster: 0 }).unwrap();
    let e1 = dss.epoch();
    assert!(e1 > e0, "online admission must bump the epoch");
    // Drive the wave to completion; each committed move bumps again.
    while dss.online_in_flight() > 0 {
        let until = dss.clock() + 3600.0;
        dss.pump_migrations(until, 8).unwrap();
        assert!(dss.parked_events().is_empty(), "healthy wave must not park");
    }
    assert!(dss.epoch() > e1, "committed moves and completion must bump the epoch");
}

fn copy_dir(src: &std::path::Path, dst: &std::path::Path) {
    std::fs::create_dir_all(dst).unwrap();
    for entry in std::fs::read_dir(src).unwrap() {
        let entry = entry.unwrap();
        std::fs::copy(entry.path(), dst.join(entry.file_name())).unwrap();
    }
}
