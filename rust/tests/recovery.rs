//! Durability conformance: the checksummed manifest + WAL journal must
//! reproduce the live coordinator bit-exactly after a restart, tolerate
//! torn tails / truncated snapshots / bit flips by recovering an earlier
//! consistent state or failing with a typed error — and never panic,
//! never return a map that fails the invariant proof, never silently
//! drop committed operations. Replayed alongside `tests/migration.rs` by
//! the forced-kernel CI matrix.

use std::path::{Path, PathBuf};
use unilrc::codes::spec::CodeFamily;
use unilrc::coordinator::manifest::{MANIFEST_CURRENT, MANIFEST_PREV};
use unilrc::coordinator::wal::{list_segments, scan_segment, ScanEnd};
use unilrc::coordinator::{recover, Dss, DssConfig, DurabilityOptions, RecoveryError};
use unilrc::experiments::{build_dss, strategy_and_topo, ExpConfig};
use unilrc::placement::{NodeState, TopologyEvent};
use unilrc::prng::Prng;
use unilrc::sim::NetConfig;

fn tiny() -> ExpConfig {
    ExpConfig { block_size: 4 * 1024, stripes: 2, time_compute: false, ..Default::default() }
}

/// Fresh per-test scratch directory (removed up front so a previous
/// aborted run cannot trip the journal's refuse-to-clobber check).
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("unilrc-rectest-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The standard mutation mix: ingest, scale-out, a failure + repair +
/// heal, a drain, and a cross-cluster scale-out — every WAL record kind.
fn run_scenario(fam: CodeFamily, cfg: &ExpConfig, dir: &Path, opts: DurabilityOptions) -> Dss {
    let mut dss = build_dss(fam, cfg);
    dss.enable_durability(dir, opts).unwrap();
    let mut prng = Prng::new(cfg.seed);
    dss.ingest_random_stripes(cfg.stripes, &mut prng).unwrap();
    dss.apply_topology_event(TopologyEvent::AddNode { cluster: 0 }).unwrap();
    let victim = dss.metadata().node_of(0, 0);
    dss.fail_node(victim);
    dss.recover_nodes(&[victim]).unwrap();
    dss.heal_node(victim);
    let drain = dss.metadata().node_of(0, 1);
    dss.apply_topology_event(TopologyEvent::DrainNode { node: drain }).unwrap();
    dss.apply_topology_event(TopologyEvent::AddCluster { nodes: dss.topo.max_cluster_size() })
        .unwrap();
    dss
}

#[test]
fn snapshot_plus_wal_replay_matches_live_state_all_families() {
    for fam in CodeFamily::paper_baselines() {
        let dir = scratch(&format!("rt-{fam:?}"));
        let dss = run_scenario(fam, &tiny(), &dir, DurabilityOptions::default());
        let live = dss.capture_state();
        let committed = dss.journal().unwrap().committed_ops();
        drop(dss);
        let rec = recover(&dir).unwrap();
        assert_eq!(rec.state, live, "{fam:?}: replayed state must be bit-exact");
        assert_eq!(rec.state.digest(), live.digest(), "{fam:?}");
        assert_eq!(rec.committed_ops, committed, "{fam:?}");
        assert!(rec.pending_event.is_none(), "{fam:?}");
        assert!(!rec.torn_tail, "{fam:?}");
        assert!(!rec.used_fallback, "{fam:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn asymmetric_topology_roundtrip_all_families() {
    // explicit per-cluster sizes (the --topology knob): the manifest must
    // persist variable-size clusters, not just the symmetric layout
    let cfg = ExpConfig { topology: Some(vec![14, 13, 13, 12, 12, 11, 11]), ..tiny() };
    for fam in CodeFamily::paper_baselines() {
        let dir = scratch(&format!("asym-{fam:?}"));
        let dss = run_scenario(fam, &cfg, &dir, DurabilityOptions::default());
        let live = dss.capture_state();
        drop(dss);
        let rec = recover(&dir).unwrap();
        assert_eq!(rec.state, live, "{fam:?}: asymmetric replay must be bit-exact");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn snapshot_rotation_truncates_log_and_still_replays() {
    let dir = scratch("rot");
    let dss = run_scenario(
        CodeFamily::UniLrc,
        &tiny(),
        &dir,
        DurabilityOptions { sync_every: 2, snapshot_every: 2 },
    );
    let live = dss.capture_state();
    let journal = dss.journal().unwrap();
    assert!(journal.snapshots() > 2, "cadence 2 over 7 ops must rotate manifests");
    let committed = journal.committed_ops();
    drop(dss);
    assert!(dir.join(MANIFEST_PREV).exists(), "rotation keeps the previous generation");
    let segments = list_segments(&dir).unwrap();
    assert!(!segments.is_empty());
    assert!(
        segments[0].0 > 1,
        "segments covered by both surviving snapshots must be truncated"
    );
    let rec = recover(&dir).unwrap();
    assert_eq!(rec.state, live, "multi-segment replay after truncation must be bit-exact");
    assert_eq!(rec.committed_ops, committed);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn truncated_group_surfaces_pending_event_for_replanning() {
    let cfg = tiny();
    let dir = scratch("pend");
    let mut durable = build_dss(CodeFamily::UniLrc, &cfg);
    durable.enable_durability(&dir, DurabilityOptions::default()).unwrap();
    let mut pa = Prng::new(3);
    durable.ingest_random_stripes(2, &mut pa).unwrap();
    durable.apply_topology_event(TopologyEvent::AddNode { cluster: 1 }).unwrap();
    drop(durable);
    // reference run: identical ingests, no topology event
    let mut reference = build_dss(CodeFamily::UniLrc, &cfg);
    let mut pb = Prng::new(3);
    reference.ingest_random_stripes(2, &mut pb).unwrap();
    let pre_event = reference.capture_state();

    let segments = list_segments(&dir).unwrap();
    assert_eq!(segments.len(), 1);
    let img = std::fs::read(&segments[0].1).unwrap();
    let (records, end) = scan_segment(&img);
    assert_eq!(end, ScanEnd::Clean);
    // crash before the group's CommitEvent hit disk: the event never
    // committed, so recovery drops the whole group atomically and
    // surfaces it for re-planning
    let cut = records.last().unwrap().offset;
    std::fs::write(&segments[0].1, &img[..cut]).unwrap();
    let rec = recover(&dir).unwrap();
    assert_eq!(rec.pending_event, Some(TopologyEvent::AddNode { cluster: 1 }));
    assert!(!rec.torn_tail, "cut at a record boundary is a clean stop");
    assert_eq!(rec.committed_ops, 2);
    assert_eq!(rec.state, pre_event, "uncommitted group must leave no trace");

    // crash mid-record: same outcome, flagged as a torn tail
    std::fs::write(&segments[0].1, &img[..cut + 3]).unwrap();
    let rec = recover(&dir).unwrap();
    assert!(rec.torn_tail);
    assert_eq!(rec.pending_event, Some(TopologyEvent::AddNode { cluster: 1 }));
    assert_eq!(rec.state, pre_event);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_current_manifest_falls_back_to_previous_generation() {
    let dir = scratch("fb");
    let dss = run_scenario(
        CodeFamily::UniLrc,
        &tiny(),
        &dir,
        DurabilityOptions { sync_every: 1, snapshot_every: 3 },
    );
    let live = dss.capture_state();
    drop(dss);
    let current = dir.join(MANIFEST_CURRENT);
    let mut bytes = std::fs::read(&current).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x01;
    std::fs::write(&current, &bytes).unwrap();
    let rec = recover(&dir).unwrap();
    assert!(rec.used_fallback, "current generation corrupt → previous must serve");
    assert_eq!(
        rec.state, live,
        "the older snapshot replays the longer WAL suffix to the same tip"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn typed_errors_no_manifest_and_corrupt_committed_record() {
    let dir = scratch("err-empty");
    std::fs::create_dir_all(&dir).unwrap();
    match recover(&dir) {
        Err(RecoveryError::NoManifest { .. }) => {}
        other => panic!("empty dir must be NoManifest, got {other:?}"),
    }
    let _ = std::fs::remove_dir_all(&dir);

    let dir = scratch("err-wal");
    let dss = run_scenario(CodeFamily::UniLrc, &tiny(), &dir, DurabilityOptions::default());
    drop(dss);
    let segments = list_segments(&dir).unwrap();
    let img = std::fs::read(&segments[0].1).unwrap();
    let (records, _) = scan_segment(&img);
    // flip a payload byte of the first committed record: CRC must catch
    // it, and recovery must refuse loudly rather than drop the records
    // behind it
    let mut bad = img.clone();
    bad[records[0].offset + 8] ^= 0xFF;
    std::fs::write(&segments[0].1, &bad).unwrap();
    match recover(&dir) {
        Err(RecoveryError::CorruptWal { .. }) => {}
        other => panic!("flipped committed record must be CorruptWal, got {other:?}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corruption_fuzz_recovers_or_fails_typed_never_panics() {
    let pristine = scratch("fuzz-pristine");
    let dss = run_scenario(
        CodeFamily::UniLrc,
        &tiny(),
        &pristine,
        DurabilityOptions { sync_every: 1, snapshot_every: 3 },
    );
    let oracle_digest = dss.capture_state().digest();
    let total_ops = dss.journal().unwrap().committed_ops();
    drop(dss);
    let files: Vec<(String, Vec<u8>)> = std::fs::read_dir(&pristine)
        .unwrap()
        .map(|e| {
            let e = e.unwrap();
            (e.file_name().into_string().unwrap(), std::fs::read(e.path()).unwrap())
        })
        .collect();
    assert!(files.len() >= 3, "want both manifest generations plus WAL segments");

    let work = scratch("fuzz-work");
    for seed in 0..30u64 {
        let mut p = Prng::new(0xF022 + seed);
        let _ = std::fs::remove_dir_all(&work);
        std::fs::create_dir_all(&work).unwrap();
        for (name, bytes) in &files {
            std::fs::write(work.join(name), bytes).unwrap();
        }
        let (name, bytes) = &files[p.gen_range(files.len())];
        let mut mutated = bytes.clone();
        if mutated.is_empty() {
            continue; // a freshly rotated, still-empty segment
        }
        if p.gen_range(2) == 0 {
            let at = p.gen_range(mutated.len());
            mutated[at] ^= 1 << p.gen_range(8);
        } else {
            mutated.truncate(p.gen_range(mutated.len()));
        }
        std::fs::write(work.join(name), &mutated).unwrap();
        match recover(&work) {
            Ok(rec) => {
                // whatever survived must be a consistent state, and
                // recovery must never invent operations
                rec.state.prove_invariants().unwrap_or_else(|e| {
                    panic!("seed {seed} ({name}): invariant violation surfaced as Ok: {e}")
                });
                assert!(rec.committed_ops <= total_ops, "seed {seed} ({name})");
                if rec.committed_ops == total_ops && rec.pending_event.is_none() {
                    assert_eq!(
                        rec.state.digest(),
                        oracle_digest,
                        "seed {seed} ({name}): full-length recovery must match the oracle"
                    );
                }
            }
            Err(e) => {
                // typed, displayable, diagnosable — never a panic
                assert!(!format!("{e}").is_empty(), "seed {seed} ({name})");
            }
        }
    }
    let _ = std::fs::remove_dir_all(&work);
    let _ = std::fs::remove_dir_all(&pristine);
}

/// The log-then-apply ordering pin: block-map mutations commit strictly
/// after byte-verification, so an event whose rebuild fails verification
/// leaves no trace — in memory, in the topology lifecycle, or in the WAL.
#[test]
fn failed_event_commits_nothing() {
    let dir = scratch("abort");
    let cfg = tiny();
    let mut dss = build_dss(CodeFamily::UniLrc, &cfg);
    dss.enable_durability(&dir, DurabilityOptions::default()).unwrap();
    let mut prng = Prng::new(5);
    dss.ingest_random_stripes(2, &mut prng).unwrap();
    // draining a failed node rebuilds its blocks; corrupt one so
    // byte-verification rejects the rebuild mid-event
    let victim = dss.metadata().node_of(0, 0);
    dss.fail_node(victim);
    dss.corrupt_block_data(0, 0);
    let pre = dss.capture_state();
    let pre_records = dss.journal().unwrap().wal_records();
    let pre_ops = dss.journal().unwrap().committed_ops();
    let err = dss.apply_topology_event(TopologyEvent::DrainNode { node: victim });
    assert!(err.is_err(), "verification must reject the corrupted rebuild");
    assert_eq!(dss.capture_state(), pre, "no in-memory mutation may commit");
    assert_eq!(dss.journal().unwrap().wal_records(), pre_records, "no WAL record may land");
    assert_eq!(dss.journal().unwrap().committed_ops(), pre_ops);
    assert_eq!(dss.topo.state(victim), NodeState::Active, "lifecycle rolled back");
    drop(dss);
    let rec = recover(&dir).unwrap();
    assert_eq!(rec.state, pre, "the journal replays to the pre-event state");
    assert!(rec.pending_event.is_none(), "nothing of the event was logged");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn restore_refuses_a_map_with_missing_blocks() {
    let cfg = tiny();
    let mut dss = build_dss(CodeFamily::UniLrc, &cfg);
    let mut prng = Prng::new(9);
    dss.ingest_random_stripes(2, &mut prng).unwrap();
    let state = dss.capture_state();
    let mut blocks = dss.export_blocks();
    let engine = dss.engine().clone();
    drop(dss);
    blocks.remove(&(0, 0));
    let code = cfg.scheme.build(CodeFamily::UniLrc);
    let (strategy, _) = strategy_and_topo(CodeFamily::UniLrc, &code);
    let err = Dss::restore(
        code,
        strategy,
        &state,
        blocks,
        NetConfig::default(),
        engine,
        DssConfig { block_size: cfg.block_size, aggregated: cfg.aggregated, time_compute: false },
    );
    let msg = format!("{:#}", err.expect_err("a silently shrunken block store must be refused"));
    assert!(msg.contains("missing"), "error must name the loss: {msg}");
}
