//! Elastic-topology conformance: every topology event (scale-out node,
//! drain, scale-out cluster, decommission) must leave the coordinator's
//! block map in a state where
//!
//! * no block lives on a non-live node,
//! * no two blocks of a stripe share a node,
//! * losing any whole cluster still decodes byte-exactly (the §2.3.2
//!   one-cluster-failure invariant, re-proven from the *migrated* map),
//! * served reads and batched recoveries still verify against ground
//!   truth,
//!
//! and `exp8_elastic` digests must reproduce run to run (the determinism
//! contract the forced-kernel CI matrix replays per engine tier).

use std::collections::HashSet;
use unilrc::codes::spec::CodeFamily;
use unilrc::coordinator::Dss;
use unilrc::experiments::{build_dss, exp8_elastic, ElasticConfig, ExpConfig};
use unilrc::placement::{NodeState, TopologyEvent};
use unilrc::prng::Prng;

fn tiny() -> ExpConfig {
    ExpConfig { block_size: 8 * 1024, stripes: 3, time_compute: false, ..Default::default() }
}

/// Assert the full post-migration safety contract on a live DSS.
fn assert_map_sane(dss: &Dss, ctx: &str) {
    let meta = dss.metadata();
    for s in 0..meta.stripe_count() {
        // distinct live nodes per stripe
        let mut nodes = HashSet::new();
        for b in 0..dss.code.n() {
            let n = meta.node_of(s, b);
            assert!(dss.topo.is_live(n), "{ctx}: stripe {s} block {b} on dead node {n}");
            assert!(nodes.insert(n), "{ctx}: stripe {s} has two blocks on node {n}");
            assert_eq!(
                dss.topo.cluster_of_node(n),
                meta.cluster_of(s, b),
                "{ctx}: stripe {s} block {b} cluster/node mismatch"
            );
        }
        // whole-cluster loss decodes byte-exactly from surviving blocks
        for c in 0..dss.topo.clusters() {
            let erased = meta.blocks_in_cluster(s, c);
            if erased.is_empty() {
                continue;
            }
            let plan = dss
                .code
                .decode_plan(erased)
                .unwrap_or_else(|| panic!("{ctx}: stripe {s} cluster {c} loss unrecoverable"));
            let sources: Vec<std::sync::Arc<Vec<u8>>> =
                plan.sources.iter().map(|&b| meta.block_data(s, b)).collect();
            let srcs: Vec<&[u8]> = sources.iter().map(|d| d.as_slice()).collect();
            let rebuilt = plan.execute(&srcs);
            for (i, &b) in plan.erased.iter().enumerate() {
                assert_eq!(
                    rebuilt[i],
                    meta.block_data(s, b).as_slice(),
                    "{ctx}: stripe {s} cluster {c} block {b} decode mismatch"
                );
            }
        }
    }
}

#[test]
fn scale_out_drain_decommission_all_families() {
    for fam in CodeFamily::paper_baselines() {
        let mut prng = Prng::new(11);
        let mut dss = build_dss(fam, &tiny());
        dss.ingest_random_stripes(3, &mut prng).unwrap();
        assert_map_sane(&dss, &format!("{fam:?} initial"));

        // scale-out: one node into cluster 0
        let before_nodes = dss.topo.total_nodes();
        let r = dss.apply_topology_event(TopologyEvent::AddNode { cluster: 0 }).unwrap();
        let new_node = before_nodes;
        assert_eq!(dss.topo.total_nodes(), before_nodes + 1);
        assert_eq!(dss.topo.state(new_node), NodeState::Active);
        assert!(r.moves > 0, "{fam:?}: rebalance must shed blocks onto the new node");
        assert_eq!(r.cross_bytes, 0, "{fam:?}: add-node rebalance stays intra-cluster");
        assert!(dss.metadata().block_map().node_load(new_node) > 0);
        assert_map_sane(&dss, &format!("{fam:?} after add-node"));

        // drain the node hosting stripe 0 block 0
        let victim = dss.metadata().node_of(0, 0);
        let hosted = dss.metadata().blocks_on_node(victim).len();
        let r = dss.apply_topology_event(TopologyEvent::DrainNode { node: victim }).unwrap();
        assert_eq!(r.moves, hosted, "{fam:?}: every hosted block must move off");
        assert_eq!(r.repaired_moves, 0, "{fam:?}: live-source drain copies, no repair");
        assert_eq!(dss.topo.state(victim), NodeState::Dead);
        assert!(dss.metadata().blocks_on_node(victim).is_empty());
        assert_map_sane(&dss, &format!("{fam:?} after drain"));

        // whole-cluster scale-out rebalances units across the gateway
        let before_clusters = dss.topo.clusters();
        let r = dss
            .apply_topology_event(TopologyEvent::AddCluster {
                nodes: dss.topo.max_cluster_size(),
            })
            .unwrap();
        assert_eq!(dss.topo.clusters(), before_clusters + 1);
        if r.moves > 0 {
            assert!(r.cross_bytes > 0, "{fam:?}: unit relocation crosses clusters");
        }
        assert_map_sane(&dss, &format!("{fam:?} after add-cluster"));

        // decommission the cluster we just filled: its units relocate back
        let retired = before_clusters; // the added cluster's id
        let r = dss
            .apply_topology_event(TopologyEvent::DecommissionCluster { cluster: retired })
            .unwrap();
        assert!(dss.topo.is_retired(retired));
        for &n in dss.topo.nodes_of(retired) {
            assert_eq!(dss.topo.state(n), NodeState::Dead, "{fam:?}");
        }
        for s in 0..dss.metadata().stripe_count() {
            assert!(dss.metadata().blocks_in_cluster(s, retired).is_empty(), "{fam:?}");
        }
        let _ = r;
        assert_map_sane(&dss, &format!("{fam:?} after decommission"));

        // the system still serves: normal read + degraded read + recovery
        dss.quiesce();
        assert!(dss.normal_read(0).unwrap().latency > 0.0);
        let node = dss.metadata().node_of(0, 0);
        dss.fail_node(node);
        assert!(dss.degraded_read(0, 0).unwrap().latency > 0.0, "{fam:?}");
        let rec = dss.recover_node(node).unwrap();
        assert!(rec.blocks > 0, "{fam:?}");
        dss.heal_node(node);
    }
}

#[test]
fn drain_of_failed_node_rebuilds_through_batched_repair() {
    // a failed node cannot source copies: its blocks must be rebuilt via
    // the batched repair pipeline, verified against ground truth, and land
    // on the migration targets
    let mut prng = Prng::new(23);
    let mut dss = build_dss(CodeFamily::UniLrc, &tiny());
    dss.ingest_random_stripes(3, &mut prng).unwrap();
    let victim = dss.metadata().node_of(0, 0);
    let hosted = dss.metadata().blocks_on_node(victim).len();
    dss.fail_node(victim);
    let r = dss.apply_topology_event(TopologyEvent::DrainNode { node: victim }).unwrap();
    assert_eq!(r.moves, hosted);
    assert_eq!(r.repaired_moves, hosted, "every move needs a rebuild");
    assert_eq!(dss.topo.state(victim), NodeState::Dead);
    assert!(!dss.failed_nodes().contains(&victim), "dead nodes leave the failure set");
    assert_map_sane(&dss, "failed-drain");
    // reads over the rebuilt placements still verify
    dss.quiesce();
    assert!(dss.normal_read(0).unwrap().latency > 0.0);
}

#[test]
fn migration_under_unrelated_failure_avoids_failed_targets() {
    let mut prng = Prng::new(31);
    let mut dss = build_dss(CodeFamily::Ulrc, &tiny());
    dss.ingest_random_stripes(2, &mut prng).unwrap();
    // fail an unrelated node, then scale out a cluster
    let bystander = dss.metadata().node_of(1, 5);
    dss.fail_node(bystander);
    let r = dss
        .apply_topology_event(TopologyEvent::AddCluster { nodes: dss.topo.max_cluster_size() })
        .unwrap();
    for s in 0..dss.metadata().stripe_count() {
        for b in 0..dss.code.n() {
            let n = dss.metadata().node_of(s, b);
            if n != bystander {
                assert!(dss.topo.is_live(n));
            }
        }
    }
    let _ = r;
    dss.heal_node(bystander);
    assert_map_sane(&dss, "scale-out under failure");
}

#[test]
fn unplannable_decommission_fails_cleanly_and_is_retryable() {
    let mut prng = Prng::new(53);
    let mut dss = build_dss(CodeFamily::UniLrc, &tiny());
    dss.ingest_random_stripes(2, &mut prng).unwrap();
    // each of the 6 clusters hosts one group of every stripe: no cluster
    // is empty for any stripe, so the units have no eligible home and the
    // event must fail *without* mutating topology or lifecycle state
    let err = dss.apply_topology_event(TopologyEvent::DecommissionCluster { cluster: 5 });
    assert!(err.is_err());
    assert!(!dss.topo.is_retired(5), "failed event must leave the cluster open");
    for &n in dss.topo.nodes_of(5) {
        assert_eq!(dss.topo.state(n), NodeState::Active, "no node may be stuck draining");
    }
    // the system is fully operational: new stripes still place over all
    // six clusters, and the invariants hold
    dss.ingest_random_stripes(1, &mut prng).unwrap();
    assert_map_sane(&dss, "after failed decommission");
    // once capacity arrives the same event succeeds
    dss.apply_topology_event(TopologyEvent::AddCluster { nodes: dss.topo.max_cluster_size() })
        .unwrap();
    dss.apply_topology_event(TopologyEvent::DecommissionCluster { cluster: 5 }).unwrap();
    assert!(dss.topo.is_retired(5));
    for s in 0..dss.metadata().stripe_count() {
        assert!(dss.metadata().blocks_in_cluster(s, 5).is_empty());
    }
    assert_map_sane(&dss, "after retried decommission");
}

#[test]
fn exp8_digest_reproduces_and_varies_with_seed() {
    let cfg = ExpConfig { block_size: 4 * 1024, stripes: 2, seed: 9, ..tiny() };
    let ecfg = ElasticConfig {
        add_nodes: 1,
        drain_nodes: 1,
        add_clusters: 1,
        cluster_nodes: 0,
        fault_horizon_hours: 120.0,
    };
    let a = exp8_elastic(&cfg, &ecfg).unwrap();
    let b = exp8_elastic(&cfg, &ecfg).unwrap();
    assert_eq!(a.len(), 5);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.family, y.family);
        assert_eq!(x.digest, y.digest, "{:?}: digest must reproduce", x.family);
        assert_eq!(x.moves, y.moves);
        assert_eq!(x.cross_migration_bytes, y.cross_migration_bytes);
        assert_eq!(x.migration_seconds.to_bits(), y.migration_seconds.to_bits());
        assert!(x.invariant_checks > 0);
    }
    let mut other = cfg.clone();
    other.seed = 10;
    let c = exp8_elastic(&other, &ecfg).unwrap();
    // the migration schedule itself is seed-independent given identical
    // ingest order, but the ingest data and post-scale fault trace are
    // seeded — digests must move
    assert_ne!(a[0].digest, c[0].digest);
}

#[test]
fn asymmetric_topology_serves_and_migrates() {
    // explicit per-cluster sizes (the --topology knob), then a drain on
    // the smallest cluster — the planner must respect real capacities
    // sized for the most demanding family: OLRC's ECWide chunks need 11
    // nodes per cluster (g+1 = 11 plus spares come from the bigger ones)
    let cfg = ExpConfig {
        block_size: 4 * 1024,
        stripes: 2,
        topology: Some(vec![14, 13, 13, 12, 12, 11, 11]),
        ..tiny()
    };
    for fam in CodeFamily::paper_baselines() {
        let mut prng = Prng::new(17);
        let mut dss = build_dss(fam, &cfg);
        assert_eq!(dss.topo.clusters(), 7, "{fam:?}");
        assert_eq!(dss.topo.cluster_size(0), 14, "{fam:?}");
        dss.ingest_random_stripes(2, &mut prng).unwrap();
        assert_map_sane(&dss, &format!("{fam:?} asymmetric initial"));
        let victim = dss.metadata().node_of(0, 1);
        dss.apply_topology_event(TopologyEvent::DrainNode { node: victim }).unwrap();
        assert_map_sane(&dss, &format!("{fam:?} asymmetric after drain"));
        dss.quiesce();
        assert!(dss.normal_read(0).unwrap().latency > 0.0, "{fam:?}");
    }
}

#[test]
fn migration_spawns_no_extra_threads() {
    // migration coding must ride the persistent worker pool (one batched
    // repair_node submission), never per-move thread spawns
    fn thread_count() -> usize {
        std::fs::read_to_string("/proc/self/status")
            .ok()
            .and_then(|s| {
                s.lines()
                    .find(|l| l.starts_with("Threads:"))
                    .and_then(|l| l.split_whitespace().nth(1))
                    .and_then(|v| v.parse().ok())
            })
            .unwrap_or(0)
    }
    let mut prng = Prng::new(41);
    let mut dss = build_dss(CodeFamily::UniLrc, &tiny());
    dss.ingest_random_stripes(2, &mut prng).unwrap();
    // warm the pool: one batched repair spins up the persistent workers
    let node = dss.metadata().node_of(0, 0);
    dss.fail_node(node);
    dss.recover_node(node).unwrap();
    dss.heal_node(node);
    let before = thread_count();
    // a failed-source drain pushes every move through the repair pipeline
    let victim = dss.metadata().node_of(1, 0);
    dss.fail_node(victim);
    dss.apply_topology_event(TopologyEvent::DrainNode { node: victim }).unwrap();
    let after = thread_count();
    if before > 0 {
        assert_eq!(before, after, "migration must not spawn threads");
    }
}
