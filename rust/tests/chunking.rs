//! `CodingBatch` adaptive-chunking edge cases: the granularity policy
//! itself (explicit `--gf-chunk-kb` override, whole-lane rounding, floor
//! at one lane) and batch-vs-sequential byte equality at the shapes that
//! stress it — a single stripe, a single-threaded engine, stripe counts
//! far above the worker count, and sub-lane blocks. GF(2^8) is exact, so
//! equality is bit-for-bit.

use unilrc::codes::spec::{CodeFamily, Scheme};
use unilrc::gf::{GfEngine, Kernel};
use unilrc::prng::Prng;

/// Tier under test: the one forced via `UNILRC_GF_KERNEL` (the CI kernel
/// matrix), else the detected best; `Kernel::forced_from_env` fails
/// loudly on unknown/unsupported names.
fn kernel_under_test() -> Kernel {
    Kernel::forced_from_env().unwrap_or_else(Kernel::detect)
}

/// Apply a `UNILRC_GF_NT_KB` override (the CI streaming-store legs) so the
/// chunking equivalence suite also runs with non-temporal stores forced
/// on/off; without the env the engine is returned unchanged.
fn with_env_nt(e: GfEngine) -> GfEngine {
    let nt = std::env::var("UNILRC_GF_NT_KB")
        .ok()
        .and_then(|v| unilrc::gf::dispatch::parse_nt_kb(&v));
    match nt {
        Some(n) => e.with_nt(n),
        None => e,
    }
}

/// Encode `stripes` random stripes batched on a configured engine and
/// compare against per-stripe scalar sequential encodes.
fn check_encode_equivalence(stripes: usize, block: usize, threads: usize, chunk: usize) {
    let code = Scheme::S42.build(CodeFamily::UniLrc);
    let mut p = Prng::new((stripes * 31 + block * 7 + threads + chunk) as u64);
    let data: Vec<Vec<Vec<u8>>> =
        (0..stripes).map(|_| (0..code.k()).map(|_| p.bytes(block)).collect()).collect();
    let srefs: Vec<Vec<&[u8]>> =
        data.iter().map(|d| d.iter().map(|v| v.as_slice()).collect()).collect();
    let expect: Vec<Vec<Vec<u8>>> = srefs.iter().map(|d| code.encode_blocks(d)).collect();
    let e = with_env_nt(
        GfEngine::new(kernel_under_test())
            .with_threads(threads)
            .with_lane(1024)
            .with_par_work(0)
            .with_chunk(chunk),
    );
    let got = code.encode_stripes_on(&e, &srefs);
    assert_eq!(got, expect, "stripes={stripes} block={block} threads={threads} chunk={chunk}");
}

#[test]
fn one_stripe_batch_matches_sequential() {
    // a lone stripe must be correct whether the granularity is adaptive,
    // splintered, lane-sized, or far larger than the whole op
    for chunk in [0usize, 64, 4096, 1 << 20] {
        check_encode_equivalence(1, 3000, 2, chunk);
    }
}

#[test]
fn single_threaded_engine_runs_batches_inline() {
    let code = Scheme::S42.build(CodeFamily::UniLrc);
    let mut p = Prng::new(5);
    let data: Vec<Vec<u8>> = (0..code.k()).map(|_| p.bytes(2048)).collect();
    let stripe: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
    let srefs: Vec<Vec<&[u8]>> = vec![stripe.clone(); 4];
    let e = with_env_nt(GfEngine::new(kernel_under_test()).with_threads(1).with_par_work(0));
    let got = code.encode_stripes_on(&e, &srefs);
    assert!(!e.pool_started(), "--gf-threads 1 must run batches inline, no pool");
    let expect = code.encode_blocks(&stripe);
    for g in &got {
        assert_eq!(g, &expect);
    }
}

#[test]
fn many_stripes_few_workers() {
    // stripe count ≫ worker count: the adaptive policy floors at one task
    // per stripe instead of lane-splintering every block — and stays
    // byte-identical
    check_encode_equivalence(64, 1500, 2, 0);
}

#[test]
fn sub_lane_blocks_with_explicit_chunks() {
    // blocks below the lane size exercise the single-task-per-op floor
    for chunk in [0usize, 64, 1024, 1 << 22] {
        check_encode_equivalence(9, 700, 8, chunk);
    }
}

#[test]
fn fold_batches_respect_chunk_overrides() {
    let mut p = Prng::new(11);
    let block = 2500;
    let stripes: Vec<Vec<Vec<u8>>> =
        (0..10).map(|_| (0..5).map(|_| p.bytes(block)).collect()).collect();
    let mut expect: Vec<Vec<u8>> = Vec::new();
    for srcs in &stripes {
        let refs: Vec<&[u8]> = srcs.iter().map(|v| v.as_slice()).collect();
        let mut out = vec![0u8; block];
        GfEngine::scalar().fold_blocks(&mut out, &refs);
        expect.push(out);
    }
    for chunk in [0usize, 64, 2048, 1 << 21] {
        let e = with_env_nt(
            GfEngine::new(kernel_under_test())
                .with_threads(3)
                .with_lane(512)
                .with_par_work(0)
                .with_chunk(chunk),
        );
        let mut got: Vec<Vec<u8>> = vec![vec![9u8; block]; 10];
        e.batch(10 * 5 * block, |b| {
            for (srcs, out) in stripes.iter().zip(got.iter_mut()) {
                let refs: Vec<&[u8]> = srcs.iter().map(|v| v.as_slice()).collect();
                b.fold(out, refs);
            }
        });
        assert_eq!(got, expect, "chunk={chunk}");
    }
}

#[test]
fn chunk_floor_is_the_lane_size() {
    // a sub-lane explicit chunk degrades to lane-sized tasks, never
    // sub-vector splinters
    let e = GfEngine::new(Kernel::Scalar).with_threads(4).with_lane(4096).with_chunk(64);
    assert_eq!(e.batch_step(1 << 24, 6), 4096);
    assert_eq!(e.batch_chunk(1 << 24), 64, "explicit chunk is reported as-is");
    // and the adaptive policy never goes below one lane either
    let a = GfEngine::new(Kernel::Scalar).with_threads(4).with_lane(4096);
    assert_eq!(a.batch_chunk(0), 4096);
    assert_eq!(a.batch_step(1, 100), 4096);
}

#[test]
fn env_knob_parses_into_engine() {
    // UNILRC_GF_CHUNK_KB pins the granularity in from_env engines
    std::env::set_var("UNILRC_GF_CHUNK_KB", "128");
    let e = GfEngine::from_env();
    std::env::remove_var("UNILRC_GF_CHUNK_KB");
    assert_eq!(e.batch_chunk(1 << 30), 128 * 1024);
}
