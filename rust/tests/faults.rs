//! Fault-injection scenario conformance:
//!
//! * trace determinism — same seed ⇒ bit-identical schedule and digest,
//!   text round-trip exact (the replayable trace format);
//! * `exp7_faults` determinism — the full scenario digest (trace + every
//!   measured virtual latency) reproduces across runs, and plan-cache
//!   warm-up never changes a single measured value (warm ≡ cold);
//! * differential reliability — occupancy and MTTDL estimates from short
//!   injected traces agree with the `analysis::markov` closed forms
//!   within stated tolerances, for all five code families;
//! * correlated cluster bursts run end to end (batched recovery, data-loss
//!   accounting) without corrupting any served byte (every repair verifies
//!   against ground truth internally).

use unilrc::analysis::markov;
use unilrc::experiments::{exp7_faults, family_tolerance, ExpConfig, FaultSimConfig};
use unilrc::placement::Topology;
use unilrc::sim::faults::{FaultConfig, FaultTrace};

/// Deterministic scenario base: virtual clock only, small blocks.
fn tiny_exp() -> ExpConfig {
    ExpConfig { block_size: 4 * 1024, stripes: 2, seed: 7, ..Default::default() }
}

fn short_faults() -> FaultSimConfig {
    FaultSimConfig {
        fault: FaultConfig {
            node_mttf_hours: 300.0,
            node_mttr_hours: 10.0,
            cluster_mttf_hours: 1_500.0,
            cluster_mttr_hours: 5.0,
            sector_mtte_hours: 0.0,
            horizon_hours: 600.0,
        },
        tenants: 2,
        objects_per_tenant: 6,
        reads_per_event: 1,
        measure_cap: 8,
    }
}

#[test]
fn trace_generation_is_seed_deterministic() {
    let cfg = FaultConfig::accelerated();
    let topo = Topology::new(6, 9);
    let a = FaultTrace::generate(&topo, &cfg, 11);
    let b = FaultTrace::generate(&topo, &cfg, 11);
    assert_eq!(a, b, "same seed ⇒ identical schedule");
    assert_eq!(a.digest(), b.digest());
    assert_ne!(a.digest(), FaultTrace::generate(&topo, &cfg, 12).digest());
    // replayable text format round-trips bit-exact
    let parsed = FaultTrace::parse(&a.to_text()).unwrap();
    assert_eq!(parsed.digest(), a.digest());
}

#[test]
fn exp7_digest_reproduces_across_runs() {
    let cfg = tiny_exp();
    let fc = short_faults();
    let a = exp7_faults(&cfg, &fc).unwrap();
    let b = exp7_faults(&cfg, &fc).unwrap();
    assert_eq!(a.len(), 5);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.family, y.family);
        assert_eq!(x.digest, y.digest, "{:?}: digest must reproduce", x.family);
        assert_eq!(x.events, y.events);
        assert_eq!(x.repaired_blocks, y.repaired_blocks);
        assert_eq!(x.cross_bytes, y.cross_bytes);
        assert_eq!(x.mean_repair_ms.to_bits(), y.mean_repair_ms.to_bits());
        assert_eq!(x.mean_degraded_ms.to_bits(), y.mean_degraded_ms.to_bits());
    }
    // a different seed produces a different schedule (and digest)
    let mut other = tiny_exp();
    other.seed = 8;
    let c = exp7_faults(&other, &fc).unwrap();
    assert_ne!(a[0].digest, c[0].digest);
}

#[test]
fn plan_warmup_never_changes_measurements() {
    // Runs at S136 — no other test in this binary touches S136 exp7, and
    // cache keys embed the code name, so concurrently-running S42 tests
    // cannot interfere. The COLD run goes first: its measurements are
    // taken before prefetch touches the shared global cache, so a
    // divergent prefetched plan could not also serve the cold side. The
    // warm run's prefetch still finds plenty to insert afterwards —
    // predicted patterns (e.g. pure whole-cluster states) are a strict
    // superset of the failure states the cold replay realized.
    let mut warm_cfg = tiny_exp();
    warm_cfg.scheme = unilrc::codes::spec::Scheme::S136;
    warm_cfg.seed = 99;
    warm_cfg.plan_warmup = unilrc::experiments::WarmupMode::Trace;
    let mut cold_cfg = warm_cfg.clone();
    cold_cfg.plan_warmup = unilrc::experiments::WarmupMode::Off;
    let mut fc = short_faults();
    // frequent cluster events: fully-grouped codes predict only cluster
    // patterns (single-node repairs bypass the cache), and pure-cluster
    // states are essentially never realized exactly by the cold replay,
    // so the warm run always has plans left to insert
    fc.fault.cluster_mttf_hours = 300.0;
    let cold = exp7_faults(&cold_cfg, &fc).unwrap();
    let warm = exp7_faults(&warm_cfg, &fc).unwrap();
    for (c, w) in cold.iter().zip(&warm) {
        assert_eq!(c.digest, w.digest, "{:?}: warm-up must be output-invisible", c.family);
        assert_eq!(c.repaired_blocks, w.repaired_blocks);
        assert_eq!(c.cross_bytes, w.cross_bytes);
        assert_eq!(c.mean_repair_ms.to_bits(), w.mean_repair_ms.to_bits());
        assert_eq!(c.prefetched_plans, 0, "cold run must not prefetch");
        assert!(w.prefetched_plans > 0, "{:?}: warm run must prefetch plans", w.family);
    }
}

#[test]
fn learned_warmup_is_output_invisible_and_prefetches() {
    // Runs exp7 at S210, reserved for this test: plan-cache keys embed the
    // code name, so S42 (the other scenario tests) and S136 (the
    // trace-warm-up test) traffic cannot interfere with the insert counts
    // asserted here. The OFF run goes first; its demand path only inserts
    // *realized* mixed failure states, while the learned predictor inserts
    // pure whole-cluster patterns on each cluster's first observed outage
    // — with ~7 of 230 nodes down on average at these rates, a realized
    // state is essentially never cluster-pure, so the learned run always
    // has plans left to insert.
    use unilrc::experiments::WarmupMode;
    let mut learned_cfg = tiny_exp();
    learned_cfg.scheme = unilrc::codes::spec::Scheme::S210;
    learned_cfg.stripes = 1;
    learned_cfg.block_size = 1024;
    learned_cfg.seed = 77;
    learned_cfg.plan_warmup = WarmupMode::Learned;
    let mut off_cfg = learned_cfg.clone();
    off_cfg.plan_warmup = WarmupMode::Off;
    let fc = FaultSimConfig {
        fault: FaultConfig {
            node_mttf_hours: 300.0,
            node_mttr_hours: 10.0,
            cluster_mttf_hours: 250.0,
            cluster_mttr_hours: 5.0,
            sector_mtte_hours: 0.0,
            horizon_hours: 250.0,
        },
        tenants: 1,
        objects_per_tenant: 2,
        reads_per_event: 1,
        measure_cap: 4,
    };
    let off = exp7_faults(&off_cfg, &fc).unwrap();
    let learned = exp7_faults(&learned_cfg, &fc).unwrap();
    for (c, l) in off.iter().zip(&learned) {
        assert_eq!(c.family, l.family);
        assert_eq!(
            c.digest, l.digest,
            "{:?}: learned warm-up must be output-invisible",
            c.family
        );
        assert_eq!(c.repaired_blocks, l.repaired_blocks);
        assert_eq!(c.cross_bytes, l.cross_bytes);
        assert_eq!(c.prefetched_plans, 0, "off mode must not prefetch");
        assert!(
            l.prefetched_plans > 0,
            "{:?}: learned mode must prefetch from observed history",
            l.family
        );
    }
}

#[test]
fn predictor_prefetch_drives_cache_stats_counters() {
    // Satellite check on a *local* PlanCache (no global-state interference):
    // learned-history prefetch must surface through the CacheStats counters
    // exactly like trace-driven warm-up — prefetched ≠ demand misses, and
    // demand lookups of predicted patterns count as prefetch_hits.
    use unilrc::codes::PlanCache;
    use unilrc::experiments::{build_dss, PatternPredictor};
    use unilrc::prng::Prng;
    let cfg = ExpConfig { block_size: 1024, stripes: 2, ..tiny_exp() };
    let mut dss = build_dss(unilrc::codes::spec::CodeFamily::UniLrc, &cfg);
    let mut p = Prng::new(5);
    dss.ingest_random_stripes(2, &mut p).unwrap();
    let mut pred = PatternPredictor::new();
    let node = dss.metadata().node_of(0, 0);
    let cluster = dss.metadata().cluster_of(0, 0);
    let patterns = pred.observe(&dss, &[node], &[cluster]);
    assert!(!patterns.is_empty());

    let cache = PlanCache::new(64);
    let inserted = cache.prefetch(&dss.code, &patterns);
    assert_eq!(inserted, patterns.len());
    let stats = cache.stats(8);
    assert_eq!(stats.prefetched as usize, inserted);
    assert_eq!(stats.prefetch_hits, 0);
    assert_eq!((stats.hits, stats.misses), (0, 0), "warm-up is not demand traffic");

    // demand lookup of a predicted pattern: hit, tagged prefetch_hit
    assert!(cache.get_or_compute(&dss.code, &patterns[0]).is_some());
    let stats = cache.stats(8);
    assert_eq!((stats.hits, stats.misses), (1, 0));
    assert_eq!(stats.prefetch_hits, 1);
    assert!(stats.top.iter().any(|e| e.prefetched));

    // re-observing predicts nothing, re-prefetching inserts nothing
    assert!(pred.observe(&dss, &[node], &[cluster]).is_empty());
    assert_eq!(cache.prefetch(&dss.code, &patterns), 0);
    assert_eq!(cache.stats(8).prefetched as usize, inserted);
}

#[test]
fn simulated_reliability_matches_markov_closed_form() {
    // Node-level clocks only (the chain the closed form models), long
    // horizon, occupancy-only (measure_cap 0 — no DSS ops, so this stays
    // cheap while the estimator converges).
    let (mttf, mttr) = (1_000.0f64, 10.0f64);
    let cfg = ExpConfig { block_size: 1024, stripes: 1, seed: 21, ..Default::default() };
    let fc = FaultSimConfig {
        fault: FaultConfig {
            node_mttf_hours: mttf,
            node_mttr_hours: mttr,
            cluster_mttf_hours: 0.0,
            cluster_mttr_hours: 0.0,
            sector_mtte_hours: 0.0,
            horizon_hours: 30_000.0,
        },
        tenants: 1,
        objects_per_tenant: 2,
        reads_per_event: 0,
        measure_cap: 0,
    };
    let rows = exp7_faults(&cfg, &fc).unwrap();
    assert_eq!(rows.len(), 5);
    for r in &rows {
        // degraded-time fraction of stripe 0 vs the birth–death steady
        // state: stated tolerance 25% relative (the estimator sees ~1500
        // up/down cycles at these rates).
        let rel = (r.sim_degraded_frac - r.markov_degraded_frac).abs() / r.markov_degraded_frac;
        assert!(
            rel < 0.25,
            "{:?}: sim {} vs markov {} (rel {rel:.3})",
            r.family,
            r.sim_degraded_frac,
            r.markov_degraded_frac
        );
        // MTTDL from trace-estimated rates vs from configured rates: the
        // chain amplifies rate error ~(2f+1)×, so the stated tolerance is
        // a factor bound, not a relative one.
        let f_tol = family_tolerance(cfg.scheme, r.family);
        let bound = if f_tol > 8 { 10.0 } else { 4.0 };
        let ratio = r.mttdl_est_years / r.mttdl_markov_years;
        assert!(
            ratio.is_finite() && ratio > 1.0 / bound && ratio < bound,
            "{:?}: MTTDL est {:.3e} vs markov {:.3e} (ratio {ratio:.3})",
            r.family,
            r.mttdl_est_years,
            r.mttdl_markov_years
        );
        // sanity: the closed form itself matches the direct formula
        let expect = markov::degraded_fraction(42, 1.0 / mttf, 1.0 / mttr);
        assert_eq!(r.markov_degraded_frac.to_bits(), expect.to_bits());
    }
}

#[test]
fn correlated_cluster_bursts_run_batched_and_account_loss() {
    // Cluster events dominate: whole-rack outages land many repairs in one
    // batched event; unrecoverable windows are counted, never panicked on,
    // and every served byte still verifies against ground truth.
    let cfg = ExpConfig { block_size: 4 * 1024, stripes: 2, seed: 3, ..Default::default() };
    let fc = FaultSimConfig {
        fault: FaultConfig {
            node_mttf_hours: 500.0,
            node_mttr_hours: 20.0,
            cluster_mttf_hours: 300.0,
            cluster_mttr_hours: 10.0,
            sector_mtte_hours: 0.0,
            horizon_hours: 1_200.0,
        },
        tenants: 3,
        objects_per_tenant: 6,
        reads_per_event: 2,
        measure_cap: 16,
    };
    let rows = exp7_faults(&cfg, &fc).unwrap();
    for r in &rows {
        assert!(r.cluster_failures > 0, "{:?}: schedule must include cluster events", r.family);
        assert!(r.degraded_hours > 0.0);
        assert!(r.unavailable_hours >= 0.0);
        assert!(r.unavailable_hours <= r.degraded_hours + 1e-9);
        // a whole-cluster repair must rebuild more blocks than a
        // single-node one can host per stripe — proves batching saw bursts
        if r.repair_events > 0 {
            assert!(r.repaired_blocks >= r.repair_events, "{:?}", r.family);
        }
    }
    // same seed reproduces even under cluster bursts and data loss
    let again = exp7_faults(&cfg, &fc).unwrap();
    for (x, y) in rows.iter().zip(&again) {
        assert_eq!(x.digest, y.digest);
        assert_eq!(x.data_loss_stripe_events, y.data_loss_stripe_events);
    }
}

#[test]
fn every_family_uses_fixed_seeds_for_trace_randomness() {
    // Trace determinism is the repo-wide seed policy made testable: two
    // fresh generations from the same explicit seed must agree event by
    // event for every family's topology shape.
    for (clusters, nodes) in [(6usize, 9usize), (11, 8), (2, 4)] {
        let topo = Topology::new(clusters, nodes);
        let cfg = FaultConfig::accelerated();
        let a = FaultTrace::generate(&topo, &cfg, 0xF00D);
        let b = FaultTrace::generate(&topo, &cfg, 0xF00D);
        assert_eq!(a.digest(), b.digest(), "topo {clusters}x{nodes}");
    }
}
