//! Randomized property tests (proptest is unavailable offline; a seeded
//! PRNG drives the same shape of invariant checking):
//!
//! * code-level: any ≤ f erasure pattern decodes and reproduces exact
//!   bytes, for every family × scheme;
//! * coordinator-level: arbitrary interleavings of fail/heal/read/repair
//!   preserve ground truth and never corrupt served data;
//! * placement-level: rotation preserves structural invariants;
//! * network-level: more bandwidth never increases any transfer time.

use std::sync::Arc;
use unilrc::codes::spec::{CodeFamily, Scheme};
use unilrc::coordinator::{Dss, DssConfig};
use unilrc::experiments::strategy_and_topo;
use unilrc::prng::Prng;
use unilrc::runtime::NativeCoder;
use unilrc::sim::{Endpoint, NetConfig, NetSim};

fn make_dss(fam: CodeFamily, scheme: Scheme, bs: usize) -> Dss {
    let code = scheme.build(fam);
    let (strategy, topo) = strategy_and_topo(fam, &code);
    Dss::new(
        code,
        strategy,
        topo,
        NetConfig::default(),
        Arc::new(NativeCoder),
        DssConfig { block_size: bs, aggregated: true, time_compute: false },
    )
}

#[test]
fn prop_all_families_decode_random_f_patterns_bytes_exact() {
    let mut prng = Prng::new(0xDEC0DE);
    for fam in CodeFamily::paper_baselines() {
        let scheme = Scheme::S42;
        let code = scheme.build(fam);
        let f = match fam {
            CodeFamily::Olrc => 11,
            _ => scheme.f,
        };
        let data: Vec<Vec<u8>> = (0..code.k()).map(|_| prng.bytes(64)).collect();
        let drefs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
        let parities = code.encode_blocks(&drefs);
        let stripe: Vec<&[u8]> =
            drefs.iter().copied().chain(parities.iter().map(|v| v.as_slice())).collect();
        for _ in 0..40 {
            let t = 1 + prng.gen_range(f);
            let erased = prng.choose_distinct(code.n(), t);
            let plan = code
                .decode_plan(&erased)
                .unwrap_or_else(|| panic!("{fam:?} failed {erased:?}"));
            let srcs: Vec<&[u8]> = plan.sources.iter().map(|&s| stripe[s]).collect();
            let rebuilt = plan.execute(&srcs);
            for (i, &b) in plan.erased.iter().enumerate() {
                assert_eq!(rebuilt[i].as_slice(), stripe[b], "{fam:?} {erased:?}");
            }
        }
    }
}

#[test]
fn prop_coordinator_random_op_sequences_never_corrupt() {
    let mut prng = Prng::new(0xC0FFEE);
    for fam in [CodeFamily::UniLrc, CodeFamily::Ulrc] {
        let mut dss = make_dss(fam, Scheme::S42, 8 * 1024);
        dss.ingest_random_stripes(3, &mut prng).unwrap();
        let total_nodes = dss.topo.total_nodes();
        for step in 0..120 {
            match prng.gen_range(5) {
                0 => {
                    // fail a random node, but never beyond cluster tolerance:
                    // keep at most 2 failures alive at once
                    if dss.failed_nodes().len() < 2 {
                        dss.fail_node(prng.gen_range(total_nodes));
                    }
                }
                1 => {
                    if let Some(&n) = dss.failed_nodes().iter().next() {
                        dss.heal_node(n);
                    }
                }
                2 => {
                    // normal read of a stripe with no failed data blocks
                    let s = prng.gen_range(3);
                    if dss.failed_blocks(s).iter().all(|&b| b >= dss.code.k()) {
                        let r = dss.normal_read(s).unwrap();
                        assert!(r.latency > 0.0, "step {step}");
                    }
                }
                3 => {
                    // degraded read of a random failed data block, if any
                    let s = prng.gen_range(3);
                    let failed = dss.failed_blocks(s);
                    if let Some(&b) = failed.iter().find(|&&b| b < dss.code.k()) {
                        // ops verify bytes internally; an Err here = corruption
                        dss.degraded_read(s, b).unwrap();
                    }
                }
                _ => {
                    let s = prng.gen_range(3);
                    if let Some(&b) = dss.failed_blocks(s).first() {
                        dss.reconstruct(s, b).unwrap();
                    }
                }
            }
            if step % 10 == 0 {
                dss.quiesce();
            }
        }
    }
}

#[test]
fn prop_placement_rotation_invariants() {
    let mut prng = Prng::new(0x9A7);
    for fam in CodeFamily::paper_baselines() {
        for scheme in [Scheme::S42, Scheme::S136] {
            let code = scheme.build(fam);
            let (strategy, topo) = strategy_and_topo(fam, &code);
            let base = strategy.place(&code, &topo, 0);
            let base_hist: Vec<usize> = {
                let mut h: Vec<usize> =
                    (0..topo.clusters()).map(|c| base.blocks_in_cluster(c).len()).collect();
                h.sort_unstable();
                h
            };
            for _ in 0..8 {
                let rot = prng.gen_range(97);
                let p = strategy.place(&code, &topo, rot);
                // every block placed exactly once on a distinct node
                let mut nodes = p.node_of.clone();
                nodes.sort_unstable();
                nodes.dedup();
                assert_eq!(nodes.len(), code.n(), "{fam:?} rot {rot}");
                // rotation permutes clusters but preserves the load shape
                let mut h: Vec<usize> =
                    (0..topo.clusters()).map(|c| p.blocks_in_cluster(c).len()).collect();
                h.sort_unstable();
                assert_eq!(h, base_hist, "{fam:?} rot {rot}");
            }
        }
    }
}

#[test]
fn prop_more_bandwidth_never_slower() {
    let mut prng = Prng::new(0xBAD);
    let topo = unilrc::placement::Topology::new(4, 4);
    for _ in 0..30 {
        let gbps_lo = 0.5 + prng.gen_f64() * 2.0;
        let gbps_hi = gbps_lo * (1.5 + prng.gen_f64());
        let mut lo = NetSim::new(&topo, NetConfig::default().with_cross_gbps(gbps_lo));
        let mut hi = NetSim::new(&topo, NetConfig::default().with_cross_gbps(gbps_hi));
        // identical random transfer schedule through both
        let mut t_lo = 0.0f64;
        let mut t_hi = 0.0f64;
        for _ in 0..20 {
            let from = Endpoint::Node(prng.gen_range(16));
            let to = if prng.gen_range(2) == 0 {
                Endpoint::Client
            } else {
                Endpoint::Node(prng.gen_range(16))
            };
            let bytes = 1024 * (1 + prng.gen_range(2048));
            t_lo = t_lo.max(lo.transfer(0.0, from, to, bytes));
            t_hi = t_hi.max(hi.transfer(0.0, from, to, bytes));
        }
        assert!(t_hi <= t_lo + 1e-12, "{gbps_lo} vs {gbps_hi}: {t_lo} {t_hi}");
    }
}

#[test]
fn prop_aggregation_never_increases_cross_bytes() {
    let mut prng = Prng::new(0xA66);
    for fam in [CodeFamily::Olrc, CodeFamily::Ulrc] {
        let mut raw = make_dss(fam, Scheme::S42, 8 * 1024);
        raw.cfg.aggregated = false;
        let mut agg = make_dss(fam, Scheme::S42, 8 * 1024);
        let mut p2 = Prng::new(0xA66);
        raw.ingest_random_stripes(1, &mut prng).unwrap();
        agg.ingest_random_stripes(1, &mut p2).unwrap();
        for target in 0..raw.code.k() {
            let node = raw.metadata().node_of(0, target);
            raw.fail_node(node);
            agg.fail_node(node);
            let r_raw = raw.degraded_read(0, target).unwrap();
            let r_agg = agg.degraded_read(0, target).unwrap();
            assert!(
                r_agg.cross_bytes <= r_raw.cross_bytes,
                "{fam:?} block {target}: agg {} raw {}",
                r_agg.cross_bytes,
                r_raw.cross_bytes
            );
            raw.heal_node(node);
            agg.heal_node(node);
            raw.quiesce();
            agg.quiesce();
        }
    }
}

#[test]
fn prop_relaxed_unilrc_spans_match_theory() {
    use unilrc::codes::unilrc::UniLrc;
    // relaxed construction: rate strictly increases with t, locality grows
    for (alpha, z) in [(1usize, 6usize), (2, 8)] {
        let mut last_rate = 0.0;
        for t in [1usize, 2] {
            let c = UniLrc::new_relaxed(alpha, z, t);
            assert!(c.rate() > last_rate, "α={alpha} z={z} t={t}");
            last_rate = c.rate();
            // every repair XOR-only regardless of t
            let mut prng = Prng::new(7);
            for _ in 0..10 {
                let b = prng.gen_range(c.n());
                assert!(c.repair_plan(b).xor_only());
            }
        }
    }
}
