//! Online-migration conformance: topology events running as background
//! workloads must
//!
//! * serialize conflicting admissions with a typed retryable error —
//!   never a half-claimed map,
//! * keep a `Migrating` block readable from its source until the move
//!   commits (no phantom unavailability window),
//! * survive source death mid-move by flipping the remaining moves onto
//!   the batched rebuild, byte-identically,
//! * survive destination death by re-planning onto a fresh
//!   invariant-satisfying target,
//! * recover a coordinator crash mid-wave digest-identical to a
//!   never-crashed oracle, resuming the logged plan tail.
//!
//! Replayed alongside `tests/migration.rs` and `tests/recovery.rs` by
//! the forced-kernel CI matrix.

use std::collections::HashSet;
use std::path::PathBuf;
use unilrc::codes::spec::CodeFamily;
use unilrc::coordinator::manifest::{MANIFEST_CURRENT, MANIFEST_PREV};
use unilrc::coordinator::wal::list_segments;
use unilrc::coordinator::{recover, BlockState, Dss, DssConfig, DurabilityOptions, MigrationError};
use unilrc::experiments::{build_dss, strategy_and_topo, ExpConfig};
use unilrc::placement::{NodeState, TopologyEvent};
use unilrc::prng::Prng;
use unilrc::sim::NetConfig;

fn tiny() -> ExpConfig {
    ExpConfig { block_size: 4 * 1024, stripes: 2, time_compute: false, ..Default::default() }
}

/// Fresh per-test scratch directory (removed up front so a previous
/// aborted run cannot trip the journal's refuse-to-clobber check).
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("unilrc-migload-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Pump until every in-flight event completes (bounded: a stuck event
/// fails the test instead of hanging it).
fn drain(dss: &mut Dss) {
    for _ in 0..10_000 {
        if dss.online_in_flight() == 0 {
            return;
        }
        dss.pump_migrations(f64::INFINITY, 64).unwrap();
        if dss.online_in_flight() > 0 && !dss.parked_events().is_empty() {
            dss.retry_parked();
        }
    }
    panic!("online migration failed to drain: parked {:?}", dss.parked_events());
}

/// The post-migration safety contract: blocks on distinct live nodes,
/// cluster/node indexes consistent, any one-cluster loss decodes
/// byte-exactly from the migrated map.
fn assert_map_sane(dss: &Dss, ctx: &str) {
    let meta = dss.metadata();
    for s in 0..meta.stripe_count() {
        let mut nodes = HashSet::new();
        for b in 0..dss.code.n() {
            let n = meta.node_of(s, b);
            assert!(dss.topo.is_live(n), "{ctx}: stripe {s} block {b} on dead node {n}");
            assert!(nodes.insert(n), "{ctx}: stripe {s} has two blocks on node {n}");
            assert_eq!(
                dss.topo.cluster_of_node(n),
                meta.cluster_of(s, b),
                "{ctx}: stripe {s} block {b} cluster/node mismatch"
            );
        }
        for c in 0..dss.topo.clusters() {
            let erased = meta.blocks_in_cluster(s, c);
            if erased.is_empty() {
                continue;
            }
            let plan = dss
                .code
                .decode_plan(erased)
                .unwrap_or_else(|| panic!("{ctx}: stripe {s} cluster {c} loss unrecoverable"));
            let sources: Vec<std::sync::Arc<Vec<u8>>> =
                plan.sources.iter().map(|&b| meta.block_data(s, b)).collect();
            let srcs: Vec<&[u8]> = sources.iter().map(|d| d.as_slice()).collect();
            let rebuilt = plan.execute(&srcs);
            for (i, &b) in plan.erased.iter().enumerate() {
                assert_eq!(
                    rebuilt[i],
                    meta.block_data(s, b).as_slice(),
                    "{ctx}: stripe {s} cluster {c} block {b} decode mismatch"
                );
            }
        }
    }
}

#[test]
fn conflicting_events_serialize_with_typed_errors_all_families() {
    for fam in CodeFamily::paper_baselines() {
        let run = || {
            let mut prng = Prng::new(7);
            let mut dss = build_dss(fam, &tiny());
            dss.ingest_random_stripes(2, &mut prng).unwrap();
            let victim = dss.metadata().node_of(0, 0);
            dss.submit_topology_event(TopologyEvent::DrainNode { node: victim }).unwrap();

            // a second drain of the same node hits the in-flight claims:
            // typed, retryable, and the map/topology stay untouched
            let err = dss
                .submit_topology_event(TopologyEvent::DrainNode { node: victim })
                .expect_err(&format!("{fam:?}: duplicate drain must not admit"));
            assert!(
                matches!(err, MigrationError::Conflicting { .. }),
                "{fam:?}: wrong rejection: {err:?}"
            );
            assert!(err.retryable(), "{fam:?}: conflicts must be retryable");
            assert_eq!(dss.migration_stats().conflicts, 1, "{fam:?}");
            assert_eq!(dss.online_in_flight(), 1, "{fam:?}: rejected event must not enqueue");
            assert_eq!(
                dss.metadata().node_of(0, 0),
                victim,
                "{fam:?}: failed admission must not move residency"
            );

            drain(&mut dss);
            assert_eq!(dss.topo.state(victim), NodeState::Dead, "{fam:?}");
            assert!(dss.metadata().blocks_on_node(victim).is_empty(), "{fam:?}");

            // serialized retry: once the first event committed, draining
            // another node admits cleanly
            let next = dss.metadata().node_of(0, 1);
            dss.submit_topology_event(TopologyEvent::DrainNode { node: next }).unwrap();
            drain(&mut dss);
            let stats = dss.migration_stats();
            assert_eq!(stats.submitted, 2, "{fam:?}");
            assert_eq!(stats.completed, 2, "{fam:?}");
            assert_map_sane(&dss, &format!("{fam:?} after serialized drains"));
            dss.capture_state().digest()
        };
        // the whole conflict/serialize schedule is deterministic
        assert_eq!(run(), run(), "{fam:?}: serialization must be deterministic");
    }
}

#[test]
fn migrating_block_serves_from_source_until_commit() {
    let mut prng = Prng::new(13);
    let mut dss = build_dss(CodeFamily::UniLrc, &tiny());
    dss.ingest_random_stripes(2, &mut prng).unwrap();
    let victim = dss.metadata().node_of(0, 0);
    dss.submit_topology_event(TopologyEvent::DrainNode { node: victim }).unwrap();

    // claimed but uncommitted: state says Migrating, residency (and
    // therefore reads) still point at the source
    match dss.metadata().block_state(0, 0) {
        BlockState::Migrating { from, .. } => assert_eq!(from, victim),
        other => panic!("drained block must be claimed, got {other:?}"),
    }
    assert_eq!(dss.metadata().node_of(0, 0), victim, "reads must keep hitting the source");
    assert_eq!(
        dss.availability(),
        (false, false),
        "in-flight claims must not register as degraded or unavailable"
    );
    assert!(dss.normal_read(0).unwrap().latency > 0.0, "foreground reads keep working");

    drain(&mut dss);
    assert_eq!(dss.metadata().block_state(0, 0), BlockState::Stable);
    assert_ne!(dss.metadata().node_of(0, 0), victim, "commit re-points the block");
    assert_map_sane(&dss, "after commit");
}

#[test]
fn source_death_mid_drain_flips_moves_onto_rebuild() {
    let mut prng = Prng::new(23);
    let mut dss = build_dss(CodeFamily::UniLrc, &tiny());
    dss.ingest_random_stripes(2, &mut prng).unwrap();
    let victim = dss.metadata().node_of(0, 0);
    let hosted = dss.metadata().blocks_on_node(victim).len();
    assert!(hosted > 0);
    dss.submit_topology_event(TopologyEvent::DrainNode { node: victim }).unwrap();

    // the source dies before a single move ran: every planned move must
    // flip onto the batched repair pipeline instead of copying
    dss.fail_node(victim);
    drain(&mut dss);
    let stats = dss.migration_stats();
    assert_eq!(stats.source_flips, hosted, "every move rebuilds, none copies");
    assert_eq!(stats.completed, 1);
    assert_eq!(dss.topo.state(victim), NodeState::Dead);
    assert!(!dss.failed_nodes().contains(&victim), "dead nodes leave the failure set");
    assert!(dss.metadata().blocks_on_node(victim).is_empty());
    // byte-identical: the decode proof in assert_map_sane reconstructs
    // every migrated block from the rebuilt placements
    assert_map_sane(&dss, "after source-death drain");
    dss.quiesce();
    assert!(dss.normal_read(0).unwrap().latency > 0.0);
}

#[test]
fn destination_death_replans_onto_spare_target() {
    let mut prng = Prng::new(31);
    let mut dss = build_dss(CodeFamily::UniLrc, &tiny());
    dss.ingest_random_stripes(2, &mut prng).unwrap();
    // one spare node beyond the per-stripe need guarantees a replacement
    // target exists inside the new cluster after one member dies
    let nodes = dss.topo.max_cluster_size() + 1;
    dss.submit_topology_event(TopologyEvent::AddCluster { nodes }).unwrap();
    let new_cluster = dss.topo.clusters() - 1;

    // discover the planned targets from the claims, then kill one before
    // any byte lands on it
    let mut targets: Vec<usize> = Vec::new();
    for s in 0..dss.metadata().stripe_count() {
        for b in 0..dss.code.n() {
            if let BlockState::Migrating { to, .. } = dss.metadata().block_state(s, b) {
                if dss.topo.cluster_of_node(to) == new_cluster {
                    targets.push(to);
                }
            }
        }
    }
    targets.sort_unstable();
    targets.dedup();
    let dest = *targets.first().expect("scale-out must plan moves into the new cluster");
    dss.fail_node(dest);

    drain(&mut dss);
    let stats = dss.migration_stats();
    assert!(stats.dest_replans >= 1, "dead destination must force a re-plan");
    assert_eq!(stats.completed, 1);
    assert!(
        dss.metadata().blocks_on_node(dest).is_empty(),
        "nothing may land on the dead destination"
    );
    dss.heal_node(dest); // nothing landed, nothing to rebuild
    assert_map_sane(&dss, "after destination-death scale-out");
}

#[test]
fn crash_mid_wave_recovers_digest_identical_to_oracle() {
    let cfg = tiny();
    // the shared op schedule: ingest, an online scale-out, then a drain
    // that the crashed run abandons mid-wave
    let setup = |dir: &PathBuf| -> Dss {
        let mut dss = build_dss(CodeFamily::UniLrc, &cfg);
        dss.enable_durability(dir, DurabilityOptions { sync_every: 1, snapshot_every: 64 })
            .unwrap();
        let mut prng = Prng::new(cfg.seed);
        dss.ingest_random_stripes(cfg.stripes, &mut prng).unwrap();
        dss.submit_topology_event(TopologyEvent::AddNode { cluster: 0 }).unwrap();
        drain(&mut dss);
        // drain the most loaded node so the wave spans several moves —
        // the crash must land strictly inside it
        let victim = (0..dss.topo.total_nodes())
            .filter(|&n| dss.topo.is_active(n) && !dss.failed_nodes().contains(&n))
            .max_by_key(|&n| (dss.metadata().block_map().node_load(n), std::cmp::Reverse(n)))
            .unwrap();
        assert!(dss.metadata().blocks_on_node(victim).len() >= 2, "need a multi-move wave");
        dss.submit_topology_event(TopologyEvent::DrainNode { node: victim }).unwrap();
        dss
    };

    // oracle: never crashes, the drain wave runs to completion
    let oracle_dir = scratch("oracle");
    let mut oracle = setup(&oracle_dir);
    drain(&mut oracle);
    let oracle_digest = oracle.capture_state().digest();
    let blocks = oracle.export_blocks();
    let engine = oracle.engine().clone();
    drop(oracle);

    // crashed run: one move commits, then the coordinator dies
    let crash_dir = scratch("crash");
    let mut crashed = setup(&crash_dir);
    let reports = crashed.pump_migrations(f64::INFINITY, 1).unwrap();
    assert!(!reports.is_empty() || crashed.online_in_flight() > 0);
    assert_eq!(crashed.online_in_flight(), 1, "the drain wave must still be open");
    drop(crashed); // crash: no commit record for the wave

    let rec = recover(&crash_dir).unwrap();
    assert_eq!(rec.pending_online.len(), 1, "the open wave must surface for resumption");
    let pend = &rec.pending_online[0];
    assert!(!pend.remaining.is_empty(), "unfinished moves must be in the recovered plan");

    let code = cfg.scheme.build(CodeFamily::UniLrc);
    let (strategy, _) = strategy_and_topo(CodeFamily::UniLrc, &code);
    let mut rdss = Dss::restore(
        code,
        strategy,
        &rec.state,
        blocks,
        NetConfig::default(),
        engine,
        DssConfig { block_size: cfg.block_size, aggregated: cfg.aggregated, time_compute: false },
    )
    .unwrap();
    rdss.resume_online(&rec.pending_online);
    assert_eq!(rdss.online_in_flight(), 1);
    assert_eq!(rdss.migration_stats().resumed, 1);
    drain(&mut rdss);

    assert_eq!(
        rdss.capture_state().digest(),
        oracle_digest,
        "resumed run must converge on the never-crashed oracle"
    );
    assert_map_sane(&rdss, "after crash-resume");

    let _ = std::fs::remove_dir_all(&oracle_dir);
    let _ = std::fs::remove_dir_all(&crash_dir);
}

#[test]
fn torn_wal_tails_never_panic_recovery() {
    // crash the coordinator mid-wave, then re-truncate its WAL at every
    // byte of the tail region: recovery must always return a usable
    // state (typed errors allowed, panics and corrupt maps are not)
    let cfg = tiny();
    let base_dir = scratch("fuzz-base");
    let mut dss = build_dss(CodeFamily::UniLrc, &cfg);
    dss.enable_durability(&base_dir, DurabilityOptions { sync_every: 1, snapshot_every: 64 })
        .unwrap();
    let mut prng = Prng::new(cfg.seed);
    dss.ingest_random_stripes(cfg.stripes, &mut prng).unwrap();
    let victim = dss.metadata().node_of(0, 1);
    dss.submit_topology_event(TopologyEvent::DrainNode { node: victim }).unwrap();
    dss.pump_migrations(f64::INFINITY, 1).unwrap();
    drop(dss);

    let segments = list_segments(&base_dir).unwrap();
    assert_eq!(segments.len(), 1);
    let wal_path = segments[0].1.clone();
    let wal_img = std::fs::read(&wal_path).unwrap();
    let fuzz_dir = scratch("fuzz");
    // stride keeps the test fast while still cutting inside the admission
    // group, inside move records, and at torn record boundaries
    for cut in (0..=wal_img.len()).step_by(7).chain([wal_img.len()]) {
        let _ = std::fs::remove_dir_all(&fuzz_dir);
        std::fs::create_dir_all(&fuzz_dir).unwrap();
        for name in [MANIFEST_CURRENT, MANIFEST_PREV] {
            let src = base_dir.join(name);
            if src.exists() {
                std::fs::copy(&src, fuzz_dir.join(name)).unwrap();
            }
        }
        std::fs::write(fuzz_dir.join(wal_path.file_name().unwrap()), &wal_img[..cut]).unwrap();
        let rec = recover(&fuzz_dir)
            .unwrap_or_else(|e| panic!("recovery must not fail at torn tail {cut}: {e}"));
        assert!(rec.pending_online.len() <= 1, "cut {cut}");
        for p in &rec.pending_online {
            // a surfaced drain wave had its full admission group on disk
            // (a torn one must be dropped, not half-applied): the drained
            // node's prior lifecycle state rides along for abort paths
            assert!(!p.prior.is_empty(), "cut {cut}: drain wave without rollback state");
        }
    }
    let _ = std::fs::remove_dir_all(&base_dir);
    let _ = std::fs::remove_dir_all(&fuzz_dir);
}
