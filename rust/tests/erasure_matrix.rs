//! Erasure-matrix conformance suite — the backbone the fault injector
//! stands on: for every code family, enumerate erasure patterns up to the
//! code's fault tolerance (exhaustively for singles and doubles, sampled
//! beyond) and assert the generic decoder restores byte-identical data.
//! Every pattern the fault scenarios can realize must already be proven
//! here, so a scenario failure can only ever be a *system* bug, never a
//! coding bug.
//!
//! All decodes go through fresh plans (`Code::decode_plan`), bypassing the
//! plan cache — `tests/plan_cache.rs` separately proves cached ≡ fresh.

use unilrc::codes::spec::{CodeFamily, Scheme};
use unilrc::codes::Code;
use unilrc::experiments::{family_tolerance, strategy_and_topo};
use unilrc::prng::Prng;

const BLOCK: usize = 48;

fn stripe_for(code: &Code, prng: &mut Prng) -> Vec<Vec<u8>> {
    let data: Vec<Vec<u8>> = (0..code.k()).map(|_| prng.bytes(BLOCK)).collect();
    let drefs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
    let parities = code.encode_blocks(&drefs);
    data.into_iter().chain(parities).collect()
}

/// Decode `erased` from scratch and check every rebuilt block byte-for-byte.
fn check_decodes(code: &Code, stripe: &[Vec<u8>], erased: &[usize], ctx: &str) {
    let plan = code
        .decode_plan(erased)
        .unwrap_or_else(|| panic!("{ctx}: pattern {erased:?} must be recoverable"));
    let srcs: Vec<&[u8]> = plan.sources.iter().map(|&s| stripe[s].as_slice()).collect();
    let rebuilt = plan.execute(&srcs);
    for (i, &b) in plan.erased.iter().enumerate() {
        assert_eq!(rebuilt[i], stripe[b], "{ctx}: pattern {erased:?}, block {b}");
    }
}

#[test]
fn exhaustive_single_erasures_all_families() {
    let mut prng = Prng::new(0xE1);
    for fam in CodeFamily::paper_baselines() {
        let code = Scheme::S42.build(fam);
        let stripe = stripe_for(&code, &mut prng);
        for a in 0..code.n() {
            check_decodes(&code, &stripe, &[a], &format!("{fam:?} singles"));
        }
    }
}

#[test]
fn exhaustive_double_erasures_all_families() {
    let mut prng = Prng::new(0xE2);
    for fam in CodeFamily::paper_baselines() {
        let code = Scheme::S42.build(fam);
        let stripe = stripe_for(&code, &mut prng);
        for a in 0..code.n() {
            for b in a + 1..code.n() {
                check_decodes(&code, &stripe, &[a, b], &format!("{fam:?} doubles"));
            }
        }
    }
}

#[test]
fn sampled_patterns_up_to_family_tolerance() {
    let mut prng = Prng::new(0xE3);
    for fam in CodeFamily::paper_baselines() {
        let code = Scheme::S42.build(fam);
        let f = family_tolerance(Scheme::S42, fam);
        let stripe = stripe_for(&code, &mut prng);
        for t in 3..=f {
            for _ in 0..25 {
                let erased = prng.choose_distinct(code.n(), t);
                check_decodes(&code, &stripe, &erased, &format!("{fam:?} |E|={t}"));
            }
        }
    }
}

#[test]
fn whole_cluster_erasures_decode_all_families() {
    // One-cluster failure tolerance is a placement invariant (§2.3.2):
    // erasing every block a cluster hosts must decode, for every rotation.
    let mut prng = Prng::new(0xE4);
    for fam in CodeFamily::paper_baselines() {
        let code = Scheme::S42.build(fam);
        let (strategy, topo) = strategy_and_topo(fam, &code);
        let stripe = stripe_for(&code, &mut prng);
        for rot in 0..topo.clusters() {
            let placement = strategy.place(&code, &topo, rot);
            for cluster in 0..topo.clusters() {
                let erased = placement.blocks_in_cluster(cluster);
                if erased.is_empty() {
                    continue;
                }
                check_decodes(
                    &code,
                    &stripe,
                    &erased,
                    &format!("{fam:?} cluster {cluster} rot {rot}"),
                );
            }
        }
    }
}

#[test]
fn whole_cluster_erasures_decode_after_each_migration_step() {
    // Post-migration safety: the one-cluster-failure invariant must hold
    // not just at initial placement but after *every* step of a topology
    // event sequence, for every placement strategy — asserted here with
    // fresh decode plans against the coordinator's live block map (the
    // migrated ground truth), byte for byte.
    use unilrc::experiments::{build_dss, ExpConfig};
    use unilrc::placement::TopologyEvent;
    let cfg = ExpConfig {
        block_size: 1024,
        stripes: 2,
        time_compute: false,
        ..Default::default()
    };
    for fam in CodeFamily::paper_baselines() {
        let mut prng = Prng::new(0xE6);
        let mut dss = build_dss(fam, &cfg);
        dss.ingest_random_stripes(2, &mut prng).unwrap();
        for si in 0..4usize {
            // victims resolve against the *current* map: a block's host is
            // always live, so each drain targets a live node
            let ev = match si {
                0 => TopologyEvent::AddNode { cluster: 0 },
                1 => TopologyEvent::DrainNode { node: dss.metadata().node_of(0, 0) },
                2 => TopologyEvent::AddCluster { nodes: dss.topo.max_cluster_size() },
                _ => TopologyEvent::DrainNode { node: dss.metadata().node_of(1, 2) },
            };
            dss.apply_topology_event(ev).unwrap();
            for s in 0..dss.metadata().stripe_count() {
                // reassemble the stripe from the (migrated) ground truth
                let stripe: Vec<Vec<u8>> = (0..dss.code.n())
                    .map(|b| dss.metadata().block_data(s, b).to_vec())
                    .collect();
                for cluster in 0..dss.topo.clusters() {
                    let erased = dss.metadata().blocks_in_cluster(s, cluster);
                    if erased.is_empty() {
                        continue;
                    }
                    check_decodes(
                        &dss.code,
                        &stripe,
                        erased,
                        &format!("{fam:?} step {si} stripe {s} cluster {cluster}"),
                    );
                }
            }
        }
    }
}

#[test]
fn beyond_tolerance_never_panics_and_never_lies() {
    // Past the guaranteed tolerance the decoder may return None — but when
    // it claims recoverability it must deliver exact bytes, and patterns
    // wider than n−k must always be rejected.
    let mut prng = Prng::new(0xE5);
    for fam in CodeFamily::paper_baselines() {
        let code = Scheme::S42.build(fam);
        let f = family_tolerance(Scheme::S42, fam);
        let stripe = stripe_for(&code, &mut prng);
        for t in (f + 1)..=code.m() {
            for _ in 0..10 {
                let erased = prng.choose_distinct(code.n(), t);
                match code.decode_plan(&erased) {
                    Some(plan) => {
                        assert!(code.can_decode(&erased));
                        let srcs: Vec<&[u8]> =
                            plan.sources.iter().map(|&s| stripe[s].as_slice()).collect();
                        let rebuilt = plan.execute(&srcs);
                        for (i, &b) in plan.erased.iter().enumerate() {
                            assert_eq!(rebuilt[i], stripe[b], "{fam:?} {erased:?}");
                        }
                    }
                    None => assert!(!code.can_decode(&erased), "{fam:?} {erased:?}"),
                }
            }
        }
        let too_many = prng.choose_distinct(code.n(), code.m() + 1);
        assert!(code.decode_plan(&too_many).is_none());
        assert!(!code.can_decode(&too_many));
    }
}
