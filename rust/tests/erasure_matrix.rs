//! Erasure-matrix conformance suite — the backbone the fault injector
//! stands on: for every code family, enumerate erasure patterns up to the
//! code's fault tolerance (exhaustively for singles and doubles, sampled
//! beyond) and assert the generic decoder restores byte-identical data.
//! Every pattern the fault scenarios can realize must already be proven
//! here, so a scenario failure can only ever be a *system* bug, never a
//! coding bug.
//!
//! All decodes go through fresh plans (`Code::decode_plan`), bypassing the
//! plan cache — `tests/plan_cache.rs` separately proves cached ≡ fresh.

use unilrc::codes::spec::{CodeFamily, Scheme};
use unilrc::codes::Code;
use unilrc::experiments::{family_tolerance, strategy_and_topo};
use unilrc::prng::Prng;

const BLOCK: usize = 48;

fn stripe_for(code: &Code, prng: &mut Prng) -> Vec<Vec<u8>> {
    let data: Vec<Vec<u8>> = (0..code.k()).map(|_| prng.bytes(BLOCK)).collect();
    let drefs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
    let parities = code.encode_blocks(&drefs);
    data.into_iter().chain(parities).collect()
}

/// Decode `erased` from scratch and check every rebuilt block byte-for-byte.
fn check_decodes(code: &Code, stripe: &[Vec<u8>], erased: &[usize], ctx: &str) {
    let plan = code
        .decode_plan(erased)
        .unwrap_or_else(|| panic!("{ctx}: pattern {erased:?} must be recoverable"));
    let srcs: Vec<&[u8]> = plan.sources.iter().map(|&s| stripe[s].as_slice()).collect();
    let rebuilt = plan.execute(&srcs);
    for (i, &b) in plan.erased.iter().enumerate() {
        assert_eq!(rebuilt[i], stripe[b], "{ctx}: pattern {erased:?}, block {b}");
    }
}

#[test]
fn exhaustive_single_erasures_all_families() {
    let mut prng = Prng::new(0xE1);
    for fam in CodeFamily::paper_baselines() {
        let code = Scheme::S42.build(fam);
        let stripe = stripe_for(&code, &mut prng);
        for a in 0..code.n() {
            check_decodes(&code, &stripe, &[a], &format!("{fam:?} singles"));
        }
    }
}

#[test]
fn exhaustive_double_erasures_all_families() {
    let mut prng = Prng::new(0xE2);
    for fam in CodeFamily::paper_baselines() {
        let code = Scheme::S42.build(fam);
        let stripe = stripe_for(&code, &mut prng);
        for a in 0..code.n() {
            for b in a + 1..code.n() {
                check_decodes(&code, &stripe, &[a, b], &format!("{fam:?} doubles"));
            }
        }
    }
}

#[test]
fn sampled_patterns_up_to_family_tolerance() {
    let mut prng = Prng::new(0xE3);
    for fam in CodeFamily::paper_baselines() {
        let code = Scheme::S42.build(fam);
        let f = family_tolerance(Scheme::S42, fam);
        let stripe = stripe_for(&code, &mut prng);
        for t in 3..=f {
            for _ in 0..25 {
                let erased = prng.choose_distinct(code.n(), t);
                check_decodes(&code, &stripe, &erased, &format!("{fam:?} |E|={t}"));
            }
        }
    }
}

#[test]
fn whole_cluster_erasures_decode_all_families() {
    // One-cluster failure tolerance is a placement invariant (§2.3.2):
    // erasing every block a cluster hosts must decode, for every rotation.
    let mut prng = Prng::new(0xE4);
    for fam in CodeFamily::paper_baselines() {
        let code = Scheme::S42.build(fam);
        let (strategy, topo) = strategy_and_topo(fam, &code);
        let stripe = stripe_for(&code, &mut prng);
        for rot in 0..topo.clusters {
            let placement = strategy.place(&code, &topo, rot);
            for cluster in 0..topo.clusters {
                let erased = placement.blocks_in_cluster(cluster);
                if erased.is_empty() {
                    continue;
                }
                check_decodes(
                    &code,
                    &stripe,
                    &erased,
                    &format!("{fam:?} cluster {cluster} rot {rot}"),
                );
            }
        }
    }
}

#[test]
fn beyond_tolerance_never_panics_and_never_lies() {
    // Past the guaranteed tolerance the decoder may return None — but when
    // it claims recoverability it must deliver exact bytes, and patterns
    // wider than n−k must always be rejected.
    let mut prng = Prng::new(0xE5);
    for fam in CodeFamily::paper_baselines() {
        let code = Scheme::S42.build(fam);
        let f = family_tolerance(Scheme::S42, fam);
        let stripe = stripe_for(&code, &mut prng);
        for t in (f + 1)..=code.m() {
            for _ in 0..10 {
                let erased = prng.choose_distinct(code.n(), t);
                match code.decode_plan(&erased) {
                    Some(plan) => {
                        assert!(code.can_decode(&erased));
                        let srcs: Vec<&[u8]> =
                            plan.sources.iter().map(|&s| stripe[s].as_slice()).collect();
                        let rebuilt = plan.execute(&srcs);
                        for (i, &b) in plan.erased.iter().enumerate() {
                            assert_eq!(rebuilt[i], stripe[b], "{fam:?} {erased:?}");
                        }
                    }
                    None => assert!(!code.can_decode(&erased), "{fam:?} {erased:?}"),
                }
            }
        }
        let too_many = prng.choose_distinct(code.n(), code.m() + 1);
        assert!(code.decode_plan(&too_many).is_none());
        assert!(!code.can_decode(&too_many));
    }
}
