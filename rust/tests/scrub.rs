//! Latent-error / scrubbing conformance (experiment 11):
//!
//! * sweep determinism — same seed ⇒ bit-identical rows and digest, a
//!   different seed moves the digest (the exp7 replayability contract);
//! * differential reliability — the simulated scrub replay agrees with
//!   the `analysis::markov` latent-error chain within stated tolerances:
//!   mean injection→detection dwell vs the `T/2` renewal closed form,
//!   and the Little's-law undetected-errors-per-node meter vs `λ̂·T/2`
//!   with `λ̂` estimated from the trace;
//! * budget accounting — no grid cell ever scrubs more bytes than the
//!   shared token bucket granted, detection never exceeds injection, and
//!   the grid covers every paper family (CLRC included) at every
//!   (interval × sector-rate) point.

use unilrc::codes::spec::CodeFamily;
use unilrc::experiments::{exp11_scrub, ExpConfig, ScrubSimConfig};
use unilrc::sim::faults::FaultConfig;

/// Exp11 never touches block data, so the base config only needs the
/// scheme and seed; `stripes` feeds the blocks-per-node conversion.
fn tiny_exp() -> ExpConfig {
    ExpConfig { block_size: 4 * 1024, stripes: 2, seed: 7, ..Default::default() }
}

/// Small grid on a short horizon — determinism and accounting, fast.
fn short_scrub() -> ScrubSimConfig {
    ScrubSimConfig {
        intervals_hours: vec![12.0, 48.0],
        sector_mtte_hours: vec![50.0, 200.0],
        fault: FaultConfig { horizon_hours: 500.0, ..FaultConfig::accelerated() },
        ..Default::default()
    }
}

#[test]
fn exp11_digest_reproduces_across_runs() {
    let cfg = tiny_exp();
    let sc = short_scrub();
    let a = exp11_scrub(&cfg, &sc).unwrap();
    let b = exp11_scrub(&cfg, &sc).unwrap();
    assert_eq!(a.digest, b.digest, "same seed ⇒ identical sweep digest");
    assert_eq!(a.rows.len(), b.rows.len());
    for (x, y) in a.rows.iter().zip(&b.rows) {
        assert_eq!(x.family, y.family);
        assert_eq!(x.injected, y.injected);
        assert_eq!(x.detected, y.detected);
        assert_eq!(x.scrubbed_bytes, y.scrubbed_bytes);
        assert_eq!(x.granted_bytes, y.granted_bytes);
        assert_eq!(x.sim_dwell_hours.to_bits(), y.sim_dwell_hours.to_bits());
        assert_eq!(
            x.sim_undetected_per_node.to_bits(),
            y.sim_undetected_per_node.to_bits()
        );
    }
    let mut other = tiny_exp();
    other.seed = 8;
    let c = exp11_scrub(&other, &sc).unwrap();
    assert_ne!(a.digest, c.digest, "a different seed must move the digest");
}

#[test]
fn exp11_grid_covers_every_family_and_cell() {
    let cfg = tiny_exp();
    let sc = short_scrub();
    let res = exp11_scrub(&cfg, &sc).unwrap();
    let fams = CodeFamily::paper_baselines();
    assert_eq!(
        res.rows.len(),
        fams.len() * sc.intervals_hours.len() * sc.sector_mtte_hours.len(),
        "one row per family × interval × sector rate"
    );
    for fam in fams {
        for &t in &sc.intervals_hours {
            for &m in &sc.sector_mtte_hours {
                assert!(
                    res.rows.iter().any(|r| r.family == fam
                        && r.interval_hours == t
                        && r.sector_mtte_hours == m),
                    "missing grid cell {fam:?} × {t} h × {m} h"
                );
            }
        }
    }
    assert!(
        res.rows.iter().any(|r| r.family == CodeFamily::Clrc),
        "the cascaded-parity family must compete in the sweep"
    );
}

#[test]
fn exp11_accounting_invariants_hold_everywhere() {
    let cfg = tiny_exp();
    let res = exp11_scrub(&cfg, &short_scrub()).unwrap();
    for r in &res.rows {
        assert!(
            r.scrubbed_bytes <= r.granted_bytes,
            "{:?}: scrubbed {} bytes but the bucket only granted {}",
            r.family,
            r.scrubbed_bytes,
            r.granted_bytes
        );
        assert!(r.detected <= r.injected, "{:?}: detected > injected", r.family);
        assert!(r.injected > 0, "{:?}: the latent stream must fire on this grid", r.family);
        assert!(r.at_risk_block_hours >= 0.0);
        assert!(
            (0.0..=1.0).contains(&r.loss_fraction_markov),
            "{:?}: loss fraction {} outside [0, 1]",
            r.family,
            r.loss_fraction_markov
        );
    }
    // dirtier disks (smaller MTTE) strictly raise injections per family
    for fam in CodeFamily::paper_baselines() {
        let inj = |mtte: f64| -> usize {
            res.rows
                .iter()
                .filter(|r| r.family == fam && r.sector_mtte_hours == mtte)
                .map(|r| r.injected)
                .sum()
        };
        assert!(inj(50.0) > inj(200.0), "{fam:?}: 4× the error rate must inject more");
    }
}

#[test]
fn exp11_sim_matches_markov_within_bounds() {
    // Single cell with an ample budget (passes complete within a tick of
    // starting) and a long horizon so the dwell statistics converge: the
    // renewal closed form says mean dwell is exactly T/2 regardless of
    // scan offset, and Little's law pins the standing undetected count at
    // λT/2 per node. 0.25 relative tolerance, exp7-style (tick
    // quantization, down-node deferrals, and horizon truncation are the
    // real, small, biases).
    let cfg = tiny_exp();
    let sc = ScrubSimConfig {
        intervals_hours: vec![24.0],
        sector_mtte_hours: vec![50.0],
        fault: FaultConfig { horizon_hours: 2_000.0, ..FaultConfig::accelerated() },
        rate_bytes_per_hour: 1e12,
        burst_bytes: 1e12,
        ..Default::default()
    };
    let res = exp11_scrub(&cfg, &sc).unwrap();
    assert_eq!(res.rows.len(), CodeFamily::paper_baselines().len());
    for r in &res.rows {
        assert!(r.detected > 100, "{:?}: need statistics, got {}", r.family, r.detected);
        let dwell_rel = (r.sim_dwell_hours - r.markov_dwell_hours).abs() / r.markov_dwell_hours;
        assert!(
            dwell_rel < 0.25,
            "{:?}: dwell sim {:.3} h vs markov {:.3} h (rel {:.3})",
            r.family,
            r.sim_dwell_hours,
            r.markov_dwell_hours,
            dwell_rel
        );
        let undet_rel = (r.sim_undetected_per_node - r.markov_undetected_per_node).abs()
            / r.markov_undetected_per_node;
        assert!(
            undet_rel < 0.25,
            "{:?}: undetected/node sim {:.4} vs markov {:.4} (rel {:.3})",
            r.family,
            r.sim_undetected_per_node,
            r.markov_undetected_per_node,
            undet_rel
        );
    }
}
