//! Integration: the PJRT backend (artifacts built by python/jax/pallas)
//! must produce byte-identical results to the native GF substrate — the
//! cross-language correctness contract of the three-layer architecture.
//!
//! Requires `make artifacts`; tests are skipped (with a note) if the
//! manifest is absent so `cargo test` stays runnable pre-AOT.

use unilrc::codes::spec::{CodeFamily, Scheme};
use unilrc::prng::Prng;
use unilrc::runtime::{CodingEngine, CombineJob, Manifest, NativeCoder, PjrtCoder};

fn coder() -> Option<PjrtCoder> {
    if Manifest::load(Manifest::default_dir()).is_err() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    match PjrtCoder::new(None) {
        Ok(c) => Some(c),
        // artifacts exist but this is a stub build (no `pjrt` feature)
        Err(e) => {
            eprintln!("skipping: {e}");
            None
        }
    }
}

#[test]
fn pjrt_encode_matches_native_unilrc_42() {
    let Some(pjrt) = coder() else { return };
    let code = Scheme::S42.build(CodeFamily::UniLrc);
    let mut p = Prng::new(1);
    // 100_000 exercises the chunking + tail-padding path (not a multiple of 65536)
    let data: Vec<Vec<u8>> = (0..code.k()).map(|_| p.bytes(100_000)).collect();
    let drefs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
    let native = NativeCoder.encode(&code, &drefs).unwrap();
    let via_pjrt = pjrt.encode(&code, &drefs).unwrap();
    assert_eq!(native, via_pjrt);
}

#[test]
fn pjrt_fold_matches_native() {
    let Some(pjrt) = coder() else { return };
    let mut p = Prng::new(2);
    for s in [2usize, 5, 6, 7, 8] {
        let srcs: Vec<Vec<u8>> = (0..s).map(|_| p.bytes(70_000)).collect();
        let refs: Vec<&[u8]> = srcs.iter().map(|v| v.as_slice()).collect();
        let native = NativeCoder.fold(&refs).unwrap();
        let via_pjrt = pjrt.fold(&refs).unwrap();
        assert_eq!(native, via_pjrt, "s={s}");
    }
}

#[test]
fn pjrt_matmul_matches_native() {
    let Some(pjrt) = coder() else { return };
    let mut p = Prng::new(3);
    let srcs: Vec<Vec<u8>> = (0..10).map(|_| p.bytes(65_536)).collect();
    let refs: Vec<&[u8]> = srcs.iter().map(|v| v.as_slice()).collect();
    let coeffs: Vec<Vec<u8>> =
        (0..4).map(|_| (0..10).map(|_| p.next_u32() as u8).collect()).collect();
    let native = NativeCoder.matmul(&coeffs, &refs).unwrap();
    let via_pjrt = pjrt.matmul(&coeffs, &refs).unwrap();
    assert_eq!(native, via_pjrt);
}

#[test]
fn pjrt_repairs_unilrc_block_end_to_end() {
    // encode via PJRT, fail a block, repair via the PJRT xor-fold artifact
    let Some(pjrt) = coder() else { return };
    let code = Scheme::S42.build(CodeFamily::UniLrc);
    let mut p = Prng::new(4);
    let data: Vec<Vec<u8>> = (0..code.k()).map(|_| p.bytes(65_536)).collect();
    let drefs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
    let parities = pjrt.encode(&code, &drefs).unwrap();
    let stripe: Vec<&[u8]> =
        drefs.iter().copied().chain(parities.iter().map(|v| v.as_slice())).collect();
    for &target in &[0usize, 29, 30, 36, 41] {
        let plan = code.repair_plan(target);
        assert!(plan.xor_only());
        let srcs: Vec<&[u8]> = plan.sources.iter().map(|&s| stripe[s]).collect();
        let rebuilt = pjrt.fold(&srcs).unwrap();
        assert_eq!(rebuilt.as_slice(), stripe[target], "block {target}");
    }
}

#[test]
fn pjrt_multi_failure_decode_via_gfdec() {
    let Some(pjrt) = coder() else { return };
    let code = Scheme::S42.build(CodeFamily::Ulrc);
    let mut p = Prng::new(5);
    let data: Vec<Vec<u8>> = (0..code.k()).map(|_| p.bytes(65_536)).collect();
    let drefs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
    let parities = NativeCoder.encode(&code, &drefs).unwrap();
    let stripe: Vec<&[u8]> =
        drefs.iter().copied().chain(parities.iter().map(|v| v.as_slice())).collect();
    let erased = vec![0usize, 7, 31];
    let plan = code.decode_plan(&erased).unwrap();
    let srcs: Vec<&[u8]> = plan.sources.iter().map(|&s| stripe[s]).collect();
    let coeffs: Vec<Vec<u8>> =
        (0..plan.coeffs.rows()).map(|i| plan.coeffs.row(i).to_vec()).collect();
    let rebuilt = pjrt.matmul(&coeffs, &srcs).unwrap();
    for (i, &b) in plan.erased.iter().enumerate() {
        assert_eq!(rebuilt[i].as_slice(), stripe[b], "block {b}");
    }
}

#[test]
fn pjrt_encode_other_families_via_gfdec() {
    let Some(pjrt) = coder() else { return };
    for fam in [CodeFamily::Alrc, CodeFamily::Olrc] {
        let code = Scheme::S42.build(fam);
        let mut p = Prng::new(6);
        let data: Vec<Vec<u8>> = (0..code.k()).map(|_| p.bytes(4_096)).collect();
        let drefs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
        let native = NativeCoder.encode(&code, &drefs).unwrap();
        let via_pjrt = pjrt.encode(&code, &drefs).unwrap();
        assert_eq!(native, via_pjrt, "{fam:?}");
    }
}

#[test]
fn every_manifest_artifact_compiles() {
    // regression net: all 20 artifacts parse + compile on the PJRT client,
    // not just the ones other tests happen to exercise.
    let Some(pjrt) = coder() else { return };
    let manifest = pjrt.manifest().clone();
    assert!(manifest.artifacts.len() >= 20);
    for art in &manifest.artifacts {
        match art.kind {
            unilrc::runtime::ArtifactKind::XorFold => {
                let s = art.param("s").unwrap();
                let b = art.param("b").unwrap();
                let mut p = Prng::new(s as u64);
                let srcs: Vec<Vec<u8>> = (0..s).map(|_| p.bytes(b.min(8192))).collect();
                let refs: Vec<&[u8]> = srcs.iter().map(|v| v.as_slice()).collect();
                let out = pjrt.fold(&refs).unwrap();
                let native = NativeCoder.fold(&refs).unwrap();
                assert_eq!(out, native, "{}", art.name);
            }
            _ => {
                // encode/gfdec artifacts are exercised via encode below
            }
        }
    }
    // all three scheme encodes through their dedicated artifacts
    for scheme in [Scheme::S42, Scheme::S136, Scheme::S210] {
        let code = scheme.build(CodeFamily::UniLrc);
        let mut p = Prng::new(scheme.n as u64);
        let data: Vec<Vec<u8>> = (0..code.k()).map(|_| p.bytes(4096)).collect();
        let drefs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
        assert_eq!(
            pjrt.encode(&code, &drefs).unwrap(),
            NativeCoder.encode(&code, &drefs).unwrap(),
            "{}",
            scheme.label()
        );
    }
}

#[test]
fn pjrt_combine_batch_matches_per_job_calls() {
    // The real combine_batch groups same-shape jobs into shared artifact
    // invocations (concatenated along the block axis); results must be
    // byte-identical to per-job fold/matmul, including the lone odd-shape
    // job that forms its own group.
    let Some(pjrt) = coder() else { return };
    let mut p = Prng::new(7);
    let fold_srcs: Vec<Vec<Vec<u8>>> =
        (0..5).map(|_| (0..4).map(|_| p.bytes(10_000)).collect()).collect();
    let mm_srcs: Vec<Vec<Vec<u8>>> =
        (0..3).map(|_| (0..6).map(|_| p.bytes(10_000)).collect()).collect();
    let odd: Vec<Vec<u8>> = (0..2).map(|_| p.bytes(7_777)).collect();
    let mm_coeffs: Vec<Vec<u8>> =
        (0..2).map(|r| (0..6).map(|j| (r * 7 + j * 13 + 2) as u8).collect()).collect();
    let mut jobs: Vec<CombineJob> = Vec::new();
    for s in &fold_srcs {
        jobs.push(CombineJob {
            coeffs: vec![vec![1; 4]],
            sources: s.iter().map(|v| v.as_slice()).collect(),
        });
    }
    for s in &mm_srcs {
        jobs.push(CombineJob {
            coeffs: mm_coeffs.clone(),
            sources: s.iter().map(|v| v.as_slice()).collect(),
        });
    }
    jobs.push(CombineJob {
        coeffs: vec![vec![1, 1]],
        sources: odd.iter().map(|v| v.as_slice()).collect(),
    });
    let expect: Vec<Vec<Vec<u8>>> = jobs
        .iter()
        .map(|j| {
            if j.xor_only() {
                vec![pjrt.fold(&j.sources).unwrap()]
            } else {
                pjrt.matmul(&j.coeffs, &j.sources).unwrap()
            }
        })
        .collect();
    let got = pjrt.combine_batch(&jobs).unwrap();
    assert_eq!(got, expect);
}

#[cfg(not(feature = "pjrt"))]
#[test]
fn stub_parity_fails_with_actionable_error() {
    // Feature-off builds must keep the full CodingEngine surface —
    // including the combine_batch override — and fail construction with a
    // clear message instead of silently running a different backend.
    let err = match PjrtCoder::new(None) {
        Ok(_) => panic!("stub construction must fail"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("pjrt"), "unexpected stub error: {err}");
    let _ = <PjrtCoder as CodingEngine>::combine_batch;
}

#[test]
fn experiments_run_on_pjrt_backend() {
    // the §6 drivers compose with the AOT path end to end
    use unilrc::experiments::{exp1_normal_read, exp2_degraded_read, ExpConfig};
    if Manifest::load(Manifest::default_dir()).is_err() {
        return;
    }
    let cfg = ExpConfig { block_size: 16 * 1024, stripes: 1, ..Default::default() }
        .with_pjrt()
        .unwrap();
    let rows = exp1_normal_read(&cfg).unwrap();
    assert_eq!(rows.len(), 5);
    assert!(rows.iter().all(|r| r.value > 0.0));
    let rows = exp2_degraded_read(&cfg).unwrap();
    assert!(rows.iter().all(|r| r.value > 0.0));
}
