//! Engine reconfiguration must not leak worker threads: every dropped
//! [`WorkPool`](unilrc::gf::WorkPool) joins its workers. This is the only
//! test in the file on purpose — it counts process-wide OS threads, so it
//! cannot share a test binary slot with concurrently running tests.

#![cfg(target_os = "linux")]

use unilrc::gf::{GfEngine, Kernel};
use unilrc::prng::Prng;

/// Current thread count of this process (Linux: /proc/self/status).
fn thread_count() -> usize {
    let status = std::fs::read_to_string("/proc/self/status").expect("read /proc/self/status");
    status
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .and_then(|v| v.trim().parse().ok())
        .expect("Threads: line")
}

fn pooled_engine(threads: usize) -> GfEngine {
    GfEngine::new(Kernel::detect()).with_threads(threads).with_lane(512).with_par_work(0)
}

fn run_striped_op(e: &GfEngine) {
    let mut p = Prng::new(7);
    let srcs: Vec<Vec<u8>> = (0..4).map(|_| p.bytes(8 * 1024)).collect();
    let refs: Vec<&[u8]> = srcs.iter().map(|v| v.as_slice()).collect();
    let mut out = vec![0u8; 8 * 1024];
    e.fold_blocks(&mut out, &refs);
}

#[test]
fn engine_reconfiguration_does_not_leak_threads() {
    // Warm up: one full engine lifecycle so any lazy runtime threads
    // (allocator, test harness) are already counted in the baseline.
    {
        let e = pooled_engine(2);
        run_striped_op(&e);
    }
    let baseline = thread_count();
    for round in 0..10 {
        // with_threads replaces the pool handle — reconfigure repeatedly
        // and make sure dropped pools actually join their workers.
        let e = pooled_engine(2 + round % 3);
        run_striped_op(&e);
        let reconfigured = e.clone().with_threads(4);
        run_striped_op(&reconfigured);
        drop(reconfigured);
        drop(e);
    }
    // Dropping the last engine clone joins its pool; allow brief settling.
    let mut now = thread_count();
    for _ in 0..50 {
        if now <= baseline {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
        now = thread_count();
    }
    assert!(now <= baseline, "thread leak: baseline {baseline}, after reconfiguration {now}");
}
