//! Batch-vs-sequential equivalence fuzz: every batched entry point
//! (`Code::encode_stripes`, `DecodePlan::execute_batch`,
//! `CachedPlan::execute_batch`, `NativeCoder::combine_batch`) must produce
//! bytes identical to its per-stripe sequential counterpart, across thread
//! counts 1 / 2 / 8 and block sizes that straddle the lane and vector
//! widths. GF(2^8) is exact, so equality is bit-for-bit.

use unilrc::codes::plan_cache::PlanCache;
use unilrc::codes::spec::{CodeFamily, Scheme};
use unilrc::codes::Code;
use unilrc::gf::{GfEngine, Kernel};
use unilrc::prng::Prng;
use unilrc::runtime::{CodingEngine, CombineJob, NativeCoder};

const THREADS: [usize; 3] = [1, 2, 8];

/// Tier under test: the one forced via `UNILRC_GF_KERNEL` (the CI kernel
/// matrix sets it per job; `Kernel::forced_from_env` fails loudly on
/// unknown or unsupported names instead of silently testing whatever
/// dispatch picks), else the detected best.
fn kernel_under_test() -> Kernel {
    Kernel::forced_from_env().unwrap_or_else(Kernel::detect)
}

/// Engines under test: every thread count, lane shrunk and the work
/// threshold zeroed so even tiny blocks exercise the pooled path. A
/// `UNILRC_GF_NT_KB` override (the CI streaming-store legs) applies to
/// every engine, so the whole equivalence suite also runs with
/// non-temporal stores forced on/off.
fn engines() -> Vec<GfEngine> {
    let nt = std::env::var("UNILRC_GF_NT_KB")
        .ok()
        .and_then(|v| unilrc::gf::dispatch::parse_nt_kb(&v));
    THREADS
        .iter()
        .map(|&t| {
            let e = GfEngine::new(kernel_under_test())
                .with_threads(t)
                .with_lane(1024)
                .with_par_work(0);
            match nt {
                Some(n) => e.with_nt(n),
                None => e,
            }
        })
        .collect()
}

fn stripes_for(code: &Code, count: usize, block: usize, p: &mut Prng) -> Vec<Vec<Vec<u8>>> {
    (0..count).map(|_| (0..code.k()).map(|_| p.bytes(block)).collect()).collect()
}

fn refs(stripe: &[Vec<u8>]) -> Vec<&[u8]> {
    stripe.iter().map(|v| v.as_slice()).collect()
}

#[test]
fn encode_stripes_matches_per_stripe_encode() {
    let code = Scheme::S42.build(CodeFamily::UniLrc);
    let mut p = Prng::new(101);
    for block in [63usize, 1024, 5000] {
        let data = stripes_for(&code, 6, block, &mut p);
        let stripe_refs: Vec<Vec<&[u8]>> = data.iter().map(|d| refs(d)).collect();
        let expect: Vec<Vec<Vec<u8>>> =
            stripe_refs.iter().map(|d| code.encode_blocks(d)).collect();
        for e in engines() {
            let got = code.encode_stripes_on(&e, &stripe_refs);
            assert_eq!(got, expect, "threads={} block={block}", e.threads());
        }
    }
}

#[test]
fn decode_plan_execute_batch_matches_sequential() {
    let code = Scheme::S42.build(CodeFamily::UniLrc);
    let mut p = Prng::new(102);
    let block = 3333;
    // build full stripes (data + parities)
    let full: Vec<Vec<Vec<u8>>> = stripes_for(&code, 5, block, &mut p)
        .into_iter()
        .map(|data| {
            let drefs = refs(&data);
            let parities = code.encode_blocks(&drefs);
            data.into_iter().chain(parities).collect()
        })
        .collect();
    for erased in [vec![0usize], vec![2, 7], vec![1, 30, 41]] {
        let plan = code.decode_plan(&erased).expect("recoverable");
        let srcs: Vec<Vec<&[u8]>> = full
            .iter()
            .map(|stripe| plan.sources.iter().map(|&s| stripe[s].as_slice()).collect())
            .collect();
        let expect: Vec<_> = srcs.iter().map(|s| plan.execute(s)).collect();
        for e in engines() {
            let got = plan.execute_batch_on(&e, &srcs);
            assert_eq!(got, expect, "threads={} erased={erased:?}", e.threads());
            // and the batch really reconstructs the erased blocks
            for (stripe, rebuilt) in full.iter().zip(&got) {
                for (i, &b) in plan.erased.iter().enumerate() {
                    assert_eq!(rebuilt[i], stripe[b], "block {b}");
                }
            }
        }
    }
}

#[test]
fn cached_plan_execute_batch_matches_sequential() {
    let cache = PlanCache::new(8);
    let code = Scheme::S42.build(CodeFamily::UniLrc);
    let mut p = Prng::new(103);
    let block = 2000;
    let full: Vec<Vec<Vec<u8>>> = stripes_for(&code, 4, block, &mut p)
        .into_iter()
        .map(|data| {
            let drefs = refs(&data);
            let parities = code.encode_blocks(&drefs);
            data.into_iter().chain(parities).collect()
        })
        .collect();
    let cached = cache.get_or_compute(&code, &[3, 11]).unwrap();
    let srcs: Vec<Vec<&[u8]>> = full
        .iter()
        .map(|stripe| cached.plan.sources.iter().map(|&s| stripe[s].as_slice()).collect())
        .collect();
    let expect: Vec<_> = srcs.iter().map(|s| cached.execute(s)).collect();
    for e in engines() {
        let got = cached.execute_batch_on(&e, &srcs);
        assert_eq!(got, expect, "threads={}", e.threads());
    }
}

#[test]
fn native_combine_batch_matches_sequential_jobs() {
    let coder = NativeCoder;
    let mut p = Prng::new(104);
    let block = 1500;
    // a mix of xor-only folds and general matmuls, ragged source counts
    let all_srcs: Vec<Vec<Vec<u8>>> = (0..7)
        .map(|i| (0..3 + i % 3).map(|_| p.bytes(block)).collect())
        .collect();
    let jobs: Vec<CombineJob> = all_srcs
        .iter()
        .enumerate()
        .map(|(i, srcs)| {
            let coeffs: Vec<u8> = if i % 2 == 0 {
                vec![1; srcs.len()]
            } else {
                (0..srcs.len()).map(|j| (j * 37 + 3) as u8).collect()
            };
            CombineJob { coeffs: vec![coeffs], sources: refs(srcs) }
        })
        .collect();
    let expect: Vec<_> = jobs
        .iter()
        .map(|j| {
            if j.xor_only() {
                vec![coder.fold(&j.sources).unwrap()]
            } else {
                coder.matmul(&j.coeffs, &j.sources).unwrap()
            }
        })
        .collect();
    let got = coder.combine_batch(&jobs).unwrap();
    assert_eq!(got, expect);
}

#[test]
fn batched_recovery_end_to_end_is_correct() {
    // The Dss-level consumer: full-node recovery and a degraded burst run
    // the batched proxy path and self-verify every rebuilt block against
    // ground truth (Dss::recover_node / parallel_read ensure! it).
    use std::sync::Arc;
    use unilrc::coordinator::{Dss, DssConfig};
    use unilrc::placement::{Topology, UniLrcPlace};
    use unilrc::sim::NetConfig;

    let code = Scheme::S42.build(CodeFamily::UniLrc);
    let clusters = code.groups().len();
    let topo = Topology::new(clusters, 10);
    let mut dss = Dss::new(
        code,
        Box::new(UniLrcPlace),
        topo,
        NetConfig::default(),
        Arc::new(NativeCoder),
        DssConfig { block_size: 8 * 1024, aggregated: true, time_compute: false },
    );
    let mut prng = Prng::new(105);
    dss.ingest_random_stripes(5, &mut prng).unwrap();
    let k = dss.code.k();
    let node = dss.metadata().node_of(0, 0);
    dss.fail_node(node);
    let lost = dss.metadata().blocks_on_node(node);
    let r = dss.recover_node(node).unwrap();
    assert_eq!(r.blocks, lost.len());
    // degraded burst across every affected stripe in one event
    let data_blocks: Vec<_> = lost.into_iter().filter(|&(_, b)| b < k).collect();
    if !data_blocks.is_empty() {
        let r = dss.parallel_read(&data_blocks).unwrap();
        assert!(r.latency > 0.0);
    }
}
