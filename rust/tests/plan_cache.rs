//! PlanCache integration: cached plans must be exactly the plans the
//! decoder would compute fresh, repeated lookups must not re-invert, and
//! the proxy repair path must go through the cache.

use anyhow::Result;
use std::sync::Arc;
use unilrc::codes::plan_cache;
use unilrc::codes::spec::{CodeFamily, Scheme};
use unilrc::experiments::{build_dss, ExpConfig};
use unilrc::prng::Prng;

#[test]
fn cached_plan_equals_fresh_plan_property() {
    let mut p = Prng::new(7);
    for fam in [CodeFamily::UniLrc, CodeFamily::Alrc, CodeFamily::Olrc, CodeFamily::Ulrc] {
        let code = Scheme::S42.build(fam);
        for t in 1..=3usize {
            for _ in 0..10 {
                let pattern = p.choose_distinct(code.n(), t);
                let cached = code.decode_plan_cached(&pattern);
                let fresh = code.decode_plan(&pattern);
                match (cached, fresh) {
                    (Some(c), Some(f)) => {
                        assert_eq!(c.plan, f, "{fam:?} pattern {pattern:?}")
                    }
                    (None, None) => {}
                    (c, f) => panic!(
                        "{fam:?} pattern {pattern:?}: cached {:?} vs fresh {:?}",
                        c.is_some(),
                        f.is_some()
                    ),
                }
            }
        }
    }
}

#[test]
fn repeated_pattern_hits_cache_no_reinversion() {
    let code = Scheme::S42.build(CodeFamily::UniLrc);
    let pattern = [4usize, 11, 23];
    let first = code.decode_plan_cached(&pattern).expect("recoverable");
    for _ in 0..5 {
        let again = code.decode_plan_cached(&pattern).expect("recoverable");
        // Same Arc ⇒ the cached object was returned — no rank test, no
        // Gauss–Jordan, no table rebuild.
        assert!(Arc::ptr_eq(&first, &again), "lookup must not recompute the plan");
    }
    // unsorted/duplicated spellings of the same pattern share the entry
    let spelled = code.decode_plan_cached(&[23, 4, 11, 4]).expect("recoverable");
    assert!(Arc::ptr_eq(&first, &spelled));
}

#[test]
fn cached_plan_executes_identically_to_fresh() {
    let code = Scheme::S42.build(CodeFamily::UniLrc);
    let mut p = Prng::new(9);
    let block = 2048;
    let data: Vec<Vec<u8>> = (0..code.k()).map(|_| p.bytes(block)).collect();
    let drefs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
    let parities = code.encode_blocks(&drefs);
    let stripe: Vec<Vec<u8>> = data.into_iter().chain(parities).collect();

    let pattern = [0usize, 7, 35];
    let cached = code.decode_plan_cached(&pattern).unwrap();
    let fresh = code.decode_plan(&pattern).unwrap();
    let srcs: Vec<&[u8]> = cached.plan.sources.iter().map(|&s| stripe[s].as_slice()).collect();
    let via_cache = cached.execute(&srcs);
    let via_fresh = fresh.execute(&srcs);
    assert_eq!(via_cache, via_fresh);
    for (i, &b) in cached.plan.erased.iter().enumerate() {
        assert_eq!(via_cache[i], stripe[b], "block {b}");
    }
}

#[test]
fn proxy_repairs_of_one_stripe_hit_the_cache() -> Result<()> {
    // Repairing several blocks of a stripe under the same multi-erasure
    // pattern used to re-invert the repair matrix once per block; after
    // the refactor the proxy routes through the global PlanCache, so only
    // the first repair computes a plan and the rest are lookups. Counters
    // are global and other tests bump them concurrently, so assertions are
    // monotonic: repairs here must add at least the expected hits.
    let cfg = ExpConfig { block_size: 8 * 1024, stripes: 1, ..Default::default() };
    let mut prng = Prng::new(12345);
    let mut dss = build_dss(CodeFamily::UniLrc, &cfg);
    dss.ingest_random_stripes(1, &mut prng)?;

    // Fail the nodes of two data blocks: every stripe-0 repair now plans
    // through the generic multi-erasure decoder with the same pattern.
    dss.fail_node(dss.metadata().node_of(0, 0));
    dss.fail_node(dss.metadata().node_of(0, 1));

    let cache = plan_cache::global();
    dss.reconstruct(0, 0)?; // seeds the entry (miss or hit, other tests aside)
    let h_before = cache.hits();
    dss.reconstruct(0, 1)?;
    dss.reconstruct(0, 0)?;
    dss.reconstruct(0, 1)?;
    let h_after = cache.hits();
    assert!(
        h_after >= h_before + 3,
        "3 follow-up repairs must be ≥3 cache hits (hits {h_before} -> {h_after})"
    );
    Ok(())
}

#[test]
fn warm_and_cold_repairs_are_byte_identical() -> Result<()> {
    // Two identical systems, one with the failure pattern prefetched
    // (`Dss::prefetch_plans`), one repairing cold: the recovered payloads
    // must match each other and ground truth exactly — warm-up only moves
    // where the inversion cost lands, never what gets rebuilt. (Each
    // recovery also verifies bytes against ground truth internally.)
    let cfg =
        ExpConfig { block_size: 8 * 1024, stripes: 2, time_compute: false, ..Default::default() };
    let mut warm = build_dss(CodeFamily::UniLrc, &cfg);
    let mut cold = build_dss(CodeFamily::UniLrc, &cfg);
    warm.ingest_random_stripes(2, &mut Prng::new(777))?;
    cold.ingest_random_stripes(2, &mut Prng::new(777))?;

    let node = warm.metadata().node_of(0, 2);
    warm.fail_node(node);
    cold.fail_node(node);
    let patterns: Vec<Vec<usize>> =
        (0..2).map(|s| warm.failed_blocks(s)).filter(|p| !p.is_empty()).collect();

    // cold recovery FIRST — before prefetch touches the shared global
    // cache — so a divergent prefetched plan could not also serve it
    let rc = cold.recover_node(node)?;

    let cache = plan_cache::global();
    let pre_before = cache.prefetched();
    let inserted = warm.prefetch_plans(&patterns);
    // entries may already be resident from other tests (global cache);
    // the counter must move exactly as many times as insertions happened
    assert_eq!(cache.prefetched(), pre_before + inserted as u64);

    let rw = warm.recover_node(node)?;
    assert_eq!(rw.blocks, rc.blocks);
    assert_eq!(rw.bytes, rc.bytes);
    assert_eq!(rw.cross_bytes, rc.cross_bytes);
    assert_eq!(rw.seconds.to_bits(), rc.seconds.to_bits(), "virtual repair time must match");
    Ok(())
}

#[test]
fn prefetch_is_visible_in_global_stats() {
    // `unilrc engine` surfaces warm-up separately from demand misses.
    let code = Scheme::S42.build(CodeFamily::Ulrc);
    let cache = plan_cache::global();
    let (pre0, hit0) = (cache.prefetched(), cache.prefetch_hits());
    let pattern = vec![1usize, 2, 40];
    let inserted = cache.prefetch(&code, std::slice::from_ref(&pattern));
    assert!(cache.prefetched() >= pre0 + inserted as u64);
    let _ = code.decode_plan_cached(&pattern).expect("recoverable");
    if inserted > 0 {
        assert!(cache.prefetch_hits() > hit0, "demand hit on a prefetched entry must be tagged");
    }
    let stats = cache.stats(64);
    assert!(stats.prefetched >= inserted as u64);
}
