//! End-to-end prototype integration: DSS assembly, reads, degraded reads,
//! reconstruction and full-node recovery, for every code family — and the
//! paper's qualitative claims checked on the virtual testbed.

use std::sync::Arc;
use unilrc::codes::spec::{CodeFamily, Scheme};
use unilrc::coordinator::{Dss, DssConfig};
use unilrc::placement::{EcWide, PlacementStrategy, Topology, UniLrcPlace};
use unilrc::prng::Prng;
use unilrc::runtime::NativeCoder;
use unilrc::sim::NetConfig;

const BS: usize = 64 * 1024;

fn build(fam: CodeFamily, scheme: Scheme) -> Dss {
    let code = scheme.build(fam);
    let (strategy, clusters): (Box<dyn PlacementStrategy>, usize) = match fam {
        CodeFamily::UniLrc => (Box::new(UniLrcPlace), code.groups().len()),
        _ => (Box::new(EcWide), EcWide::clusters_needed(&code)),
    };
    let npc = code.n().div_ceil(clusters) + 2; // room for spares
    let topo = Topology::new(clusters, npc);
    Dss::new(
        code,
        strategy,
        topo,
        NetConfig::default(),
        Arc::new(NativeCoder),
        DssConfig { block_size: BS, aggregated: true, time_compute: false },
    )
}

#[test]
fn ingest_and_normal_read_all_families() {
    let mut prng = Prng::new(1);
    for fam in CodeFamily::paper_baselines() {
        let mut dss = build(fam, Scheme::S42);
        dss.ingest_random_stripes(2, &mut prng).unwrap();
        let r = dss.normal_read(0).unwrap();
        assert!(r.latency > 0.0, "{fam:?}");
        assert_eq!(r.bytes, 30 * BS);
    }
}

#[test]
fn degraded_read_correct_and_unilrc_zero_cross() {
    let mut prng = Prng::new(2);
    let mut dss = build(CodeFamily::UniLrc, Scheme::S42);
    dss.ingest_random_stripes(1, &mut prng).unwrap();
    let node = dss.metadata().node_of(0, 3);
    dss.fail_node(node);
    let r = dss.degraded_read(0, 3).unwrap();
    // Property 2: repair itself moves zero cross-cluster bytes; the only
    // crossing is the final proxy→client hop.
    assert_eq!(r.cross_bytes as usize, BS, "only the client hop crosses");
    assert!(r.latency > 0.0);
}

#[test]
fn degraded_read_correct_all_families() {
    let mut prng = Prng::new(3);
    for fam in CodeFamily::paper_baselines() {
        let mut dss = build(fam, Scheme::S42);
        dss.ingest_random_stripes(1, &mut prng).unwrap();
        for target in [0usize, 7, 29] {
            let node = dss.metadata().node_of(0, target);
            dss.fail_node(node);
            let r = dss.degraded_read(0, target).unwrap();
            assert!(r.latency > 0.0, "{fam:?} block {target}");
            dss.heal_node(node);
            dss.quiesce();
        }
    }
}

#[test]
fn reconstruction_all_block_kinds() {
    let mut prng = Prng::new(4);
    for fam in CodeFamily::paper_baselines() {
        let mut dss = build(fam, Scheme::S42);
        dss.ingest_random_stripes(1, &mut prng).unwrap();
        // one data, one global parity, one local parity
        for target in [0usize, 30, 41] {
            let node = dss.metadata().node_of(0, target);
            dss.fail_node(node);
            let r = dss.reconstruct(0, target).unwrap();
            assert!(r.latency > 0.0, "{fam:?} block {target}");
            dss.heal_node(node);
            dss.quiesce();
        }
    }
}

#[test]
fn multi_failure_degraded_read() {
    let mut prng = Prng::new(5);
    let mut dss = build(CodeFamily::UniLrc, Scheme::S42);
    dss.ingest_random_stripes(1, &mut prng).unwrap();
    // fail three blocks in the same group: local XOR no longer suffices,
    // the proxy must fall back to the generic decoder
    for b in [0usize, 1, 2] {
        dss.fail_node(dss.metadata().node_of(0, b));
    }
    let r = dss.degraded_read(0, 1).unwrap();
    assert!(r.latency > 0.0);
    // cross-cluster sources are now unavoidable
    assert!(r.cross_bytes as usize > BS);
}

#[test]
fn full_node_recovery_runs_and_is_parallel() {
    let mut prng = Prng::new(6);
    let mut dss = build(CodeFamily::UniLrc, Scheme::S42);
    dss.ingest_random_stripes(6, &mut prng).unwrap();
    // pick the node hosting stripe 0 block 0
    let node = dss.metadata().node_of(0, 0);
    let lost = dss.metadata().blocks_on_node(node).len();
    assert!(lost >= 1);
    dss.fail_node(node);
    let r = dss.recover_node(node).unwrap();
    assert_eq!(r.blocks, lost);
    assert_eq!(r.bytes, lost * BS);
    assert!(r.cross_bytes == 0, "UniLRC node recovery is cluster-local");
    // parallel: total time far less than sum of serialized repairs
    assert!(r.seconds < lost as f64 * 0.05);
}

#[test]
fn unilrc_beats_baselines_on_reconstruction_latency() {
    // the Fig 10(c) shape on the virtual testbed
    let mut prng = Prng::new(7);
    let mut lat = std::collections::HashMap::new();
    for fam in CodeFamily::paper_baselines() {
        let mut dss = build(fam, Scheme::S42);
        dss.ingest_random_stripes(1, &mut prng).unwrap();
        let mut acc = 0.0;
        let mut cnt = 0;
        for target in 0..dss.code.n() {
            let node = dss.metadata().node_of(0, target);
            dss.fail_node(node);
            let r = dss.reconstruct(0, target).unwrap();
            acc += r.latency;
            cnt += 1;
            dss.heal_node(node);
            dss.quiesce();
        }
        lat.insert(fam, acc / cnt as f64);
    }
    let uni = lat[&CodeFamily::UniLrc];
    for fam in [CodeFamily::Alrc, CodeFamily::Olrc, CodeFamily::Ulrc] {
        assert!(
            uni <= lat[&fam] * 1.05,
            "UniLRC {uni:.6}s vs {fam:?} {:.6}s",
            lat[&fam]
        );
    }
    // OLRC's 25-wide groups must be clearly worst
    assert!(lat[&CodeFamily::Olrc] > uni * 1.5);
}

#[test]
fn normal_read_load_balance_shape() {
    // Fig 10(a)/Fig 2(b): UniLRC ≤ ULRC on normal-read latency
    let mut prng = Prng::new(8);
    let mut lat = std::collections::HashMap::new();
    for fam in [CodeFamily::UniLrc, CodeFamily::Ulrc] {
        let mut dss = build(fam, Scheme::S42);
        dss.ingest_random_stripes(2, &mut prng).unwrap();
        let a = dss.normal_read(0).unwrap().latency;
        dss.quiesce();
        let b = dss.normal_read(1).unwrap().latency;
        lat.insert(fam, (a + b) / 2.0);
    }
    assert!(lat[&CodeFamily::UniLrc] < lat[&CodeFamily::Ulrc] * 1.01);
}

#[test]
fn exp4_unilrc_flat_under_bandwidth_sweep() {
    // Fig 11(a): UniLRC reconstruction is insensitive to cross-cluster bw
    let mut prng = Prng::new(9);
    let mut lats = Vec::new();
    for gbps in [0.5, 1.0, 10.0] {
        let code = Scheme::S42.build(CodeFamily::UniLrc);
        let topo = Topology::new(6, 10);
        let mut dss = Dss::new(
            code,
            Box::new(UniLrcPlace),
            topo,
            NetConfig::default().with_cross_gbps(gbps),
            Arc::new(NativeCoder),
            DssConfig { block_size: BS, aggregated: true, time_compute: false },
        );
        dss.ingest_random_stripes(1, &mut prng).unwrap();
        let node = dss.metadata().node_of(0, 0);
        dss.fail_node(node);
        lats.push(dss.reconstruct(0, 0).unwrap().latency);
    }
    let spread = (lats[2] - lats[0]).abs() / lats[0];
    assert!(spread < 0.05, "UniLRC should be flat: {lats:?}");
}

#[test]
fn workload_reads_correct_mix() {
    use unilrc::client::workload::{Workload, WorkloadSpec};
    let mut prng = Prng::new(10);
    let mut dss = build(CodeFamily::UniLrc, Scheme::S42);
    dss.ingest_random_stripes(12, &mut prng).unwrap();
    let wl = Workload::place(&dss, WorkloadSpec::default(), 25, &mut prng);
    assert_eq!(wl.objects.len(), 25);
    // read every object, then degrade one node and re-read
    for o in 0..wl.objects.len() {
        let r = wl.read_object(&mut dss, o).unwrap();
        assert!(r.latency > 0.0);
        dss.quiesce();
    }
    let node = dss.metadata().node_of(0, 0);
    dss.fail_node(node);
    for o in 0..wl.objects.len() {
        let r = wl.read_object(&mut dss, o).unwrap();
        assert!(r.latency > 0.0);
        dss.quiesce();
    }
}
