//! Worker-pool lifecycle: pools start lazily, are reused across calls and
//! batches, and shut down cleanly on drop. (The process-wide thread-leak
//! check lives alone in `tests/thread_leak.rs` — it counts OS threads and
//! must not race concurrently running tests.)

use unilrc::gf::{GfEngine, Kernel, WorkPool};
use unilrc::prng::Prng;

fn pooled_engine(threads: usize) -> GfEngine {
    GfEngine::new(Kernel::detect()).with_threads(threads).with_lane(512).with_par_work(0)
}

fn run_striped_op(e: &GfEngine) {
    let mut p = Prng::new(7);
    let srcs: Vec<Vec<u8>> = (0..4).map(|_| p.bytes(8 * 1024)).collect();
    let refs: Vec<&[u8]> = srcs.iter().map(|v| v.as_slice()).collect();
    let mut out = vec![0u8; 8 * 1024];
    e.fold_blocks(&mut out, &refs);
    let mut expect = vec![0u8; 8 * 1024];
    GfEngine::scalar().fold_blocks(&mut expect, &refs);
    assert_eq!(out, expect);
}

#[test]
fn pool_shutdown_joins_workers() {
    let pool = WorkPool::new(4);
    assert_eq!(pool.worker_count(), 4);
    pool.scope(|s| {
        for _ in 0..32 {
            s.submit(|| {
                std::hint::black_box(1 + 1);
            });
        }
    });
    drop(pool); // joins; must not hang (the test harness would time out)
}

#[test]
fn pool_reused_across_many_batches() {
    let e = pooled_engine(3);
    for _ in 0..50 {
        run_striped_op(&e);
    }
    assert!(e.pool_started());
}

#[test]
fn distinct_engines_get_distinct_pools_with_right_size() {
    let a = pooled_engine(2);
    let b = pooled_engine(5);
    run_striped_op(&a);
    run_striped_op(&b);
    assert!(a.pool_started() && b.pool_started());
    assert_eq!(a.threads(), 2);
    assert_eq!(b.threads(), 5);
}
