//! Vendored **offline stub** of the `xla` FFI crate.
//!
//! The real crate links the XLA C library at build time, which an offline
//! container cannot fetch. This stub reproduces exactly the API surface
//! `runtime/pjrt.rs` consumes — same type names, same signatures, same
//! error plumbing — so `cargo build --features pjrt` compiles (and CI can
//! type-check the real backend) with no network. Literal packing is
//! fully functional (it is pure Rust); only runtime entry points fail:
//! [`PjRtClient::cpu`] returns a descriptive error, so `PjrtCoder::new`
//! degrades identically to the feature-off stub at run time.
//!
//! To run the real PJRT path, point the `xla` dependency back at the
//! upstream crate (see `Cargo.toml`) in an online build.

use std::fmt;

/// Error type mirroring the upstream crate's (string-backed here).
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla (vendored offline stub): {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "no XLA runtime in this offline build — swap the vendored `xla` path \
         dependency for the upstream crate to execute PJRT artifacts"
            .to_string(),
    ))
}

/// Element types the coding artifacts use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    U8,
}

impl ElementType {
    fn byte_width(&self) -> usize {
        match self {
            ElementType::U8 => 1,
        }
    }
}

/// Marker for element types a [`Literal`] can be read back as.
pub trait NativeType: Sized + Copy {
    const ELEMENT: ElementType;
    fn from_byte(b: u8) -> Self;
}

impl NativeType for u8 {
    const ELEMENT: ElementType = ElementType::U8;
    fn from_byte(b: u8) -> u8 {
        b
    }
}

/// A host-side typed array. Fully functional in the stub (pure Rust).
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    shape: Vec<usize>,
    data: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        shape: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let elems: usize = shape.iter().product();
        if elems * ty.byte_width() != data.len() {
            return Err(Error(format!(
                "shape {shape:?} needs {} bytes, got {}",
                elems * ty.byte_width(),
                data.len()
            )));
        }
        Ok(Literal { ty, shape: shape.to_vec(), data: data.to_vec() })
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }

    /// Unwrap a 1-tuple result (identity for non-tuples in the stub).
    pub fn to_tuple1(self) -> Result<Literal> {
        Ok(self)
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if T::ELEMENT != self.ty {
            return Err(Error("element type mismatch".to_string()));
        }
        Ok(self.data.iter().map(|&b| T::from_byte(b)).collect())
    }
}

/// Parsed HLO module text (held verbatim; nothing executes offline).
pub struct HloModuleProto {
    _text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading HLO text {path}: {e}")))?;
        Ok(HloModuleProto { _text: text })
    }
}

/// A computation wrapping a parsed HLO module.
pub struct XlaComputation {
    _proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _proto: HloModuleProto { _text: proto._text.clone() } }
    }
}

/// PJRT client handle. [`PjRtClient::cpu`] always fails in the stub so
/// callers degrade exactly like the feature-off build.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

/// Compiled executable handle (unreachable offline: the client that would
/// produce one cannot be constructed).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// Device buffer handle returned by `execute`.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_shape_check() {
        let bytes = [1u8, 2, 3, 4, 5, 6];
        let l = Literal::create_from_shape_and_untyped_data(ElementType::U8, &[2, 3], &bytes)
            .unwrap();
        assert_eq!(l.shape(), &[2, 3]);
        assert_eq!(l.to_vec::<u8>().unwrap(), vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(l.clone().to_tuple1().unwrap().to_vec::<u8>().unwrap().len(), 6);
        let short = Literal::create_from_shape_and_untyped_data(ElementType::U8, &[2, 3], &[1]);
        assert!(short.is_err());
    }

    #[test]
    fn runtime_entry_points_fail_with_actionable_error() {
        let err = PjRtClient::cpu().err().expect("stub client must not construct");
        let msg = err.to_string();
        assert!(msg.contains("offline"), "{msg}");
        assert!(msg.contains("xla"), "{msg}");
    }
}
