//! Offline, thread-backed shim of the `tokio` API surface used by the
//! UniLRC serving plane (`rust/src/serve/`).
//!
//! Module paths and signatures mirror upstream tokio so the serve code
//! reads (and later swaps) as ordinary tokio code, but the execution
//! model is deliberately simple: every spawned task owns an OS thread,
//! and "async" socket methods are blocking `std::net` calls. That makes
//! blocking inside a task sound — there is no shared reactor to starve.
//! See README.md for the exact deviations from upstream.

pub mod runtime {
    //! `Runtime`/`Builder` with upstream shapes; both are thin wrappers
    //! over the thread-backed executor in [`crate::task`].

    use std::future::Future;

    pub struct Runtime {
        _priv: (),
    }

    impl Runtime {
        pub fn new() -> std::io::Result<Runtime> {
            Ok(Runtime { _priv: () })
        }

        /// Drive `fut` to completion on the calling thread with a
        /// park/unpark waker loop.
        pub fn block_on<F: Future>(&self, fut: F) -> F::Output {
            crate::task::block_on(fut)
        }

        pub fn spawn<F>(&self, fut: F) -> crate::task::JoinHandle<F::Output>
        where
            F: Future + Send + 'static,
            F::Output: Send + 'static,
        {
            crate::task::spawn(fut)
        }
    }

    pub struct Builder {
        _priv: (),
    }

    impl Builder {
        pub fn new_multi_thread() -> Builder {
            Builder { _priv: () }
        }

        pub fn enable_all(&mut self) -> &mut Builder {
            self
        }

        pub fn build(&mut self) -> std::io::Result<Runtime> {
            Runtime::new()
        }
    }
}

pub mod task {
    //! Thread-per-task executor. `spawn` starts an OS thread that runs
    //! the future under its own `block_on` loop; the returned
    //! `JoinHandle` is itself a future (as upstream), resolving to
    //! `Err(JoinError)` if the task panicked.

    use std::future::Future;
    use std::pin::Pin;
    use std::sync::{Arc, Mutex};
    use std::task::{Context, Poll, Wake, Waker};

    struct ThreadWaker(std::thread::Thread);

    impl Wake for ThreadWaker {
        fn wake(self: Arc<Self>) {
            self.0.unpark();
        }

        fn wake_by_ref(self: &Arc<Self>) {
            self.0.unpark();
        }
    }

    /// Poll `fut` on the current thread, parking between polls. A
    /// spurious unpark only costs one extra poll; `Poll::Pending` with
    /// no registered wakeup cannot deadlock because every wake source
    /// in this shim (JoinHandle completion, channel send) unparks.
    pub(crate) fn block_on<F: Future>(fut: F) -> F::Output {
        let waker = Waker::from(Arc::new(ThreadWaker(std::thread::current())));
        let mut cx = Context::from_waker(&waker);
        let mut fut = std::pin::pin!(fut);
        loop {
            match fut.as_mut().poll(&mut cx) {
                Poll::Ready(v) => return v,
                Poll::Pending => std::thread::park(),
            }
        }
    }

    struct JoinState<T> {
        result: Option<std::thread::Result<T>>,
        waker: Option<Waker>,
    }

    pub struct JoinHandle<T> {
        state: Arc<Mutex<JoinState<T>>>,
    }

    #[derive(Debug)]
    pub struct JoinError {
        panicked: bool,
    }

    impl JoinError {
        pub fn is_panic(&self) -> bool {
            self.panicked
        }
    }

    impl std::fmt::Display for JoinError {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            if self.panicked {
                write!(f, "task panicked")
            } else {
                write!(f, "task failed")
            }
        }
    }

    impl std::error::Error for JoinError {}

    impl<T> Future for JoinHandle<T> {
        type Output = Result<T, JoinError>;

        fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<Self::Output> {
            let mut g = self.state.lock().unwrap();
            if let Some(res) = g.result.take() {
                Poll::Ready(res.map_err(|_| JoinError { panicked: true }))
            } else {
                g.waker = Some(cx.waker().clone());
                Poll::Pending
            }
        }
    }

    pub fn spawn<F>(fut: F) -> JoinHandle<F::Output>
    where
        F: Future + Send + 'static,
        F::Output: Send + 'static,
    {
        let state = Arc::new(Mutex::new(JoinState { result: None, waker: None }));
        let shared = Arc::clone(&state);
        std::thread::spawn(move || {
            let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| block_on(fut)));
            let mut g = shared.lock().unwrap();
            g.result = Some(out);
            if let Some(w) = g.waker.take() {
                w.wake();
            }
        });
        JoinHandle { state }
    }
}

pub mod net {
    //! Blocking `std::net` sockets behind async method signatures.
    //! Sound under the thread-per-task executor: a blocked read parks
    //! one OS thread, never a shared poll loop. Methods are *inherent*
    //! (not `AsyncReadExt`/`AsyncWriteExt` traits) — see README.md.

    use std::io::{Read, Write};
    use std::net::{Shutdown, SocketAddr, ToSocketAddrs};

    pub struct TcpListener {
        inner: std::net::TcpListener,
    }

    impl TcpListener {
        pub async fn bind<A: ToSocketAddrs>(addr: A) -> std::io::Result<TcpListener> {
            Ok(TcpListener { inner: std::net::TcpListener::bind(addr)? })
        }

        pub async fn accept(&self) -> std::io::Result<(TcpStream, SocketAddr)> {
            let (s, a) = self.inner.accept()?;
            s.set_nodelay(true).ok();
            Ok((TcpStream { inner: s }, a))
        }

        pub fn local_addr(&self) -> std::io::Result<SocketAddr> {
            self.inner.local_addr()
        }
    }

    pub struct TcpStream {
        inner: std::net::TcpStream,
    }

    impl TcpStream {
        pub async fn connect<A: ToSocketAddrs>(addr: A) -> std::io::Result<TcpStream> {
            let s = std::net::TcpStream::connect(addr)?;
            s.set_nodelay(true).ok();
            Ok(TcpStream { inner: s })
        }

        pub fn set_nodelay(&self, on: bool) -> std::io::Result<()> {
            self.inner.set_nodelay(on)
        }

        pub async fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            self.inner.read(buf)
        }

        pub async fn read_exact(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            self.inner.read_exact(buf)?;
            Ok(buf.len())
        }

        pub async fn write_all(&mut self, buf: &[u8]) -> std::io::Result<()> {
            self.inner.write_all(buf)
        }

        pub async fn flush(&mut self) -> std::io::Result<()> {
            self.inner.flush()
        }

        /// Split into owned halves via `try_clone` (both halves wrap
        /// the same kernel socket, as with upstream's split).
        pub fn into_split(self) -> (OwnedReadHalf, OwnedWriteHalf) {
            let r = self.inner.try_clone().expect("TcpStream::try_clone");
            (OwnedReadHalf { inner: r }, OwnedWriteHalf { inner: self.inner })
        }
    }

    pub struct OwnedReadHalf {
        inner: std::net::TcpStream,
    }

    impl OwnedReadHalf {
        pub async fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            self.inner.read(buf)
        }

        pub async fn read_exact(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            self.inner.read_exact(buf)?;
            Ok(buf.len())
        }
    }

    pub struct OwnedWriteHalf {
        inner: std::net::TcpStream,
    }

    impl OwnedWriteHalf {
        pub async fn write_all(&mut self, buf: &[u8]) -> std::io::Result<()> {
            self.inner.write_all(buf)
        }

        pub async fn flush(&mut self) -> std::io::Result<()> {
            self.inner.flush()
        }

        pub fn shutdown_now(&self) -> std::io::Result<()> {
            self.inner.shutdown(Shutdown::Write)
        }
    }
}

pub mod sync {
    pub mod mpsc {
        //! Bounded channel over `std::sync::mpsc::sync_channel`.
        //! `Sender::send` and `Receiver::recv` are async methods (their
        //! bodies block, which is fine thread-per-task); `try_recv` is
        //! sync, used by the serve writer to coalesce pending frames.

        pub use std::sync::mpsc::TryRecvError;

        pub struct SendError<T>(pub T);

        impl<T> std::fmt::Debug for SendError<T> {
            fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                write!(f, "SendError(..)")
            }
        }

        impl<T> std::fmt::Display for SendError<T> {
            fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                write!(f, "channel closed")
            }
        }

        pub fn channel<T>(buffer: usize) -> (Sender<T>, Receiver<T>) {
            let (tx, rx) = std::sync::mpsc::sync_channel(buffer.max(1));
            (Sender { tx }, Receiver { rx })
        }

        pub struct Sender<T> {
            tx: std::sync::mpsc::SyncSender<T>,
        }

        impl<T> Clone for Sender<T> {
            fn clone(&self) -> Sender<T> {
                Sender { tx: self.tx.clone() }
            }
        }

        impl<T> Sender<T> {
            pub async fn send(&self, value: T) -> Result<(), SendError<T>> {
                self.tx.send(value).map_err(|e| SendError(e.0))
            }
        }

        pub struct Receiver<T> {
            rx: std::sync::mpsc::Receiver<T>,
        }

        impl<T> Receiver<T> {
            /// `None` when every sender has dropped.
            pub async fn recv(&mut self) -> Option<T> {
                self.rx.recv().ok()
            }

            pub fn try_recv(&mut self) -> Result<T, TryRecvError> {
                self.rx.try_recv()
            }
        }
    }
}

pub mod time {
    pub use std::time::{Duration, Instant};

    pub async fn sleep(dur: Duration) {
        std::thread::sleep(dur);
    }
}

pub mod io {
    pub use std::io::{Error, ErrorKind, Result};
}

pub use task::spawn;

#[cfg(test)]
mod tests {
    use super::*;

    fn rt() -> runtime::Runtime {
        runtime::Builder::new_multi_thread().enable_all().build().unwrap()
    }

    #[test]
    fn block_on_returns_value() {
        assert_eq!(rt().block_on(async { 6 * 7 }), 42);
    }

    #[test]
    fn spawn_and_join() {
        let rt = rt();
        let out = rt.block_on(async {
            let h = task::spawn(async { 1 + 2 });
            h.await.unwrap()
        });
        assert_eq!(out, 3);
    }

    #[test]
    fn join_surfaces_panic() {
        let rt = rt();
        let res = rt.block_on(async {
            let h = task::spawn(async { panic!("boom") });
            h.await
        });
        assert!(res.unwrap_err().is_panic());
    }

    #[test]
    fn mpsc_round_trip_and_try_recv() {
        let rt = rt();
        rt.block_on(async {
            let (tx, mut rx) = sync::mpsc::channel(4);
            let tx2 = tx.clone();
            tx.send(1u32).await.unwrap();
            tx2.send(2u32).await.unwrap();
            assert_eq!(rx.recv().await, Some(1));
            assert_eq!(rx.try_recv().unwrap(), 2);
            assert!(rx.try_recv().is_err());
            drop(tx);
            drop(tx2);
            assert_eq!(rx.recv().await, None);
        });
    }

    #[test]
    fn tcp_echo_round_trip() {
        let rt = rt();
        rt.block_on(async {
            let listener = net::TcpListener::bind("127.0.0.1:0").await.unwrap();
            let addr = listener.local_addr().unwrap();
            let server = task::spawn(async move {
                let (stream, _) = listener.accept().await.unwrap();
                let (mut r, mut w) = stream.into_split();
                let mut buf = [0u8; 5];
                r.read_exact(&mut buf).await.unwrap();
                w.write_all(&buf).await.unwrap();
                w.flush().await.unwrap();
            });
            let mut client = net::TcpStream::connect(addr).await.unwrap();
            client.write_all(b"hello").await.unwrap();
            let mut back = [0u8; 5];
            client.read_exact(&mut back).await.unwrap();
            assert_eq!(&back, b"hello");
            server.await.unwrap();
        });
    }
}
