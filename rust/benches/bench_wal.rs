//! Durability-layer bench: WAL append throughput, group-commit latency
//! across fsync cadences, manifest snapshot + log-truncation cost, and
//! crash-recovery replay time as a function of log length (the numbers
//! behind PERF.md's durability-overhead section).
//!
//! Set `UNILRC_BENCH_JSON=BENCH_wal.json` for the machine-readable
//! artifact — CI joins it to the rolling perf trajectory next to
//! `BENCH_gf.json` / `BENCH_pool.json` / `BENCH_rebalance.json`.

use std::path::PathBuf;
use unilrc::bench_util::{black_box, section, Bencher, JsonReport};
use unilrc::codes::spec::CodeFamily;
use unilrc::coordinator::manifest::{CoordinatorState, MANIFEST_CURRENT};
use unilrc::coordinator::recover;
use unilrc::coordinator::wal::{DurabilityOptions, Journal, WalRecord};
use unilrc::experiments::{build_dss, ExpConfig};
use unilrc::prng::Prng;

fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("unilrc-benchwal-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// A real coordinator state to seed journals with (two S42 stripes).
fn seed_state() -> CoordinatorState {
    let cfg =
        ExpConfig { block_size: 4 * 1024, stripes: 2, time_compute: false, ..Default::default() };
    let mut dss = build_dss(CodeFamily::UniLrc, &cfg);
    let mut prng = Prng::new(42);
    dss.ingest_random_stripes(cfg.stripes, &mut prng).expect("ingest");
    dss.capture_state()
}

/// A representative committed group: one full-width (n = 42) stripe
/// registration — the largest standalone record the coordinator logs.
fn stripe_record(state: &CoordinatorState) -> WalRecord {
    WalRecord::AddStripe {
        cluster_of: state.placements[0].0.clone(),
        node_of: state.placements[0].1.clone(),
    }
}

fn main() {
    let b = Bencher::from_env();
    let mut report = JsonReport::new("bench_wal");
    report.meta("engine", &unilrc::gf::dispatch::engine().describe());
    let state = seed_state();

    // ------------------------------------------------ append throughput
    section("WAL append (group commit, sync-every 8)");
    let rec = stripe_record(&state);
    let frame_bytes = rec.encode(1).len();
    let dir = scratch("append");
    let mut journal = Journal::create(
        &dir,
        &state,
        DurabilityOptions { sync_every: 8, snapshot_every: usize::MAX },
    )
    .expect("journal");
    let s = b.bench_throughput("wal/append-stripe-record", frame_bytes, || {
        journal.commit_op(std::slice::from_ref(&rec)).expect("append");
    });
    report.add(&s, frame_bytes);
    println!("  appended {} records / {} bytes", journal.wal_records(), journal.wal_bytes());
    drop(journal);
    let _ = std::fs::remove_dir_all(&dir);

    // -------------------------------- group-commit latency vs fsync cadence
    section("group-commit latency vs --wal-sync-every");
    for sync_every in [1usize, 8, 64] {
        let dir = scratch(&format!("sync-{sync_every}"));
        let mut journal = Journal::create(
            &dir,
            &state,
            DurabilityOptions { sync_every, snapshot_every: usize::MAX },
        )
        .expect("journal");
        let toggle = WalRecord::SetFailed { node: 0, down: true };
        let name = format!("wal/commit-latency/sync-{sync_every}");
        let s = b.bench_latency(&name, || {
            journal.commit_op(std::slice::from_ref(&toggle)).expect("append");
        });
        report.add(&s, frame_bytes);
        drop(journal);
        let _ = std::fs::remove_dir_all(&dir);
    }

    // ------------------------------------- snapshot + truncation cost
    section("manifest snapshot + log truncation");
    let dir = scratch("snap");
    let mut journal = Journal::create(
        &dir,
        &state,
        DurabilityOptions { sync_every: 8, snapshot_every: usize::MAX },
    )
    .expect("journal");
    let manifest_bytes = std::fs::metadata(dir.join(MANIFEST_CURRENT)).map_or(1, |m| m.len());
    let s = b.bench_latency("wal/snapshot-truncate", || {
        journal.commit_op(std::slice::from_ref(&rec)).expect("append");
        journal.snapshot(&state).expect("snapshot");
    });
    report.add(&s, manifest_bytes as usize);
    println!("  manifest {} bytes, {} snapshots", manifest_bytes, journal.snapshots());
    drop(journal);
    let _ = std::fs::remove_dir_all(&dir);

    // ----------------------------------- recovery replay vs log length
    section("crash-recovery replay vs log length");
    for n in [100usize, 1000] {
        let dir = scratch(&format!("recover-{n}"));
        let mut journal = Journal::create(
            &dir,
            &state,
            DurabilityOptions { sync_every: 64, snapshot_every: usize::MAX },
        )
        .expect("journal");
        for i in 0..n {
            journal
                .commit_op(&[WalRecord::SetFailed { node: 0, down: i % 2 == 0 }])
                .expect("append");
        }
        let log_bytes = journal.wal_bytes() as usize;
        drop(journal);
        let name = format!("wal/recover/{n}-records");
        let s = b.bench_throughput(&name, log_bytes, || {
            let rec = recover(&dir).expect("recovery");
            assert_eq!(rec.replayed_records, n);
            black_box(rec);
        });
        report.add(&s, log_bytes);
        let _ = std::fs::remove_dir_all(&dir);
    }

    report.write_if_requested();
}
