//! Ablation (§3.3 Discussion): the "one local group, t clusters"
//! relaxation — code rate vs cross-cluster repair traffic, z = 6.
//!
//! t = 1 is the strict UniLRC; larger t trades local parities (higher
//! rate) for t−1 aggregated cross-cluster blocks per repair.

use unilrc::analysis::metrics::{evaluate, CrossModel};
use unilrc::bench_util::section;
use unilrc::codes::unilrc::UniLrc;
use unilrc::placement::{PlacementStrategy, Topology, UniLrcPlace, UniLrcSpread};

fn main() {
    section("Ablation — relaxed UniLRC (α=1, z=6): rate vs cross-cluster repair traffic");
    println!(
        "{:>2} {:>4} {:>4} {:>8} {:>6} {:>6} {:>6}",
        "t", "n", "lp", "rate", "r̄", "CARC", "ADRC"
    );
    for t in [1usize, 2, 3, 6] {
        let code = UniLrc::new_relaxed(1, 6, t);
        let topo = Topology::new(6, 16);
        let p = if t == 1 {
            UniLrcPlace.place(&code, &topo, 0)
        } else {
            UniLrcSpread { t }.place(&code, &topo, 0)
        };
        let m = evaluate(&code, &p, CrossModel::Aggregated, 0.1);
        println!(
            "{:>2} {:>4} {:>4} {:>8.4} {:>6.2} {:>6.2} {:>6.2}",
            t,
            code.n(),
            code.local_parities().len(),
            code.rate(),
            m.arc,
            m.carc,
            m.adrc
        );
    }
    println!("(t=1: zero cross traffic; each step of t drops local parities for rate)");
}
