//! Fault-injection scenario bench: end-to-end `exp7_faults` replay,
//! per-family batched multi-node recovery bursts, and the decode-plan
//! warm-up prefetch cost.
//!
//! Set `UNILRC_BENCH_JSON=BENCH_faults.json` for the machine-readable
//! artifact — CI appends it to the rolling perf trajectory next to
//! `BENCH_gf.json` / `BENCH_pool.json` (PERF.md explains the rows).

use unilrc::bench_util::{black_box, section, Bencher, JsonReport};
use unilrc::codes::spec::CodeFamily;
use unilrc::codes::PlanCache;
use unilrc::experiments::{build_dss, exp7_faults, predicted_patterns, ExpConfig, FaultSimConfig};
use unilrc::prng::Prng;
use unilrc::sim::faults::{replay_scrub, FaultConfig, FaultTrace, ScrubConfig};

fn scenario_cfgs() -> (ExpConfig, FaultSimConfig) {
    let cfg = ExpConfig {
        block_size: 16 * 1024,
        stripes: 2,
        seed: 42,
        time_compute: false,
        ..Default::default()
    };
    let fc = FaultSimConfig {
        fault: FaultConfig {
            node_mttf_hours: 300.0,
            node_mttr_hours: 10.0,
            cluster_mttf_hours: 1_500.0,
            cluster_mttr_hours: 5.0,
            sector_mtte_hours: 0.0,
            horizon_hours: 400.0,
        },
        tenants: 3,
        objects_per_tenant: 6,
        reads_per_event: 1,
        measure_cap: 12,
    };
    (cfg, fc)
}

fn main() {
    let b = Bencher::from_env();
    let mut report = JsonReport::new("bench_faults");
    report.meta("engine", &unilrc::gf::dispatch::engine().describe());

    // ---------------- end-to-end scenario replay (all five families)
    section("exp7 fault-injection scenario (5 families, deterministic)");
    let (cfg, fc) = scenario_cfgs();
    let rows = exp7_faults(&cfg, &fc).expect("scenario runs");
    let scenario_bytes: usize =
        rows.iter().map(|r| r.repaired_blocks).sum::<usize>() * cfg.block_size;
    let s = b.bench_throughput("faults/exp7-scenario", scenario_bytes, || {
        black_box(exp7_faults(&cfg, &fc).expect("scenario runs"));
    });
    report.add(&s, scenario_bytes);

    // ---------------- batched burst recovery per family
    section("batched two-node recovery burst (recover_nodes)");
    for fam in CodeFamily::paper_baselines() {
        let mut dss = build_dss(fam, &cfg);
        let mut prng = Prng::new(cfg.seed);
        dss.ingest_random_stripes(cfg.stripes, &mut prng).expect("ingest");
        // two nodes from different clusters — a correlated-burst shape
        let n0 = dss.metadata().node_of(0, 0);
        let n1 = dss.metadata().node_of(0, dss.code.k() - 1);
        assert_ne!(n0, n1);
        dss.fail_node(n0);
        dss.fail_node(n1);
        let blocks =
            dss.metadata().blocks_on_node(n0).len() + dss.metadata().blocks_on_node(n1).len();
        dss.heal_node(n0);
        dss.heal_node(n1);
        let bytes = blocks * cfg.block_size;
        let name = format!("faults/recover-burst/{}", fam.name());
        let s = b.bench_throughput(&name, bytes, || {
            dss.fail_node(n0);
            dss.fail_node(n1);
            black_box(dss.recover_nodes(&[n0, n1]).expect("burst recovery"));
            dss.heal_node(n0);
            dss.heal_node(n1);
            dss.quiesce();
        });
        report.add(&s, bytes);
    }

    // ---------------- plan-cache warm-up prefetch cost
    section("decode-plan warm-up prefetch (predicted trace patterns)");
    let mut dss = build_dss(CodeFamily::UniLrc, &cfg);
    let mut prng = Prng::new(cfg.seed);
    dss.ingest_random_stripes(cfg.stripes, &mut prng).expect("ingest");
    let trace = FaultTrace::generate(&dss.topo, &fc.fault, cfg.seed);
    let patterns = predicted_patterns(&dss, &trace);
    println!("predicted patterns: {}", patterns.len());
    let s = b.bench_latency("faults/plan-warmup-prefetch", || {
        let cache = PlanCache::new(1024);
        black_box(cache.prefetch(&dss.code, &patterns));
    });
    report.add(&s, 0);

    // ---------------- budget-throttled scrub replay over a latent-error trace
    section("latent-error scrub replay (token-bucket budget)");
    let scrub_fault = FaultConfig { sector_mtte_hours: 60.0, ..fc.fault };
    let scrub_trace = FaultTrace::generate(&dss.topo, &scrub_fault, cfg.seed);
    let sc = ScrubConfig::accelerated(dss.topo.total_nodes());
    let rep = replay_scrub(&dss.topo, &scrub_trace, &sc);
    println!(
        "latent errors: {} injected, {} detected, mean dwell {:.2} h",
        rep.injected, rep.detected, rep.mean_dwell_hours
    );
    let s = b.bench_throughput("faults/scrub-replay", rep.scrubbed_bytes as usize, || {
        black_box(replay_scrub(&dss.topo, &scrub_trace, &sc));
    });
    report.add(&s, rep.scrubbed_bytes as usize);
    // trajectory rows: detection latency and residual exposure are the
    // model outputs CI watches drift on, next to the replay throughput
    report.add_value("faults/scrub-mean-dwell", rep.mean_dwell_hours, "h");
    report.add_value("faults/scrub-detected", rep.detected as f64, "count");
    report.add_value(
        "faults/scrub-undetected-occupancy",
        rep.undetected_block_hours,
        "block-h",
    );

    report.write_if_requested();
}
