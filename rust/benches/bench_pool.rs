//! Multi-stripe repair pipeline bench: persistent-pool batched dispatch vs
//! the per-call scoped-spawn executor it replaced vs sequential inline —
//! on small blocks (≤ 256 KiB), where spawn overhead used to eat the
//! parallel win and the striping gate forced stripe-by-stripe execution.
//!
//! The "spawn" rows reimplement the old executor shape (a
//! `std::thread::scope` + per-lane spawns on *every* stripe) here in the
//! bench, since the engine itself no longer contains it. All variants run
//! the same SIMD kernels; only dispatch differs.
//!
//! Set `UNILRC_BENCH_JSON=BENCH_pool.json` for the machine-readable
//! artifact (CI archives it next to `BENCH_gf.json`).

use std::sync::Arc;
use unilrc::bench_util::{black_box, section, Bencher, JsonReport};
use unilrc::codes::spec::{CodeFamily, Scheme};
use unilrc::coordinator::{Dss, DssConfig};
use unilrc::gf::{GfEngine, Kernel};
use unilrc::placement::{Topology, UniLrcPlace};
use unilrc::prng::Prng;
use unilrc::runtime::NativeCoder;
use unilrc::sim::NetConfig;

const STRIPES: usize = 40;
const SOURCES: usize = 6; // UniLRC S42 local-group repair reads r=6 blocks
const LANE: usize = 16 * 1024;

/// The old executor: scoped threads spawned per call, lanes fanned across
/// them, joined before returning — reproduced for comparison.
fn spawn_striped_fold(e: &GfEngine, threads: usize, dst: &mut [u8], srcs: &[&[u8]]) {
    let block = dst.len();
    let workers = threads.min(block.div_ceil(LANE)).max(1);
    if workers <= 1 {
        dst.copy_from_slice(srcs[0]);
        for s in &srcs[1..] {
            e.xor(dst, s);
        }
        return;
    }
    let mut lanes: Vec<(usize, &mut [u8])> = Vec::new();
    for (l, chunk) in dst.chunks_mut(LANE).enumerate() {
        lanes.push((l * LANE, chunk));
    }
    let per = lanes.len().div_ceil(workers);
    std::thread::scope(|scope| {
        while !lanes.is_empty() {
            let group: Vec<_> = lanes.drain(..per.min(lanes.len())).collect();
            scope.spawn(move || {
                for (off, chunk) in group {
                    let w = chunk.len();
                    chunk.copy_from_slice(&srcs[0][off..off + w]);
                    for s in &srcs[1..] {
                        e.xor(chunk, &s[off..off + w]);
                    }
                }
            });
        }
    });
}

fn main() {
    let b = Bencher::from_env();
    let mut p = Prng::new(9);
    let mut report = JsonReport::new("bench_pool");
    let best = Kernel::detect();
    let threads = std::thread::available_parallelism().map_or(1, |n| n.get());
    report.meta("detected_kernel", best.name());
    report.meta("threads", &threads.to_string());

    for block in [64 * 1024usize, 256 * 1024] {
        let kb = block / 1024;
        section(&format!(
            "Multi-stripe repair — {STRIPES} stripes × r={SOURCES} fold, {kb} KiB blocks"
        ));
        let stripes: Vec<Vec<Vec<u8>>> =
            (0..STRIPES).map(|_| (0..SOURCES).map(|_| p.bytes(block)).collect()).collect();
        let srefs: Vec<Vec<&[u8]>> =
            stripes.iter().map(|s| s.iter().map(|v| v.as_slice()).collect()).collect();
        let mut outs: Vec<Vec<u8>> = (0..STRIPES).map(|_| vec![0u8; block]).collect();
        let bytes = STRIPES * SOURCES * block;

        // 1. sequential inline, one thread (what the old defaults did at
        //    this block size: below the 2 MiB gate, never parallel)
        let seq = GfEngine::new(best);
        let s = b.bench_throughput(&format!("fold seq x1 [{kb}KiB]"), bytes, || {
            for (out, srcs) in outs.iter_mut().zip(&srefs) {
                seq.fold_blocks(black_box(out), black_box(srcs));
            }
        });
        report.add(&s, bytes);
        let seq_mibs = s.mib_per_s(bytes);

        // 2. the old executor, forced parallel: a scoped spawn per stripe
        let s = b.bench_throughput(&format!("fold spawn-per-call x{threads} [{kb}KiB]"), bytes, || {
            for (out, srcs) in outs.iter_mut().zip(&srefs) {
                spawn_striped_fold(&seq, threads, black_box(out), black_box(srcs));
            }
        });
        report.add(&s, bytes);
        let spawn_mibs = s.mib_per_s(bytes);

        // 3. batched persistent-pool dispatch: the whole event in one wave
        let pooled = GfEngine::new(best).with_threads(threads).with_lane(LANE).with_par_work(0);
        let s = b.bench_throughput(&format!("fold pool-batched x{threads} [{kb}KiB]"), bytes, || {
            pooled.batch(bytes, |bt| {
                for (out, srcs) in outs.iter_mut().zip(&srefs) {
                    bt.fold(black_box(out), black_box(srcs.clone()));
                }
            });
        });
        report.add(&s, bytes);
        let pool_mibs = s.mib_per_s(bytes);
        println!(
            "  -> pool-batched: {:.2}x over spawn-per-call, {:.2}x over sequential",
            pool_mibs / spawn_mibs,
            pool_mibs / seq_mibs
        );
    }

    // Chunk sweep: batch task granularity vs throughput on the
    // degraded-burst shape (many small blocks in one wave). chunk=adaptive
    // is the default policy (~2–4 tasks per worker); the fixed rows show
    // where the knob pays and where task-flooding hurts.
    section(&format!(
        "Chunk sweep — {STRIPES} stripes × r={SOURCES} fold, 64 KiB blocks, pool x{threads}"
    ));
    let block = 64 * 1024;
    let stripes: Vec<Vec<Vec<u8>>> =
        (0..STRIPES).map(|_| (0..SOURCES).map(|_| p.bytes(block)).collect()).collect();
    let srefs: Vec<Vec<&[u8]>> =
        stripes.iter().map(|s| s.iter().map(|v| v.as_slice()).collect()).collect();
    let mut outs: Vec<Vec<u8>> = (0..STRIPES).map(|_| vec![0u8; block]).collect();
    let bytes = STRIPES * SOURCES * block;
    for chunk_kb in [0usize, 16, 64, 256, 1024] {
        let label =
            if chunk_kb == 0 { "adaptive".to_string() } else { format!("{chunk_kb}KiB") };
        let e = GfEngine::new(best)
            .with_threads(threads)
            .with_lane(LANE)
            .with_par_work(0)
            .with_chunk(chunk_kb * 1024);
        let s = b.bench_throughput(&format!("fold chunk={label} x{threads}"), bytes, || {
            e.batch(bytes, |bt| {
                for (out, srcs) in outs.iter_mut().zip(&srefs) {
                    bt.fold(black_box(out), black_box(srcs.clone()));
                }
            });
        });
        report.add(&s, bytes);
    }

    // Buffer-pool contention: 8 threads hammering take/recycle pairs on
    // the sharded size-classed pool vs the single-Mutex LIFO it replaced
    // (reproduced inline). Reported as ns per take+recycle pair — lower is
    // better; this is the acceptance row for the sharded pool.
    section("Buffer pool — contended take/recycle, 8 threads × 64 KiB");
    {
        use unilrc::gf::pool::BufferPool;
        const POOL_THREADS: usize = 8;
        const OPS: usize = 2000;
        let len = 64 * 1024;
        let sharded = Arc::new(BufferPool::new(64 << 20));
        let s = b.bench_latency("pool 8t take/recycle (sharded classes)", || {
            let mut hs = Vec::new();
            for _ in 0..POOL_THREADS {
                let pl = Arc::clone(&sharded);
                hs.push(std::thread::spawn(move || {
                    for _ in 0..OPS {
                        let buf = pl.take_for_overwrite(len);
                        pl.recycle(black_box(buf));
                    }
                }));
            }
            for h in hs {
                h.join().unwrap();
            }
        });
        let sharded_ns = s.median.as_secs_f64() * 1e9 / (POOL_THREADS * OPS) as f64;
        report.add_value_directed("pool/take-recycle-8t/sharded", sharded_ns, "ns", "lower");
        let single: Arc<std::sync::Mutex<Vec<Vec<u8>>>> = Arc::default();
        let s = b.bench_latency("pool 8t take/recycle (single mutex)", || {
            let mut hs = Vec::new();
            for _ in 0..POOL_THREADS {
                let pl = Arc::clone(&single);
                hs.push(std::thread::spawn(move || {
                    for _ in 0..OPS {
                        let buf =
                            pl.lock().unwrap().pop().unwrap_or_else(|| vec![0u8; len]);
                        pl.lock().unwrap().push(black_box(buf));
                    }
                }));
            }
            for h in hs {
                h.join().unwrap();
            }
        });
        let single_ns = s.median.as_secs_f64() * 1e9 / (POOL_THREADS * OPS) as f64;
        report.add_value_directed("pool/take-recycle-8t/single-mutex", single_ns, "ns", "lower");
        println!(
            "  -> sharded {sharded_ns:.0} ns/op vs single-mutex {single_ns:.0} ns/op \
             ({:.2}x)",
            single_ns / sharded_ns
        );
    }

    // Cross-op task merging: a burst of tiny stripes far above the worker
    // count. Unmerged, every fold submits its own sub-chunk task; merged,
    // small ops fuse into chunk-sized tasks so the queue sees ~tasks-per-
    // worker instead of one per stripe.
    section(&format!("Cross-op merging — 200-stripe burst of 4 KiB folds, x{threads}"));
    {
        const BURST: usize = 200;
        let small = 4 * 1024;
        let stripes: Vec<Vec<Vec<u8>>> =
            (0..BURST).map(|_| (0..SOURCES).map(|_| p.bytes(small)).collect()).collect();
        let srefs: Vec<Vec<&[u8]>> =
            stripes.iter().map(|s| s.iter().map(|v| v.as_slice()).collect()).collect();
        let mut outs: Vec<Vec<u8>> = (0..BURST).map(|_| vec![0u8; small]).collect();
        let bytes = BURST * SOURCES * small;
        let mut mibs = [0.0f64; 2];
        for (i, (label, merge)) in
            [("merge=off", false), ("merge=on", true)].into_iter().enumerate()
        {
            let e = GfEngine::new(best)
                .with_threads(threads)
                .with_lane(LANE)
                .with_par_work(0)
                .with_merge(merge);
            let s = b.bench_throughput(&format!("fold burst [{label}]"), bytes, || {
                e.batch(bytes, |bt| {
                    for (out, srcs) in outs.iter_mut().zip(&srefs) {
                        bt.fold(black_box(out), black_box(srcs.clone()));
                    }
                });
            });
            report.add(&s, bytes);
            mibs[i] = s.mib_per_s(bytes);
        }
        println!("  -> merged: {:.2}x over unmerged", mibs[1] / mibs[0]);
    }

    // Decode-plan shape: multi-erasure matmul batched across stripes.
    section("Cached-plan decode — 2 erasures, 16 stripes, 64 KiB blocks");
    let code = Scheme::S42.build(CodeFamily::UniLrc);
    let block = 64 * 1024;
    let full: Vec<Vec<Vec<u8>>> = (0..16)
        .map(|_| {
            let data: Vec<Vec<u8>> = (0..code.k()).map(|_| p.bytes(block)).collect();
            let drefs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
            let parities = code.encode_blocks(&drefs);
            data.into_iter().chain(parities).collect()
        })
        .collect();
    let plan = code.decode_plan(&[0, 1]).expect("recoverable");
    let srcs: Vec<Vec<&[u8]>> = full
        .iter()
        .map(|stripe| plan.sources.iter().map(|&s| stripe[s].as_slice()).collect())
        .collect();
    let bytes = srcs.iter().map(|s| s.len()).sum::<usize>() * block;
    let seq = GfEngine::new(best);
    let s = b.bench_throughput("decode seq x1", bytes, || {
        for stripe in &srcs {
            black_box(plan.execute_batch_on(&seq, std::slice::from_ref(stripe)));
        }
    });
    report.add(&s, bytes);
    let pooled = GfEngine::new(best).with_threads(threads).with_lane(LANE).with_par_work(0);
    let s = b.bench_throughput(&format!("decode pool-batched x{threads}"), bytes, || {
        black_box(plan.execute_batch_on(&pooled, &srcs));
    });
    report.add(&s, bytes);

    // End-to-end: full-node recovery on the virtual testbed (real compute,
    // virtual network) through the batched proxy path.
    section("Full-node recovery end-to-end (Dss::recover_node, 64 KiB blocks)");
    let code = Scheme::S42.build(CodeFamily::UniLrc);
    let clusters = code.groups().len();
    let mut dss = Dss::new(
        code,
        Box::new(UniLrcPlace),
        Topology::new(clusters, 10),
        NetConfig::default(),
        Arc::new(NativeCoder),
        DssConfig { block_size: 64 * 1024, aggregated: true, time_compute: true },
    );
    let mut prng = Prng::new(10);
    dss.ingest_random_stripes(8, &mut prng).expect("ingest");
    let node = dss.metadata().node_of(0, 0);
    let lost = dss.metadata().blocks_on_node(node).len();
    dss.fail_node(node);
    let bytes = lost * 64 * 1024;
    let s = b.bench_throughput(&format!("recover_node ({lost} blocks)"), bytes, || {
        black_box(dss.recover_node(black_box(node)).expect("recover"));
        dss.quiesce();
    });
    report.add(&s, bytes);

    report.write_if_requested();
}
