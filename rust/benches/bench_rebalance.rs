//! Elastic-topology migration bench: end-to-end `exp8_elastic` scenario
//! replay plus per-family single-event costs (scale-out rebalance, drain,
//! whole-cluster scale-out) on the batched coding pipeline.
//!
//! Set `UNILRC_BENCH_JSON=BENCH_rebalance.json` for the machine-readable
//! artifact — CI joins it to the rolling perf trajectory next to
//! `BENCH_gf.json` / `BENCH_pool.json` / `BENCH_faults.json` (PERF.md
//! explains the rows).

use unilrc::bench_util::{black_box, section, Bencher, JsonReport};
use unilrc::codes::spec::CodeFamily;
use unilrc::experiments::{
    build_dss, exp10_interference, exp10_rates, exp8_elastic, ElasticConfig, ExpConfig,
};
use unilrc::placement::TopologyEvent;
use unilrc::prng::Prng;

fn cfgs() -> (ExpConfig, ElasticConfig) {
    let cfg = ExpConfig {
        block_size: 16 * 1024,
        stripes: 2,
        seed: 42,
        time_compute: false,
        ..Default::default()
    };
    let ec = ElasticConfig {
        add_nodes: 1,
        drain_nodes: 1,
        add_clusters: 1,
        cluster_nodes: 0,
        fault_horizon_hours: 150.0,
    };
    (cfg, ec)
}

fn main() {
    let b = Bencher::from_env();
    let mut report = JsonReport::new("bench_rebalance");
    report.meta("engine", &unilrc::gf::dispatch::engine().describe());
    let (cfg, ec) = cfgs();

    // ------------- end-to-end elastic scenario (all five families)
    section("exp8 elastic scenario (5 families, deterministic)");
    let rows = exp8_elastic(&cfg, &ec).expect("scenario runs");
    let scenario_bytes: usize = rows.iter().map(|r| r.migrated_bytes).sum();
    for r in &rows {
        println!(
            "  {:<8} moves {:>5}  cross {:>8.1} KiB  window {:>8.2} ms",
            r.family.name(),
            r.moves,
            r.cross_migration_bytes as f64 / 1024.0,
            r.migration_seconds * 1e3
        );
    }
    let s = b.bench_throughput("rebalance/exp8-scenario", scenario_bytes, || {
        black_box(exp8_elastic(&cfg, &ec).expect("scenario runs"));
    });
    report.add(&s, scenario_bytes);

    // ------------- per-family single events (fresh DSS per iteration —
    // topology events are irreversible, so setup cost is inside the loop
    // for every family alike; the numbers compare families, not absolutes)
    for fam in CodeFamily::paper_baselines() {
        section(&format!("single events — {}", fam.name()));
        let mk = || {
            let mut dss = build_dss(fam, &cfg);
            let mut prng = Prng::new(cfg.seed);
            dss.ingest_random_stripes(cfg.stripes, &mut prng).expect("ingest");
            dss
        };
        // bytes per event measured once on a probe run
        let mut probe = mk();
        let add = probe.apply_topology_event(TopologyEvent::AddNode { cluster: 0 }).unwrap();
        let name = format!("rebalance/add-node/{}", fam.name());
        let s = b.bench_throughput(&name, add.bytes_moved.max(1), || {
            let mut dss = mk();
            black_box(dss.apply_topology_event(TopologyEvent::AddNode { cluster: 0 }).unwrap());
        });
        report.add(&s, add.bytes_moved.max(1));

        let mut probe = mk();
        let victim = probe.metadata().node_of(0, 0);
        let drain = probe.apply_topology_event(TopologyEvent::DrainNode { node: victim }).unwrap();
        let name = format!("rebalance/drain/{}", fam.name());
        let s = b.bench_throughput(&name, drain.bytes_moved.max(1), || {
            let mut dss = mk();
            let victim = dss.metadata().node_of(0, 0);
            black_box(dss.apply_topology_event(TopologyEvent::DrainNode { node: victim }).unwrap());
        });
        report.add(&s, drain.bytes_moved.max(1));

        let mut probe = mk();
        let nodes = probe.topo.max_cluster_size();
        let grow = probe.apply_topology_event(TopologyEvent::AddCluster { nodes }).unwrap();
        println!(
            "  add-cluster moves {} blocks, {:.1} KiB cross",
            grow.moves,
            grow.cross_bytes as f64 / 1024.0
        );
        let name = format!("rebalance/add-cluster/{}", fam.name());
        let s = b.bench_throughput(&name, grow.bytes_moved.max(1), || {
            let mut dss = mk();
            let nodes = dss.topo.max_cluster_size();
            black_box(dss.apply_topology_event(TopologyEvent::AddCluster { nodes }).unwrap());
        });
        report.add(&s, grow.bytes_moved.max(1));
    }

    // ------------- migration under load: background-move throttle sweep
    // × foreground degraded-read latency on the shared network budget
    // (virtual-clock percentiles, deterministic — see PERF.md on reading
    // the interference curve), plus the retry counters of an online drain
    // whose source is down
    section("migration under load (throttle sweep × foreground p50/p99)");
    let rates = exp10_rates(400.0);
    let burst = 512.0 * 1024.0;
    for fam in CodeFamily::paper_baselines() {
        let mut dss = build_dss(fam, &cfg);
        let mut prng = Prng::new(cfg.seed);
        dss.ingest_random_stripes(cfg.stripes, &mut prng).expect("ingest");
        let curve = exp10_interference(&mut dss, &rates, burst, 32).expect("interference curve");
        for (mbps, p50, p99) in &curve {
            println!(
                "  {:<8} throttle {:>8.1} Mb/s   fg p50 {:>8.3} ms   p99 {:>8.3} ms",
                fam.name(),
                mbps,
                p50 * 1e3,
                p99 * 1e3
            );
            let tag = format!("rebalance/migrate-load/{}/r{:.0}", fam.name(), mbps);
            report.add_value(&format!("{tag}/fg-p50"), p50 * 1e3, "ms");
            report.add_value(&format!("{tag}/fg-p99"), p99 * 1e3, "ms");
        }

        // online drain of a dead source: the rebuild/retry pipeline
        let victim = dss.metadata().node_of(0, 0);
        dss.fail_node(victim);
        dss.submit_topology_event(TopologyEvent::DrainNode { node: victim }).expect("drain");
        while dss.online_in_flight() > 0 {
            dss.pump_migrations(f64::INFINITY, 64).expect("pump");
            if dss.online_in_flight() > 0 && !dss.parked_events().is_empty() {
                dss.retry_parked();
            }
        }
        let stats = dss.migration_stats();
        println!(
            "  {:<8} dead-source drain: {} moves rebuilt, {:.2} retries/event",
            fam.name(),
            stats.source_flips,
            stats.retries as f64 / stats.submitted.max(1) as f64
        );
        report.add_value(
            &format!("rebalance/migrate-load/{}/retries-per-event", fam.name()),
            stats.retries as f64 / stats.submitted.max(1) as f64,
            "retries",
        );
        report.add_value(
            &format!("rebalance/migrate-load/{}/rebuilt-moves", fam.name()),
            stats.source_flips as f64,
            "moves",
        );
    }

    report.write_if_requested();
}
