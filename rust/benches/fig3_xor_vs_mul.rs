//! Figure 3(a): XOR vs MUL+XOR coding throughput.
//!
//! The paper sweeps three CPU families at 64 MB buffers; we have one CPU,
//! so we sweep buffer sizes instead — the reproduced claim is the ratio
//! (XOR consistently 1.6–2.3× faster than MUL+XOR), not absolute numbers.

use unilrc::bench_util::{black_box, section, Bencher};
use unilrc::gf::slice::{mul_acc_slice, xor_slice};
use unilrc::prng::Prng;

fn main() {
    let b = Bencher::from_env();
    let mut p = Prng::new(1);
    section("Figure 3(a) — XOR vs MUL+XOR throughput (two-block combine)");
    for size in [1 << 20, 16 << 20, 64 << 20] {
        let src = p.bytes(size);
        let mut dst = p.bytes(size);
        let sx = b.bench_throughput(&format!("xor      {:>3} MiB", size >> 20), size, || {
            xor_slice(black_box(&mut dst), black_box(&src));
        });
        let sm = b.bench_throughput(&format!("mul+xor  {:>3} MiB", size >> 20), size, || {
            mul_acc_slice(black_box(0x53), black_box(&src), black_box(&mut dst));
        });
        let ratio = sm.median.as_secs_f64() / sx.median.as_secs_f64();
        println!("  -> XOR is {ratio:.2}x faster at {} MiB", size >> 20);
    }
}
