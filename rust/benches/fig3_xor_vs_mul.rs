//! Figure 3(a): XOR vs MUL+XOR coding throughput.
//!
//! The paper sweeps three CPU families at 64 MB buffers; we have one CPU,
//! so we sweep buffer sizes instead — the reproduced claim is the ratio
//! (XOR consistently 1.6–2.3× faster than MUL+XOR), not absolute numbers.
//! Since the engine refactor the ratio is reported per kernel tier: the
//! paper's numbers assume PSHUFB-class MUL kernels (ISA-L), which is the
//! SSSE3/AVX2/NEON row here; the scalar row shows why that assumption
//! matters.

use unilrc::bench_util::{black_box, section, Bencher};
use unilrc::gf::dispatch::{GfEngine, Kernel};
use unilrc::prng::Prng;

fn main() {
    let b = Bencher::from_env();
    let mut p = Prng::new(1);
    section("Figure 3(a) — XOR vs MUL+XOR throughput (two-block combine)");
    let tiers: Vec<Kernel> = Kernel::all().into_iter().rev().filter(|k| k.available()).collect();
    for size in [1 << 20, 16 << 20, 64 << 20] {
        let src = p.bytes(size);
        let mut dst = p.bytes(size);
        for &k in &tiers {
            let e = GfEngine::new(k);
            let sx = b.bench_throughput(&format!("xor      {:>3} MiB [{k}]", size >> 20), size, || {
                e.xor(black_box(&mut dst), black_box(&src));
            });
            let sm = b.bench_throughput(&format!("mul+xor  {:>3} MiB [{k}]", size >> 20), size, || {
                e.mul_acc(black_box(0x53), black_box(&src), black_box(&mut dst));
            });
            let ratio = sm.median.as_secs_f64() / sx.median.as_secs_f64();
            println!("  -> XOR is {ratio:.2}x faster at {} MiB on {k}", size >> 20);
        }
    }
}
