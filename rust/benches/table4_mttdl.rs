//! Table 4: MTTDL across all wide LRCs (exact Markov absorption times),
//! plus the paper's closed-form approximation for comparison.

use unilrc::analysis::markov::{mttdl_years, mttdl_years_approx, MttdlParams};
use unilrc::analysis::metrics::{evaluate, CrossModel};
use unilrc::bench_util::section;
use unilrc::codes::spec::{CodeFamily, Scheme};
use unilrc::experiments::strategy_and_topo;

fn main() {
    let params = MttdlParams::default();
    section("Table 4 — MTTDL (years)");
    println!(
        "{:<12} {:<8} {:>6} {:>8} {:>12} {:>12}",
        "scheme", "code", "f", "C", "exact", "approx"
    );
    for scheme in Scheme::paper_schemes() {
        for fam in CodeFamily::paper_baselines() {
            let code = scheme.build(fam);
            let (strategy, topo) = strategy_and_topo(fam, &code);
            let p = strategy.place(&code, &topo, 0);
            let m = evaluate(&code, &p, CrossModel::Aggregated, 0.1);
            let f = match fam {
                CodeFamily::Olrc => {
                    let r = code.repair_plan(0).sources.len();
                    code.n() - code.k() - code.k().div_ceil(r) + 2 - 1
                }
                _ => scheme.f,
            };
            let c = m.mttdl_c.max(0.05);
            println!(
                "{:<12} {:<8} {:>6} {:>8.3} {:>12.2e} {:>12.2e}",
                scheme.label(),
                fam.name(),
                f,
                c,
                mttdl_years(code.n(), f, c, &params),
                mttdl_years_approx(code.n(), f, c, &params),
            );
        }
    }
}
