//! Experiment 2 / Fig 10(b): degraded-read latency across k-of-n schemes.

use unilrc::bench_util::section;
use unilrc::codes::spec::Scheme;
use unilrc::experiments::{exp2_degraded_read, ExpConfig};

fn main() {
    for scheme in Scheme::paper_schemes() {
        let cfg = ExpConfig { scheme, ..Default::default() };
        section(&format!("Experiment 2 — degraded read latency [{}]", scheme.label()));
        for r in exp2_degraded_read(&cfg).unwrap() {
            println!("  {:<8} {:>12.3} {}", r.family.name(), r.value, r.unit);
        }
    }
}
