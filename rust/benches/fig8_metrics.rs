//! Figure 8: ADRC / CDRC / ARC / CARC / LBNR for all four families across
//! the three Table 2 schemes (both cross-traffic models).

use unilrc::analysis::metrics::{evaluate, CrossModel};
use unilrc::bench_util::section;
use unilrc::codes::spec::{CodeFamily, Scheme};
use unilrc::experiments::strategy_and_topo;

fn main() {
    for model in [CrossModel::Raw, CrossModel::Aggregated] {
        section(&format!("Figure 8 — recovery/read metrics ({model:?} cross model)"));
        for scheme in Scheme::paper_schemes() {
            println!("--- {} ---", scheme.label());
            println!(
                "{:<40} {:>7} {:>7} {:>7} {:>7} {:>6} {:>7}",
                "code", "ADRC", "CDRC", "ARC", "CARC", "LBNR", "maxmin"
            );
            for fam in CodeFamily::paper_baselines() {
                let code = scheme.build(fam);
                let (strategy, topo) = strategy_and_topo(fam, &code);
                let p = strategy.place(&code, &topo, 0);
                let m = evaluate(&code, &p, model, 0.1);
                println!(
                    "{:<40} {:>7.2} {:>7.2} {:>7.2} {:>7.2} {:>6.2} {:>7.2}",
                    m.code_name, m.adrc, m.cdrc, m.arc, m.carc, m.lbnr, m.imbalance
                );
            }
        }
    }
}
