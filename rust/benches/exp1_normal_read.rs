//! Experiment 1 / Fig 10(a): normal-read throughput across k-of-n schemes.

use unilrc::bench_util::section;
use unilrc::codes::spec::Scheme;
use unilrc::experiments::{exp1_normal_read, ExpConfig};

fn main() {
    for scheme in Scheme::paper_schemes() {
        let cfg = ExpConfig { scheme, ..Default::default() };
        section(&format!("Experiment 1 — normal read throughput [{}]", scheme.label()));
        for r in exp1_normal_read(&cfg).unwrap() {
            println!("  {:<8} {:>12.2} {}", r.family.name(), r.value, r.unit);
        }
    }
}
