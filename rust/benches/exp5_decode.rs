//! Experiment 5 / Fig 11(b): decoding (coding-library) throughput across
//! k-of-n schemes — XOR locality vs wide/MUL repairs in pure compute.

use unilrc::bench_util::section;
use unilrc::codes::spec::Scheme;
use unilrc::experiments::{exp5_decode, ExpConfig};

fn main() {
    for scheme in Scheme::paper_schemes() {
        let cfg = ExpConfig { scheme, ..Default::default() };
        section(&format!("Experiment 5 — decode throughput [{}]", scheme.label()));
        for r in exp5_decode(&cfg).unwrap() {
            println!("  {:<8} {:>12.2} {}", r.family.name(), r.value, r.unit);
        }
    }
}
