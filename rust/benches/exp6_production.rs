//! Experiment 6 / Fig 12: production object-store workload — normal and
//! degraded read latency CDFs under the 180-of-210 scheme.

use unilrc::bench_util::section;
use unilrc::codes::spec::Scheme;
use unilrc::experiments::{exp6_production, ExpConfig};

fn main() {
    let fast = std::env::var("UNILRC_BENCH_FAST").as_deref() == Ok("1");
    let (stripes, objects, requests) = if fast { (2, 8, 40) } else { (4, 40, 400) };
    let cfg = ExpConfig { scheme: Scheme::S210, stripes, ..Default::default() };
    section("Experiment 6 — production workload [180-of-210]");
    let res = exp6_production(&cfg, objects, requests).unwrap();
    println!("{:<8} {:>14} {:>14}", "code", "normal (ms)", "degraded (ms)");
    for r in &res {
        println!("{:<8} {:>14.3} {:>14.3}", r.family.name(), r.normal_mean_ms, r.degraded_mean_ms);
    }
    for r in &res {
        println!("\nCDF degraded read, {} (ms, fraction):", r.family.name());
        for (lat, frac) in &r.degraded_cdf {
            println!("  {lat:>10.3}  {frac:>5.2}");
        }
    }
}
