//! Figure 5: UniLRC design-space sweep — cluster count z, scale
//! coefficient α vs code rate and stripe width, with the §3.3 industry
//! feasibility window marked.

use unilrc::analysis::tradeoff::{sweep, TARGET_RATE, WIDTH_MAX, WIDTH_MIN};
use unilrc::bench_util::section;

fn main() {
    section("Figure 5 — code-rate / stripe-width trade-off");
    println!("feasible: rate ≥ {TARGET_RATE}, n ∈ [{WIDTH_MIN},{WIDTH_MAX}]");
    println!(
        "{:>2} {:>3} {:>5} {:>5} {:>4} {:>8} {:>9}",
        "α", "z", "n", "k", "r", "rate", "feasible"
    );
    for p in sweep(20, &[1, 2, 3]) {
        println!(
            "{:>2} {:>3} {:>5} {:>5} {:>4} {:>8.4} {:>9}",
            p.alpha, p.z, p.n, p.k, p.r, p.rate,
            if p.feasible() { "yes" } else { "-" }
        );
    }
}
