//! Experiment 4 / Fig 11(a): reconstruction throughput vs cross-cluster
//! bandwidth (0.5 → 10 Gb/s), 180-of-210 scheme.

use unilrc::bench_util::section;
use unilrc::codes::spec::Scheme;
use unilrc::experiments::{exp4_bandwidth, ExpConfig};

fn main() {
    let cfg = ExpConfig { scheme: Scheme::S210, ..Default::default() };
    section("Experiment 4 — recovery throughput vs cross-cluster bandwidth [180-of-210]");
    println!("{:>6}  {:>10} {:>10} {:>10} {:>10}", "Gb/s", "UniLRC", "ALRC", "OLRC", "ULRC");
    for (gbps, rows) in exp4_bandwidth(&cfg, &[0.5, 1.0, 2.5, 5.0, 10.0]).unwrap() {
        let v = |name: &str| {
            rows.iter().find(|r| r.family.name() == name).map(|r| r.value).unwrap_or(0.0)
        };
        println!(
            "{:>6}  {:>10.2} {:>10.2} {:>10.2} {:>10.2}",
            gbps, v("UniLRC"), v("ALRC"), v("OLRC"), v("ULRC")
        );
    }
    println!("(MiB/s; UniLRC stays flat — zero cross-cluster recovery traffic)");
}
