//! §Perf micro-benchmarks: the GF(2^8) slice kernels (native backend) and
//! the PJRT fold path — the prototype's coding hot spots.

use unilrc::bench_util::{black_box, section, Bencher};
use unilrc::codes::spec::{CodeFamily, Scheme};
use unilrc::gf::slice::{gf_matmul_blocks, mul_slice, xor_fold};
use unilrc::prng::Prng;
use unilrc::runtime::{CodingEngine, Manifest, NativeCoder, PjrtCoder};

fn main() {
    let b = Bencher::from_env();
    let mut p = Prng::new(3);
    const MB: usize = 1 << 20;

    section("GF slice kernels (1 MiB blocks)");
    let srcs: Vec<Vec<u8>> = (0..6).map(|_| p.bytes(MB)).collect();
    let refs: Vec<&[u8]> = srcs.iter().map(|v| v.as_slice()).collect();
    let mut out = vec![0u8; MB];
    b.bench_throughput("xor_fold r=6 (UniLRC repair)", 6 * MB, || {
        xor_fold(black_box(&mut out), black_box(&refs));
    });
    b.bench_throughput("mul_slice c=0x53", MB, || {
        mul_slice(black_box(0x53), black_box(&srcs[0]), black_box(&mut out));
    });

    section("Full-stripe encode (native), 64 KiB blocks");
    for scheme in Scheme::paper_schemes() {
        let code = scheme.build(CodeFamily::UniLrc);
        let data: Vec<Vec<u8>> = (0..code.k()).map(|_| p.bytes(65536)).collect();
        let drefs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
        let rows: Vec<&[u8]> = (0..code.m()).map(|i| code.parity_matrix().row(i)).collect();
        let mut outs = vec![vec![0u8; 65536]; code.m()];
        b.bench_throughput(&format!("encode {} (k·B in)", scheme.label()), code.k() * 65536, || {
            gf_matmul_blocks(black_box(&rows), black_box(&drefs), black_box(&mut outs));
        });
    }

    if Manifest::load(Manifest::default_dir()).is_ok() {
        section("PJRT backend vs native (xor fold r=6, 1 MiB)");
        let pjrt = PjrtCoder::new(None).unwrap();
        b.bench_throughput("pjrt fold", 6 * MB, || {
            black_box(pjrt.fold(black_box(&refs)).unwrap());
        });
        b.bench_throughput("native fold", 6 * MB, || {
            black_box(NativeCoder.fold(black_box(&refs)).unwrap());
        });
    } else {
        println!("artifacts/ missing — run `make artifacts` for the PJRT section");
    }
}
