//! §Perf micro-benchmarks: the GF(2^8) engine tiers (scalar SWAR vs SIMD
//! vs striped-parallel), the slice kernels on the default engine, and the
//! PJRT fold path — the prototype's coding hot spots.
//!
//! Set `UNILRC_BENCH_JSON=BENCH_gf.json` to also emit a machine-readable
//! artifact (CI archives it for the perf trajectory).

use unilrc::bench_util::{black_box, section, Bencher, JsonReport};
use unilrc::codes::spec::{CodeFamily, Scheme};
use unilrc::gf::dispatch::{GfEngine, Kernel};
use unilrc::gf::slice::{gf_matmul_blocks, mul_slice, xor_fold};
use unilrc::gf::NibbleTables;
use unilrc::prng::Prng;
use unilrc::runtime::{CodingEngine, Manifest, NativeCoder, PjrtCoder};

fn main() {
    let b = Bencher::from_env();
    let mut p = Prng::new(3);
    const MB: usize = 1 << 20;
    let mut report = JsonReport::new("bench_gf");
    report.meta("detected_kernel", Kernel::detect().name());
    let avail: Vec<&str> =
        Kernel::all().into_iter().filter(|k| k.available()).map(|k| k.name()).collect();
    report.meta("available_kernels", &avail.join(","));

    // ------------------------------------------------ engine tier shootout
    section("GF engine tiers — mul_acc 1 MiB, single thread");
    let src = p.bytes(MB);
    let mut dst = p.bytes(MB);
    let mut scalar_mibs = 0.0;
    for k in Kernel::all().into_iter().rev() {
        // rev(): scalar first, so the baseline prints before the SIMD tiers
        if !k.available() {
            continue;
        }
        let e = GfEngine::new(k);
        let s = b.bench_throughput(&format!("mul_acc c=0x53 [{k}]"), MB, || {
            e.mul_acc(black_box(0x53), black_box(&src), black_box(&mut dst));
        });
        if k == Kernel::Scalar {
            scalar_mibs = s.mib_per_s(MB);
        } else if scalar_mibs > 0.0 {
            println!("  -> {:.2}x over scalar", s.mib_per_s(MB) / scalar_mibs);
        }
        report.add(&s, MB);
    }

    // --------------------------------------- fused two-coefficient kernel
    section("GF engine tiers — fused mul_acc2 (2 sources, 1 MiB), single thread");
    let src2 = p.bytes(MB);
    for k in Kernel::all().into_iter().rev() {
        if !k.available() {
            continue;
        }
        let e = GfEngine::new(k);
        let (t1, t2) = (NibbleTables::new(0x53), NibbleTables::new(0x2B));
        // 2 MiB of source input per iteration; compare against two chained
        // single-source mul_acc calls at the same tier.
        let s = b.bench_throughput(&format!("mul_acc2 fused [{k}]"), 2 * MB, || {
            e.mul_acc2_t(
                black_box(&t1),
                black_box(&src),
                black_box(&t2),
                black_box(&src2),
                black_box(&mut dst),
            );
        });
        report.add(&s, 2 * MB);
        let s = b.bench_throughput(&format!("mul_acc x2 chained [{k}]"), 2 * MB, || {
            e.mul_acc_t(black_box(&t1), black_box(&src), black_box(&mut dst));
            e.mul_acc_t(black_box(&t2), black_box(&src2), black_box(&mut dst));
        });
        report.add(&s, 2 * MB);
    }

    section("GF engine tiers — xor 1 MiB, single thread");
    for k in Kernel::all().into_iter().rev() {
        if !k.available() {
            continue;
        }
        let e = GfEngine::new(k);
        let s = b.bench_throughput(&format!("xor [{k}]"), MB, || {
            e.xor(black_box(&mut dst), black_box(&src));
        });
        report.add(&s, MB);
    }

    // ------------------------------------------- striped parallel executor
    section("Striped executor — UniLRC(42,30) encode, 1 MiB blocks");
    let code = Scheme::S42.build(CodeFamily::UniLrc);
    let data: Vec<Vec<u8>> = (0..code.k()).map(|_| p.bytes(MB)).collect();
    let drefs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
    let rows: Vec<&[u8]> = (0..code.m()).map(|i| code.parity_matrix().row(i)).collect();
    let mut outs = vec![vec![0u8; MB]; code.m()];
    let best = Kernel::detect();
    let threads = std::thread::available_parallelism().map_or(1, |n| n.get());
    for (label, e) in [
        ("scalar x1".to_string(), GfEngine::scalar()),
        (format!("{best} x1"), GfEngine::new(best)),
        (format!("{best} x{threads}"), GfEngine::new(best).with_threads(threads)),
    ] {
        let s = b.bench_throughput(&format!("encode 42 [{label}]"), code.k() * MB, || {
            e.matmul_blocks(black_box(&rows), black_box(&drefs), black_box(&mut outs));
        });
        report.add(&s, code.k() * MB);
    }

    // --------------------------------------- streaming stores past the LLC
    // When the output span exceeds the LLC, regular stores thrash the cache
    // and pay a read-for-ownership per line; non-temporal stores bypass
    // both. The pair of rows (same shape, nt off vs on) is the acceptance
    // metric for the streaming path.
    section("Streaming stores — output span beyond the LLC (nt off vs on)");
    let llc = unilrc::gf::topo::llc_bytes();
    let nt_rows = 4usize;
    let nt_block = (llc / 2).max(8 * MB);
    let span_mb = nt_rows * nt_block / MB;
    println!("LLC {:.1} MiB, output span {span_mb} MiB", llc as f64 / MB as f64);
    let nt_srcs: Vec<Vec<u8>> = (0..6).map(|_| p.bytes(nt_block)).collect();
    let nt_refs: Vec<&[u8]> = nt_srcs.iter().map(|v| v.as_slice()).collect();
    let nt_coeff: Vec<Vec<u8>> = (0..nt_rows).map(|_| p.bytes(6)).collect();
    let nt_crefs: Vec<&[u8]> = nt_coeff.iter().map(|v| v.as_slice()).collect();
    let mut nt_outs = vec![vec![0u8; nt_block]; nt_rows];
    let nt_work = 6 * nt_block;
    let mut nt_mibs = [0.0f64; 2];
    for (i, (label, e)) in [
        ("nt=off", GfEngine::new(best).with_threads(threads).with_nt(usize::MAX)),
        ("nt=on", GfEngine::new(best).with_threads(threads).with_nt(0)),
    ]
    .into_iter()
    .enumerate()
    {
        let name = format!("matmul 4x6 {span_mb}MiB-out [{label}]");
        let s = b.bench_throughput(&name, nt_work, || {
            e.matmul_blocks(black_box(&nt_crefs), black_box(&nt_refs), black_box(&mut nt_outs));
        });
        report.add(&s, nt_work);
        nt_mibs[i] = s.mib_per_s(nt_work);
    }
    println!("  -> nt-on: {:.2}x over nt-off", nt_mibs[1] / nt_mibs[0]);
    let mut nt_out = vec![0u8; nt_rows * nt_block];
    for (label, e) in [
        ("nt=off", GfEngine::new(best).with_threads(threads).with_nt(usize::MAX)),
        ("nt=on", GfEngine::new(best).with_threads(threads).with_nt(0)),
    ] {
        let name = format!("fold r=6 {span_mb}MiB-out [{label}]");
        let s = b.bench_throughput(&name, 6 * nt_rows * nt_block, || {
            for out in nt_out.chunks_mut(nt_block) {
                e.fold_blocks(black_box(out), black_box(&nt_refs));
            }
        });
        report.add(&s, 6 * nt_rows * nt_block);
    }
    // free the >LLC fixtures before the remaining sections run
    drop(nt_out);
    drop(nt_outs);
    drop(nt_refs);
    drop(nt_srcs);

    // ---------------------------------------- default-engine slice kernels
    section("GF slice kernels on the default engine (1 MiB blocks)");
    let srcs: Vec<Vec<u8>> = (0..6).map(|_| p.bytes(MB)).collect();
    let refs: Vec<&[u8]> = srcs.iter().map(|v| v.as_slice()).collect();
    let mut out = vec![0u8; MB];
    let s = b.bench_throughput("xor_fold r=6 (UniLRC repair)", 6 * MB, || {
        xor_fold(black_box(&mut out), black_box(&refs));
    });
    report.add(&s, 6 * MB);
    let s = b.bench_throughput("mul_slice c=0x53", MB, || {
        mul_slice(black_box(0x53), black_box(&srcs[0]), black_box(&mut out));
    });
    report.add(&s, MB);

    section("Full-stripe encode (default engine), 64 KiB blocks");
    for scheme in Scheme::paper_schemes() {
        let code = scheme.build(CodeFamily::UniLrc);
        let data: Vec<Vec<u8>> = (0..code.k()).map(|_| p.bytes(65536)).collect();
        let drefs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
        let rows: Vec<&[u8]> = (0..code.m()).map(|i| code.parity_matrix().row(i)).collect();
        let mut outs = vec![vec![0u8; 65536]; code.m()];
        let name = format!("encode {} (k·B in)", scheme.label());
        let s = b.bench_throughput(&name, code.k() * 65536, || {
            gf_matmul_blocks(black_box(&rows), black_box(&drefs), black_box(&mut outs));
        });
        report.add(&s, code.k() * 65536);
    }

    // --------------------------- PJRT backend rows in the engine-tier table
    // The PJRT coder is a peer engine tier: fold + matmul + batched-combine
    // rows land next to the native tiers whenever a runtime and artifacts
    // exist. Builds with the vendored offline `xla` stub (or without
    // artifacts) record why the rows are absent instead of silently
    // skipping — the trajectory join keys stay stable either way.
    let pjrt_state = if Manifest::load(Manifest::default_dir()).is_ok() {
        match PjrtCoder::new(None) {
            Ok(pjrt) => {
                section("PJRT backend tier (vs native, 1 MiB blocks)");
                let s = b.bench_throughput("pjrt fold r=6", 6 * MB, || {
                    black_box(pjrt.fold(black_box(&refs)).unwrap());
                });
                report.add(&s, 6 * MB);
                let s = b.bench_throughput("native fold r=6", 6 * MB, || {
                    black_box(NativeCoder.fold(black_box(&refs)).unwrap());
                });
                report.add(&s, 6 * MB);
                let coeffs: Vec<Vec<u8>> =
                    (0..2).map(|r| (0..6).map(|j| (r * 7 + j * 13 + 2) as u8).collect()).collect();
                let s = b.bench_throughput("pjrt matmul 2x6", 6 * MB, || {
                    black_box(pjrt.matmul(black_box(&coeffs), black_box(&refs)).unwrap());
                });
                report.add(&s, 6 * MB);
                // same-shape jobs share artifact invocations (PjrtCoder's
                // combine_batch override) — the multi-stripe repair shape
                let jobs: Vec<unilrc::runtime::CombineJob> = (0..8)
                    .map(|_| unilrc::runtime::CombineJob {
                        coeffs: vec![vec![1; 6]],
                        sources: refs.clone(),
                    })
                    .collect();
                let batch_bytes = 8 * 6 * MB;
                let s = b.bench_throughput("pjrt combine_batch 8x fold", batch_bytes, || {
                    black_box(pjrt.combine_batch(black_box(&jobs)).unwrap());
                });
                report.add(&s, batch_bytes);
                "available".to_string()
            }
            Err(e) => {
                println!("PJRT rows skipped: {e}");
                format!("unavailable: {e}")
            }
        }
    } else {
        println!("artifacts/ missing — run `make artifacts` for the PJRT rows");
        "unavailable: artifacts/ not built".to_string()
    };
    report.meta("pjrt_backend", &pjrt_state);

    report.write_if_requested();
}
