//! Experiment 3 / Fig 10(c)+(d): single-block and full-node recovery
//! throughput across k-of-n schemes.

use unilrc::bench_util::section;
use unilrc::codes::spec::Scheme;
use unilrc::experiments::{exp3_node_recovery, exp3_reconstruction, ExpConfig};

fn main() {
    for scheme in Scheme::paper_schemes() {
        let cfg = ExpConfig { scheme, ..Default::default() };
        section(&format!("Experiment 3 — single-block recovery [{}]", scheme.label()));
        for r in exp3_reconstruction(&cfg).unwrap() {
            println!("  {:<8} {:>12.2} {}", r.family.name(), r.value, r.unit);
        }
        section(&format!("Experiment 3 — full-node recovery [{}]", scheme.label()));
        for r in exp3_node_recovery(&cfg).unwrap() {
            println!("  {:<8} {:>12.2} {}", r.family.name(), r.value, r.unit);
        }
        // ablation: raw cross-cluster transfers (no gateway aggregation) —
        // the paper's accounting; ALRC's all-k global repairs pay full price
        let raw = ExpConfig { aggregated: false, ..cfg.clone() };
        section(&format!(
            "Experiment 3 — single-block recovery, RAW cross transfers [{}]",
            scheme.label()
        ));
        for r in exp3_reconstruction(&raw).unwrap() {
            println!("  {:<8} {:>12.2} {}", r.family.name(), r.value, r.unit);
        }
    }
}
