//! Proxy-side operation execution (§4.2): each cluster's proxy gathers
//! surviving blocks, runs the coding library (PJRT artifacts or native GF),
//! and ships results — with optional ECWide-style *gateway aggregation*
//! (a remote proxy pre-combines its cluster's contribution so only one
//! block crosses the oversubscribed link).
//!
//! Network time is virtual ([`NetSim`]); coding time is *real*, measured
//! around the engine call and folded into the virtual clock.

use crate::codes::Code;
use crate::coordinator::metadata::{Metadata, StripeId};
use crate::runtime::CodingEngine;
use crate::sim::{Endpoint, NetSim};
use anyhow::Result;
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

/// Result of a proxy-coordinated block repair.
pub struct OpOutcome {
    /// Virtual time at which the rebuilt block is ready on the home proxy.
    pub ready_at: f64,
    /// The rebuilt block bytes.
    pub rebuilt: Vec<u8>,
    /// Home cluster id (where the repair ran).
    pub home: usize,
}

/// Borrowed view of the system a proxy op needs.
pub struct ProxyCtx<'a> {
    pub code: &'a Code,
    pub meta: &'a Metadata,
    pub net: &'a mut NetSim,
    pub engine: &'a dyn CodingEngine,
    pub aggregated: bool,
    pub block_size: usize,
    /// Fold real coding time into the virtual clock.
    pub time_compute: bool,
}

/// One repair input: where it lives and its combination coefficient.
struct SourceRef {
    coeff: u8,
    node: usize,
    cluster: usize,
    data: Arc<Vec<u8>>,
}

impl ProxyCtx<'_> {
    /// Rebuild `block` of `stripe` on its home-cluster proxy, given the
    /// stripe's full erasure set. Returns the rebuilt bytes and the
    /// virtual-clock instant they are ready.
    pub fn repair_block(
        &mut self,
        t0: f64,
        stripe: StripeId,
        block: usize,
        erased: &[usize],
    ) -> Result<OpOutcome> {
        let home = self.meta.cluster_of(stripe, block);
        let (source_ids, coeffs) = self.plan_for(block, erased)?;
        let sources: Vec<SourceRef> = source_ids
            .iter()
            .zip(&coeffs)
            .map(|(&b, &c)| SourceRef {
                coeff: c,
                node: self.meta.node_of(stripe, b),
                cluster: self.meta.cluster_of(stripe, b),
                data: self.meta.block_data(stripe, b),
            })
            .collect();

        // Partition by cluster.
        let mut local: Vec<&SourceRef> = Vec::new();
        let mut remote: BTreeMap<usize, Vec<&SourceRef>> = BTreeMap::new();
        for s in &sources {
            if s.cluster == home {
                local.push(s);
            } else {
                remote.entry(s.cluster).or_default().push(s);
            }
        }

        // Inputs to the final combine at the home proxy: (arrival, coeff, bytes)
        let mut inputs: Vec<(f64, u8, Arc<Vec<u8>>)> = Vec::new();

        for s in &local {
            let t = self.net.transfer(t0, Endpoint::Node(s.node), Endpoint::Proxy(home), self.block_size);
            inputs.push((t, s.coeff, s.data.clone()));
        }

        for (rc, srcs) in &remote {
            if self.aggregated && srcs.len() > 1 {
                // gather within the remote cluster, pre-combine, ship one block
                let mut arrive = t0;
                for s in srcs {
                    let t = self.net.transfer(
                        t0,
                        Endpoint::Node(s.node),
                        Endpoint::Proxy(*rc),
                        self.block_size,
                    );
                    arrive = arrive.max(t);
                }
                let refs: Vec<&[u8]> = srcs.iter().map(|s| s.data.as_slice()).collect();
                let cs: Vec<u8> = srcs.iter().map(|s| s.coeff).collect();
                let (partial, secs) = self.timed_combine(&cs, &refs)?;
                let t = self.net.transfer(
                    arrive + secs,
                    Endpoint::Proxy(*rc),
                    Endpoint::Proxy(home),
                    self.block_size,
                );
                inputs.push((t, 1, Arc::new(partial)));
            } else {
                // raw: each block crosses the gateway individually
                for s in srcs {
                    let t = self.net.transfer(
                        t0,
                        Endpoint::Node(s.node),
                        Endpoint::Proxy(home),
                        self.block_size,
                    );
                    inputs.push((t, s.coeff, s.data.clone()));
                }
            }
        }

        // Final combine once everything arrived.
        let arrived = inputs.iter().fold(t0, |a, (t, _, _)| a.max(*t));
        let refs: Vec<&[u8]> = inputs.iter().map(|(_, _, d)| d.as_slice()).collect();
        let cs: Vec<u8> = inputs.iter().map(|(_, c, _)| *c).collect();
        let (rebuilt, secs) = self.timed_combine(&cs, &refs)?;
        // Aggregation partials are solely owned by `inputs` (stored blocks
        // keep a metadata reference, so try_unwrap skips them); hand the
        // consumed buffers back to the block pool.
        for (_, _, d) in inputs {
            if let Ok(buf) = Arc::try_unwrap(d) {
                crate::gf::pool::recycle(buf);
            }
        }
        Ok(OpOutcome { ready_at: arrived + secs, rebuilt, home })
    }

    /// (sources, coefficients) reconstructing `block` with every member of
    /// `erased` unavailable.
    fn plan_for(&self, block: usize, erased: &[usize]) -> Result<(Vec<usize>, Vec<u8>)> {
        if erased == [block] {
            let plan = self.code.repair_plan(block);
            return Ok((plan.sources, plan.coeffs));
        }
        // One cached plan serves every repaired block of the same erasure
        // pattern: repairing a whole stripe (or node) is a map hit per
        // block after the first, not a fresh rank test + inversion.
        let cached = self
            .code
            .decode_plan_cached(erased)
            .ok_or_else(|| anyhow::anyhow!("erasure pattern {erased:?} unrecoverable"))?;
        let plan = &cached.plan;
        let row = plan
            .erased
            .iter()
            .position(|&b| b == block)
            .ok_or_else(|| anyhow::anyhow!("block {block} not in erasure set"))?;
        let coeffs: Vec<u8> = plan.coeffs.row(row).to_vec();
        // prune zero coefficients (sources other rows need, not this one)
        let keep: Vec<usize> = (0..coeffs.len()).filter(|&i| coeffs[i] != 0).collect();
        Ok((
            keep.iter().map(|&i| plan.sources[i]).collect(),
            keep.iter().map(|&i| coeffs[i]).collect(),
        ))
    }

    /// Run the linear combine on the engine, returning (bytes, virtual
    /// seconds to charge — the measured real time, or 0 when compute
    /// timing is disabled for determinism).
    fn timed_combine(&self, coeffs: &[u8], sources: &[&[u8]]) -> Result<(Vec<u8>, f64)> {
        let t = Instant::now();
        let out = if coeffs.iter().all(|&c| c == 1) {
            self.engine.fold(sources)?
        } else {
            self.engine
                .matmul(&[coeffs.to_vec()], sources)?
                .pop()
                .expect("one output row")
        };
        let secs = if self.time_compute { t.elapsed().as_secs_f64() } else { 0.0 };
        Ok((out, secs))
    }
}
