//! Proxy-side operation execution (§4.2): each cluster's proxy gathers
//! surviving blocks, runs the coding library (PJRT artifacts or native GF),
//! and ships results — with optional ECWide-style *gateway aggregation*
//! (a remote proxy pre-combines its cluster's contribution so only one
//! block crosses the oversubscribed link).
//!
//! Network time is virtual ([`NetSim`]); coding time is *real*, measured
//! around the engine call and folded into the virtual clock.
//!
//! Repairs are *batched by event*: [`ProxyCtx::repair_node`] takes every
//! (stripe, block) of a whole-node recovery or degraded-read fan-out and
//! executes all gateway pre-combines, then all final combines, as two
//! [`CodingEngine::combine_batch`] waves — the worker pool schedules
//! tasks across stripes instead of serializing stripe by stripe, with the
//! task granularity adapted to the wave's size (`GfEngine::batch_chunk`),
//! so a whole-node burst never floods the queue with tiny tasks.
//! Measured compute time for each wave is apportioned to the requests by
//! input bytes and folded into the virtual clock. [`ProxyCtx::repair_block`]
//! is the single-request special case of the same path.

use crate::codes::Code;
use crate::coordinator::metadata::{Metadata, StripeId};
use crate::gf::pool;
use crate::runtime::{CodingEngine, CombineJob};
use crate::sim::{Endpoint, NetSim};
use anyhow::Result;
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

/// Result of a proxy-coordinated block repair.
pub struct OpOutcome {
    /// Virtual time at which the rebuilt block is ready on the home proxy.
    pub ready_at: f64,
    /// The rebuilt block bytes (64-byte-aligned pooled buffer; hand it
    /// back via [`crate::gf::pool::recycle`] once consumed).
    pub rebuilt: pool::PooledBuf,
    /// Home cluster id (where the repair ran).
    pub home: usize,
}

/// One repair of a batched event: rebuild `block` of `stripe` with every
/// member of `erased` unavailable.
pub struct RepairRequest {
    pub stripe: StripeId,
    pub block: usize,
    pub erased: Vec<usize>,
}

/// Borrowed view of the system a proxy op needs.
pub struct ProxyCtx<'a> {
    pub code: &'a Code,
    pub meta: &'a Metadata,
    pub net: &'a mut NetSim,
    pub engine: &'a dyn CodingEngine,
    pub aggregated: bool,
    pub block_size: usize,
    /// Fold real coding time into the virtual clock.
    pub time_compute: bool,
}

/// A gateway pre-combine waiting for the phase-1 batch: one remote
/// cluster's contribution to one request.
struct AggJob {
    coeffs: Vec<u8>,
    data: Vec<Arc<Vec<u8>>>,
    /// Virtual instant all sources reached the remote proxy.
    arrive: f64,
    cluster: usize,
    /// Index into the request list this partial feeds.
    req: usize,
}

/// A final-combine input buffer: stored blocks stay shared with the
/// metadata store; phase-1 aggregation partials are solely-owned pooled
/// buffers that go back to the block pool after the combine consumes them.
enum SourceBuf {
    Stored(Arc<Vec<u8>>),
    Pooled(pool::PooledBuf),
}

impl SourceBuf {
    fn as_slice(&self) -> &[u8] {
        match self {
            SourceBuf::Stored(d) => d.as_slice(),
            SourceBuf::Pooled(b) => b.as_slice(),
        }
    }
}

/// Per-request state between the gather and final-combine phases.
struct PendingRepair {
    home: usize,
    /// Final-combine inputs: (arrival, coefficient, bytes).
    inputs: Vec<(f64, u8, SourceBuf)>,
}

impl ProxyCtx<'_> {
    /// Rebuild `block` of `stripe` on its home-cluster proxy, given the
    /// stripe's full erasure set. Returns the rebuilt bytes and the
    /// virtual-clock instant they are ready. (The single-request case of
    /// [`Self::repair_node`].)
    pub fn repair_block(
        &mut self,
        t0: f64,
        stripe: StripeId,
        block: usize,
        erased: &[usize],
    ) -> Result<OpOutcome> {
        let req = RepairRequest { stripe, block, erased: erased.to_vec() };
        let mut outcomes = self.repair_node(t0, std::slice::from_ref(&req))?;
        Ok(outcomes.pop().expect("one outcome per request"))
    }

    /// Rebuild every requested block of a multi-stripe event, all repairs
    /// issued at virtual instant `t0`. The virtual network moves each
    /// stripe's sources independently, then the *compute* runs as two
    /// batched waves shared by the whole event (gateway pre-combines, then
    /// final combines), so the engine's worker pool overlaps stripes.
    /// Outcomes are returned in request order.
    pub fn repair_node(&mut self, t0: f64, reqs: &[RepairRequest]) -> Result<Vec<OpOutcome>> {
        // ------------------------------------------------ gather (virtual)
        let mut pend: Vec<PendingRepair> = Vec::with_capacity(reqs.len());
        let mut aggs: Vec<AggJob> = Vec::new();
        for (ri, req) in reqs.iter().enumerate() {
            let home = self.meta.cluster_of(req.stripe, req.block);
            let (source_ids, coeffs) = self.plan_for(req.block, &req.erased)?;

            // Partition sources by cluster.
            let mut inputs: Vec<(f64, u8, SourceBuf)> = Vec::new();
            let mut remote: BTreeMap<usize, Vec<(u8, usize, Arc<Vec<u8>>)>> = BTreeMap::new();
            for (&b, &c) in source_ids.iter().zip(&coeffs) {
                let node = self.meta.node_of(req.stripe, b);
                let cluster = self.meta.cluster_of(req.stripe, b);
                let data = self.meta.block_data(req.stripe, b);
                if cluster == home {
                    let t = self.net.transfer(
                        t0,
                        Endpoint::Node(node),
                        Endpoint::Proxy(home),
                        self.block_size,
                    );
                    inputs.push((t, c, SourceBuf::Stored(data)));
                } else {
                    remote.entry(cluster).or_default().push((c, node, data));
                }
            }

            for (rc, srcs) in remote {
                if self.aggregated && srcs.len() > 1 {
                    // gather within the remote cluster; the pre-combine and
                    // the single cross-gateway ship happen in phase 1
                    let mut arrive = t0;
                    for (_, node, _) in &srcs {
                        let t = self.net.transfer(
                            t0,
                            Endpoint::Node(*node),
                            Endpoint::Proxy(rc),
                            self.block_size,
                        );
                        arrive = arrive.max(t);
                    }
                    aggs.push(AggJob {
                        coeffs: srcs.iter().map(|(c, _, _)| *c).collect(),
                        data: srcs.into_iter().map(|(_, _, d)| d).collect(),
                        arrive,
                        cluster: rc,
                        req: ri,
                    });
                } else {
                    // raw: each block crosses the gateway individually
                    for (c, node, data) in srcs {
                        let t = self.net.transfer(
                            t0,
                            Endpoint::Node(node),
                            Endpoint::Proxy(home),
                            self.block_size,
                        );
                        inputs.push((t, c, SourceBuf::Stored(data)));
                    }
                }
            }
            pend.push(PendingRepair { home, inputs });
        }

        // ------------------------- phase 1: all gateway pre-combines, batched
        let agg_coeffs: Vec<Vec<u8>> = aggs.iter().map(|a| a.coeffs.clone()).collect();
        let agg_srcs: Vec<Vec<&[u8]>> =
            aggs.iter().map(|a| a.data.iter().map(|d| d.as_slice()).collect()).collect();
        let (partials, agg_secs) = self.batch_combine(&agg_coeffs, &agg_srcs)?;
        drop(agg_srcs);
        for ((agg, partial), secs) in aggs.into_iter().zip(partials).zip(agg_secs) {
            let home = pend[agg.req].home;
            let t = self.net.transfer(
                agg.arrive + secs,
                Endpoint::Proxy(agg.cluster),
                Endpoint::Proxy(home),
                self.block_size,
            );
            pend[agg.req].inputs.push((t, 1, SourceBuf::Pooled(partial)));
        }

        // ----------------------------- phase 2: all final combines, batched
        let fin_coeffs: Vec<Vec<u8>> =
            pend.iter().map(|p| p.inputs.iter().map(|(_, c, _)| *c).collect()).collect();
        let fin_srcs: Vec<Vec<&[u8]>> = pend
            .iter()
            .map(|p| p.inputs.iter().map(|(_, _, d)| d.as_slice()).collect())
            .collect();
        let (rebuilt, fin_secs) = self.batch_combine(&fin_coeffs, &fin_srcs)?;
        drop(fin_srcs);

        let mut out = Vec::with_capacity(reqs.len());
        for ((p, rb), secs) in pend.into_iter().zip(rebuilt).zip(fin_secs) {
            let arrived = p.inputs.iter().fold(t0, |a, (t, _, _)| a.max(*t));
            // Aggregation partials are solely owned by `inputs` (stored
            // blocks stay shared with the metadata store); hand the
            // consumed pooled buffers back to the block pool.
            for (_, _, d) in p.inputs {
                if let SourceBuf::Pooled(buf) = d {
                    pool::recycle(buf);
                }
            }
            out.push(OpOutcome { ready_at: arrived + secs, rebuilt: rb, home: p.home });
        }
        Ok(out)
    }

    /// Pre-build decode plans for predicted erasure `patterns` on this
    /// proxy's code ([`crate::codes::PlanCache::prefetch`]): the first
    /// failure burst that realizes a predicted pattern then skips the rank
    /// test + inversion entirely. Repairs are byte-identical warm or cold —
    /// only where the cold-start cost lands moves. Returns plans inserted.
    pub fn warm_plans(&self, patterns: &[Vec<usize>]) -> usize {
        crate::codes::plan_cache::global().prefetch(self.code, patterns)
    }

    /// (sources, coefficients) reconstructing `block` with every member of
    /// `erased` unavailable.
    fn plan_for(&self, block: usize, erased: &[usize]) -> Result<(Vec<usize>, Vec<u8>)> {
        if erased == [block] {
            let plan = self.code.repair_plan(block);
            return Ok((plan.sources, plan.coeffs));
        }
        // One cached plan serves every repaired block of the same erasure
        // pattern: repairing a whole stripe (or node) is a map hit per
        // block after the first, not a fresh rank test + inversion.
        let cached = self
            .code
            .decode_plan_cached(erased)
            .ok_or_else(|| anyhow::anyhow!("erasure pattern {erased:?} unrecoverable"))?;
        let plan = &cached.plan;
        let row = plan
            .erased
            .iter()
            .position(|&b| b == block)
            .ok_or_else(|| anyhow::anyhow!("block {block} not in erasure set"))?;
        let coeffs: Vec<u8> = plan.coeffs.row(row).to_vec();
        // prune zero coefficients (sources other rows need, not this one)
        let keep: Vec<usize> = (0..coeffs.len()).filter(|&i| coeffs[i] != 0).collect();
        Ok((
            keep.iter().map(|&i| plan.sources[i]).collect(),
            keep.iter().map(|&i| coeffs[i]).collect(),
        ))
    }

    /// Run a set of single-output combines as one batched engine wave.
    /// Returns the output blocks plus each job's share of the measured
    /// compute time (apportioned by input bytes; all zeros when compute
    /// timing is disabled for determinism).
    fn batch_combine(
        &self,
        coeffs: &[Vec<u8>],
        sources: &[Vec<&[u8]>],
    ) -> Result<(Vec<pool::PooledBuf>, Vec<f64>)> {
        debug_assert_eq!(coeffs.len(), sources.len());
        if coeffs.is_empty() {
            return Ok((Vec::new(), Vec::new()));
        }
        let jobs: Vec<CombineJob> = coeffs
            .iter()
            .zip(sources)
            .map(|(c, s)| CombineJob { coeffs: vec![c.clone()], sources: s.clone() })
            .collect();
        let t = Instant::now();
        let outs = self.engine.combine_batch(&jobs)?;
        let elapsed = if self.time_compute { t.elapsed().as_secs_f64() } else { 0.0 };
        let bytes: Vec<usize> = jobs.iter().map(|j| j.work()).collect();
        let total: usize = bytes.iter().sum();
        let secs: Vec<f64> = bytes
            .iter()
            .map(|&b| if total > 0 { elapsed * b as f64 / total as f64 } else { 0.0 })
            .collect();
        let blocks: Vec<pool::PooledBuf> = outs
            .into_iter()
            .map(|mut rows| rows.pop().expect("one output row per combine"))
            .collect();
        Ok((blocks, secs))
    }
}
