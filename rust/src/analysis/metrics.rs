//! The performance metrics of Table 3 — ADRC, CDRC, ARC, CARC, LBNR —
//! plus the Fig 3(b) decode-op accounting, computed from a (code,
//! placement) pair.
//!
//! `cost(b)` is the number of blocks read to repair block `b` (the repair
//! plan's source count); `cost^c(b)` is the cross-cluster traffic in blocks.
//! Cross traffic supports two models (DESIGN.md §4):
//!
//! * [`CrossModel::Raw`] — every remote source block crosses a gateway.
//! * [`CrossModel::Aggregated`] — ECWide-style gateway aggregation: a
//!   source cluster pre-combines its contribution, so it ships one block
//!   regardless of how many sources it holds (valid for any linear plan).

use crate::codes::Code;
use crate::placement::Placement;

/// Cross-cluster traffic accounting model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CrossModel {
    Raw,
    Aggregated,
}

/// Cross-cluster blocks moved to repair `block` under `model`.
pub fn cross_cost(code: &Code, place: &Placement, block: usize, model: CrossModel) -> usize {
    let plan = code.repair_plan(block);
    let home = place.cluster_of[block];
    let mut per_cluster = std::collections::BTreeMap::new();
    for &s in &plan.sources {
        let c = place.cluster_of[s];
        if c != home {
            *per_cluster.entry(c).or_insert(0usize) += 1;
        }
    }
    match model {
        CrossModel::Raw => per_cluster.values().sum(),
        CrossModel::Aggregated => per_cluster.len(),
    }
}

/// Inner-cluster blocks read to repair `block`.
pub fn inner_cost(code: &Code, place: &Placement, block: usize) -> usize {
    let plan = code.repair_plan(block);
    let home = place.cluster_of[block];
    plan.sources.iter().filter(|&&s| place.cluster_of[s] == home).count()
}

/// All Table 3 metrics for one (code, placement) pair.
#[derive(Debug, Clone)]
pub struct MetricSet {
    pub code_name: String,
    /// Average degraded read cost: mean `cost(b)` over data blocks.
    pub adrc: f64,
    /// Cross-cluster ADRC.
    pub cdrc: f64,
    /// Average recovery cost: mean `cost(b)` over all blocks (= r̄).
    pub arc: f64,
    /// Cross-cluster ARC.
    pub carc: f64,
    /// Load-balance ratio of normal read: max/avg data blocks per
    /// data-holding cluster (1.0 = perfectly balanced).
    pub lbnr: f64,
    /// max/min imbalance across data-holding clusters (the "7×" of Fig 2(b)).
    pub imbalance: f64,
    /// Fig 3(b): average XOR slice-ops per single-block decode.
    pub avg_xor_ops: f64,
    /// Fig 3(b): average GF-MUL slice-ops per single-block decode.
    pub avg_mul_ops: f64,
    /// Average recovery traffic per block in the MTTDL model's units:
    /// `C = C1 + δ·C2` (cross blocks + δ·inner blocks), aggregated model.
    pub mttdl_c: f64,
}

/// Compute every metric for a code under a placement.
pub fn evaluate(code: &Code, place: &Placement, model: CrossModel, delta: f64) -> MetricSet {
    let n = code.n();
    let k = code.k();

    let cost = |b: usize| code.repair_plan(b).sources.len();
    let adrc = (0..k).map(cost).sum::<usize>() as f64 / k as f64;
    let arc = (0..n).map(cost).sum::<usize>() as f64 / n as f64;
    let cdrc =
        (0..k).map(|b| cross_cost(code, place, b, model)).sum::<usize>() as f64 / k as f64;
    let carc =
        (0..n).map(|b| cross_cost(code, place, b, model)).sum::<usize>() as f64 / n as f64;

    // LBNR over clusters that hold ≥1 data block.
    let clusters = place.cluster_of.iter().copied().max().unwrap_or(0) + 1;
    let hist = place.data_per_cluster(code, clusters);
    let nonzero: Vec<usize> = hist.iter().copied().filter(|&h| h > 0).collect();
    let max = *nonzero.iter().max().unwrap() as f64;
    let min = *nonzero.iter().min().unwrap() as f64;
    let avg = nonzero.iter().sum::<usize>() as f64 / nonzero.len() as f64;

    let avg_xor_ops =
        (0..n).map(|b| code.repair_plan(b).xor_ops()).sum::<usize>() as f64 / n as f64;
    let avg_mul_ops =
        (0..n).map(|b| code.repair_plan(b).mul_ops()).sum::<usize>() as f64 / n as f64;

    let mttdl_c = (0..n)
        .map(|b| {
            cross_cost(code, place, b, CrossModel::Aggregated) as f64
                + delta * inner_cost(code, place, b) as f64
        })
        .sum::<f64>()
        / n as f64;

    MetricSet {
        code_name: code.name().to_string(),
        adrc,
        cdrc,
        arc,
        carc,
        lbnr: max / avg,
        imbalance: max / min,
        avg_xor_ops,
        avg_mul_ops,
        mttdl_c,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::spec::{CodeFamily, Scheme};
    use crate::placement::{EcWide, PlacementStrategy, Topology, UniLrcPlace};

    fn metrics_for(fam: CodeFamily) -> MetricSet {
        let code = Scheme::S42.build(fam);
        if fam == CodeFamily::UniLrc {
            let topo = Topology::new(6, 8);
            let p = UniLrcPlace.place(&code, &topo, 0);
            evaluate(&code, &p, CrossModel::Raw, 0.1)
        } else {
            let need = EcWide::clusters_needed(&code);
            let topo = Topology::new(need, 32);
            let p = EcWide.place(&code, &topo, 0);
            evaluate(&code, &p, CrossModel::Raw, 0.1)
        }
    }

    #[test]
    fn unilrc_hits_paper_numbers() {
        let m = metrics_for(CodeFamily::UniLrc);
        assert!((m.adrc - 6.0).abs() < 1e-9);
        assert!((m.arc - 6.0).abs() < 1e-9);
        assert_eq!(m.cdrc, 0.0, "Property 2: zero cross-cluster traffic");
        assert_eq!(m.carc, 0.0);
        assert!((m.lbnr - 1.0).abs() < 1e-9, "Property 1: perfect balance");
        assert_eq!(m.avg_mul_ops, 0.0, "XOR locality: no MULs ever");
        // MTTDL C = 0 + 0.1·6 = 0.6 (paper §5 example)
        assert!((m.mttdl_c - 0.6).abs() < 1e-9);
    }

    #[test]
    fn alrc_matches_paper_figures() {
        let m = metrics_for(CodeFamily::Alrc);
        // Fig 1(a): ADRC = 5 (all data repair from 5), ARC = 8.57
        assert!((m.adrc - 5.0).abs() < 1e-9);
        assert!((m.arc - 8.5714).abs() < 1e-3);
        // ECWide keeps data repair in-cluster ⇒ CDRC = 0
        assert_eq!(m.cdrc, 0.0);
        // but global repair crosses: CARC > 0
        assert!(m.carc > 0.0);
        // data uniformly 5 per cluster ⇒ LBNR = 1
        assert!((m.lbnr - 1.0).abs() < 1e-9);
        // globals decode with MULs
        assert!(m.avg_mul_ops > 0.0);
    }

    #[test]
    fn olrc_is_worst_on_locality() {
        let uni = metrics_for(CodeFamily::UniLrc);
        let olrc = metrics_for(CodeFamily::Olrc);
        assert!((olrc.adrc - 25.0).abs() < 1e-9, "uniform r̄ = 25");
        assert!(olrc.adrc > uni.adrc);
        assert!(olrc.carc > uni.carc);
        assert!(olrc.cdrc > 0.0, "large groups must cross clusters");
    }

    #[test]
    fn ulrc_between_uni_and_olrc() {
        let uni = metrics_for(CodeFamily::UniLrc);
        let ulrc = metrics_for(CodeFamily::Ulrc);
        let olrc = metrics_for(CodeFamily::Olrc);
        assert!((ulrc.arc - 7.4286).abs() < 1e-3);
        assert!(uni.arc < ulrc.arc && ulrc.arc < olrc.arc);
        assert!(ulrc.carc > 0.0, "split groups cross clusters");
        assert!(ulrc.lbnr > 1.0, "Fig 2(b): ECWide imbalances ULRC reads");
    }

    #[test]
    fn aggregated_model_never_exceeds_raw() {
        for fam in CodeFamily::paper_baselines() {
            let code = Scheme::S42.build(fam);
            let need = EcWide::clusters_needed(&code).max(6);
            let topo = Topology::new(need, 32);
            let p = EcWide.place(&code, &topo, 0);
            for b in 0..code.n() {
                let raw = cross_cost(&code, &p, b, CrossModel::Raw);
                let agg = cross_cost(&code, &p, b, CrossModel::Aggregated);
                assert!(agg <= raw, "{fam:?} block {b}");
            }
        }
    }

    #[test]
    fn table1_qualitative_ranking() {
        // Table 1: UniLRC best on recovery/topology/XOR locality
        let uni = metrics_for(CodeFamily::UniLrc);
        for fam in [CodeFamily::Alrc, CodeFamily::Olrc, CodeFamily::Ulrc] {
            let m = metrics_for(fam);
            assert!(uni.arc <= m.arc + 1e-9, "{fam:?} recovery locality");
            assert!(uni.carc <= m.carc + 1e-9, "{fam:?} topology locality");
            assert!(uni.avg_mul_ops <= m.avg_mul_ops + 1e-9, "{fam:?} XOR locality");
        }
    }
}
