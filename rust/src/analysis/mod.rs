//! Theoretical analysis suite (§5): the six metrics of Table 3, the MTTDL
//! Markov model of Fig 9 / Table 4, and the Fig 5 design-space trade-off.

pub mod markov;
pub mod metrics;
pub mod tradeoff;

pub use markov::{MttdlParams, mttdl_years};
pub use metrics::{CrossModel, MetricSet, evaluate};
