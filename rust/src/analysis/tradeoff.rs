//! Fig 5: the UniLRC design space — cluster count `z`, scale coefficient
//! `α`, code rate `k/n`, stripe width `n` — and the industry feasibility
//! window (rate ≥ 0.85, width 25–504).

/// One design point of Fig 5.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DesignPoint {
    pub alpha: usize,
    pub z: usize,
    pub n: usize,
    pub k: usize,
    pub r: usize,
    pub rate: f64,
}

/// Industry targets quoted in §3.3.
pub const TARGET_RATE: f64 = 0.85;
pub const WIDTH_MIN: usize = 25;
pub const WIDTH_MAX: usize = 504;

impl DesignPoint {
    pub fn new(alpha: usize, z: usize) -> DesignPoint {
        let n = alpha * z * z + z;
        let k = alpha * z * z - alpha * z;
        DesignPoint { alpha, z, n, k, r: alpha * z, rate: k as f64 / n as f64 }
    }

    /// Theorem 3.1 closed form (must equal `rate`).
    pub fn rate_closed_form(&self) -> f64 {
        1.0 - (self.alpha as f64 + 1.0) / (self.alpha as f64 * self.z as f64 + 1.0)
    }

    /// Inside the practical window of §3.3?
    pub fn feasible(&self) -> bool {
        self.rate >= TARGET_RATE && (WIDTH_MIN..=WIDTH_MAX).contains(&self.n)
    }
}

/// Enumerate the Fig 5 sweep: `z ≤ z_max`, `α ∈ alphas`.
pub fn sweep(z_max: usize, alphas: &[usize]) -> Vec<DesignPoint> {
    let mut pts = Vec::new();
    for &alpha in alphas {
        for z in 2..=z_max {
            pts.push(DesignPoint::new(alpha, z));
        }
    }
    pts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closed_form_matches_direct() {
        for p in sweep(20, &[1, 2, 3]) {
            assert!(
                (p.rate - p.rate_closed_form()).abs() < 1e-12,
                "α={} z={}",
                p.alpha,
                p.z
            );
        }
    }

    #[test]
    fn paper_example_z10_alpha2() {
        // §3.3: UniLRC(210, 180, 20) at z=10, α=2 achieves 85.71%
        let p = DesignPoint::new(2, 10);
        assert_eq!((p.n, p.k, p.r), (210, 180, 20));
        assert!((p.rate - 0.8571).abs() < 1e-4);
        assert!(p.feasible());
    }

    #[test]
    fn rate_monotone_in_z_and_alpha() {
        for alpha in [1usize, 2, 3] {
            for z in 3..=19 {
                assert!(DesignPoint::new(alpha, z + 1).rate > DesignPoint::new(alpha, z).rate);
                assert!(DesignPoint::new(alpha + 1, z).rate > DesignPoint::new(alpha, z).rate);
            }
        }
    }

    #[test]
    fn feasibility_kicks_in_near_z10() {
        // §3.3: "UniLRC easily achieves the target setting when z ≥ 10"
        assert!(!DesignPoint::new(2, 8).feasible()); // rate 0.8235 < 0.85
        assert!(DesignPoint::new(2, 10).feasible());
        assert!(DesignPoint::new(3, 9).feasible());
        // small clusters can't reach 0.85 with α ≤ 3 (Discussion §3.3)
        for alpha in [1, 2, 3] {
            for z in 2..=7 {
                let p = DesignPoint::new(alpha, z);
                assert!(
                    !(p.feasible() && p.rate >= 0.85) || p.n > 504 || z > 7,
                    "α={alpha} z={z} unexpectedly feasible"
                );
            }
        }
    }

    #[test]
    fn rate_approaches_1_minus_1_over_z() {
        // §3.3: large r ⇒ rate → 1 − 1/z
        let p = DesignPoint::new(50, 5);
        assert!((p.rate - (1.0 - 0.2)).abs() < 0.01);
    }
}
