//! MTTDL via the Fig 9 Markov chain.
//!
//! States count failed blocks of one stripe: `0 ⇢ 1 ⇢ … ⇢ f+1` where
//! `f = d − 1` is the maximum tolerable failures and `f+1` is absorption
//! (data loss). Downward (failure) rate from state `i` is `(n−i)·λ`;
//! upward (repair) rate is `μ` from state 1 (bandwidth-limited single-node
//! recovery, `μ = ε(N−1)B / (C·S)` with `C = C1 + δ·C2` the per-block
//! recovery traffic, §5) and `μ' = 1/T` from states ≥ 2 (detection-latency
//! limited multi-failure recovery).
//!
//! We compute the *exact* expected absorption time of the chain (first-step
//! linear system, solved by the standard birth–death recursion) instead of
//! the paper's product approximation — same ordering, no approximation
//! error; EXPERIMENTS.md compares both.

/// Parameters of the reliability model (paper defaults in `Default`).
#[derive(Debug, Clone, Copy)]
pub struct MttdlParams {
    /// Total nodes in the DSS.
    pub n_nodes: usize,
    /// Node capacity in GB.
    pub node_capacity_gb: f64,
    /// Per-node network bandwidth in Gb/s.
    pub bandwidth_gbps: f64,
    /// Fraction of bandwidth reserved for recovery.
    pub epsilon: f64,
    /// Inner-cluster traffic weight (cross-cluster bandwidth ratio).
    pub delta: f64,
    /// Multi-failure detection/trigger time in hours.
    pub detect_hours: f64,
    /// Mean time to node failure in years.
    pub node_mttf_years: f64,
}

impl Default for MttdlParams {
    fn default() -> Self {
        // §6 Setup defaults: N=400, S=16 TB, ε=0.1, δ=0.1, T=30 min,
        // B=1 Gb/s, 1/λ = 4 years.
        MttdlParams {
            n_nodes: 400,
            node_capacity_gb: 16_000.0,
            bandwidth_gbps: 1.0,
            epsilon: 0.1,
            delta: 0.1,
            detect_hours: 0.5,
            node_mttf_years: 4.0,
        }
    }
}

const HOURS_PER_YEAR: f64 = 24.0 * 365.0;

impl MttdlParams {
    /// Single-failure repair rate μ (per hour) given the per-block recovery
    /// traffic `c` (in block units, `C = C1 + δ·C2`).
    pub fn mu(&self, c: f64) -> f64 {
        assert!(c > 0.0, "recovery traffic must be positive");
        let gb_per_hour = self.bandwidth_gbps / 8.0 * 3600.0;
        self.epsilon * (self.n_nodes as f64 - 1.0) * gb_per_hour / (c * self.node_capacity_gb)
    }

    /// Multi-failure repair rate μ' (per hour).
    pub fn mu_prime(&self) -> f64 {
        1.0 / self.detect_hours
    }

    /// Per-node failure rate λ (per hour).
    pub fn lambda(&self) -> f64 {
        1.0 / (self.node_mttf_years * HOURS_PER_YEAR)
    }
}

/// Exact expected absorption time (hours) of a birth–death chain with
/// failure rates `lam[i]` (state i → i+1 failures) and repair rates
/// `mu[i]` (state i → i−1, `mu[0]` unused), absorbing at `lam.len()`.
///
/// Uses the standard per-state hitting-time recursion, which is numerically
/// stable (sums and products of positive terms only): let `h_j` be the
/// expected time to first reach state `j+1` from state `j`; then
/// `h_0 = 1/λ_0`, `h_j = (1 + μ_j·h_{j−1}) / λ_j`, and the absorption time
/// from the all-healthy state is `Σ_j h_j`.
pub fn absorption_time_hours(lam: &[f64], mu: &[f64]) -> f64 {
    let f = lam.len(); // states 0..f−1 alive, state f = absorbed
    assert_eq!(mu.len(), f);
    assert!(lam.iter().all(|&l| l > 0.0), "failure rates must be positive");
    let mut h = 1.0 / lam[0];
    let mut total = h;
    for i in 1..f {
        h = (1.0 + mu[i] * h) / lam[i];
        total += h;
    }
    total
}

/// MTTDL (years) of a stripe of width `n` with failure tolerance `f = d−1`
/// and average per-block recovery traffic `c` (`C = C1 + δ·C2`).
pub fn mttdl_years(n: usize, f: usize, c: f64, p: &MttdlParams) -> f64 {
    assert!(f >= 1 && f < n);
    let lambda = p.lambda();
    // state i = i failed blocks; failure rate (n−i)λ; repair μ then μ'.
    let lam: Vec<f64> = (0..=f).map(|i| (n - i) as f64 * lambda).collect();
    let mut mu = vec![0.0f64; f + 1];
    if f >= 1 {
        mu[1] = p.mu(c);
    }
    for m in mu.iter_mut().skip(2) {
        *m = p.mu_prime();
    }
    absorption_time_hours(&lam, &mu) / HOURS_PER_YEAR
}

/// Steady-state distribution of an ergodic birth–death chain:
/// `lam[i]` is the rate of `i → i+1` and `mu[i]` the rate of `i+1 → i`,
/// so the chain has `lam.len() + 1` states and detailed balance gives
/// `π_{i+1} = π_i · λ_i / μ_i` (normalized).
pub fn steady_state(lam: &[f64], mu: &[f64]) -> Vec<f64> {
    assert_eq!(lam.len(), mu.len());
    let mut pi = vec![1.0f64];
    for i in 0..lam.len() {
        assert!(mu[i] > 0.0, "repair rates must be positive");
        let next = pi[i] * lam[i] / mu[i];
        pi.push(next);
    }
    let total: f64 = pi.iter().sum();
    for p in &mut pi {
        *p /= total;
    }
    pi
}

/// The fault *injector's* per-stripe chain (`sim::faults`): `n` blocks on
/// independent nodes, each failing at rate `lambda` and repairing
/// independently at rate `mu` — so state `i` fails at `(n−i)λ` and repairs
/// at `i·μ`. (The MTTDL chain above instead models bandwidth-limited /
/// detection-limited repair; this one is what the injected traces realize,
/// and is what `exp7_faults` measurements are checked against.)
pub fn injected_chain(n: usize, lambda: f64, mu: f64) -> (Vec<f64>, Vec<f64>) {
    assert!(n > 0 && lambda > 0.0 && mu > 0.0);
    let lam: Vec<f64> = (0..n).map(|i| (n - i) as f64 * lambda).collect();
    let rep: Vec<f64> = (1..=n).map(|i| i as f64 * mu).collect();
    (lam, rep)
}

/// Long-run fraction of time ≥1 of the stripe's `n` blocks is failed
/// under the injector's chain (`1 − π_0`; equivalently
/// `1 − (μ/(λ+μ))^n`, since the steady state is Binomial).
pub fn degraded_fraction(n: usize, lambda: f64, mu: f64) -> f64 {
    let (lam, rep) = injected_chain(n, lambda, mu);
    1.0 - steady_state(&lam, &rep)[0]
}

/// Long-run fraction of time more than `f` blocks are failed — data
/// unavailable under the injector's independent-repair model.
pub fn unavailable_fraction(n: usize, f: usize, lambda: f64, mu: f64) -> f64 {
    let (lam, rep) = injected_chain(n, lambda, mu);
    steady_state(&lam, &rep).iter().skip(f + 1).sum()
}

/// MTTDL (years) under the injector's chain: expected first time more
/// than `f` of `n` blocks are simultaneously failed, with independent
/// repairs at rate `i·μ` — the closed form short-trace estimates from
/// `exp7_faults` are compared against.
pub fn mttdl_injected_years(n: usize, f: usize, lambda: f64, mu: f64) -> f64 {
    assert!(f >= 1 && f < n);
    let lam: Vec<f64> = (0..=f).map(|i| (n - i) as f64 * lambda).collect();
    let mut rep = vec![0.0f64; f + 1];
    for (i, r) in rep.iter_mut().enumerate().skip(1) {
        *r = i as f64 * mu;
    }
    absorption_time_hours(&lam, &rep) / HOURS_PER_YEAR
}

/// Closed-form degraded-exposure during a migration window: probability
/// that at least one of `nodes` independent exponential failure clocks
/// (rate `lambda` per hour) fires while a topology event's block moves
/// are in flight for `hours` — `1 − e^{−n·λ·T}`. The elastic-topology
/// scenarios (`exp8`) report this next to the measured migration window
/// so the "wide stripes must survive frequent system events" claim has an
/// analytic anchor: the window is exactly the period during which a
/// coincident failure would find the system mid-move.
pub fn migration_exposure(nodes: usize, lambda: f64, hours: f64) -> f64 {
    assert!(lambda >= 0.0 && hours >= 0.0, "rates and windows are non-negative");
    1.0 - (-(nodes as f64) * lambda * hours).exp()
}

// ------------------------------------------------------- latent errors
//
// The scrub model (`sim::faults::replay_scrub`): latent sector errors
// arrive Poisson at rate λ_s per node, silent until a periodic scrub pass
// (period `T`) reads over them. The closed forms below are what the
// replay is differentially tested against (exp11, like exp7 vs the
// injected chain above).

/// Mean injection→detection dwell of a latent error under a periodic
/// scrub of period `T` hours.
///
/// Renewal-reward: an error arriving at uniform phase `u ∈ [0, T)` whose
/// node is verified at fixed offset `o` inside every pass waits
/// `o − u` (if `u < o`) or `T + o − u` — and the mean over `u` is exactly
/// `T/2`, independent of `o`. Holds whenever passes complete within the
/// period; a bandwidth-starved scrubber only dwells *longer*.
pub fn scrub_mean_dwell_hours(interval_hours: f64) -> f64 {
    assert!(interval_hours > 0.0);
    interval_hours / 2.0
}

/// Steady-state expected number of undetected latent errors per node:
/// Little's law over the detection queue — arrivals `λ_s`, mean dwell
/// `T/2` — so `λ_s · T/2`. (The count is Poisson-distributed: Poisson
/// arrivals with phase-determined service form an M/D/∞-type system.)
pub fn latent_undetected_mean(sector_rate_per_hour: f64, interval_hours: f64) -> f64 {
    assert!(sector_rate_per_hour >= 0.0);
    sector_rate_per_hour * scrub_mean_dwell_hours(interval_hours)
}

/// Probability some block of a `blocks`-wide stripe carries an undetected
/// latent error, each block accruing errors at `per_block_rate` per hour:
/// `1 − e^{−b·λ_b·T/2}` (Poisson field with the Little's-law mean).
pub fn latent_risk_fraction(blocks: usize, per_block_rate: f64, interval_hours: f64) -> f64 {
    assert!(per_block_rate >= 0.0);
    1.0 - (-(blocks as f64) * latent_undetected_mean(per_block_rate, interval_hours)).exp()
}

/// `P(X > k)` for `X ~ Binomial(m, p)`, by the stable iterative pmf
/// recurrence (no factorials; every term positive).
fn binomial_tail_gt(m: usize, p: f64, k: i64) -> f64 {
    if k < 0 {
        return 1.0;
    }
    if k as usize >= m || p <= 0.0 {
        return 0.0;
    }
    if p >= 1.0 {
        return 1.0; // X = m > k here
    }
    let q = 1.0 - p;
    let mut pmf = q.powi(m as i32);
    let mut cdf = pmf;
    for j in 0..k as usize {
        pmf *= (m - j) as f64 / (j + 1) as f64 * (p / q);
        cdf += pmf;
    }
    (1.0 - cdf).max(0.0)
}

/// Long-run fraction of time a stripe of width `n`, tolerance `f`, is
/// *unreadable counting silent corruption*: whole-node failures follow
/// the injector's birth–death chain (rates `lambda`/`mu` as in
/// [`unavailable_fraction`]) and, independently, each surviving block is
/// silently corrupt with probability `p_block` (from
/// [`latent_risk_fraction`]'s per-block factor `1 − e^{−λ_b·T/2}`). Loss
/// when failed + corrupt blocks exceed `f`:
/// `Σ_i π_i · P(Bin(n−i, p_block) > f−i)`.
///
/// This is where scrubbing couples to the code family: wider tolerance
/// `f` buries the same latent-error field deeper below the loss line.
pub fn latent_loss_fraction(n: usize, f: usize, lambda: f64, mu: f64, p_block: f64) -> f64 {
    assert!(f >= 1 && f < n);
    assert!((0.0..=1.0).contains(&p_block), "p_block is a probability");
    if lambda <= 0.0 || mu <= 0.0 {
        // node clocks disabled: corruption alone must exceed the tolerance
        return binomial_tail_gt(n, p_block, f as i64);
    }
    let (lam, rep) = injected_chain(n, lambda, mu);
    let pi = steady_state(&lam, &rep);
    let mut total = 0.0;
    for (i, &w) in pi.iter().enumerate() {
        if i > f {
            total += w;
        } else {
            total += w * binomial_tail_gt(n - i, p_block, (f - i) as i64);
        }
    }
    total.min(1.0)
}

/// The paper's closed-form product approximation
/// `MTTDL ≈ (μ·μ'^{f−1}) / Π_{i=0}^{f} λ_i` — kept for comparison.
pub fn mttdl_years_approx(n: usize, f: usize, c: f64, p: &MttdlParams) -> f64 {
    let lambda = p.lambda();
    let mut denom = 1.0;
    for i in 0..=f {
        denom *= (n - i) as f64 * lambda;
    }
    let numer = p.mu(c) * p.mu_prime().powi(f as i32 - 1);
    numer / denom / HOURS_PER_YEAR
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn absorption_time_single_state() {
        // one alive state, failure rate λ, no repair: T = 1/λ
        let t = absorption_time_hours(&[0.5], &[0.0]);
        assert!((t - 2.0).abs() < 1e-12);
    }

    #[test]
    fn absorption_time_two_states_no_repair() {
        // T0 = 1/λ0 + 1/λ1
        let t = absorption_time_hours(&[0.5, 0.25], &[0.0, 0.0]);
        assert!((t - 6.0).abs() < 1e-12);
    }

    #[test]
    fn repair_extends_lifetime() {
        let no_repair = absorption_time_hours(&[0.1, 0.1], &[0.0, 0.0]);
        let with_repair = absorption_time_hours(&[0.1, 0.1], &[0.0, 10.0]);
        assert!(with_repair > 10.0 * no_repair);
    }

    #[test]
    fn matches_closed_form_two_state() {
        // classic M/M absorption: states 0,1 alive; T0 known analytically:
        // T0 = (λ0+λ1+μ1)/(λ0 λ1)
        let (l0, l1, m1) = (0.3, 0.7, 5.0);
        let expect = (l0 + l1 + m1) / (l0 * l1);
        let got = absorption_time_hours(&[l0, l1], &[0.0, m1]);
        assert!((got - expect).abs() / expect < 1e-12);
    }

    #[test]
    fn mttdl_decreases_with_traffic() {
        let p = MttdlParams::default();
        let hi = mttdl_years(42, 7, 0.6, &p);
        let lo = mttdl_years(42, 7, 4.7, &p);
        assert!(hi > lo, "more recovery traffic ⇒ lower MTTDL");
    }

    #[test]
    fn mttdl_increases_with_tolerance() {
        let p = MttdlParams::default();
        let f7 = mttdl_years(42, 7, 1.0, &p);
        let f11 = mttdl_years(42, 11, 3.0, &p);
        assert!(f11 > f7 * 1e6, "longer chains dominate traffic penalty");
    }

    #[test]
    fn paper_ordering_table4() {
        // UniLRC C=0.6; ALRC C≈1.29; ULRC C≈1.10 (all f=7);
        // OLRC C≈3 but f=11.
        let p = MttdlParams::default();
        let uni = mttdl_years(42, 7, 0.6, &p);
        let alrc = mttdl_years(42, 7, 1.29, &p);
        let ulrc = mttdl_years(42, 7, 1.10, &p);
        let olrc = mttdl_years(42, 11, 3.0, &p);
        assert!(uni > ulrc && ulrc > alrc, "Table 4 ordering");
        assert!(olrc > 1e6 * uni, "OLRC dominates via larger d");
        // ratios in the paper's ballpark (2.02× / 1.71×)
        assert!(uni / alrc > 1.5 && uni / alrc < 3.0);
        assert!(uni / ulrc > 1.3 && uni / ulrc < 2.5);
    }

    #[test]
    fn steady_state_is_binomial_for_independent_nodes() {
        // n independent up/down nodes ⇒ π_i = C(n,i) p^i (1−p)^{n−i} with
        // p = λ/(λ+μ); check the chain reproduces it exactly for n = 4.
        let (n, lambda, mu) = (4usize, 0.3f64, 1.7f64);
        let (lam, rep) = injected_chain(n, lambda, mu);
        let pi = steady_state(&lam, &rep);
        assert!((pi.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        let p = lambda / (lambda + mu);
        let binom = [1.0, 4.0, 6.0, 4.0, 1.0];
        for (i, &c) in binom.iter().enumerate() {
            let expect = c * p.powi(i as i32) * (1.0 - p).powi((n - i) as i32);
            assert!((pi[i] - expect).abs() < 1e-12, "state {i}: {} vs {expect}", pi[i]);
        }
        let degraded = degraded_fraction(n, lambda, mu);
        assert!((degraded - (1.0 - (1.0 - p).powi(4))).abs() < 1e-12);
    }

    #[test]
    fn unavailable_fraction_monotone_in_tolerance() {
        let (n, lambda, mu) = (42usize, 1.0 / 1000.0, 1.0 / 10.0);
        let u7 = unavailable_fraction(n, 7, lambda, mu);
        let u11 = unavailable_fraction(n, 11, lambda, mu);
        assert!(u7 > u11, "more tolerance ⇒ less unavailable time: {u7} vs {u11}");
        assert!(u7 < degraded_fraction(n, lambda, mu));
    }

    #[test]
    fn mttdl_injected_grows_with_repair_rate_and_tolerance() {
        let slow = mttdl_injected_years(42, 7, 1.0 / 1000.0, 1.0 / 100.0);
        let fast = mttdl_injected_years(42, 7, 1.0 / 1000.0, 1.0 / 10.0);
        assert!(fast > slow * 100.0);
        let wide = mttdl_injected_years(42, 11, 1.0 / 1000.0, 1.0 / 10.0);
        assert!(wide > fast * 100.0);
    }

    #[test]
    fn migration_exposure_closed_form() {
        // hand-computed: 10 nodes, λ = 1/1000 h⁻¹, 2 h window
        let got = migration_exposure(10, 1e-3, 2.0);
        let expect = 1.0 - (-0.02f64).exp();
        assert!((got - expect).abs() < 1e-15);
        // bounds and monotonicity
        assert_eq!(migration_exposure(10, 1e-3, 0.0), 0.0);
        assert_eq!(migration_exposure(0, 1e-3, 5.0), 0.0);
        let short = migration_exposure(100, 1e-4, 0.5);
        let long = migration_exposure(100, 1e-4, 5.0);
        assert!((0.0..1.0).contains(&short) && short < long && long < 1.0);
        // small-rate limit ≈ n·λ·T
        let tiny = migration_exposure(4, 1e-9, 1.0);
        assert!((tiny - 4e-9).abs() / 4e-9 < 1e-6);
    }

    #[test]
    fn latent_field_closed_forms() {
        // Little's law and the Poisson field
        assert_eq!(scrub_mean_dwell_hours(24.0), 12.0);
        assert!((latent_undetected_mean(0.01, 24.0) - 0.12).abs() < 1e-12);
        // small-rate limit ≈ b·λ_b·T/2
        let tiny = latent_risk_fraction(42, 1e-9, 24.0);
        assert!((tiny - 42.0 * 1e-9 * 12.0).abs() / tiny < 1e-5);
        // monotone in every knob
        assert!(
            latent_risk_fraction(42, 1e-4, 48.0) > latent_risk_fraction(42, 1e-4, 24.0)
        );
        assert!(
            latent_risk_fraction(210, 1e-4, 24.0) > latent_risk_fraction(42, 1e-4, 24.0)
        );
    }

    #[test]
    fn binomial_tail_matches_hand_expansion() {
        // m = 3, p = 0.2: P(X > 1) = 3p²(1−p) + p³
        let p: f64 = 0.2;
        let expect = 3.0 * p * p * (1.0 - p) + p * p * p;
        assert!((binomial_tail_gt(3, p, 1) - expect).abs() < 1e-12);
        assert_eq!(binomial_tail_gt(3, p, -1), 1.0);
        assert_eq!(binomial_tail_gt(3, p, 3), 0.0);
        assert_eq!(binomial_tail_gt(3, 0.0, 1), 0.0);
        assert_eq!(binomial_tail_gt(3, 1.0, 1), 1.0);
    }

    #[test]
    fn latent_loss_reduces_to_unavailability_without_corruption() {
        let (n, lambda, mu) = (42usize, 1.0 / 1000.0, 1.0 / 10.0);
        for f in [7usize, 11] {
            let plain = unavailable_fraction(n, f, lambda, mu);
            let with0 = latent_loss_fraction(n, f, lambda, mu, 0.0);
            assert!((plain - with0).abs() < 1e-15, "f={f}: {plain} vs {with0}");
            // corruption only makes things worse
            let with = latent_loss_fraction(n, f, lambda, mu, 1e-3);
            assert!(with > with0);
        }
        // family coupling: wider tolerance buries the same field deeper
        let f7 = latent_loss_fraction(42, 7, lambda, mu, 1e-3);
        let f11 = latent_loss_fraction(42, 11, lambda, mu, 1e-3);
        assert!(f7 > f11 * 1e3, "{f7} vs {f11}");
    }

    #[test]
    fn latent_loss_hand_check_width_two() {
        // n = 2, f = 1: loss = π0·p² + π1·p + π2
        let (lambda, mu, p) = (0.3f64, 1.1f64, 0.05f64);
        let (lam, rep) = injected_chain(2, lambda, mu);
        let pi = steady_state(&lam, &rep);
        let expect = pi[0] * p * p + pi[1] * p + pi[2];
        let got = latent_loss_fraction(2, 1, lambda, mu, p);
        assert!((got - expect).abs() < 1e-14, "{got} vs {expect}");
    }

    #[test]
    fn exact_vs_approx_same_order_of_magnitude() {
        let p = MttdlParams::default();
        for f in [7usize, 11] {
            let e = mttdl_years(42, f, 1.0, &p);
            let a = mttdl_years_approx(42, f, 1.0, &p);
            let ratio = e / a;
            assert!(ratio > 0.05 && ratio < 20.0, "f={f}: exact={e:.3e} approx={a:.3e}");
        }
    }
}
