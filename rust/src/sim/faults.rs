//! Deterministic fault injection — the event generator behind
//! `experiments::exp7_faults` (§6 "frequent system events" and the Table 4
//! reliability claims, exercised on the *running* prototype instead of only
//! the closed-form Markov model in [`crate::analysis::markov`]).
//!
//! The model is the one the MTTDL analysis assumes, made executable:
//!
//! * every node alternates up/down with independent exponential clocks —
//!   `Exp(1/MTTF)` until the next failure, `Exp(1/MTTR)` until the
//!   replacement is back — seeded per node so the whole trace is a pure
//!   function of `(topology, config, seed)`;
//! * every cluster additionally carries a *correlated* failure clock
//!   (rack power / ToR switch events): a cluster failure takes all of its
//!   nodes down at once, and its repair brings back exactly the nodes it
//!   took (node-level clocks keep ticking independently — a node can stay
//!   down after its cluster heals, or fail again on its own).
//!
//! Traces are replayable: [`FaultTrace::to_text`] / [`FaultTrace::parse`]
//! round-trip bit-exact event times (hex `f64` bits), and
//! [`FaultTrace::digest`] is a stable FNV-1a fingerprint used by tests and
//! `exp7_faults` to assert *same seed ⇒ identical trace* across runs and
//! worker-thread counts.

use crate::placement::Topology;
use crate::prng::Prng;
use crate::sim::TokenBucket;
use std::collections::VecDeque;

/// Fault-model parameters (hours on the virtual clock). A rate of `0.0`
/// disables that event class entirely.
#[derive(Debug, Clone, Copy)]
pub struct FaultConfig {
    /// Mean time to failure of a single node (paper §6: 4 years).
    pub node_mttf_hours: f64,
    /// Mean time until a failed node's replacement is serviceable.
    pub node_mttr_hours: f64,
    /// Mean time between correlated whole-cluster events (0 = off).
    pub cluster_mttf_hours: f64,
    /// Mean duration of a whole-cluster outage.
    pub cluster_mttr_hours: f64,
    /// Mean time between latent sector errors per node (0 = off). Unlike
    /// node failures these are *silent*: the event corrupts one block's
    /// worth of data in place and nothing notices until a background
    /// scrub pass ([`replay_scrub`]) reads over it — so errors accumulate
    /// (a node can carry several at once) and the trace carries no paired
    /// repair event.
    pub sector_mtte_hours: f64,
    /// Trace length (hours).
    pub horizon_hours: f64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        // §6 Setup: 1/λ = 4 years; repairs land within a day; cluster-wide
        // events are rare (decade scale) and short (half a shift).
        FaultConfig {
            node_mttf_hours: 4.0 * 24.0 * 365.0,
            node_mttr_hours: 24.0,
            cluster_mttf_hours: 10.0 * 24.0 * 365.0,
            cluster_mttr_hours: 12.0,
            sector_mtte_hours: 0.0,
            horizon_hours: 10.0 * 24.0 * 365.0,
        }
    }
}

impl FaultConfig {
    /// Accelerated-aging preset for tests and benches: failures every few
    /// hundred virtual hours, so short horizons still see correlated
    /// bursts and multi-failure windows.
    pub fn accelerated() -> FaultConfig {
        FaultConfig {
            node_mttf_hours: 400.0,
            node_mttr_hours: 8.0,
            cluster_mttf_hours: 2_000.0,
            cluster_mttr_hours: 4.0,
            sector_mtte_hours: 0.0,
            horizon_hours: 2_000.0,
        }
    }
}

/// One injected event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// A single node fails (node-level clock).
    NodeFail(usize),
    /// A failed node's replacement is serviceable again.
    NodeRepair(usize),
    /// A correlated whole-cluster outage begins.
    ClusterFail(usize),
    /// The cluster outage ends.
    ClusterRepair(usize),
    /// A latent sector error silently corrupts one block's worth of data
    /// on the node. No availability transition — the node stays up and
    /// keeps serving; detection is the scrubber's job ([`replay_scrub`]).
    LatentError(usize),
}

impl FaultKind {
    /// Stable tag for digests, sort tie-breaks and the trace text format.
    pub fn tag(&self) -> u64 {
        match self {
            FaultKind::NodeFail(_) => 0,
            FaultKind::NodeRepair(_) => 1,
            FaultKind::ClusterFail(_) => 2,
            FaultKind::ClusterRepair(_) => 3,
            FaultKind::LatentError(_) => 4,
        }
    }

    /// Node or cluster index the event applies to.
    pub fn index(&self) -> usize {
        match self {
            FaultKind::NodeFail(i)
            | FaultKind::NodeRepair(i)
            | FaultKind::ClusterFail(i)
            | FaultKind::ClusterRepair(i)
            | FaultKind::LatentError(i) => *i,
        }
    }

    fn name(&self) -> &'static str {
        match self {
            FaultKind::NodeFail(_) => "node-fail",
            FaultKind::NodeRepair(_) => "node-repair",
            FaultKind::ClusterFail(_) => "cluster-fail",
            FaultKind::ClusterRepair(_) => "cluster-repair",
            FaultKind::LatentError(_) => "latent-error",
        }
    }
}

/// A timestamped fault event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    /// Virtual hours since trace start.
    pub at_hours: f64,
    pub kind: FaultKind,
}

/// A generated (or parsed) failure schedule, sorted by time.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultTrace {
    pub events: Vec<FaultEvent>,
    pub horizon_hours: f64,
    pub nodes: usize,
    pub clusters: usize,
}

/// Draw from `Exp(1/mean)` by inversion; `1 − u ∈ (0, 1]` keeps the log
/// finite for every PRNG output.
fn exp_sample(prng: &mut Prng, mean: f64) -> f64 {
    -mean * (1.0 - prng.gen_f64()).ln()
}

/// Alternate fail/repair draws for one node- or cluster-level stream
/// until the horizon, appending to `events`.
fn renewal(
    prng: &mut Prng,
    mttf: f64,
    mttr: f64,
    horizon: f64,
    idx: usize,
    node_level: bool,
    events: &mut Vec<FaultEvent>,
) {
    let mut t = 0.0f64;
    loop {
        t += exp_sample(prng, mttf);
        if t >= horizon {
            return;
        }
        let kind = if node_level {
            FaultKind::NodeFail(idx)
        } else {
            FaultKind::ClusterFail(idx)
        };
        events.push(FaultEvent { at_hours: t, kind });
        t += exp_sample(prng, mttr);
        if t >= horizon {
            return;
        }
        let kind = if node_level {
            FaultKind::NodeRepair(idx)
        } else {
            FaultKind::ClusterRepair(idx)
        };
        events.push(FaultEvent { at_hours: t, kind });
    }
}

/// FNV-1a step over one 64-bit word (byte-wise, little-endian).
pub fn digest_mix(mut h: u64, v: u64) -> u64 {
    for b in v.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// FNV-1a offset basis — seed for [`digest_mix`] chains.
pub const DIGEST_SEED: u64 = 0xCBF2_9CE4_8422_2325;

impl FaultTrace {
    /// Generate the schedule for `topo` — a pure function of
    /// `(topo, cfg, seed)`. Each node and each cluster draws from its own
    /// seeded stream, so the trace is independent of iteration order,
    /// thread counts, and everything else in the process.
    ///
    /// Fail/repair clocks follow **live membership**: only nodes that are
    /// not [`crate::placement::NodeState::Dead`] draw a stream (a drained
    /// node generates no events; a scaled-out node gets clocks keyed to
    /// its fresh stable id), and correlated cluster events only fire for
    /// clusters with at least one live member.
    pub fn generate(topo: &Topology, cfg: &FaultConfig, seed: u64) -> FaultTrace {
        let mut events: Vec<FaultEvent> = Vec::new();
        if cfg.node_mttf_hours > 0.0 && cfg.node_mttr_hours > 0.0 {
            for node in topo.live_nodes() {
                // splitmix64 seeding decorrelates consecutive stream ids
                let mut prng = Prng::new(seed.wrapping_add(1 + node as u64));
                renewal(
                    &mut prng,
                    cfg.node_mttf_hours,
                    cfg.node_mttr_hours,
                    cfg.horizon_hours,
                    node,
                    true,
                    &mut events,
                );
            }
        }
        if cfg.sector_mtte_hours > 0.0 {
            for node in topo.live_nodes() {
                // fresh seed namespace — latent clocks never perturb the
                // node/cluster streams, so enabling scrubbing leaves every
                // pre-existing trace's fail/repair schedule byte-identical
                let mut prng = Prng::new(seed.wrapping_add(2_000_003 + node as u64));
                let mut t = 0.0f64;
                loop {
                    t += exp_sample(&mut prng, cfg.sector_mtte_hours);
                    if t >= cfg.horizon_hours {
                        break;
                    }
                    events.push(FaultEvent { at_hours: t, kind: FaultKind::LatentError(node) });
                }
            }
        }
        if cfg.cluster_mttf_hours > 0.0 && cfg.cluster_mttr_hours > 0.0 {
            for cluster in 0..topo.clusters() {
                if !topo.nodes_of(cluster).iter().any(|&n| topo.is_live(n)) {
                    continue;
                }
                let mut prng = Prng::new(seed.wrapping_add(1_000_003 + cluster as u64));
                renewal(
                    &mut prng,
                    cfg.cluster_mttf_hours,
                    cfg.cluster_mttr_hours,
                    cfg.horizon_hours,
                    cluster,
                    false,
                    &mut events,
                );
            }
        }
        events.sort_by(|a, b| {
            a.at_hours
                .total_cmp(&b.at_hours)
                .then(a.kind.tag().cmp(&b.kind.tag()))
                .then(a.kind.index().cmp(&b.kind.index()))
        });
        FaultTrace {
            events,
            horizon_hours: cfg.horizon_hours,
            nodes: topo.total_nodes(),
            clusters: topo.clusters(),
        }
    }

    /// Stable fingerprint of the whole schedule (event times bit-exact).
    pub fn digest(&self) -> u64 {
        let mut h = DIGEST_SEED;
        h = digest_mix(h, self.horizon_hours.to_bits());
        h = digest_mix(h, self.nodes as u64);
        h = digest_mix(h, self.clusters as u64);
        for e in &self.events {
            h = digest_mix(h, e.at_hours.to_bits());
            h = digest_mix(h, e.kind.tag());
            h = digest_mix(h, e.kind.index() as u64);
        }
        h
    }

    /// Distinct node ids that fail at least once (directly or through a
    /// cluster event) — the support of predicted failure patterns. Cluster
    /// events expand through `topo`'s live membership (clusters are no
    /// longer uniform, so the old `node / nodes_per_cluster` arithmetic
    /// would misattribute members on elastic topologies).
    pub fn failing_nodes(&self, topo: &Topology) -> Vec<usize> {
        let mut seen = vec![false; self.nodes];
        for e in &self.events {
            match e.kind {
                FaultKind::NodeFail(n) => seen[n] = true,
                FaultKind::ClusterFail(c) => {
                    for &n in topo.nodes_of(c) {
                        if topo.is_live(n) {
                            seen[n] = true;
                        }
                    }
                }
                _ => {}
            }
        }
        (0..self.nodes).filter(|&n| seen[n]).collect()
    }

    /// Distinct cluster ids hit by a correlated event.
    pub fn failing_clusters(&self) -> Vec<usize> {
        let mut seen = vec![false; self.clusters];
        for e in &self.events {
            if let FaultKind::ClusterFail(c) = e.kind {
                seen[c] = true;
            }
        }
        (0..self.clusters).filter(|&c| seen[c]).collect()
    }

    /// Replayable text form: a header plus one event per line, event times
    /// serialized as hex `f64` bits so [`Self::parse`] round-trips exactly.
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        out.push_str("unilrc-fault-trace v1\n");
        out.push_str(&format!("nodes {}\n", self.nodes));
        out.push_str(&format!("clusters {}\n", self.clusters));
        out.push_str(&format!(
            "horizon {:016x} # {:.3} h\n",
            self.horizon_hours.to_bits(),
            self.horizon_hours
        ));
        for e in &self.events {
            out.push_str(&format!(
                "{:016x} {} {} # t={:.3} h\n",
                e.at_hours.to_bits(),
                e.kind.name(),
                e.kind.index(),
                e.at_hours
            ));
        }
        out
    }

    /// Parse [`Self::to_text`] output back into a trace.
    pub fn parse(text: &str) -> anyhow::Result<FaultTrace> {
        let mut lines = text.lines().map(|l| match l.find('#') {
            Some(i) => l[..i].trim(),
            None => l.trim(),
        });
        anyhow::ensure!(
            lines.next() == Some("unilrc-fault-trace v1"),
            "bad trace header (want unilrc-fault-trace v1)"
        );
        let mut field = |name: &str| -> anyhow::Result<String> {
            let line = lines.next().unwrap_or("");
            let (key, val) = line
                .split_once(' ')
                .ok_or_else(|| anyhow::anyhow!("expected `{name} <value>`, got {line:?}"))?;
            anyhow::ensure!(key == name, "expected `{name}`, got {key:?}");
            Ok(val.trim().to_string())
        };
        let nodes: usize = field("nodes")?.parse()?;
        let clusters: usize = field("clusters")?.parse()?;
        let horizon_hours = f64::from_bits(u64::from_str_radix(&field("horizon")?, 16)?);
        let mut events = Vec::new();
        for line in lines {
            if line.is_empty() {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            anyhow::ensure!(parts.len() == 3, "bad event line {line:?}");
            let at_hours = f64::from_bits(u64::from_str_radix(parts[0], 16)?);
            let idx: usize = parts[2].parse()?;
            let kind = match parts[1] {
                "node-fail" => FaultKind::NodeFail(idx),
                "node-repair" => FaultKind::NodeRepair(idx),
                "cluster-fail" => FaultKind::ClusterFail(idx),
                "cluster-repair" => FaultKind::ClusterRepair(idx),
                "latent-error" => FaultKind::LatentError(idx),
                other => anyhow::bail!("unknown event kind {other:?}"),
            };
            events.push(FaultEvent { at_hours, kind });
        }
        Ok(FaultTrace { events, horizon_hours, nodes, clusters })
    }
}

/// Effective node up/down state during trace replay, tracking *causes*
/// separately: a node is down while its node-level clock has it failed
/// **or** its cluster is in an outage, and only transitions when the
/// combined state flips — so a node-level repair during a cluster outage
/// does not resurrect the node early.
#[derive(Debug, Clone)]
pub struct DownState {
    node_cause: Vec<bool>,
    cluster_cause: Vec<bool>,
    /// node id → owning cluster (snapshot of the topology's map).
    cluster_of: Vec<usize>,
    /// cluster → live member node ids.
    members: Vec<Vec<usize>>,
}

impl DownState {
    pub fn new(topo: &Topology) -> DownState {
        DownState {
            node_cause: vec![false; topo.total_nodes()],
            cluster_cause: vec![false; topo.clusters()],
            cluster_of: (0..topo.total_nodes()).map(|n| topo.cluster_of_node(n)).collect(),
            members: (0..topo.clusters())
                .map(|c| topo.nodes_of(c).iter().copied().filter(|&n| topo.is_live(n)).collect())
                .collect(),
        }
    }

    pub fn is_down(&self, node: usize) -> bool {
        self.node_cause[node] || self.cluster_cause[self.cluster_of[node]]
    }

    /// Number of effectively-down nodes.
    pub fn down_count(&self) -> usize {
        (0..self.node_cause.len()).filter(|&n| self.is_down(n)).count()
    }

    /// Apply one event; returns `(node, now_down)` for every node whose
    /// *effective* state flipped (empty for redundant events, e.g. a
    /// node-level failure inside an ongoing cluster outage).
    pub fn apply(&mut self, kind: FaultKind) -> Vec<(usize, bool)> {
        let mut changed = Vec::new();
        match kind {
            FaultKind::NodeFail(n) | FaultKind::NodeRepair(n) => {
                let failing = matches!(kind, FaultKind::NodeFail(_));
                let before = self.is_down(n);
                self.node_cause[n] = failing;
                let after = self.is_down(n);
                if before != after {
                    changed.push((n, after));
                }
            }
            FaultKind::ClusterFail(c) | FaultKind::ClusterRepair(c) => {
                let failing = matches!(kind, FaultKind::ClusterFail(_));
                let was = self.cluster_cause[c];
                self.cluster_cause[c] = failing;
                if was != failing {
                    for &n in &self.members[c] {
                        let before = self.node_cause[n] || was;
                        let after = self.node_cause[n] || failing;
                        if before != after {
                            changed.push((n, after));
                        }
                    }
                }
            }
            // silent by definition: the node keeps serving, nothing flips
            FaultKind::LatentError(_) => {}
        }
        changed
    }
}

// ------------------------------------------------------------------ scrub
//
// Background scrubbing turns the latent-error stream into a repair
// schedule: a pass starts every `interval_hours`, reads `node_bytes` off
// every live node, and every byte it reads is admitted through a
// [`TokenBucket`] — the same fixed-cadence `drain` discipline the
// migration throttle uses, so scrub I/O competes for the background
// budget instead of bursting past foreground traffic. When a node's scan
// completes, every latent error injected on it so far is detected and
// repaired on the spot (the repair is a local-group XOR; detection
// latency, not rebuild time, dominates the dwell).

/// Scrub-pass policy. Time unit is the trace's virtual hour.
#[derive(Debug, Clone, Copy)]
pub struct ScrubConfig {
    /// Cadence of pass starts; the first pass starts at `interval_hours`.
    /// A pass that overruns its slot skips the missed starts (no backlog).
    pub interval_hours: f64,
    /// Bytes verified per node per pass.
    pub node_bytes: u64,
    /// Background-budget refill rate (bytes per virtual hour).
    pub rate_bytes_per_hour: f64,
    /// Token-bucket capacity (bytes).
    pub burst_bytes: f64,
    /// Admission cadence of the replay: budget is drained and spent at
    /// this granularity, and detections land on tick boundaries.
    pub tick_hours: f64,
}

impl ScrubConfig {
    /// Companion preset to [`FaultConfig::accelerated`]: hourly ticks,
    /// a pass every day, budget sized to finish a pass in a few ticks.
    pub fn accelerated(nodes: usize) -> ScrubConfig {
        let node_bytes = 1 << 20;
        ScrubConfig {
            interval_hours: 24.0,
            node_bytes,
            rate_bytes_per_hour: (nodes as u64 * node_bytes) as f64 / 4.0,
            burst_bytes: node_bytes as f64,
            tick_hours: 0.25,
        }
    }
}

/// One completed scrub pass (the replay's audit trail: summing `bytes`
/// across passes must reproduce [`ScrubReport::scrubbed_bytes`]).
#[derive(Debug, Clone, PartialEq)]
pub struct ScrubPass {
    pub started_hours: f64,
    pub finished_hours: f64,
    /// Bytes read by this pass.
    pub bytes: u64,
    /// Latent errors this pass detected (and repaired).
    pub detected: usize,
    /// Node visit order chosen at pass start — stripes-at-risk first:
    /// nodes whose cluster currently has a down member lead the queue
    /// (under one-group-one-cluster placement a down co-cluster node
    /// means this node's local groups are already one failure deep).
    pub order: Vec<usize>,
}

/// Aggregate outcome of [`replay_scrub`] — a pure function of
/// `(topo, trace, config)`, so every field is digest-stable.
#[derive(Debug, Clone, Default)]
pub struct ScrubReport {
    /// Latent errors injected by the trace.
    pub injected: usize,
    /// Errors found (and repaired) by a scrub scan.
    pub detected: usize,
    /// Errors wiped by a node *replacement* (the node-level repair
    /// rebuilds content from peers, clearing its latent errors; a
    /// cluster repair is a power event and clears nothing).
    pub cleared_by_rebuild: usize,
    /// Errors still undetected when the horizon ends.
    pub undetected_at_horizon: usize,
    /// Mean injection→detection delay over scrub-detected errors.
    pub mean_dwell_hours: f64,
    /// Bytes granted by the budget (Σ of per-tick drains).
    pub granted_bytes: u64,
    /// Bytes actually read by scans — never exceeds `granted_bytes`.
    pub scrubbed_bytes: u64,
    /// ∫ (undetected errors) dt — the Little's-law meter the closed-form
    /// chain ([`crate::analysis::markov::latent_undetected_mean`])
    /// predicts as `λ̂ · T/2` per node.
    pub undetected_block_hours: f64,
    /// Like `undetected_block_hours`, restricted to errors on nodes whose
    /// cluster has another member down — undetected corruption in a local
    /// group that is already degraded (the scrub scheduler's priority
    /// signal, integrated).
    pub at_risk_block_hours: f64,
    pub passes: Vec<ScrubPass>,
}

impl ScrubReport {
    /// Stable FNV-1a fingerprint over every counter, meter, and pass
    /// record (times bit-exact) — the exp11 determinism witness.
    pub fn digest(&self) -> u64 {
        let mut h = DIGEST_SEED;
        for v in [
            self.injected as u64,
            self.detected as u64,
            self.cleared_by_rebuild as u64,
            self.undetected_at_horizon as u64,
            self.granted_bytes,
            self.scrubbed_bytes,
            self.mean_dwell_hours.to_bits(),
            self.undetected_block_hours.to_bits(),
            self.at_risk_block_hours.to_bits(),
        ] {
            h = digest_mix(h, v);
        }
        for p in &self.passes {
            h = digest_mix(h, p.started_hours.to_bits());
            h = digest_mix(h, p.finished_hours.to_bits());
            h = digest_mix(h, p.bytes);
            h = digest_mix(h, p.detected as u64);
            for &n in &p.order {
                h = digest_mix(h, n as u64);
            }
        }
        h
    }
}

/// In-flight pass state: nodes still to scan (front = current), with the
/// byte position inside the front node.
struct PassState {
    started: f64,
    queue: VecDeque<usize>,
    /// Bytes left on the front node.
    remaining: u64,
    bytes: u64,
    detected: usize,
    order: Vec<usize>,
}

/// Replay `trace` through the periodic scrubber. Deterministic — no
/// randomness beyond the trace itself. Semantics:
///
/// * budget is drained every `tick_hours` while a pass is in flight and
///   spent front-to-back along the pass's priority order; a grant that
///   cannot be spent (every remaining node is down) is forfeited —
///   use-it-or-lose-it, exactly like the migration throttle's
///   fixed-cadence admission;
/// * a down node cannot be scanned; it rotates to the back of the queue
///   and the pass completes only once every queued node has been read;
/// * a node-level repair (replacement) clears the node's latent errors
///   (`cleared_by_rebuild`); cluster repairs clear nothing;
/// * detections land on the tick boundary where the node's scan finishes.
pub fn replay_scrub(topo: &Topology, trace: &FaultTrace, cfg: &ScrubConfig) -> ScrubReport {
    assert!(cfg.interval_hours > 0.0, "scrub interval must be positive");
    assert!(cfg.tick_hours > 0.0, "scrub tick must be positive");
    assert!(cfg.node_bytes > 0, "scrub must read something per node");
    let live: Vec<usize> = (0..topo.total_nodes()).filter(|&n| topo.is_live(n)).collect();
    let members: Vec<Vec<usize>> = (0..topo.clusters())
        .map(|c| topo.nodes_of(c).iter().copied().filter(|&n| topo.is_live(n)).collect())
        .collect();
    let cluster_of: Vec<usize> =
        (0..topo.total_nodes()).map(|n| topo.cluster_of_node(n)).collect();

    let mut down = DownState::new(topo);
    let mut pending: Vec<Vec<f64>> = vec![Vec::new(); topo.total_nodes()];
    let mut bucket = TokenBucket::new(cfg.rate_bytes_per_hour, cfg.burst_bytes);
    let mut report = ScrubReport::default();
    let mut dwell_sum = 0.0f64;
    let mut pass: Option<PassState> = None;
    let mut next_start = cfg.interval_hours;
    let mut ei = 0usize;

    let ticks = (trace.horizon_hours / cfg.tick_hours).ceil() as u64;
    for k in 1..=ticks {
        let now = (k as f64 * cfg.tick_hours).min(trace.horizon_hours);

        // apply every trace event up to this tick, in schedule order
        while ei < trace.events.len() && trace.events[ei].at_hours <= now {
            let ev = trace.events[ei];
            ei += 1;
            match ev.kind {
                FaultKind::LatentError(n) => {
                    report.injected += 1;
                    pending[n].push(ev.at_hours);
                }
                FaultKind::NodeRepair(n) => {
                    report.cleared_by_rebuild += pending[n].len();
                    pending[n].clear();
                    down.apply(ev.kind);
                }
                _ => {
                    down.apply(ev.kind);
                }
            }
        }

        // per-cluster down counts drive both the risk meter and (at pass
        // start) the scan priority
        let down_in: Vec<usize> =
            members.iter().map(|m| m.iter().filter(|&&n| down.is_down(n)).count()).collect();

        if pass.is_none() && now >= next_start {
            let mut order = live.clone();
            // stripes-at-risk first: most down co-cluster members, then
            // stable node id so equal-risk ties are deterministic
            order.sort_by_key(|&n| {
                let c = cluster_of[n];
                let peers_down = down_in[c] - usize::from(down.is_down(n));
                (usize::MAX - peers_down, n)
            });
            pass = Some(PassState {
                started: now,
                queue: order.iter().copied().collect(),
                remaining: cfg.node_bytes,
                bytes: 0,
                detected: 0,
                order,
            });
        }

        if let Some(p) = pass.as_mut() {
            let mut grant = bucket.drain(now) as u64;
            report.granted_bytes += grant;
            let mut skips = 0usize;
            while grant > 0 {
                let Some(&n) = p.queue.front() else { break };
                if down.is_down(n) {
                    // defer: rotate to the back and restart its scan from
                    // scratch when it comes around (an interrupted verify
                    // can't be trusted); stall the tick once every
                    // remaining node has been tried
                    p.queue.rotate_left(1);
                    p.remaining = cfg.node_bytes;
                    skips += 1;
                    if skips >= p.queue.len() {
                        break;
                    }
                    continue;
                }
                skips = 0;
                let take = grant.min(p.remaining);
                grant -= take;
                p.remaining -= take;
                p.bytes += take;
                report.scrubbed_bytes += take;
                if p.remaining == 0 {
                    // node fully verified: every error injected so far on
                    // it is detected and repaired now
                    report.detected += pending[n].len();
                    p.detected += pending[n].len();
                    for &born in &pending[n] {
                        dwell_sum += now - born;
                    }
                    pending[n].clear();
                    p.queue.pop_front();
                    p.remaining = cfg.node_bytes;
                }
            }
            if p.queue.is_empty() {
                report.passes.push(ScrubPass {
                    started_hours: p.started,
                    finished_hours: now,
                    bytes: p.bytes,
                    detected: p.detected,
                    order: std::mem::take(&mut p.order),
                });
                pass = None;
                // next slot strictly in the future: overruns skip starts
                while next_start <= now {
                    next_start += cfg.interval_hours;
                }
            }
        }

        // occupancy integrals over this tick (state as of the tick)
        let dt = cfg.tick_hours.min(trace.horizon_hours - (k - 1) as f64 * cfg.tick_hours);
        for &n in &live {
            let cnt = pending[n].len();
            if cnt == 0 {
                continue;
            }
            report.undetected_block_hours += cnt as f64 * dt;
            let peers_down = down_in[cluster_of[n]] - usize::from(down.is_down(n));
            if peers_down > 0 {
                report.at_risk_block_hours += cnt as f64 * dt;
            }
        }
    }

    report.undetected_at_horizon = pending.iter().map(|p| p.len()).sum();
    report.mean_dwell_hours =
        if report.detected > 0 { dwell_sum / report.detected as f64 } else { 0.0 };
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo() -> Topology {
        Topology::new(4, 5)
    }

    #[test]
    fn same_seed_same_digest() {
        let cfg = FaultConfig::accelerated();
        let a = FaultTrace::generate(&topo(), &cfg, 42);
        let b = FaultTrace::generate(&topo(), &cfg, 42);
        assert_eq!(a, b);
        assert_eq!(a.digest(), b.digest());
        let c = FaultTrace::generate(&topo(), &cfg, 43);
        assert_ne!(a.digest(), c.digest());
    }

    #[test]
    fn events_sorted_and_within_horizon() {
        let cfg = FaultConfig::accelerated();
        let t = FaultTrace::generate(&topo(), &cfg, 7);
        assert!(!t.events.is_empty());
        for w in t.events.windows(2) {
            assert!(w[0].at_hours <= w[1].at_hours);
        }
        assert!(t.events.iter().all(|e| e.at_hours > 0.0 && e.at_hours < cfg.horizon_hours));
    }

    #[test]
    fn event_count_tracks_rates() {
        let cfg = FaultConfig {
            node_mttf_hours: 100.0,
            node_mttr_hours: 10.0,
            cluster_mttf_hours: 0.0,
            cluster_mttr_hours: 0.0,
            sector_mtte_hours: 0.0,
            horizon_hours: 10_000.0,
        };
        let t = FaultTrace::generate(&topo(), &cfg, 1);
        let fails =
            t.events.iter().filter(|e| matches!(e.kind, FaultKind::NodeFail(_))).count() as f64;
        // 20 nodes × horizon/(mttf+mttr) ≈ 1818 expected failures
        let expect = 20.0 * 10_000.0 / 110.0;
        assert!((fails - expect).abs() / expect < 0.15, "{fails} vs {expect}");
        assert!(t.failing_clusters().is_empty());
    }

    #[test]
    fn zero_rates_disable_event_classes() {
        let cfg = FaultConfig {
            node_mttf_hours: 0.0,
            node_mttr_hours: 0.0,
            cluster_mttf_hours: 50.0,
            cluster_mttr_hours: 5.0,
            sector_mtte_hours: 0.0,
            horizon_hours: 1_000.0,
        };
        let t = FaultTrace::generate(&topo(), &cfg, 9);
        assert!(t.events.iter().all(|e| e.kind.tag() >= 2));
        assert!(!t.failing_clusters().is_empty());
    }

    #[test]
    fn text_roundtrip_is_exact() {
        let cfg = FaultConfig::accelerated();
        let t = FaultTrace::generate(&topo(), &cfg, 5);
        let parsed = FaultTrace::parse(&t.to_text()).unwrap();
        assert_eq!(t, parsed);
        assert_eq!(t.digest(), parsed.digest());
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FaultTrace::parse("nope").is_err());
        assert!(FaultTrace::parse("unilrc-fault-trace v1\nnodes x\n").is_err());
        let bad_kind = "unilrc-fault-trace v1\nnodes 1\nclusters 1\nhorizon \
                        4059000000000000\n3ff0000000000000 node-melt 0\n";
        assert!(FaultTrace::parse(bad_kind).is_err());
    }

    #[test]
    fn down_state_tracks_causes() {
        let mut s = DownState::new(&Topology::new(2, 3));
        assert_eq!(s.apply(FaultKind::NodeFail(1)), vec![(1, true)]);
        // cluster 0 outage: nodes 0 and 2 flip; node 1 already down
        assert_eq!(s.apply(FaultKind::ClusterFail(0)), vec![(0, true), (2, true)]);
        // node-level repair during the outage: no effective change
        assert_eq!(s.apply(FaultKind::NodeRepair(1)), vec![]);
        assert_eq!(s.down_count(), 3);
        // outage ends: every cluster-0 node comes back (node 1 repaired above)
        let mut back = s.apply(FaultKind::ClusterRepair(0));
        back.sort_unstable();
        assert_eq!(back, vec![(0, false), (1, false), (2, false)]);
        assert_eq!(s.down_count(), 0);
    }

    #[test]
    fn failing_nodes_includes_cluster_members() {
        let cfg = FaultConfig {
            node_mttf_hours: 0.0,
            node_mttr_hours: 0.0,
            cluster_mttf_hours: 100.0,
            cluster_mttr_hours: 10.0,
            sector_mtte_hours: 0.0,
            horizon_hours: 1_000.0,
        };
        let topo = Topology::new(2, 3);
        let t = FaultTrace::generate(&topo, &cfg, 3);
        let nodes = t.failing_nodes(&topo);
        for c in t.failing_clusters() {
            for &n in topo.nodes_of(c) {
                assert!(nodes.contains(&n));
            }
        }
    }

    #[test]
    fn clocks_follow_live_membership() {
        use crate::placement::NodeState;
        // failure interarrival ≪ horizon, so every live node's stream is
        // mathematically certain (P ≈ 1 − e⁻⁴⁰) to fire at least once
        let cfg = FaultConfig {
            node_mttf_hours: 50.0,
            node_mttr_hours: 5.0,
            cluster_mttf_hours: 0.0,
            cluster_mttr_hours: 0.0,
            sector_mtte_hours: 0.0,
            horizon_hours: 2_000.0,
        };
        let mut topo = Topology::new(2, 3);
        let dead = 1usize;
        topo.set_state(dead, NodeState::Dead);
        let added = topo.add_node(0);
        let t = FaultTrace::generate(&topo, &cfg, 77);
        // the dead node draws no clock; the scaled-out node draws its own
        assert!(t.events.iter().all(|e| {
            !matches!(e.kind, FaultKind::NodeFail(n) | FaultKind::NodeRepair(n) if n == dead)
        }));
        assert!(t
            .events
            .iter()
            .any(|e| matches!(e.kind, FaultKind::NodeFail(n) if n == added)));
        // a cluster event never flips the dead node's effective state
        let mut s = DownState::new(&topo);
        let flipped = s.apply(FaultKind::ClusterFail(0));
        assert!(flipped.iter().all(|&(n, _)| n != dead));
        assert!(flipped.iter().any(|&(n, down)| n == added && down));
        // draining nodes still tick (they hold readable data until dead)
        let mut topo2 = Topology::new(1, 2);
        topo2.set_state(0, NodeState::Draining);
        let t2 = FaultTrace::generate(&topo2, &cfg, 77);
        assert!(t2
            .events
            .iter()
            .any(|e| matches!(e.kind, FaultKind::NodeFail(0))));
    }

    #[test]
    fn digest_mix_is_order_sensitive() {
        let a = digest_mix(digest_mix(DIGEST_SEED, 1), 2);
        let b = digest_mix(digest_mix(DIGEST_SEED, 2), 1);
        assert_ne!(a, b);
    }

    fn latent_only(mtte: f64, horizon: f64) -> FaultConfig {
        FaultConfig {
            node_mttf_hours: 0.0,
            node_mttr_hours: 0.0,
            cluster_mttf_hours: 0.0,
            cluster_mttr_hours: 0.0,
            sector_mtte_hours: mtte,
            horizon_hours: horizon,
        }
    }

    #[test]
    fn latent_stream_is_seeded_and_additive() {
        // enabling latent errors must not perturb the fail/repair schedule
        let base = FaultConfig::accelerated();
        let with = FaultConfig { sector_mtte_hours: 100.0, ..base };
        let a = FaultTrace::generate(&topo(), &base, 42);
        let b = FaultTrace::generate(&topo(), &with, 42);
        let b_sans_latent: Vec<FaultEvent> = b
            .events
            .iter()
            .copied()
            .filter(|e| !matches!(e.kind, FaultKind::LatentError(_)))
            .collect();
        assert_eq!(a.events, b_sans_latent);
        assert!(b.events.iter().any(|e| matches!(e.kind, FaultKind::LatentError(_))));
        // and the count tracks the rate: 20 nodes × 2000 h / 100 h ≈ 400
        let latents =
            b.events.iter().filter(|e| matches!(e.kind, FaultKind::LatentError(_))).count() as f64;
        let expect = 20.0 * 2_000.0 / 100.0;
        assert!((latents - expect).abs() / expect < 0.15, "{latents} vs {expect}");
    }

    #[test]
    fn latent_events_roundtrip_and_never_flip_state() {
        let t = FaultTrace::generate(&topo(), &latent_only(50.0, 500.0), 3);
        assert!(!t.events.is_empty());
        let parsed = FaultTrace::parse(&t.to_text()).unwrap();
        assert_eq!(t, parsed);
        let mut s = DownState::new(&topo());
        for e in &t.events {
            assert_eq!(s.apply(e.kind), vec![], "latent errors are silent");
        }
        assert_eq!(s.down_count(), 0);
    }

    #[test]
    fn scrub_detects_everything_with_ample_budget() {
        let topo = topo();
        let trace = FaultTrace::generate(&topo, &latent_only(40.0, 1_000.0), 11);
        let mut cfg = ScrubConfig::accelerated(20);
        cfg.rate_bytes_per_hour = 1e12; // budget never binds
        cfg.burst_bytes = 1e12;
        let r = replay_scrub(&topo, &trace, &cfg);
        assert!(r.injected > 100, "need a busy trace, got {}", r.injected);
        assert_eq!(r.detected + r.undetected_at_horizon, r.injected);
        assert_eq!(r.cleared_by_rebuild, 0);
        // only errors born after the last pass can be outstanding
        assert!(r.undetected_at_horizon < r.injected / 10);
        // unthrottled passes finish the tick they start
        for p in &r.passes {
            assert_eq!(p.started_hours, p.finished_hours);
            assert_eq!(p.bytes, 20 * cfg.node_bytes);
        }
        // dwell ≈ interval/2 (uniform arrival within the scrub period)
        let expect = cfg.interval_hours / 2.0;
        assert!(
            (r.mean_dwell_hours - expect).abs() / expect < 0.25,
            "dwell {} vs {expect}",
            r.mean_dwell_hours
        );
    }

    #[test]
    fn scrub_replay_is_deterministic() {
        let topo = topo();
        let cfg = FaultConfig { sector_mtte_hours: 60.0, ..FaultConfig::accelerated() };
        let trace = FaultTrace::generate(&topo, &cfg, 9);
        let scfg = ScrubConfig::accelerated(20);
        let a = replay_scrub(&topo, &trace, &scfg);
        let b = replay_scrub(&topo, &trace, &scfg);
        assert_eq!(a.digest(), b.digest());
        let c = replay_scrub(&topo, &FaultTrace::generate(&topo, &cfg, 10), &scfg);
        assert_ne!(a.digest(), c.digest());
    }

    #[test]
    fn scrub_never_reads_past_its_grants_and_passes_sum() {
        let topo = topo();
        let cfg = FaultConfig { sector_mtte_hours: 30.0, ..FaultConfig::accelerated() };
        let trace = FaultTrace::generate(&topo, &cfg, 5);
        // starved budget: a pass takes many ticks, so grants bind
        let scfg = ScrubConfig {
            rate_bytes_per_hour: 2.0 * (1 << 20) as f64,
            burst_bytes: (1 << 20) as f64,
            ..ScrubConfig::accelerated(20)
        };
        let r = replay_scrub(&topo, &trace, &scfg);
        assert!(r.scrubbed_bytes <= r.granted_bytes, "{r:?}");
        let from_trace: u64 = r.passes.iter().map(|p| p.bytes).sum();
        assert_eq!(from_trace, r.scrubbed_bytes, "pass audit trail must match the meter");
        assert!(r.detected > 0);
    }

    #[test]
    fn node_replacement_clears_latent_errors() {
        // node 0 accrues errors, fails, and is replaced before any scrub
        // pass: the rebuild wipes them, the scrubber never sees them
        let topo = Topology::new(1, 2);
        let trace = FaultTrace {
            events: vec![
                FaultEvent { at_hours: 1.0, kind: FaultKind::LatentError(0) },
                FaultEvent { at_hours: 2.0, kind: FaultKind::LatentError(0) },
                FaultEvent { at_hours: 3.0, kind: FaultKind::NodeFail(0) },
                FaultEvent { at_hours: 5.0, kind: FaultKind::NodeRepair(0) },
            ],
            horizon_hours: 40.0,
            nodes: 2,
            clusters: 1,
        };
        let mut scfg = ScrubConfig::accelerated(2);
        scfg.rate_bytes_per_hour = 1e12;
        scfg.burst_bytes = 1e12;
        let r = replay_scrub(&topo, &trace, &scfg);
        assert_eq!(r.cleared_by_rebuild, 2);
        assert_eq!(r.detected, 0);
        assert_eq!(r.undetected_at_horizon, 0);
    }

    #[test]
    fn scrub_prioritizes_clusters_with_a_down_member() {
        // cluster 1 (nodes 3..6) has a down node when the first pass
        // starts: its healthy members must lead the scan order
        let topo = Topology::new(3, 3);
        let trace = FaultTrace {
            events: vec![
                FaultEvent { at_hours: 1.0, kind: FaultKind::NodeFail(4) },
                // repaired after the pass starts: the scan defers node 4
                // and completes once the replacement lands
                FaultEvent { at_hours: 26.0, kind: FaultKind::NodeRepair(4) },
            ],
            horizon_hours: 40.0,
            nodes: 9,
            clusters: 3,
        };
        let scfg = ScrubConfig::accelerated(9);
        let r = replay_scrub(&topo, &trace, &scfg);
        assert!(!r.passes.is_empty());
        let order = &r.passes[0].order;
        // at-risk peers of the down node 4 come first (then node 4 itself
        // sorts by id among the zero-risk rest — it has no *other* down
        // peer in its cluster)
        assert_eq!(&order[..2], &[3, 5], "at-risk peers must lead: {order:?}");
    }

    #[test]
    fn at_risk_meter_requires_both_corruption_and_a_down_peer() {
        // latent error on node 1; its co-cluster node 0 is down for 10 h
        let topo = Topology::new(1, 3);
        let trace = FaultTrace {
            events: vec![
                FaultEvent { at_hours: 1.0, kind: FaultKind::LatentError(1) },
                FaultEvent { at_hours: 2.0, kind: FaultKind::NodeFail(0) },
                FaultEvent { at_hours: 12.0, kind: FaultKind::NodeRepair(0) },
            ],
            horizon_hours: 20.0,
            nodes: 3,
            clusters: 1,
        };
        // no pass ever fires inside the horizon: pure exposure metering
        let scfg = ScrubConfig { interval_hours: 1_000.0, ..ScrubConfig::accelerated(3) };
        let r = replay_scrub(&topo, &trace, &scfg);
        assert_eq!(r.detected, 0);
        assert_eq!(r.undetected_at_horizon, 1);
        // undetected for 19 h, at risk only while node 0 was down (~10 h)
        assert!((r.undetected_block_hours - 19.0).abs() < 0.6, "{}", r.undetected_block_hours);
        assert!((r.at_risk_block_hours - 10.0).abs() < 0.6, "{}", r.at_risk_block_hours);
        assert!(r.at_risk_block_hours < r.undetected_block_hours);
    }
}
