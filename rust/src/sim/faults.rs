//! Deterministic fault injection — the event generator behind
//! `experiments::exp7_faults` (§6 "frequent system events" and the Table 4
//! reliability claims, exercised on the *running* prototype instead of only
//! the closed-form Markov model in [`crate::analysis::markov`]).
//!
//! The model is the one the MTTDL analysis assumes, made executable:
//!
//! * every node alternates up/down with independent exponential clocks —
//!   `Exp(1/MTTF)` until the next failure, `Exp(1/MTTR)` until the
//!   replacement is back — seeded per node so the whole trace is a pure
//!   function of `(topology, config, seed)`;
//! * every cluster additionally carries a *correlated* failure clock
//!   (rack power / ToR switch events): a cluster failure takes all of its
//!   nodes down at once, and its repair brings back exactly the nodes it
//!   took (node-level clocks keep ticking independently — a node can stay
//!   down after its cluster heals, or fail again on its own).
//!
//! Traces are replayable: [`FaultTrace::to_text`] / [`FaultTrace::parse`]
//! round-trip bit-exact event times (hex `f64` bits), and
//! [`FaultTrace::digest`] is a stable FNV-1a fingerprint used by tests and
//! `exp7_faults` to assert *same seed ⇒ identical trace* across runs and
//! worker-thread counts.

use crate::placement::Topology;
use crate::prng::Prng;

/// Fault-model parameters (hours on the virtual clock). A rate of `0.0`
/// disables that event class entirely.
#[derive(Debug, Clone, Copy)]
pub struct FaultConfig {
    /// Mean time to failure of a single node (paper §6: 4 years).
    pub node_mttf_hours: f64,
    /// Mean time until a failed node's replacement is serviceable.
    pub node_mttr_hours: f64,
    /// Mean time between correlated whole-cluster events (0 = off).
    pub cluster_mttf_hours: f64,
    /// Mean duration of a whole-cluster outage.
    pub cluster_mttr_hours: f64,
    /// Trace length (hours).
    pub horizon_hours: f64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        // §6 Setup: 1/λ = 4 years; repairs land within a day; cluster-wide
        // events are rare (decade scale) and short (half a shift).
        FaultConfig {
            node_mttf_hours: 4.0 * 24.0 * 365.0,
            node_mttr_hours: 24.0,
            cluster_mttf_hours: 10.0 * 24.0 * 365.0,
            cluster_mttr_hours: 12.0,
            horizon_hours: 10.0 * 24.0 * 365.0,
        }
    }
}

impl FaultConfig {
    /// Accelerated-aging preset for tests and benches: failures every few
    /// hundred virtual hours, so short horizons still see correlated
    /// bursts and multi-failure windows.
    pub fn accelerated() -> FaultConfig {
        FaultConfig {
            node_mttf_hours: 400.0,
            node_mttr_hours: 8.0,
            cluster_mttf_hours: 2_000.0,
            cluster_mttr_hours: 4.0,
            horizon_hours: 2_000.0,
        }
    }
}

/// One injected event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// A single node fails (node-level clock).
    NodeFail(usize),
    /// A failed node's replacement is serviceable again.
    NodeRepair(usize),
    /// A correlated whole-cluster outage begins.
    ClusterFail(usize),
    /// The cluster outage ends.
    ClusterRepair(usize),
}

impl FaultKind {
    /// Stable tag for digests, sort tie-breaks and the trace text format.
    pub fn tag(&self) -> u64 {
        match self {
            FaultKind::NodeFail(_) => 0,
            FaultKind::NodeRepair(_) => 1,
            FaultKind::ClusterFail(_) => 2,
            FaultKind::ClusterRepair(_) => 3,
        }
    }

    /// Node or cluster index the event applies to.
    pub fn index(&self) -> usize {
        match self {
            FaultKind::NodeFail(i)
            | FaultKind::NodeRepair(i)
            | FaultKind::ClusterFail(i)
            | FaultKind::ClusterRepair(i) => *i,
        }
    }

    fn name(&self) -> &'static str {
        match self {
            FaultKind::NodeFail(_) => "node-fail",
            FaultKind::NodeRepair(_) => "node-repair",
            FaultKind::ClusterFail(_) => "cluster-fail",
            FaultKind::ClusterRepair(_) => "cluster-repair",
        }
    }
}

/// A timestamped fault event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    /// Virtual hours since trace start.
    pub at_hours: f64,
    pub kind: FaultKind,
}

/// A generated (or parsed) failure schedule, sorted by time.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultTrace {
    pub events: Vec<FaultEvent>,
    pub horizon_hours: f64,
    pub nodes: usize,
    pub clusters: usize,
}

/// Draw from `Exp(1/mean)` by inversion; `1 − u ∈ (0, 1]` keeps the log
/// finite for every PRNG output.
fn exp_sample(prng: &mut Prng, mean: f64) -> f64 {
    -mean * (1.0 - prng.gen_f64()).ln()
}

/// Alternate fail/repair draws for one node- or cluster-level stream
/// until the horizon, appending to `events`.
fn renewal(
    prng: &mut Prng,
    mttf: f64,
    mttr: f64,
    horizon: f64,
    idx: usize,
    node_level: bool,
    events: &mut Vec<FaultEvent>,
) {
    let mut t = 0.0f64;
    loop {
        t += exp_sample(prng, mttf);
        if t >= horizon {
            return;
        }
        let kind = if node_level {
            FaultKind::NodeFail(idx)
        } else {
            FaultKind::ClusterFail(idx)
        };
        events.push(FaultEvent { at_hours: t, kind });
        t += exp_sample(prng, mttr);
        if t >= horizon {
            return;
        }
        let kind = if node_level {
            FaultKind::NodeRepair(idx)
        } else {
            FaultKind::ClusterRepair(idx)
        };
        events.push(FaultEvent { at_hours: t, kind });
    }
}

/// FNV-1a step over one 64-bit word (byte-wise, little-endian).
pub fn digest_mix(mut h: u64, v: u64) -> u64 {
    for b in v.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// FNV-1a offset basis — seed for [`digest_mix`] chains.
pub const DIGEST_SEED: u64 = 0xCBF2_9CE4_8422_2325;

impl FaultTrace {
    /// Generate the schedule for `topo` — a pure function of
    /// `(topo, cfg, seed)`. Each node and each cluster draws from its own
    /// seeded stream, so the trace is independent of iteration order,
    /// thread counts, and everything else in the process.
    ///
    /// Fail/repair clocks follow **live membership**: only nodes that are
    /// not [`crate::placement::NodeState::Dead`] draw a stream (a drained
    /// node generates no events; a scaled-out node gets clocks keyed to
    /// its fresh stable id), and correlated cluster events only fire for
    /// clusters with at least one live member.
    pub fn generate(topo: &Topology, cfg: &FaultConfig, seed: u64) -> FaultTrace {
        let mut events: Vec<FaultEvent> = Vec::new();
        if cfg.node_mttf_hours > 0.0 && cfg.node_mttr_hours > 0.0 {
            for node in topo.live_nodes() {
                // splitmix64 seeding decorrelates consecutive stream ids
                let mut prng = Prng::new(seed.wrapping_add(1 + node as u64));
                renewal(
                    &mut prng,
                    cfg.node_mttf_hours,
                    cfg.node_mttr_hours,
                    cfg.horizon_hours,
                    node,
                    true,
                    &mut events,
                );
            }
        }
        if cfg.cluster_mttf_hours > 0.0 && cfg.cluster_mttr_hours > 0.0 {
            for cluster in 0..topo.clusters() {
                if !topo.nodes_of(cluster).iter().any(|&n| topo.is_live(n)) {
                    continue;
                }
                let mut prng = Prng::new(seed.wrapping_add(1_000_003 + cluster as u64));
                renewal(
                    &mut prng,
                    cfg.cluster_mttf_hours,
                    cfg.cluster_mttr_hours,
                    cfg.horizon_hours,
                    cluster,
                    false,
                    &mut events,
                );
            }
        }
        events.sort_by(|a, b| {
            a.at_hours
                .total_cmp(&b.at_hours)
                .then(a.kind.tag().cmp(&b.kind.tag()))
                .then(a.kind.index().cmp(&b.kind.index()))
        });
        FaultTrace {
            events,
            horizon_hours: cfg.horizon_hours,
            nodes: topo.total_nodes(),
            clusters: topo.clusters(),
        }
    }

    /// Stable fingerprint of the whole schedule (event times bit-exact).
    pub fn digest(&self) -> u64 {
        let mut h = DIGEST_SEED;
        h = digest_mix(h, self.horizon_hours.to_bits());
        h = digest_mix(h, self.nodes as u64);
        h = digest_mix(h, self.clusters as u64);
        for e in &self.events {
            h = digest_mix(h, e.at_hours.to_bits());
            h = digest_mix(h, e.kind.tag());
            h = digest_mix(h, e.kind.index() as u64);
        }
        h
    }

    /// Distinct node ids that fail at least once (directly or through a
    /// cluster event) — the support of predicted failure patterns. Cluster
    /// events expand through `topo`'s live membership (clusters are no
    /// longer uniform, so the old `node / nodes_per_cluster` arithmetic
    /// would misattribute members on elastic topologies).
    pub fn failing_nodes(&self, topo: &Topology) -> Vec<usize> {
        let mut seen = vec![false; self.nodes];
        for e in &self.events {
            match e.kind {
                FaultKind::NodeFail(n) => seen[n] = true,
                FaultKind::ClusterFail(c) => {
                    for &n in topo.nodes_of(c) {
                        if topo.is_live(n) {
                            seen[n] = true;
                        }
                    }
                }
                _ => {}
            }
        }
        (0..self.nodes).filter(|&n| seen[n]).collect()
    }

    /// Distinct cluster ids hit by a correlated event.
    pub fn failing_clusters(&self) -> Vec<usize> {
        let mut seen = vec![false; self.clusters];
        for e in &self.events {
            if let FaultKind::ClusterFail(c) = e.kind {
                seen[c] = true;
            }
        }
        (0..self.clusters).filter(|&c| seen[c]).collect()
    }

    /// Replayable text form: a header plus one event per line, event times
    /// serialized as hex `f64` bits so [`Self::parse`] round-trips exactly.
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        out.push_str("unilrc-fault-trace v1\n");
        out.push_str(&format!("nodes {}\n", self.nodes));
        out.push_str(&format!("clusters {}\n", self.clusters));
        out.push_str(&format!(
            "horizon {:016x} # {:.3} h\n",
            self.horizon_hours.to_bits(),
            self.horizon_hours
        ));
        for e in &self.events {
            out.push_str(&format!(
                "{:016x} {} {} # t={:.3} h\n",
                e.at_hours.to_bits(),
                e.kind.name(),
                e.kind.index(),
                e.at_hours
            ));
        }
        out
    }

    /// Parse [`Self::to_text`] output back into a trace.
    pub fn parse(text: &str) -> anyhow::Result<FaultTrace> {
        let mut lines = text.lines().map(|l| match l.find('#') {
            Some(i) => l[..i].trim(),
            None => l.trim(),
        });
        anyhow::ensure!(
            lines.next() == Some("unilrc-fault-trace v1"),
            "bad trace header (want unilrc-fault-trace v1)"
        );
        let mut field = |name: &str| -> anyhow::Result<String> {
            let line = lines.next().unwrap_or("");
            let (key, val) = line
                .split_once(' ')
                .ok_or_else(|| anyhow::anyhow!("expected `{name} <value>`, got {line:?}"))?;
            anyhow::ensure!(key == name, "expected `{name}`, got {key:?}");
            Ok(val.trim().to_string())
        };
        let nodes: usize = field("nodes")?.parse()?;
        let clusters: usize = field("clusters")?.parse()?;
        let horizon_hours = f64::from_bits(u64::from_str_radix(&field("horizon")?, 16)?);
        let mut events = Vec::new();
        for line in lines {
            if line.is_empty() {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            anyhow::ensure!(parts.len() == 3, "bad event line {line:?}");
            let at_hours = f64::from_bits(u64::from_str_radix(parts[0], 16)?);
            let idx: usize = parts[2].parse()?;
            let kind = match parts[1] {
                "node-fail" => FaultKind::NodeFail(idx),
                "node-repair" => FaultKind::NodeRepair(idx),
                "cluster-fail" => FaultKind::ClusterFail(idx),
                "cluster-repair" => FaultKind::ClusterRepair(idx),
                other => anyhow::bail!("unknown event kind {other:?}"),
            };
            events.push(FaultEvent { at_hours, kind });
        }
        Ok(FaultTrace { events, horizon_hours, nodes, clusters })
    }
}

/// Effective node up/down state during trace replay, tracking *causes*
/// separately: a node is down while its node-level clock has it failed
/// **or** its cluster is in an outage, and only transitions when the
/// combined state flips — so a node-level repair during a cluster outage
/// does not resurrect the node early.
#[derive(Debug, Clone)]
pub struct DownState {
    node_cause: Vec<bool>,
    cluster_cause: Vec<bool>,
    /// node id → owning cluster (snapshot of the topology's map).
    cluster_of: Vec<usize>,
    /// cluster → live member node ids.
    members: Vec<Vec<usize>>,
}

impl DownState {
    pub fn new(topo: &Topology) -> DownState {
        DownState {
            node_cause: vec![false; topo.total_nodes()],
            cluster_cause: vec![false; topo.clusters()],
            cluster_of: (0..topo.total_nodes()).map(|n| topo.cluster_of_node(n)).collect(),
            members: (0..topo.clusters())
                .map(|c| topo.nodes_of(c).iter().copied().filter(|&n| topo.is_live(n)).collect())
                .collect(),
        }
    }

    pub fn is_down(&self, node: usize) -> bool {
        self.node_cause[node] || self.cluster_cause[self.cluster_of[node]]
    }

    /// Number of effectively-down nodes.
    pub fn down_count(&self) -> usize {
        (0..self.node_cause.len()).filter(|&n| self.is_down(n)).count()
    }

    /// Apply one event; returns `(node, now_down)` for every node whose
    /// *effective* state flipped (empty for redundant events, e.g. a
    /// node-level failure inside an ongoing cluster outage).
    pub fn apply(&mut self, kind: FaultKind) -> Vec<(usize, bool)> {
        let mut changed = Vec::new();
        match kind {
            FaultKind::NodeFail(n) | FaultKind::NodeRepair(n) => {
                let failing = matches!(kind, FaultKind::NodeFail(_));
                let before = self.is_down(n);
                self.node_cause[n] = failing;
                let after = self.is_down(n);
                if before != after {
                    changed.push((n, after));
                }
            }
            FaultKind::ClusterFail(c) | FaultKind::ClusterRepair(c) => {
                let failing = matches!(kind, FaultKind::ClusterFail(_));
                let was = self.cluster_cause[c];
                self.cluster_cause[c] = failing;
                if was != failing {
                    for &n in &self.members[c] {
                        let before = self.node_cause[n] || was;
                        let after = self.node_cause[n] || failing;
                        if before != after {
                            changed.push((n, after));
                        }
                    }
                }
            }
        }
        changed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo() -> Topology {
        Topology::new(4, 5)
    }

    #[test]
    fn same_seed_same_digest() {
        let cfg = FaultConfig::accelerated();
        let a = FaultTrace::generate(&topo(), &cfg, 42);
        let b = FaultTrace::generate(&topo(), &cfg, 42);
        assert_eq!(a, b);
        assert_eq!(a.digest(), b.digest());
        let c = FaultTrace::generate(&topo(), &cfg, 43);
        assert_ne!(a.digest(), c.digest());
    }

    #[test]
    fn events_sorted_and_within_horizon() {
        let cfg = FaultConfig::accelerated();
        let t = FaultTrace::generate(&topo(), &cfg, 7);
        assert!(!t.events.is_empty());
        for w in t.events.windows(2) {
            assert!(w[0].at_hours <= w[1].at_hours);
        }
        assert!(t.events.iter().all(|e| e.at_hours > 0.0 && e.at_hours < cfg.horizon_hours));
    }

    #[test]
    fn event_count_tracks_rates() {
        let cfg = FaultConfig {
            node_mttf_hours: 100.0,
            node_mttr_hours: 10.0,
            cluster_mttf_hours: 0.0,
            cluster_mttr_hours: 0.0,
            horizon_hours: 10_000.0,
        };
        let t = FaultTrace::generate(&topo(), &cfg, 1);
        let fails =
            t.events.iter().filter(|e| matches!(e.kind, FaultKind::NodeFail(_))).count() as f64;
        // 20 nodes × horizon/(mttf+mttr) ≈ 1818 expected failures
        let expect = 20.0 * 10_000.0 / 110.0;
        assert!((fails - expect).abs() / expect < 0.15, "{fails} vs {expect}");
        assert!(t.failing_clusters().is_empty());
    }

    #[test]
    fn zero_rates_disable_event_classes() {
        let cfg = FaultConfig {
            node_mttf_hours: 0.0,
            node_mttr_hours: 0.0,
            cluster_mttf_hours: 50.0,
            cluster_mttr_hours: 5.0,
            horizon_hours: 1_000.0,
        };
        let t = FaultTrace::generate(&topo(), &cfg, 9);
        assert!(t.events.iter().all(|e| e.kind.tag() >= 2));
        assert!(!t.failing_clusters().is_empty());
    }

    #[test]
    fn text_roundtrip_is_exact() {
        let cfg = FaultConfig::accelerated();
        let t = FaultTrace::generate(&topo(), &cfg, 5);
        let parsed = FaultTrace::parse(&t.to_text()).unwrap();
        assert_eq!(t, parsed);
        assert_eq!(t.digest(), parsed.digest());
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FaultTrace::parse("nope").is_err());
        assert!(FaultTrace::parse("unilrc-fault-trace v1\nnodes x\n").is_err());
        let bad_kind = "unilrc-fault-trace v1\nnodes 1\nclusters 1\nhorizon \
                        4059000000000000\n3ff0000000000000 node-melt 0\n";
        assert!(FaultTrace::parse(bad_kind).is_err());
    }

    #[test]
    fn down_state_tracks_causes() {
        let mut s = DownState::new(&Topology::new(2, 3));
        assert_eq!(s.apply(FaultKind::NodeFail(1)), vec![(1, true)]);
        // cluster 0 outage: nodes 0 and 2 flip; node 1 already down
        assert_eq!(s.apply(FaultKind::ClusterFail(0)), vec![(0, true), (2, true)]);
        // node-level repair during the outage: no effective change
        assert_eq!(s.apply(FaultKind::NodeRepair(1)), vec![]);
        assert_eq!(s.down_count(), 3);
        // outage ends: every cluster-0 node comes back (node 1 repaired above)
        let mut back = s.apply(FaultKind::ClusterRepair(0));
        back.sort_unstable();
        assert_eq!(back, vec![(0, false), (1, false), (2, false)]);
        assert_eq!(s.down_count(), 0);
    }

    #[test]
    fn failing_nodes_includes_cluster_members() {
        let cfg = FaultConfig {
            node_mttf_hours: 0.0,
            node_mttr_hours: 0.0,
            cluster_mttf_hours: 100.0,
            cluster_mttr_hours: 10.0,
            horizon_hours: 1_000.0,
        };
        let topo = Topology::new(2, 3);
        let t = FaultTrace::generate(&topo, &cfg, 3);
        let nodes = t.failing_nodes(&topo);
        for c in t.failing_clusters() {
            for &n in topo.nodes_of(c) {
                assert!(nodes.contains(&n));
            }
        }
    }

    #[test]
    fn clocks_follow_live_membership() {
        use crate::placement::NodeState;
        // failure interarrival ≪ horizon, so every live node's stream is
        // mathematically certain (P ≈ 1 − e⁻⁴⁰) to fire at least once
        let cfg = FaultConfig {
            node_mttf_hours: 50.0,
            node_mttr_hours: 5.0,
            cluster_mttf_hours: 0.0,
            cluster_mttr_hours: 0.0,
            horizon_hours: 2_000.0,
        };
        let mut topo = Topology::new(2, 3);
        let dead = 1usize;
        topo.set_state(dead, NodeState::Dead);
        let added = topo.add_node(0);
        let t = FaultTrace::generate(&topo, &cfg, 77);
        // the dead node draws no clock; the scaled-out node draws its own
        assert!(t.events.iter().all(|e| {
            !matches!(e.kind, FaultKind::NodeFail(n) | FaultKind::NodeRepair(n) if n == dead)
        }));
        assert!(t
            .events
            .iter()
            .any(|e| matches!(e.kind, FaultKind::NodeFail(n) if n == added)));
        // a cluster event never flips the dead node's effective state
        let mut s = DownState::new(&topo);
        let flipped = s.apply(FaultKind::ClusterFail(0));
        assert!(flipped.iter().all(|&(n, _)| n != dead));
        assert!(flipped.iter().any(|&(n, down)| n == added && down));
        // draining nodes still tick (they hold readable data until dead)
        let mut topo2 = Topology::new(1, 2);
        topo2.set_state(0, NodeState::Draining);
        let t2 = FaultTrace::generate(&topo2, &cfg, 77);
        assert!(t2
            .events
            .iter()
            .any(|e| matches!(e.kind, FaultKind::NodeFail(0))));
    }

    #[test]
    fn digest_mix_is_order_sensitive() {
        let a = digest_mix(digest_mix(DIGEST_SEED, 1), 2);
        let b = digest_mix(digest_mix(DIGEST_SEED, 2), 1);
        assert_ne!(a, b);
    }
}
