//! Virtual-time network substrate — the testbed substitution (DESIGN.md §5).
//!
//! The paper's CloudLab testbed caps each cluster gateway's *outgoing*
//! bandwidth with Wondershaper (1 Gb/s, a 1:10 oversubscription against the
//! 10 Gb/s node NICs). We model exactly that: every node NIC, every cluster
//! gateway, and the client/coordinator NICs are FIFO rate resources on a
//! virtual clock; a transfer occupies each resource on its path for
//! `bytes / that resource's bandwidth`, starting when all of them are free,
//! and completes after the bottleneck duration plus a per-hop latency.
//!
//! Everything is deterministic: latencies and throughputs reported by the
//! prototype are functions of (code, placement, workload) only — while the
//! *data plane* still moves real bytes and runs real coding (timed
//! separately and folded into the clock by the proxy layer).

pub mod faults;

use crate::placement::Topology;

/// Gb/s → bytes/second.
pub const GBIT: f64 = 1e9 / 8.0;

/// Network parameters (§6 Setup defaults).
#[derive(Debug, Clone, Copy)]
pub struct NetConfig {
    /// Node NIC bandwidth (bytes/s). Paper: 10 Gb/s.
    pub node_bw: f64,
    /// Cluster gateway egress bandwidth (bytes/s). Paper: 1 Gb/s.
    pub cross_bw: f64,
    /// Client / coordinator NIC bandwidth (bytes/s). Paper: 10 Gb/s.
    pub client_bw: f64,
    /// Fixed per-transfer latency (seconds) — LAN RTT + software overhead.
    pub base_latency: f64,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            node_bw: 10.0 * GBIT,
            cross_bw: 1.0 * GBIT,
            client_bw: 10.0 * GBIT,
            base_latency: 200e-6,
        }
    }
}

impl NetConfig {
    /// The paper's Experiment 4 knob: cross-cluster gateway bandwidth.
    pub fn with_cross_gbps(mut self, gbps: f64) -> Self {
        self.cross_bw = gbps * GBIT;
        self
    }
}

/// A FIFO rate-limited resource (NIC or gateway).
#[derive(Debug, Clone, Copy)]
struct Resource {
    available_at: f64,
    bw: f64,
}

impl Resource {
    fn new(bw: f64) -> Resource {
        Resource { available_at: 0.0, bw }
    }

    /// Occupy for `bytes` starting no earlier than `start`; returns the
    /// (begin, busy-until) pair.
    fn occupy(&mut self, start: f64, bytes: usize) -> (f64, f64) {
        let begin = start.max(self.available_at);
        let busy = bytes as f64 / self.bw;
        self.available_at = begin + busy;
        (begin, self.available_at)
    }
}

/// Traffic class of a transfer: foreground (client reads/repairs) rides
/// the raw resources; background migration additionally pays the
/// token-bucket throttle first, so the two classes share each NIC/gateway
/// budget with foreground keeping priority.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrafficClass {
    Foreground,
    Migration,
}

/// A token bucket on the virtual clock: tokens (bytes) accrue at
/// `rate_bps` up to `burst`; an admission that finds the bucket short is
/// delayed until the deficit has accrued. Deterministic — pure function
/// of the admission sequence.
#[derive(Debug, Clone, Copy)]
pub struct TokenBucket {
    /// Refill rate in bytes per (virtual) second.
    pub rate_bps: f64,
    /// Token capacity in bytes.
    pub burst: f64,
    tokens: f64,
    /// Virtual instant the token count was last brought current.
    last: f64,
}

impl TokenBucket {
    pub fn new(rate_bps: f64, burst: f64) -> TokenBucket {
        let burst = burst.max(1.0);
        // start full: the first burst-worth of work is unthrottled
        TokenBucket { rate_bps: rate_bps.max(1.0), burst, tokens: burst, last: 0.0 }
    }

    /// Bring the token count current at `now` (capped at the burst).
    fn refill(&mut self, now: f64) {
        if now > self.last {
            self.tokens = (self.tokens + (now - self.last) * self.rate_bps).min(self.burst);
            self.last = now;
        }
    }

    /// Admit `bytes` at the earliest instant ≥ `now` the budget allows;
    /// returns that instant. Debt is taken immediately, so back-to-back
    /// acquisitions queue behind each other like a FIFO resource.
    pub fn acquire(&mut self, now: f64, bytes: usize) -> f64 {
        self.refill(now);
        let need = bytes as f64;
        if self.tokens >= need {
            self.tokens -= need;
            return now;
        }
        let wait = (need - self.tokens) / self.rate_bps;
        self.tokens = 0.0;
        self.last = now + wait;
        now + wait
    }

    /// Take *everything* accrued by `now` and return it as a byte budget
    /// (the fixed-cadence admission primitive of the interference curve:
    /// admissions happen at fixed instants, with per-admission size — not
    /// timing — scaling with the throttle rate, which makes the induced
    /// foreground delay monotone in the rate by construction).
    pub fn drain(&mut self, now: f64) -> usize {
        self.refill(now);
        let grant = self.tokens.floor();
        self.tokens -= grant;
        grant as usize
    }

    /// Reset to a full bucket at t = 0 (between experiment phases).
    pub fn reset(&mut self) {
        self.tokens = self.burst;
        self.last = 0.0;
    }
}

/// Communication endpoints of the prototype.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Endpoint {
    /// A storage node (global node id).
    Node(usize),
    /// The per-cluster proxy machine.
    Proxy(usize),
    /// The client machine.
    Client,
    /// The coordinator machine.
    Coordinator,
}

/// The virtual network: resource state + a node→cluster map kept in sync
/// with the (elastic) topology. Scale-out events allocate fresh NICs and
/// gateways via [`NetSim::sync`]; dead nodes keep their resources (their
/// ids are stable and simply see no more transfers).
#[derive(Debug, Clone)]
pub struct NetSim {
    cfg: NetConfig,
    /// node id → owning cluster (mirror of the topology's map).
    node_cluster: Vec<usize>,
    node_nics: Vec<Resource>,
    proxy_nics: Vec<Resource>,
    gateways: Vec<Resource>,
    client_nic: Resource,
    coord_nic: Resource,
    /// total bytes that crossed any gateway (cross-cluster traffic meter)
    pub cross_bytes: u64,
    /// total bytes moved at all (traffic meter)
    pub total_bytes: u64,
    /// Shared bandwidth budget for [`TrafficClass::Migration`] transfers
    /// (`None` = unthrottled).
    migration_bucket: Option<TokenBucket>,
    /// Bytes admitted through the migration throttle (meter).
    pub migration_bytes: u64,
}

impl NetSim {
    pub fn new(topo: &Topology, cfg: NetConfig) -> NetSim {
        let mut sim = NetSim {
            cfg,
            node_cluster: Vec::new(),
            node_nics: Vec::new(),
            proxy_nics: Vec::new(),
            gateways: Vec::new(),
            client_nic: Resource::new(cfg.client_bw),
            coord_nic: Resource::new(cfg.client_bw),
            cross_bytes: 0,
            total_bytes: 0,
            migration_bucket: None,
            migration_bytes: 0,
        };
        sim.sync(topo);
        sim
    }

    pub fn config(&self) -> NetConfig {
        self.cfg
    }

    /// Grow resource state to cover every node and cluster of `topo`
    /// (idempotent; called after each topology event). New NICs start
    /// idle — `occupy` never schedules before a transfer's start time.
    pub fn sync(&mut self, topo: &Topology) {
        for n in self.node_cluster.len()..topo.total_nodes() {
            self.node_cluster.push(topo.cluster_of_node(n));
            self.node_nics.push(Resource::new(self.cfg.node_bw));
        }
        for _ in self.proxy_nics.len()..topo.clusters() {
            self.proxy_nics.push(Resource::new(self.cfg.node_bw));
            self.gateways.push(Resource::new(self.cfg.cross_bw));
        }
    }

    /// Cluster an endpoint belongs to (None for client/coordinator).
    fn cluster_of(&self, e: Endpoint) -> Option<usize> {
        match e {
            Endpoint::Node(n) => Some(self.node_cluster[n]),
            Endpoint::Proxy(c) => Some(c),
            _ => None,
        }
    }

    /// Schedule a transfer starting no earlier than `start`; returns its
    /// completion time on the virtual clock.
    pub fn transfer(&mut self, start: f64, from: Endpoint, to: Endpoint, bytes: usize) -> f64 {
        if from == to || bytes == 0 {
            return start;
        }
        self.total_bytes += bytes as u64;
        let (cf, ct) = (self.cluster_of(from), self.cluster_of(to));
        let crosses = cf != ct; // leaving a cluster (or client↔cluster)

        // Resource path: src NIC → (src gateway if crossing) → dst NIC.
        // Wondershaper caps *egress*, so only the source gateway throttles.
        let mut begin = start;
        let mut bottleneck = f64::INFINITY;

        // reserve in a fixed order, FIFO per resource
        let mut reserve = |r: &mut Resource| {
            let (b, _) = r.occupy(begin, bytes);
            begin = b;
            bottleneck = bottleneck.min(r.bw);
        };
        match from {
            Endpoint::Node(n) => reserve(&mut self.node_nics[n]),
            Endpoint::Proxy(c) => reserve(&mut self.proxy_nics[c]),
            Endpoint::Client => reserve(&mut self.client_nic),
            Endpoint::Coordinator => reserve(&mut self.coord_nic),
        }
        if crosses {
            if let Some(c) = cf {
                reserve(&mut self.gateways[c]);
                self.cross_bytes += bytes as u64;
            }
        }
        match to {
            Endpoint::Node(n) => reserve(&mut self.node_nics[n]),
            Endpoint::Proxy(c) => reserve(&mut self.proxy_nics[c]),
            Endpoint::Client => reserve(&mut self.client_nic),
            Endpoint::Coordinator => reserve(&mut self.coord_nic),
        }
        begin + bytes as f64 / bottleneck + self.cfg.base_latency
    }

    /// Install (or replace) the migration token bucket: background moves
    /// are admitted at `rate_bps` bytes/s with `burst` bytes of credit,
    /// *then* contend for the same NICs/gateways foreground traffic uses.
    pub fn set_migration_throttle(&mut self, rate_bps: f64, burst: f64) {
        self.migration_bucket = Some(TokenBucket::new(rate_bps, burst));
    }

    /// Drop the migration throttle (background moves run unthrottled).
    pub fn clear_migration_throttle(&mut self) {
        self.migration_bucket = None;
    }

    /// The installed throttle's `(rate_bps, burst)`, if any.
    pub fn migration_throttle(&self) -> Option<(f64, f64)> {
        self.migration_bucket.map(|b| (b.rate_bps, b.burst))
    }

    /// Class-aware transfer: foreground is [`NetSim::transfer`] verbatim;
    /// migration first waits for token-bucket admission, then rides the
    /// same FIFO resources (so a large foreground burst still queues
    /// behind admitted migration bytes — the shared-budget interference
    /// experiment 10 measures).
    pub fn transfer_class(
        &mut self,
        start: f64,
        from: Endpoint,
        to: Endpoint,
        bytes: usize,
        class: TrafficClass,
    ) -> f64 {
        let start = match (class, self.migration_bucket.as_mut()) {
            (TrafficClass::Migration, Some(bucket)) => {
                if from != to && bytes > 0 {
                    self.migration_bytes += bytes as u64;
                }
                bucket.acquire(start, bytes)
            }
            (TrafficClass::Migration, None) => {
                if from != to && bytes > 0 {
                    self.migration_bytes += bytes as u64;
                }
                start
            }
            (TrafficClass::Foreground, _) => start,
        };
        self.transfer(start, from, to, bytes)
    }

    /// Fixed-cadence admission grant: all tokens accrued by `now`
    /// (0 without a throttle — callers must size their own waves). See
    /// [`TokenBucket::drain`].
    pub fn migration_grant(&mut self, now: f64) -> usize {
        self.migration_bucket.as_mut().map_or(0, |b| b.drain(now))
    }

    /// Reset resource clocks and meters (between experiments).
    pub fn reset(&mut self) {
        for r in self
            .node_nics
            .iter_mut()
            .chain(self.proxy_nics.iter_mut())
            .chain(self.gateways.iter_mut())
        {
            r.available_at = 0.0;
        }
        self.client_nic.available_at = 0.0;
        self.coord_nic.available_at = 0.0;
        self.cross_bytes = 0;
        self.total_bytes = 0;
        self.migration_bytes = 0;
        if let Some(b) = self.migration_bucket.as_mut() {
            b.reset();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim() -> NetSim {
        NetSim::new(&Topology::new(3, 4), NetConfig::default())
    }

    const MB: usize = 1 << 20;

    #[test]
    fn inner_cluster_transfer_at_nic_speed() {
        let mut s = sim();
        let t = s.transfer(0.0, Endpoint::Node(0), Endpoint::Node(1), 10 * MB);
        let expect = 10.0 * MB as f64 / (10.0 * GBIT) + 200e-6;
        assert!((t - expect).abs() < 1e-9, "{t} vs {expect}");
        assert_eq!(s.cross_bytes, 0);
    }

    #[test]
    fn cross_cluster_throttled_by_gateway() {
        let mut s = sim();
        let t = s.transfer(0.0, Endpoint::Node(0), Endpoint::Node(8), 10 * MB);
        let expect = 10.0 * MB as f64 / (1.0 * GBIT) + 200e-6;
        assert!((t - expect).abs() < 1e-9);
        assert_eq!(s.cross_bytes, 10 * MB as u64);
    }

    #[test]
    fn node_to_client_crosses_gateway() {
        let mut s = sim();
        let t = s.transfer(0.0, Endpoint::Node(0), Endpoint::Client, MB);
        let expect = MB as f64 / (1.0 * GBIT) + 200e-6;
        assert!((t - expect).abs() < 1e-9);
    }

    #[test]
    fn gateway_serializes_parallel_cross_transfers() {
        let mut s = sim();
        // two different nodes in cluster 0 → client, both issued at t=0:
        // the shared gateway FIFO doubles the second one's completion.
        let t1 = s.transfer(0.0, Endpoint::Node(0), Endpoint::Client, MB);
        let t2 = s.transfer(0.0, Endpoint::Node(1), Endpoint::Client, MB);
        assert!(t2 > t1);
        let per = MB as f64 / (1.0 * GBIT);
        assert!((t2 - (2.0 * per + 200e-6)).abs() < 1e-6);
    }

    #[test]
    fn different_gateways_run_parallel() {
        let mut s = sim();
        let t1 = s.transfer(0.0, Endpoint::Node(0), Endpoint::Client, MB);
        let t2 = s.transfer(0.0, Endpoint::Node(4), Endpoint::Client, MB);
        // client NIC is 10× faster than gateways ⇒ near-identical finishes
        assert!((t1 - t2).abs() < per_gw() * 0.3, "{t1} {t2}");
        fn per_gw() -> f64 {
            MB as f64 / (1.0 * GBIT)
        }
    }

    #[test]
    fn proxy_endpoint_inner_vs_cross() {
        let mut s = sim();
        let inner = s.transfer(0.0, Endpoint::Node(0), Endpoint::Proxy(0), MB);
        s.reset();
        let cross = s.transfer(0.0, Endpoint::Node(0), Endpoint::Proxy(1), MB);
        assert!(cross > inner * 5.0);
    }

    #[test]
    fn zero_bytes_and_self_transfer_free() {
        let mut s = sim();
        assert_eq!(s.transfer(3.0, Endpoint::Node(0), Endpoint::Node(0), MB), 3.0);
        assert_eq!(s.transfer(3.0, Endpoint::Node(0), Endpoint::Node(1), 0), 3.0);
    }

    #[test]
    fn reset_clears_state() {
        let mut s = sim();
        s.transfer(0.0, Endpoint::Node(0), Endpoint::Client, MB);
        s.reset();
        assert_eq!(s.cross_bytes, 0);
        let t = s.transfer(0.0, Endpoint::Node(0), Endpoint::Client, MB);
        assert!((t - (MB as f64 / GBIT + 200e-6)).abs() < 1e-9);
    }

    #[test]
    fn sync_extends_resources_for_scale_out() {
        let mut topo = Topology::new(2, 2);
        let mut s = NetSim::new(&topo, NetConfig::default());
        topo.add_node(1);
        let c = topo.add_cluster(2);
        s.sync(&topo);
        // the new node and the new cluster's nodes are routable, and the
        // fresh gateway throttles cross traffic like any other
        let t = s.transfer(0.0, Endpoint::Node(4), Endpoint::Node(topo.node_id(c, 0)), MB);
        let expect = MB as f64 / (1.0 * GBIT) + 200e-6;
        assert!((t - expect).abs() < 1e-9, "{t} vs {expect}");
        assert_eq!(s.cross_bytes, MB as u64);
    }

    #[test]
    fn exp4_bandwidth_knob() {
        let cfg = NetConfig::default().with_cross_gbps(10.0);
        let mut s = NetSim::new(&Topology::new(2, 2), cfg);
        let t = s.transfer(0.0, Endpoint::Node(0), Endpoint::Node(2), MB);
        let expect = MB as f64 / (10.0 * GBIT) + 200e-6;
        assert!((t - expect).abs() < 1e-9);
    }

    #[test]
    fn token_bucket_delays_when_short_and_caps_at_burst() {
        let mut b = TokenBucket::new(100.0, 50.0); // 100 B/s, 50 B burst
        // starts full: 50 bytes admit instantly
        assert_eq!(b.acquire(0.0, 50), 0.0);
        // next 100 bytes must wait the full deficit: 100/100 = 1 s
        assert!((b.acquire(0.0, 100) - 1.0).abs() < 1e-12);
        // tokens never accrue past the burst: after a long idle gap only
        // 50 bytes are banked, so 100 bytes wait 0.5 s past `now`
        assert!((b.acquire(100.0, 100) - 100.5).abs() < 1e-9);
    }

    #[test]
    fn token_bucket_drain_grants_accrued_bytes() {
        let mut b = TokenBucket::new(1000.0, 400.0);
        assert_eq!(b.drain(0.0), 400, "starts full");
        assert_eq!(b.drain(0.1), 100, "0.1 s × 1000 B/s");
        assert_eq!(b.drain(0.1), 0, "nothing accrues without time passing");
        assert_eq!(b.drain(10.0), 400, "capped at the burst");
    }

    #[test]
    fn migration_class_pays_the_throttle_foreground_does_not() {
        let mut s = sim();
        s.set_migration_throttle(1000.0, MB as f64); // tiny rate, 1 MB burst
        // first MB rides the burst: same completion as foreground
        let fg = s.transfer_class(0.0, Endpoint::Node(0), Endpoint::Node(1), MB,
            TrafficClass::Foreground);
        s.reset();
        let m1 = s.transfer_class(0.0, Endpoint::Node(0), Endpoint::Node(1), MB,
            TrafficClass::Migration);
        assert!((fg - m1).abs() < 1e-9, "burst admits instantly: {fg} vs {m1}");
        // the second MB waits ~MB/1000 s for tokens — far beyond NIC time
        let m2 = s.transfer_class(0.0, Endpoint::Node(0), Endpoint::Node(2), MB,
            TrafficClass::Migration);
        assert!(m2 > MB as f64 / 1000.0, "{m2}");
        assert_eq!(s.migration_bytes, 2 * MB as u64);
        // foreground still never waits on the bucket
        let fg2 = s.transfer_class(0.0, Endpoint::Node(4), Endpoint::Node(5), MB,
            TrafficClass::Foreground);
        assert!((fg2 - fg).abs() < 1e-9);
    }

    #[test]
    fn admitted_migration_contends_on_shared_resources() {
        let mut s = sim();
        s.set_migration_throttle(1e12, 1e12); // effectively unthrottled
        let base = s.transfer(0.0, Endpoint::Node(1), Endpoint::Client, MB);
        s.reset();
        // a migration leaving cluster 0 holds the gateway; a foreground
        // read from the same cluster then queues behind it
        s.transfer_class(0.0, Endpoint::Node(0), Endpoint::Node(4), MB,
            TrafficClass::Migration);
        let fg = s.transfer_class(0.0, Endpoint::Node(1), Endpoint::Client, MB,
            TrafficClass::Foreground);
        assert!(fg > base + 0.5 * MB as f64 / GBIT, "{fg} vs {base}");
    }

    #[test]
    fn reset_refills_the_bucket() {
        let mut s = sim();
        s.set_migration_throttle(100.0, MB as f64);
        s.transfer_class(0.0, Endpoint::Node(0), Endpoint::Node(1), MB,
            TrafficClass::Migration);
        s.reset();
        assert_eq!(s.migration_bytes, 0);
        let t = s.transfer_class(0.0, Endpoint::Node(0), Endpoint::Node(1), MB,
            TrafficClass::Migration);
        let expect = MB as f64 / (10.0 * GBIT) + 200e-6;
        assert!((t - expect).abs() < 1e-9, "full burst again after reset: {t}");
    }
}
