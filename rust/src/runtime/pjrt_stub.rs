//! Stub PJRT backend, compiled when the `pjrt` cargo feature is off.
//!
//! The real backend (`pjrt.rs`) links the `xla` FFI crate, which pulls the
//! XLA C library at build time — unavailable in offline builds. This stub
//! keeps the `PjrtCoder` surface identical so call sites (CLI `--backend
//! pjrt`, benches, the e2e example) compile unchanged; constructing the
//! coder fails with a clear message instead.

use super::{CodingEngine, CombineJob};
use crate::codes::Code;
use crate::gf::pool;
use anyhow::{bail, Result};

/// Placeholder with the same name and API as the real PJRT coder.
pub struct PjrtCoder {
    _private: (),
}

impl PjrtCoder {
    /// Always fails: the binary was built without PJRT support.
    pub fn new(_dir: Option<std::path::PathBuf>) -> Result<PjrtCoder> {
        bail!("this build has no PJRT backend — rebuild with `--features pjrt`")
    }
}

impl CodingEngine for PjrtCoder {
    fn backend(&self) -> &'static str {
        "pjrt-stub"
    }

    fn encode(&self, _code: &Code, _data: &[&[u8]]) -> Result<Vec<Vec<u8>>> {
        bail!("PJRT backend unavailable (built without the `pjrt` feature)")
    }

    fn fold(&self, _sources: &[&[u8]]) -> Result<pool::PooledBuf> {
        bail!("PJRT backend unavailable (built without the `pjrt` feature)")
    }

    fn matmul(&self, _coeffs: &[Vec<u8>], _sources: &[&[u8]]) -> Result<Vec<pool::PooledBuf>> {
        bail!("PJRT backend unavailable (built without the `pjrt` feature)")
    }

    /// Mirrors the real backend's `combine_batch` override so both builds
    /// expose the identical surface (the real one groups same-shape jobs
    /// into shared artifact invocations; `tests/runtime_pjrt.rs` keeps the
    /// stub honest).
    fn combine_batch(&self, _jobs: &[CombineJob]) -> Result<Vec<Vec<pool::PooledBuf>>> {
        bail!("PJRT backend unavailable (built without the `pjrt` feature)")
    }
}
