//! PJRT-backed coding engine: `HloModuleProto::from_text_file` →
//! `PjRtClient::compile` once per artifact at startup, then `execute` on
//! raw byte blocks from the L3 hot path.
//!
//! Blocks of arbitrary length are processed in artifact-block-sized
//! sub-blocks (`b=65536` by default); the tail is zero-padded, which is
//! sound for linear codes (0 encodes/decodes to 0).

use super::artifacts::{Artifact, Manifest};
use super::CodingEngine;
use crate::codes::{Code, CodeFamily};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::sync::Mutex;

/// A compiled-artifact cache plus the PJRT client.
pub struct PjrtCoder {
    client: xla::PjRtClient,
    manifest: Manifest,
    /// artifact name → compiled executable (compiled lazily, cached).
    compiled: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    /// reusable packing scratch (§Perf: avoids a fresh zeroed allocation —
    /// and its page faults — on every request-path call).
    scratch: Mutex<Vec<u8>>,
}

// The xla wrapper types are FFI handles that PJRT allows cross-thread use of.
unsafe impl Send for PjrtCoder {}
unsafe impl Sync for PjrtCoder {}

impl PjrtCoder {
    /// Create from an artifact directory (default: `Manifest::default_dir`).
    pub fn new(dir: Option<std::path::PathBuf>) -> Result<PjrtCoder> {
        let dir = dir.unwrap_or_else(Manifest::default_dir);
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtCoder {
            client,
            manifest,
            compiled: Mutex::new(HashMap::new()),
            scratch: Mutex::new(Vec::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn executable(&self, art: &Artifact) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        let mut cache = self.compiled.lock().unwrap();
        if let Some(exe) = cache.get(&art.name) {
            return Ok(exe.clone());
        }
        let path = art
            .path
            .to_str()
            .with_context(|| format!("non-utf8 artifact path {:?}", art.path))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {}", art.name))?;
        let exe = std::sync::Arc::new(exe);
        cache.insert(art.name.clone(), exe.clone());
        Ok(exe)
    }

    /// Pack `rows` equal-length byte slices into a `[rows, b]` u8 literal,
    /// taking `rows[i][offset..offset+width]` and zero-padding to `b`.
    fn pack(&self, b: usize, rows: &[&[u8]], offset: usize, width: usize, pad_rows: usize) -> xla::Literal {
        let total_rows = rows.len() + pad_rows;
        let mut flat = self.scratch.lock().unwrap();
        if flat.len() < total_rows * b {
            flat.resize(total_rows * b, 0);
        }
        for (i, r) in rows.iter().enumerate() {
            flat[i * b..i * b + width].copy_from_slice(&r[offset..offset + width]);
            if width < b {
                flat[i * b + width..(i + 1) * b].fill(0);
            }
        }
        // pad rows must be zero (stale data from a previous, larger call)
        flat[rows.len() * b..total_rows * b].fill(0);
        xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::U8,
            &[total_rows, b],
            &flat[..total_rows * b],
        )
        .expect("u8 literal creation cannot fail for matching sizes")
    }

    /// Run one artifact over a whole block length, sub-block by sub-block.
    /// `make_inputs(offset, width)` builds the literals for one sub-block;
    /// the single tuple output `[rows_out, b]` is scattered into `outs`.
    fn run_chunked(
        &self,
        art: &Artifact,
        len: usize,
        rows_out: usize,
        mut make_inputs: impl FnMut(usize, usize) -> Vec<xla::Literal>,
        outs: &mut [Vec<u8>],
    ) -> Result<()> {
        let exe = self.executable(art)?;
        let b = art.param("b")?;
        let mut offset = 0;
        while offset < len {
            let width = b.min(len - offset);
            let inputs = make_inputs(offset, width);
            let result = exe.execute::<xla::Literal>(&inputs)?[0][0]
                .to_literal_sync()
                .context("fetching PJRT result")?;
            let out = result.to_tuple1().context("unwrapping result tuple")?;
            let flat = out.to_vec::<u8>()?;
            anyhow::ensure!(flat.len() >= rows_out * b, "artifact output too small");
            for (i, o) in outs.iter_mut().enumerate() {
                o[offset..offset + width].copy_from_slice(&flat[i * b..i * b + width]);
            }
            offset += width;
        }
        Ok(())
    }
}

impl CodingEngine for PjrtCoder {
    fn backend(&self) -> &'static str {
        "pjrt"
    }

    fn encode(&self, code: &Code, data: &[&[u8]]) -> Result<Vec<Vec<u8>>> {
        anyhow::ensure!(data.len() == code.k(), "need k data blocks");
        let len = data[0].len();
        // Scheme-specific constant-folded artifact for UniLRC; other
        // families go through the generic coefficient-fed graph.
        match code.family {
            CodeFamily::UniLrc => {
                let (alpha, z) = unilrc_params(code)?;
                let art = self.manifest.encode_for(alpha, z)?.clone();
                let mut outs = vec![vec![0u8; len]; code.m()];
                let b = art.param("b")?;
                self.run_chunked(
                    &art,
                    len,
                    code.m(),
                    |off, w| vec![self.pack(b, data, off, w, 0)],
                    &mut outs,
                )?;
                Ok(outs)
            }
            _ => {
                let coeffs: Vec<Vec<u8>> =
                    (0..code.m()).map(|i| code.parity_matrix().row(i).to_vec()).collect();
                self.matmul(&coeffs, data)
            }
        }
    }

    fn fold(&self, sources: &[&[u8]]) -> Result<Vec<u8>> {
        anyhow::ensure!(!sources.is_empty(), "fold needs sources");
        let len = sources[0].len();
        let (art, s_padded) = self.manifest.fold_for(sources.len())?;
        let art = art.clone();
        let b = art.param("b")?;
        let pad = s_padded - sources.len();
        let mut outs = vec![vec![0u8; len]];
        self.run_chunked(
            &art,
            len,
            1,
            |off, w| vec![self.pack(b, sources, off, w, pad)],
            &mut outs,
        )?;
        Ok(outs.pop().unwrap())
    }

    fn matmul(&self, coeffs: &[Vec<u8>], sources: &[&[u8]]) -> Result<Vec<Vec<u8>>> {
        anyhow::ensure!(!coeffs.is_empty(), "matmul needs coefficient rows");
        anyhow::ensure!(
            coeffs.iter().all(|r| r.len() == sources.len()),
            "coefficient width must match source count"
        );
        let len = sources.first().map_or(0, |s| s.len());
        let (art, m_pad, k_pad) = self.manifest.gfdec_for(coeffs.len(), sources.len())?;
        let art = art.clone();
        let b = art.param("b")?;
        // zero-padded coefficient literal [m_pad, k_pad]
        let mut cflat = vec![0u8; m_pad * k_pad];
        for (i, row) in coeffs.iter().enumerate() {
            cflat[i * k_pad..i * k_pad + row.len()].copy_from_slice(row);
        }
        let coeff_lit = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::U8,
            &[m_pad, k_pad],
            &cflat,
        )
        .expect("coeff literal");
        let pad_rows = k_pad - sources.len();
        let mut outs = vec![vec![0u8; len]; m_pad];
        self.run_chunked(
            &art,
            len,
            m_pad,
            |off, w| {
                // NOTE: Literal isn't Clone in the crate; rebuild per chunk.
                let mut cf = vec![0u8; m_pad * k_pad];
                cf.copy_from_slice(&cflat);
                let c = xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::U8,
                    &[m_pad, k_pad],
                    &cf,
                )
                .expect("coeff literal");
                vec![c, self.pack(b, sources, off, w, pad_rows)]
            },
            &mut outs,
        )?;
        let _ = coeff_lit;
        outs.truncate(coeffs.len());
        Ok(outs)
    }
}

fn unilrc_params(code: &Code) -> Result<(usize, usize)> {
    // name format: "UniLRC(n,k,g) [α=…, z=…]"
    let name = code.name();
    let alpha = field(name, "α=")?;
    let z = field(name, "z=")?;
    Ok((alpha, z))
}

fn field(s: &str, key: &str) -> Result<usize> {
    let start = s.find(key).with_context(|| format!("missing {key} in {s}"))? + key.len();
    let rest = &s[start..];
    let end = rest.find(|c: char| !c.is_ascii_digit()).unwrap_or(rest.len());
    if end == 0 {
        bail!("empty number after {key} in {s}");
    }
    Ok(rest[..end].parse()?)
}
