//! PJRT-backed coding engine: `HloModuleProto::from_text_file` →
//! `PjRtClient::compile` once per artifact at startup, then `execute` on
//! raw byte blocks from the L3 hot path.
//!
//! Blocks of arbitrary length are processed in artifact-block-sized
//! sub-blocks (`b=65536` by default); the tail is zero-padded, which is
//! sound for linear codes (0 encodes/decodes to 0).

use super::artifacts::{Artifact, Manifest};
use super::{CodingEngine, CombineJob};
use crate::codes::{Code, CodeFamily};
use crate::gf::pool;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::sync::Mutex;

/// A compiled-artifact cache plus the PJRT client.
pub struct PjrtCoder {
    client: xla::PjRtClient,
    manifest: Manifest,
    /// artifact name → compiled executable (compiled lazily, cached).
    compiled: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    /// reusable packing scratch (§Perf: avoids a fresh zeroed allocation —
    /// and its page faults — on every request-path call).
    scratch: Mutex<Vec<u8>>,
}

// The xla wrapper types are FFI handles that PJRT allows cross-thread use of.
unsafe impl Send for PjrtCoder {}
unsafe impl Sync for PjrtCoder {}

impl PjrtCoder {
    /// Create from an artifact directory (default: `Manifest::default_dir`).
    pub fn new(dir: Option<std::path::PathBuf>) -> Result<PjrtCoder> {
        let dir = dir.unwrap_or_else(Manifest::default_dir);
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtCoder {
            client,
            manifest,
            compiled: Mutex::new(HashMap::new()),
            scratch: Mutex::new(Vec::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn executable(&self, art: &Artifact) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        let mut cache = self.compiled.lock().unwrap();
        if let Some(exe) = cache.get(&art.name) {
            return Ok(exe.clone());
        }
        let path = art
            .path
            .to_str()
            .with_context(|| format!("non-utf8 artifact path {:?}", art.path))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {}", art.name))?;
        let exe = std::sync::Arc::new(exe);
        cache.insert(art.name.clone(), exe.clone());
        Ok(exe)
    }

    /// Pack `rows` equal-length byte slices into a `[rows, b]` u8 literal,
    /// taking `rows[i][offset..offset+width]` and zero-padding to `b`.
    fn pack(
        &self,
        b: usize,
        rows: &[&[u8]],
        offset: usize,
        width: usize,
        pad_rows: usize,
    ) -> xla::Literal {
        let total_rows = rows.len() + pad_rows;
        let mut flat = self.scratch.lock().unwrap();
        if flat.len() < total_rows * b {
            flat.resize(total_rows * b, 0);
        }
        for (i, r) in rows.iter().enumerate() {
            flat[i * b..i * b + width].copy_from_slice(&r[offset..offset + width]);
            if width < b {
                flat[i * b + width..(i + 1) * b].fill(0);
            }
        }
        // pad rows must be zero (stale data from a previous, larger call)
        flat[rows.len() * b..total_rows * b].fill(0);
        xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::U8,
            &[total_rows, b],
            &flat[..total_rows * b],
        )
        .expect("u8 literal creation cannot fail for matching sizes")
    }

    /// Execute one compiled-artifact invocation and fetch the flat `u8`
    /// contents of its single tuple output (shared by the per-call and
    /// batched paths).
    fn execute_flat(
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
        min_len: usize,
    ) -> Result<Vec<u8>> {
        let result = exe.execute::<xla::Literal>(inputs)?[0][0]
            .to_literal_sync()
            .context("fetching PJRT result")?;
        let out = result.to_tuple1().context("unwrapping result tuple")?;
        let flat = out.to_vec::<u8>()?;
        anyhow::ensure!(flat.len() >= min_len, "artifact output too small");
        Ok(flat)
    }

    /// Run one artifact over a whole block length, sub-block by sub-block.
    /// `make_inputs(offset, width)` builds the literals for one sub-block;
    /// the single tuple output `[rows_out, b]` is scattered into `outs`.
    fn run_chunked<B: AsMut<[u8]>>(
        &self,
        art: &Artifact,
        len: usize,
        rows_out: usize,
        mut make_inputs: impl FnMut(usize, usize) -> Vec<xla::Literal>,
        outs: &mut [B],
    ) -> Result<()> {
        let exe = self.executable(art)?;
        let b = art.param("b")?;
        let mut offset = 0;
        while offset < len {
            let width = b.min(len - offset);
            let inputs = make_inputs(offset, width);
            let flat = Self::execute_flat(&exe, &inputs, rows_out * b)?;
            for (i, o) in outs.iter_mut().enumerate() {
                o.as_mut()[offset..offset + width].copy_from_slice(&flat[i * b..i * b + width]);
            }
            offset += width;
        }
        Ok(())
    }

    // ----------------------------------------------------- batched combines
    //
    // `combine_batch` groups same-shape jobs and treats each group as one
    // *virtual* block — the concatenation of every member's block along the
    // length axis — processed `b` artifact bytes at a time. Sub-`b` stripes
    // (the degraded-burst norm: 64 KiB blocks vs b = 65536) share artifact
    // invocations instead of each paying a zero-padded one, and executable
    // and literal setup amortize across the event.

    /// Pack virtual bytes `[offset, offset+width)` of a job group into a
    /// `[rows_total, b]` u8 literal: row `r` is source `r` of each member
    /// job in `idxs` order, chunk tail and pad rows zeroed. Virtual byte
    /// `v` maps to byte `v % len` of job `idxs[v / len]`.
    fn pack_group(
        &self,
        jobs: &[CombineJob],
        idxs: &[usize],
        b: usize,
        rows_total: usize,
        rows: usize,
        len: usize,
        offset: usize,
        width: usize,
    ) -> xla::Literal {
        let mut flat = self.scratch.lock().unwrap();
        if flat.len() < rows_total * b {
            flat.resize(rows_total * b, 0);
        }
        for r in 0..rows {
            let dst = r * b;
            let mut filled = 0usize;
            while filled < width {
                let v = offset + filled;
                let (ji, local) = (idxs[v / len], v % len);
                let take = (len - local).min(width - filled);
                flat[dst + filled..dst + filled + take]
                    .copy_from_slice(&jobs[ji].sources[r][local..local + take]);
                filled += take;
            }
            flat[dst + width..dst + b].fill(0);
        }
        flat[rows * b..rows_total * b].fill(0);
        xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::U8,
            &[rows_total, b],
            &flat[..rows_total * b],
        )
        .expect("u8 literal creation cannot fail for matching sizes")
    }

    /// Scatter `rows_out` output rows of one artifact chunk back into the
    /// member jobs' output blocks at the group's virtual range.
    fn scatter_group(
        flat: &[u8],
        b: usize,
        rows_out: usize,
        len: usize,
        idxs: &[usize],
        offset: usize,
        width: usize,
        outs: &mut [Vec<pool::PooledBuf>],
    ) {
        for i in 0..rows_out {
            let src = i * b;
            let mut filled = 0usize;
            while filled < width {
                let v = offset + filled;
                let (ji, local) = (idxs[v / len], v % len);
                let take = (len - local).min(width - filled);
                outs[ji][i][local..local + take]
                    .copy_from_slice(&flat[src + filled..src + filled + take]);
                filled += take;
            }
        }
    }

    /// One fold artifact over the virtual concatenation of a group of
    /// xor-only jobs (equal source counts and block lengths).
    fn fold_group(
        &self,
        jobs: &[CombineJob],
        idxs: &[usize],
        len: usize,
        outs: &mut [Vec<pool::PooledBuf>],
    ) -> Result<()> {
        let nsrc = jobs[idxs[0]].sources.len();
        let (art, s_padded) = self.manifest.fold_for(nsrc)?;
        let art = art.clone();
        let b = art.param("b")?;
        let exe = self.executable(&art)?;
        let total = len * idxs.len();
        let mut offset = 0usize;
        while offset < total {
            let width = b.min(total - offset);
            let input = self.pack_group(jobs, idxs, b, s_padded, nsrc, len, offset, width);
            let flat = Self::execute_flat(&exe, &[input], b)?;
            Self::scatter_group(&flat, b, 1, len, idxs, offset, width, outs);
            offset += width;
        }
        Ok(())
    }

    /// One gfdec artifact over the virtual concatenation of a group of
    /// general-combine jobs sharing one coefficient matrix.
    fn matmul_group(
        &self,
        jobs: &[CombineJob],
        idxs: &[usize],
        coeffs: &[Vec<u8>],
        len: usize,
        outs: &mut [Vec<pool::PooledBuf>],
    ) -> Result<()> {
        let nsrc = jobs[idxs[0]].sources.len();
        anyhow::ensure!(
            coeffs.iter().all(|r| r.len() == nsrc),
            "coefficient width must match source count"
        );
        let (art, m_pad, k_pad) = self.manifest.gfdec_for(coeffs.len(), nsrc)?;
        let art = art.clone();
        let b = art.param("b")?;
        let exe = self.executable(&art)?;
        let mut cflat = vec![0u8; m_pad * k_pad];
        for (i, row) in coeffs.iter().enumerate() {
            cflat[i * k_pad..i * k_pad + row.len()].copy_from_slice(row);
        }
        let total = len * idxs.len();
        let mut offset = 0usize;
        while offset < total {
            let width = b.min(total - offset);
            // NOTE: Literal isn't Clone in the crate; rebuild per chunk.
            let c = xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::U8,
                &[m_pad, k_pad],
                &cflat,
            )
            .expect("coeff literal");
            let input = self.pack_group(jobs, idxs, b, k_pad, nsrc, len, offset, width);
            let flat = Self::execute_flat(&exe, &[c, input], m_pad * b)?;
            Self::scatter_group(&flat, b, coeffs.len(), len, idxs, offset, width, outs);
            offset += width;
        }
        Ok(())
    }
}

impl CodingEngine for PjrtCoder {
    fn backend(&self) -> &'static str {
        "pjrt"
    }

    fn encode(&self, code: &Code, data: &[&[u8]]) -> Result<Vec<Vec<u8>>> {
        anyhow::ensure!(data.len() == code.k(), "need k data blocks");
        let len = data[0].len();
        // Scheme-specific constant-folded artifact for UniLRC; other
        // families go through the generic coefficient-fed graph.
        match code.family {
            CodeFamily::UniLrc => {
                let (alpha, z) = unilrc_params(code)?;
                let art = self.manifest.encode_for(alpha, z)?.clone();
                let mut outs = vec![vec![0u8; len]; code.m()];
                let b = art.param("b")?;
                self.run_chunked(
                    &art,
                    len,
                    code.m(),
                    |off, w| vec![self.pack(b, data, off, w, 0)],
                    &mut outs,
                )?;
                Ok(outs)
            }
            _ => {
                let coeffs: Vec<Vec<u8>> =
                    (0..code.m()).map(|i| code.parity_matrix().row(i).to_vec()).collect();
                let outs = self.matmul(&coeffs, data)?;
                Ok(outs.into_iter().map(Vec::from).collect())
            }
        }
    }

    fn fold(&self, sources: &[&[u8]]) -> Result<pool::PooledBuf> {
        anyhow::ensure!(!sources.is_empty(), "fold needs sources");
        let len = sources[0].len();
        let (art, s_padded) = self.manifest.fold_for(sources.len())?;
        let art = art.clone();
        let b = art.param("b")?;
        let pad = s_padded - sources.len();
        let mut outs = vec![pool::take_zeroed(len)];
        self.run_chunked(
            &art,
            len,
            1,
            |off, w| vec![self.pack(b, sources, off, w, pad)],
            &mut outs,
        )?;
        Ok(outs.pop().unwrap())
    }

    fn matmul(&self, coeffs: &[Vec<u8>], sources: &[&[u8]]) -> Result<Vec<pool::PooledBuf>> {
        anyhow::ensure!(!coeffs.is_empty(), "matmul needs coefficient rows");
        anyhow::ensure!(
            coeffs.iter().all(|r| r.len() == sources.len()),
            "coefficient width must match source count"
        );
        let len = sources.first().map_or(0, |s| s.len());
        let (art, m_pad, k_pad) = self.manifest.gfdec_for(coeffs.len(), sources.len())?;
        let art = art.clone();
        let b = art.param("b")?;
        // zero-padded coefficient literal [m_pad, k_pad]
        let mut cflat = vec![0u8; m_pad * k_pad];
        for (i, row) in coeffs.iter().enumerate() {
            cflat[i * k_pad..i * k_pad + row.len()].copy_from_slice(row);
        }
        let coeff_lit = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::U8,
            &[m_pad, k_pad],
            &cflat,
        )
        .expect("coeff literal");
        let pad_rows = k_pad - sources.len();
        let mut outs: Vec<pool::PooledBuf> = (0..m_pad).map(|_| pool::take_zeroed(len)).collect();
        self.run_chunked(
            &art,
            len,
            m_pad,
            |off, w| {
                // NOTE: Literal isn't Clone in the crate; rebuild per chunk.
                let mut cf = vec![0u8; m_pad * k_pad];
                cf.copy_from_slice(&cflat);
                let c = xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::U8,
                    &[m_pad, k_pad],
                    &cf,
                )
                .expect("coeff literal");
                vec![c, self.pack(b, sources, off, w, pad_rows)]
            },
            &mut outs,
        )?;
        let _ = coeff_lit;
        outs.truncate(coeffs.len());
        Ok(outs)
    }

    /// Batched combines through the AOT artifacts: jobs with an identical
    /// shape (coefficient rows, source count, block length) are
    /// concatenated along the block axis and run `b` artifact bytes at a
    /// time, so an event of many sub-`b` stripes shares invocations
    /// instead of paying one zero-padded execution per stripe (which is
    /// what the sequential trait default — previously the silent fallback
    /// — costs). Byte-identical to per-job [`Self::fold`] /
    /// [`Self::matmul`]; `tests/runtime_pjrt.rs` asserts it.
    fn combine_batch(&self, jobs: &[CombineJob]) -> Result<Vec<Vec<pool::PooledBuf>>> {
        let mut outs: Vec<Vec<pool::PooledBuf>> = jobs
            .iter()
            .map(|j| {
                let len = j.sources.first().map_or(0, |s| s.len());
                (0..j.coeffs.len()).map(|_| pool::take_zeroed(len)).collect()
            })
            .collect();
        // Group job indices by shape, preserving first-seen order so the
        // execution schedule is deterministic.
        type Shape = (Vec<Vec<u8>>, usize, usize);
        let mut order: Vec<Shape> = Vec::new();
        let mut groups: HashMap<Shape, Vec<usize>> = HashMap::new();
        for (i, j) in jobs.iter().enumerate() {
            let len = j.sources.first().map_or(0, |s| s.len());
            let key = (j.coeffs.clone(), j.sources.len(), len);
            match groups.get_mut(&key) {
                Some(members) => members.push(i),
                None => {
                    groups.insert(key.clone(), vec![i]);
                    order.push(key);
                }
            }
        }
        for key in &order {
            let idxs = groups.remove(key).expect("group indices");
            let (coeffs, nsrc, len) = key;
            if *len == 0 || *nsrc == 0 || coeffs.is_empty() {
                continue; // zero-length outputs are already correct
            }
            if jobs[idxs[0]].xor_only() {
                self.fold_group(jobs, &idxs, *len, &mut outs)?;
            } else {
                self.matmul_group(jobs, &idxs, coeffs, *len, &mut outs)?;
            }
        }
        Ok(outs)
    }
}

fn unilrc_params(code: &Code) -> Result<(usize, usize)> {
    // name format: "UniLRC(n,k,g) [α=…, z=…]"
    let name = code.name();
    let alpha = field(name, "α=")?;
    let z = field(name, "z=")?;
    Ok((alpha, z))
}

fn field(s: &str, key: &str) -> Result<usize> {
    let start = s.find(key).with_context(|| format!("missing {key} in {s}"))? + key.len();
    let rest = &s[start..];
    let end = rest.find(|c: char| !c.is_ascii_digit()).unwrap_or(rest.len());
    if end == 0 {
        bail!("empty number after {key} in {s}");
    }
    Ok(rest[..end].parse()?)
}
