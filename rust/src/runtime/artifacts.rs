//! Artifact manifest: index of the AOT-compiled HLO text files.
//!
//! `make artifacts` writes `artifacts/manifest.tsv` with one line per
//! artifact: `kind \t name \t file \t key=val key=val …`. This module
//! parses it and answers "which artifact encodes scheme X / folds S
//! sources / decodes scheme Y".

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Kind of compiled computation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// UniLRC encode with constant-folded generator: `(k,B) → (m,B)`.
    Encode,
    /// Generic coefficient-fed GF matmul: `((m,k),(k,B)) → (m,B)`.
    GfDecode,
    /// XOR fold: `(s,B) → (1,B)`.
    XorFold,
}

impl ArtifactKind {
    fn parse(s: &str) -> Result<ArtifactKind> {
        match s {
            "encode" => Ok(ArtifactKind::Encode),
            "gfdec" => Ok(ArtifactKind::GfDecode),
            "xorfold" => Ok(ArtifactKind::XorFold),
            other => bail!("unknown artifact kind {other:?}"),
        }
    }
}

/// One manifest entry.
#[derive(Debug, Clone)]
pub struct Artifact {
    pub kind: ArtifactKind,
    pub name: String,
    pub path: PathBuf,
    pub params: HashMap<String, usize>,
    pub scheme: Option<String>,
}

impl Artifact {
    pub fn param(&self, key: &str) -> Result<usize> {
        self.params
            .get(key)
            .copied()
            .with_context(|| format!("artifact {} missing param {key}", self.name))
    }
}

/// Parsed manifest with lookup helpers.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub artifacts: Vec<Artifact>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `dir/manifest.tsv`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let mut artifacts = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let fields: Vec<&str> = line.split('\t').collect();
            if fields.len() != 4 {
                bail!("manifest line {} malformed: {line:?}", lineno + 1);
            }
            let mut params = HashMap::new();
            let mut scheme = None;
            for kv in fields[3].split_whitespace() {
                let (k, v) = kv
                    .split_once('=')
                    .with_context(|| format!("bad key=val {kv:?} on line {}", lineno + 1))?;
                if k == "scheme" {
                    scheme = Some(v.to_string());
                } else {
                    params.insert(k.to_string(), v.parse::<usize>()?);
                }
            }
            artifacts.push(Artifact {
                kind: ArtifactKind::parse(fields[0])?,
                name: fields[1].to_string(),
                path: dir.join(fields[2]),
                params,
                scheme,
            });
        }
        Ok(Manifest { artifacts, dir })
    }

    /// Default artifact directory: `$UNILRC_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("UNILRC_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Encode artifact for a UniLRC (α, z) pair.
    pub fn encode_for(&self, alpha: usize, z: usize) -> Result<&Artifact> {
        self.artifacts
            .iter()
            .find(|a| {
                a.kind == ArtifactKind::Encode
                    && a.params.get("alpha") == Some(&alpha)
                    && a.params.get("z") == Some(&z)
            })
            .with_context(|| format!("no encode artifact for α={alpha}, z={z}"))
    }

    /// Smallest XOR-fold artifact with `s ≥ sources` (zero-padding covers
    /// the gap). Returns (artifact, padded_s).
    pub fn fold_for(&self, sources: usize) -> Result<(&Artifact, usize)> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == ArtifactKind::XorFold)
            .filter_map(|a| a.params.get("s").map(|&s| (a, s)))
            .filter(|&(_, s)| s >= sources)
            .min_by_key(|&(_, s)| s)
            .with_context(|| format!("no xorfold artifact for {sources} sources"))
    }

    /// Smallest generic decode artifact with `m ≥ outs` and `k ≥ sources`.
    pub fn gfdec_for(&self, outs: usize, sources: usize) -> Result<(&Artifact, usize, usize)> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == ArtifactKind::GfDecode)
            .filter_map(|a| {
                match (a.params.get("m"), a.params.get("k")) {
                    (Some(&m), Some(&k)) if m >= outs && k >= sources => Some((a, m, k)),
                    _ => None,
                }
            })
            .min_by_key(|&(_, m, k)| m * k)
            .with_context(|| format!("no gfdec artifact for {outs}×{sources}"))
    }

    /// Block size shared by the data-path (encode/gfdec) artifacts.
    /// XOR-fold artifacts use larger blocks (see aot.py §Perf note).
    pub fn block_size(&self) -> Result<usize> {
        let mut sizes: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.kind != ArtifactKind::XorFold)
            .filter_map(|a| a.params.get("b").copied())
            .collect();
        sizes.sort_unstable();
        sizes.dedup();
        match sizes.as_slice() {
            [one] => Ok(*one),
            other => bail!("expected one data-path block size, got {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, lines: &[&str]) {
        std::fs::create_dir_all(dir).unwrap();
        let mut f = std::fs::File::create(dir.join("manifest.tsv")).unwrap();
        for l in lines {
            writeln!(f, "{l}").unwrap();
        }
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("unilrc_manifest_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn parses_and_indexes() {
        let d = tmpdir("parse");
        write_manifest(
            &d,
            &[
                "encode\tenc\tenc.hlo.txt\tscheme=42 alpha=1 z=6 k=30 m=12 b=65536",
                "xorfold\tx5\tx5.hlo.txt\ts=5 b=65536",
                "xorfold\tx8\tx8.hlo.txt\ts=8 b=65536",
                "gfdec\tg\tg.hlo.txt\tscheme=42 m=12 k=42 b=65536",
            ],
        );
        let m = Manifest::load(&d).unwrap();
        assert_eq!(m.artifacts.len(), 4);
        assert_eq!(m.encode_for(1, 6).unwrap().name, "enc");
        assert!(m.encode_for(2, 6).is_err());
        let (a, s) = m.fold_for(6).unwrap();
        assert_eq!((a.name.as_str(), s), ("x8", 8));
        let (a, s) = m.fold_for(5).unwrap();
        assert_eq!((a.name.as_str(), s), ("x5", 5));
        assert!(m.fold_for(9).is_err());
        let (a, mm, kk) = m.gfdec_for(3, 40).unwrap();
        assert_eq!((a.name.as_str(), mm, kk), ("g", 12, 42));
        assert_eq!(m.block_size().unwrap(), 65536);
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn rejects_malformed() {
        let d = tmpdir("bad");
        write_manifest(&d, &["encode\tonly-three-fields\tx.hlo.txt"]);
        assert!(Manifest::load(&d).is_err());
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn real_manifest_if_present() {
        // when `make artifacts` has run, validate the real thing
        if let Ok(m) = Manifest::load(Manifest::default_dir()) {
            assert!(m.artifacts.len() >= 20);
            assert!(m.encode_for(1, 6).is_ok());
            assert!(m.encode_for(2, 10).is_ok());
            assert!(m.fold_for(6).is_ok());
            assert!(m.gfdec_for(30, 210).is_ok());
            assert_eq!(m.block_size().unwrap(), 65536);
        }
    }
}
