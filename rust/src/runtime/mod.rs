//! PJRT runtime — loads the AOT artifacts (`artifacts/*.hlo.txt`, built
//! once by `make artifacts`) and executes them on the request path.
//!
//! Python is *never* invoked here: [`artifacts::Manifest`] indexes the HLO
//! text files, [`pjrt::PjrtCoder`] compiles them on the PJRT CPU client at
//! startup and runs encode / xor-fold / generic-decode on raw byte blocks.
//!
//! [`CodingEngine`] abstracts the coding backend so the proxy can run
//! either through PJRT (default — proves L1/L2/L3 compose) or through the
//! native GF substrate ([`NativeCoder`], the ISA-L analogue used for wide
//! sweeps); integration tests assert the two produce identical bytes.

pub mod artifacts;

// The real PJRT backend needs the `xla` FFI crate; without the `pjrt`
// feature a stub with the same surface keeps every call site compiling
// (construction fails with a clear error instead).
#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(not(feature = "pjrt"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;

pub use artifacts::{Artifact, ArtifactKind, Manifest};
pub use pjrt::PjrtCoder;

use crate::codes::Code;
use crate::gf::slice::NibbleTables;
use crate::gf::{dispatch, pool};
use anyhow::Result;

/// One linear-combination job of a batched submission: output row `i` is
/// `⊕_j coeffs[i][j] · sources[j]`. A single all-ones row is a pure
/// XOR-fold (XOR-local repair).
pub struct CombineJob<'a> {
    pub coeffs: Vec<Vec<u8>>,
    pub sources: Vec<&'a [u8]>,
}

impl CombineJob<'_> {
    /// Is this job a single-row pure XOR fold?
    pub fn xor_only(&self) -> bool {
        self.coeffs.len() == 1 && self.coeffs[0].iter().all(|&c| c == 1)
    }

    /// Total input bytes this job reads.
    pub fn work(&self) -> usize {
        self.sources.iter().map(|s| s.len()).sum()
    }
}

/// Backend-independent coding interface used by the proxy's coding service.
pub trait CodingEngine: Send + Sync {
    /// Human-readable backend name.
    fn backend(&self) -> &'static str;

    /// Encode: `k` data blocks → `n−k` parity blocks.
    fn encode(&self, code: &Code, data: &[&[u8]]) -> Result<Vec<Vec<u8>>>;

    /// XOR-fold the sources into one block (XOR-local repair). The output
    /// is a 64-byte-aligned pooled buffer; repair-path callers should hand
    /// it back via [`crate::gf::pool::recycle`] once consumed.
    fn fold(&self, sources: &[&[u8]]) -> Result<pool::PooledBuf>;

    /// General linear combination: `coeffs` is `outs × sources.len()`.
    /// Outputs are pooled buffers (see [`Self::fold`]).
    fn matmul(&self, coeffs: &[Vec<u8>], sources: &[&[u8]]) -> Result<Vec<pool::PooledBuf>>;

    /// Execute many combine jobs (one per stripe of a multi-stripe event).
    /// The default runs them sequentially; backends with a worker pool
    /// override this to schedule all jobs as one submission wave.
    fn combine_batch(&self, jobs: &[CombineJob]) -> Result<Vec<Vec<pool::PooledBuf>>> {
        jobs.iter()
            .map(|j| {
                if j.xor_only() {
                    Ok(vec![self.fold(&j.sources)?])
                } else {
                    self.matmul(&j.coeffs, &j.sources)
                }
            })
            .collect()
    }
}

/// Pure-rust backend over the [`crate::gf`] substrate, running on the
/// process-wide [`GfEngine`](crate::gf::GfEngine) (SIMD tier + striped
/// workers) with pooled output buffers.
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeCoder;

impl CodingEngine for NativeCoder {
    fn backend(&self) -> &'static str {
        "native"
    }

    fn encode(&self, code: &Code, data: &[&[u8]]) -> Result<Vec<Vec<u8>>> {
        Ok(code.encode_blocks(data))
    }

    fn fold(&self, sources: &[&[u8]]) -> Result<pool::PooledBuf> {
        anyhow::ensure!(!sources.is_empty(), "fold needs sources");
        let mut out = pool::take_for_overwrite(sources[0].len());
        dispatch::engine().fold_blocks(&mut out, sources);
        Ok(out)
    }

    fn matmul(&self, coeffs: &[Vec<u8>], sources: &[&[u8]]) -> Result<Vec<pool::PooledBuf>> {
        let len = sources.first().map_or(0, |s| s.len());
        let rows: Vec<&[u8]> = coeffs.iter().map(|r| r.as_slice()).collect();
        let mut outs: Vec<pool::PooledBuf> =
            (0..coeffs.len()).map(|_| pool::take_for_overwrite(len)).collect();
        dispatch::engine().matmul_blocks(&rows, sources, &mut outs);
        Ok(outs)
    }

    /// All jobs of the event go into one [`crate::gf::GfEngine::batch`]
    /// wave: the
    /// worker pool schedules lane-tasks across stripes, so a multi-stripe
    /// repair of small blocks parallelizes even though each individual
    /// combine is below the intra-block striping threshold. Byte-identical
    /// to the sequential default (`tests/batch.rs` fuzzes this).
    fn combine_batch(&self, jobs: &[CombineJob]) -> Result<Vec<Vec<pool::PooledBuf>>> {
        let engine = dispatch::engine();
        // xor-only jobs (the common local-repair case) go through the fold
        // path and never read coefficient tables — don't build them.
        let tables: Vec<Option<Vec<Vec<NibbleTables>>>> = jobs
            .iter()
            .map(|j| (!j.xor_only()).then(|| NibbleTables::for_rows(j.coeffs.iter())))
            .collect();
        let mut outs: Vec<Vec<pool::PooledBuf>> = jobs
            .iter()
            .map(|j| {
                let len = j.sources.first().map_or(0, |s| s.len());
                (0..j.coeffs.len()).map(|_| pool::take_for_overwrite(len)).collect()
            })
            .collect();
        let work: usize = jobs.iter().map(|j| j.work()).sum();
        engine.batch(work, |b| {
            for ((job, tab), out) in jobs.iter().zip(&tables).zip(outs.iter_mut()) {
                match tab {
                    Some(tab) => b.matmul_t(tab, job.sources.clone(), out),
                    None if !job.sources.is_empty() => {
                        b.fold(&mut out[0], job.sources.clone());
                    }
                    // xor-only with no sources: the zero-length output row
                    // is already correct.
                    None => {}
                }
            }
        });
        Ok(outs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::spec::{CodeFamily, Scheme};
    use crate::prng::Prng;

    #[test]
    fn native_encode_matches_code() {
        let code = Scheme::S42.build(CodeFamily::UniLrc);
        let mut p = Prng::new(1);
        let data: Vec<Vec<u8>> = (0..30).map(|_| p.bytes(64)).collect();
        let drefs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
        let out = NativeCoder.encode(&code, &drefs).unwrap();
        assert_eq!(out, code.encode_blocks(&drefs));
    }

    #[test]
    fn native_fold_and_matmul() {
        let mut p = Prng::new(2);
        let a = p.bytes(100);
        let b = p.bytes(100);
        let fold = NativeCoder.fold(&[&a, &b]).unwrap();
        let expect: Vec<u8> = a.iter().zip(&b).map(|(x, y)| x ^ y).collect();
        assert_eq!(fold, expect);
        let mm = NativeCoder.matmul(&[vec![1, 1]], &[&a, &b]).unwrap();
        assert_eq!(mm[0], expect);
    }
}
