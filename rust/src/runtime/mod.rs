//! PJRT runtime — loads the AOT artifacts (`artifacts/*.hlo.txt`, built
//! once by `make artifacts`) and executes them on the request path.
//!
//! Python is *never* invoked here: [`artifacts::Manifest`] indexes the HLO
//! text files, [`pjrt::PjrtCoder`] compiles them on the PJRT CPU client at
//! startup and runs encode / xor-fold / generic-decode on raw byte blocks.
//!
//! [`CodingEngine`] abstracts the coding backend so the proxy can run
//! either through PJRT (default — proves L1/L2/L3 compose) or through the
//! native GF substrate ([`NativeCoder`], the ISA-L analogue used for wide
//! sweeps); integration tests assert the two produce identical bytes.

pub mod artifacts;

// The real PJRT backend needs the `xla` FFI crate; without the `pjrt`
// feature a stub with the same surface keeps every call site compiling
// (construction fails with a clear error instead).
#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(not(feature = "pjrt"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;

pub use artifacts::{Artifact, ArtifactKind, Manifest};
pub use pjrt::PjrtCoder;

use crate::codes::Code;
use crate::gf::{dispatch, pool};
use anyhow::Result;

/// Backend-independent coding interface used by the proxy's coding service.
pub trait CodingEngine: Send + Sync {
    /// Human-readable backend name.
    fn backend(&self) -> &'static str;

    /// Encode: `k` data blocks → `n−k` parity blocks.
    fn encode(&self, code: &Code, data: &[&[u8]]) -> Result<Vec<Vec<u8>>>;

    /// XOR-fold the sources into one block (XOR-local repair).
    fn fold(&self, sources: &[&[u8]]) -> Result<Vec<u8>>;

    /// General linear combination: `coeffs` is `outs × sources.len()`.
    fn matmul(&self, coeffs: &[Vec<u8>], sources: &[&[u8]]) -> Result<Vec<Vec<u8>>>;
}

/// Pure-rust backend over the [`crate::gf`] substrate, running on the
/// process-wide [`GfEngine`](crate::gf::GfEngine) (SIMD tier + striped
/// workers) with pooled output buffers.
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeCoder;

impl CodingEngine for NativeCoder {
    fn backend(&self) -> &'static str {
        "native"
    }

    fn encode(&self, code: &Code, data: &[&[u8]]) -> Result<Vec<Vec<u8>>> {
        Ok(code.encode_blocks(data))
    }

    fn fold(&self, sources: &[&[u8]]) -> Result<Vec<u8>> {
        anyhow::ensure!(!sources.is_empty(), "fold needs sources");
        let mut out = pool::take_zeroed(sources[0].len());
        dispatch::engine().fold_blocks(&mut out, sources);
        Ok(out)
    }

    fn matmul(&self, coeffs: &[Vec<u8>], sources: &[&[u8]]) -> Result<Vec<Vec<u8>>> {
        let len = sources.first().map_or(0, |s| s.len());
        let rows: Vec<&[u8]> = coeffs.iter().map(|r| r.as_slice()).collect();
        let mut outs: Vec<Vec<u8>> = (0..coeffs.len()).map(|_| pool::take_zeroed(len)).collect();
        dispatch::engine().matmul_blocks(&rows, sources, &mut outs);
        Ok(outs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::spec::{CodeFamily, Scheme};
    use crate::prng::Prng;

    #[test]
    fn native_encode_matches_code() {
        let code = Scheme::S42.build(CodeFamily::UniLrc);
        let mut p = Prng::new(1);
        let data: Vec<Vec<u8>> = (0..30).map(|_| p.bytes(64)).collect();
        let drefs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
        let out = NativeCoder.encode(&code, &drefs).unwrap();
        assert_eq!(out, code.encode_blocks(&drefs));
    }

    #[test]
    fn native_fold_and_matmul() {
        let mut p = Prng::new(2);
        let a = p.bytes(100);
        let b = p.bytes(100);
        let fold = NativeCoder.fold(&[&a, &b]).unwrap();
        let expect: Vec<u8> = a.iter().zip(&b).map(|(x, y)| x ^ y).collect();
        assert_eq!(fold, expect);
        let mm = NativeCoder.matmul(&[vec![1, 1]], &[&a, &b]).unwrap();
        assert_eq!(mm[0], expect);
    }
}
