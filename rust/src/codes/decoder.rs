//! Generic multi-erasure decoder.
//!
//! Works on the parity-check matrix `H = [A | I]` of any [`Code`]: an
//! erasure pattern `E` is recoverable iff the columns `H_E` have full column
//! rank (the Theorem 3.2 criterion), and the decode itself is the solve
//! `H_E · e = H_S · s` over GF(2^8). The returned [`DecodePlan`] expresses
//! each erased block as a linear combination of surviving blocks, pruned to
//! the sources actually referenced, and can be executed on real byte blocks.

use super::Code;
use crate::gf::pool;
use crate::gf::slice::{gf_matmul_blocks, NibbleTables};
use crate::gf::tables::{gf_inv, gf_mul};
use crate::gf::{dispatch, GfEngine, Matrix};

/// A planned multi-erasure decode.
#[derive(Debug, Clone, PartialEq)]
pub struct DecodePlan {
    /// Erased block ids, in the order rows of `coeffs` reconstruct them.
    pub erased: Vec<usize>,
    /// Surviving block ids actually read (columns of `coeffs`).
    pub sources: Vec<usize>,
    /// `erased.len() × sources.len()` reconstruction coefficients.
    pub coeffs: Matrix,
}

impl DecodePlan {
    /// Total blocks read.
    pub fn read_cost(&self) -> usize {
        self.sources.len()
    }

    /// GF multiplications per byte of output (coefficients ∉ {0,1}).
    pub fn mul_ops(&self) -> usize {
        (0..self.coeffs.rows())
            .map(|i| self.coeffs.row(i).iter().filter(|&&c| c > 1).count())
            .sum()
    }

    /// True if the whole decode is XOR-only.
    pub fn xor_only(&self) -> bool {
        (0..self.coeffs.rows()).all(|i| self.coeffs.row(i).iter().all(|&c| c <= 1))
    }

    /// Execute on real blocks: `sources[i]` is the block `self.sources[i]`.
    /// Returns the reconstructed blocks in `self.erased` order as
    /// 64-byte-aligned pooled buffers; callers on the repair path should
    /// return them via [`crate::gf::pool::recycle`].
    pub fn execute(&self, sources: &[&[u8]]) -> Vec<pool::PooledBuf> {
        assert_eq!(sources.len(), self.sources.len());
        let len = sources.first().map_or(0, |s| s.len());
        let rows: Vec<&[u8]> = (0..self.coeffs.rows()).map(|i| self.coeffs.row(i)).collect();
        let mut outs: Vec<pool::PooledBuf> =
            (0..self.erased.len()).map(|_| pool::take_for_overwrite(len)).collect();
        gf_matmul_blocks(&rows, sources, &mut outs);
        outs
    }

    /// Execute the same plan over many stripes in one worker-pool
    /// submission wave: `stripes[s][i]` is block `self.sources[i]` of
    /// stripe `s`. Returns per-stripe reconstructed blocks in
    /// `self.erased` order — byte-identical to per-stripe
    /// [`Self::execute`], but the coefficient tables are built once and the
    /// pool schedules lane-tasks across stripes (the full-node recovery
    /// shape). Buffers come from the block pool.
    pub fn execute_batch(&self, stripes: &[Vec<&[u8]>]) -> Vec<Vec<pool::PooledBuf>> {
        self.execute_batch_on(dispatch::engine(), stripes)
    }

    /// [`Self::execute_batch`] on a specific engine.
    pub fn execute_batch_on(
        &self,
        e: &GfEngine,
        stripes: &[Vec<&[u8]>],
    ) -> Vec<Vec<pool::PooledBuf>> {
        for sources in stripes {
            assert_eq!(sources.len(), self.sources.len());
        }
        let tables = NibbleTables::for_rows((0..self.coeffs.rows()).map(|i| self.coeffs.row(i)));
        e.matmul_stripes_t(&tables, stripes)
    }
}

/// Is the erasure pattern recoverable? (rank test only — cheaper than
/// building a full plan).
pub fn recoverable(code: &Code, erased: &[usize]) -> bool {
    let e = normalize(code, erased);
    if e.is_empty() {
        return true;
    }
    if e.len() > code.m() {
        return false;
    }
    let h = code.parity_check();
    h.select_cols(&e).rank() == e.len()
}

/// Build a decode plan, or `None` when unrecoverable.
pub fn plan(code: &Code, erased: &[usize]) -> Option<DecodePlan> {
    let e = normalize(code, erased);
    if e.is_empty() {
        return Some(DecodePlan { erased: vec![], sources: vec![], coeffs: Matrix::zero(0, 0) });
    }
    if e.len() > code.m() {
        return None;
    }
    let h = code.parity_check();
    // Boolean erasure mask instead of an O(n·|E|) `e.contains` scan per
    // block — |E| can be ~n/α for whole-cluster failures on wide codes.
    let mut erased_mask = vec![false; code.n()];
    for &b in &e {
        erased_mask[b] = true;
    }
    let surviving: Vec<usize> = (0..code.n()).filter(|&b| !erased_mask[b]).collect();

    // Augmented system [H_E | H_S], reduced so H_E → [I; 0]. In GF(2^k),
    // H_E·x_E = H_S·x_S (no sign: char 2).
    let mut aug = h.select_cols(&e).hstack(&h.select_cols(&surviving));
    let ecols = e.len();
    let mut pivot_row = 0usize;
    for col in 0..ecols {
        let p = (pivot_row..aug.rows()).find(|&r| aug.get(r, col) != 0)?; // rank-deficient ⇒ None
        swap_rows(&mut aug, pivot_row, p);
        let inv = gf_inv(aug.get(pivot_row, col));
        for j in 0..aug.cols() {
            aug.set(pivot_row, j, gf_mul(aug.get(pivot_row, j), inv));
        }
        for r in 0..aug.rows() {
            if r != pivot_row {
                let f = aug.get(r, col);
                if f != 0 {
                    for j in 0..aug.cols() {
                        let v = aug.get(r, j) ^ gf_mul(f, aug.get(pivot_row, j));
                        aug.set(r, j, v);
                    }
                }
            }
        }
        pivot_row += 1;
    }

    // Rows 0..ecols now read: x_E[i] = Σ_j aug[i][ecols + j] · x_S[j].
    // Prune unused sources.
    let mut used = vec![false; surviving.len()];
    for i in 0..ecols {
        for (j, u) in used.iter_mut().enumerate() {
            if aug.get(i, ecols + j) != 0 {
                *u = true;
            }
        }
    }
    let src_idx: Vec<usize> = (0..surviving.len()).filter(|&j| used[j]).collect();
    let sources: Vec<usize> = src_idx.iter().map(|&j| surviving[j]).collect();
    let mut coeffs = Matrix::zero(ecols, sources.len());
    for i in 0..ecols {
        for (jj, &j) in src_idx.iter().enumerate() {
            coeffs.set(i, jj, aug.get(i, ecols + j));
        }
    }
    Some(DecodePlan { erased: e, sources, coeffs })
}

fn normalize(code: &Code, erased: &[usize]) -> Vec<usize> {
    let mut e: Vec<usize> = erased.to_vec();
    e.sort_unstable();
    e.dedup();
    assert!(e.iter().all(|&b| b < code.n()), "erased block out of range");
    e
}

fn swap_rows(m: &mut Matrix, a: usize, b: usize) {
    if a == b {
        return;
    }
    for j in 0..m.cols() {
        let (va, vb) = (m.get(a, j), m.get(b, j));
        m.set(a, j, vb);
        m.set(b, j, va);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::rs::Rs;
    use crate::codes::unilrc::UniLrc;
    use crate::prng::Prng;

    fn stripe_for(code: &Code, p: &mut Prng, block: usize) -> Vec<Vec<u8>> {
        let data: Vec<Vec<u8>> = (0..code.k()).map(|_| p.bytes(block)).collect();
        let drefs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
        let parities = code.encode_blocks(&drefs);
        data.into_iter().chain(parities).collect()
    }

    fn check_decode(code: &Code, erased: &[usize], stripe: &[Vec<u8>]) {
        let plan = plan(code, erased).expect("pattern should decode");
        let srcs: Vec<&[u8]> = plan.sources.iter().map(|&s| stripe[s].as_slice()).collect();
        let rebuilt = plan.execute(&srcs);
        for (i, &b) in plan.erased.iter().enumerate() {
            assert_eq!(rebuilt[i], stripe[b], "block {b}");
        }
    }

    #[test]
    fn rs_decodes_any_nk_erasures() {
        let code = Rs::new(10, 6);
        let mut p = Prng::new(1);
        let stripe = stripe_for(&code, &mut p, 32);
        // all 4-subsets of 10 blocks
        for a in 0..10 {
            for b in a + 1..10 {
                for c in b + 1..10 {
                    for d in c + 1..10 {
                        check_decode(&code, &[a, b, c, d], &stripe);
                    }
                }
            }
        }
    }

    #[test]
    fn rs_rejects_too_many_erasures() {
        let code = Rs::new(10, 6);
        assert!(!recoverable(&code, &[0, 1, 2, 3, 4]));
        assert!(plan(&code, &[0, 1, 2, 3, 4]).is_none());
    }

    #[test]
    fn empty_erasure_is_trivial() {
        let code = Rs::new(6, 4);
        let p = plan(&code, &[]).unwrap();
        assert!(p.erased.is_empty());
        assert!(recoverable(&code, &[]));
    }

    #[test]
    fn duplicate_erasures_deduped() {
        let code = Rs::new(10, 6);
        let mut p = Prng::new(2);
        let stripe = stripe_for(&code, &mut p, 16);
        let plan = plan(&code, &[3, 3, 7]).unwrap();
        assert_eq!(plan.erased, vec![3, 7]);
        let srcs: Vec<&[u8]> = plan.sources.iter().map(|&s| stripe[s].as_slice()).collect();
        let rebuilt = plan.execute(&srcs);
        assert_eq!(rebuilt[0], stripe[3]);
        assert_eq!(rebuilt[1], stripe[7]);
    }

    #[test]
    fn single_erasure_plan_matches_local_repair_cost_unilrc() {
        let code = UniLrc::new(1, 4); // n=20, k=12, r=4
        for b in 0..code.n() {
            let p = plan(&code, &[b]).unwrap();
            // The generic decoder may pick any equation; it must never need
            // more than the worst-case k sources, and the dedicated local
            // plan is r.
            assert!(p.read_cost() <= code.k());
            assert_eq!(code.repair_plan(b).sources.len(), 4);
        }
    }

    #[test]
    fn plan_sources_are_pruned() {
        let code = Rs::new(8, 5);
        let p = plan(&code, &[0]).unwrap();
        // decoding 1 block of an MDS code needs exactly k sources
        assert_eq!(p.read_cost(), 5);
    }
}
