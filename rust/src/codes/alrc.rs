//! Azure-LRC (Huang et al., "Erasure Coding in Windows Azure Storage",
//! USENIX ATC'12) — the first LRC deployed in production (§2.3, Fig 1(a)).
//!
//! Structure: the `k` data blocks are split into `l` equal groups; each
//! group gets one *pure XOR* local parity. `g` global parities are computed
//! over all `k` data blocks with Cauchy coefficients (so the
//! data ∪ globals subcode is MDS). Locality is therefore `k/l` for data and
//! local parities but `k` for global parities — the asymmetry the paper's
//! Figure 1(a) example shows (r̄ = (36·5 + 6·30)/42 = 8.57).

use super::{BlockRole, Code, CodeFamily, LocalGroup};
use crate::gf::Matrix;

pub struct Alrc;

impl Alrc {
    /// Build ALRC(n, k) with `l` local groups and `g` globals
    /// (`l + g = n − k`, `l | k`, `g + k ≤ 255` for Cauchy points).
    pub fn new(n: usize, k: usize, l: usize, g: usize) -> Code {
        assert_eq!(l + g, n - k, "l + g must equal n − k");
        assert!(l >= 1 && k % l == 0, "l must divide k");
        assert!(g + k <= 255, "Cauchy point budget exceeded");
        let seg = k / l;

        // Globals: Cauchy rows (x-set and y-set disjoint by construction).
        let xs: Vec<u8> = (0..g as u16).map(|i| i as u8).collect();
        let ys: Vec<u8> = (g as u16..(g + k) as u16).map(|i| i as u8).collect();
        let gmat = Matrix::cauchy(&xs, &ys);

        // Locals: ones over each data segment.
        let mut lmat = Matrix::zero(l, k);
        for i in 0..l {
            for j in i * seg..(i + 1) * seg {
                lmat.set(i, j, 1);
            }
        }

        // Block order: data, globals, locals.
        let parity = gmat.vstack(&lmat);
        let mut roles = vec![BlockRole::Data; k];
        roles.extend(vec![BlockRole::GlobalParity; g]);
        roles.extend(vec![BlockRole::LocalParity; l]);

        let groups: Vec<LocalGroup> = (0..l)
            .map(|i| {
                let mut members: Vec<usize> = (i * seg..(i + 1) * seg).collect();
                let lp = k + g + i;
                members.push(lp);
                LocalGroup { members, local_parity: lp }
            })
            .collect();

        Code::assemble(
            CodeFamily::Alrc,
            format!("ALRC({n},{k},{{{seg},{k}}}) [l={l}, g={g}]"),
            parity,
            roles,
            groups,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::tests::roundtrip_battery;
    use crate::codes::BlockRole;
    use crate::prng::Prng;

    #[test]
    fn paper_example_42_30() {
        // Fig 1(a): ALRC(42, 30, {5, 30}) — 6 groups of 5 data, 6 globals
        let c = Alrc::new(42, 30, 6, 6);
        assert_eq!(c.groups().len(), 6);
        // r̄ = (36·5 + 6·30)/42 = 8.57
        assert!((c.recovery_locality() - 8.5714).abs() < 1e-3);
    }

    #[test]
    fn data_repair_is_xor_global_repair_is_mul() {
        let c = Alrc::new(42, 30, 6, 6);
        for b in 0..c.n() {
            let plan = c.repair_plan(b);
            match c.role(b) {
                BlockRole::Data | BlockRole::LocalParity => {
                    assert!(plan.xor_only(), "block {b}");
                    assert_eq!(plan.sources.len(), 5);
                }
                BlockRole::GlobalParity => {
                    assert!(!plan.xor_only(), "block {b}");
                    assert_eq!(plan.sources.len(), 30);
                }
            }
        }
    }

    #[test]
    fn tolerates_g_plus_1_sampled() {
        // d = g + 2 ⇒ any g+1 = 7 failures decodable
        let c = Alrc::new(42, 30, 6, 6);
        let mut p = Prng::new(5);
        assert_eq!(c.tolerance_failures_sampled(7, 150, &mut p), 0);
    }

    #[test]
    fn tolerates_g_plus_1_small_exhaustive() {
        // ALRC(12, 8): l=2 groups of 4, g=2 ⇒ any 3 erasures decode
        let c = Alrc::new(12, 8, 2, 2);
        assert!(c.tolerates_all_exhaustive(3));
    }

    #[test]
    fn group_plus_global_failure() {
        // a whole group (5+1) plus one global = 7 = g+1 failures
        let c = Alrc::new(42, 30, 6, 6);
        let mut pattern = c.groups()[0].members.clone();
        pattern.push(30); // first global
        assert!(c.can_decode(&pattern));
    }

    #[test]
    fn beyond_tolerance_fails_somewhere() {
        let c = Alrc::new(42, 30, 6, 6);
        // Gopalan-bound witness (d ≤ g+2 = 8): erase one full local group
        // (its 5 data + local parity) plus 2 global parities — survivors
        // have rank < k, so this 8-pattern is unrecoverable.
        let mut pattern = c.groups()[0].members.clone();
        pattern.push(30);
        pattern.push(31);
        assert_eq!(pattern.len(), 8);
        assert!(!c.can_decode(&pattern), "d should be exactly g+2");
    }

    #[test]
    fn roundtrip() {
        roundtrip_battery(&Alrc::new(42, 30, 6, 6), 50);
        roundtrip_battery(&Alrc::new(24, 16, 4, 4), 51);
    }

    #[test]
    fn paper_schemes_construct() {
        // Table 2 parameterizations: g = f − 1
        let c136 = Alrc::new(136, 112, 8, 16);
        assert_eq!(c136.groups().len(), 8);
        let c210 = Alrc::new(210, 180, 10, 20);
        assert_eq!(c210.groups().len(), 10);
    }
}
