//! Uniform Cauchy LRC (Kadekodi et al., FAST'23) — Google's deployed wide
//! LRC and the paper's headline baseline (§2.3, Fig 1(c)).
//!
//! Structure: data ∪ global parities form a Cauchy MDS code; **all n
//! blocks** (data, globals and the local parities themselves) are
//! partitioned into `l` near-uniform local groups (sizes ⌊n/l⌋ and ⌈n/l⌉),
//! and each group's local parity is the XOR of its other members. Locality
//! is `size − 1`, i.e. two adjacent values — the paper's (42, 30) example
//! has sizes {8, 8, 8, 9, 9} and r̄ = (24·7 + 18·8)/42 = 7.43.
//!
//! Parameterized by the fault-tolerance target `f`: `g = f` globals,
//! `l = n − k − g` locals.

use super::{BlockRole, Code, CodeFamily, LocalGroup};
use crate::gf::Matrix;

pub struct Ulrc;

impl Ulrc {
    /// Build ULRC(n, k) with `g = f` global parities.
    pub fn new(n: usize, k: usize, f: usize) -> Code {
        let g = f;
        assert!(n - k > g, "need at least one local parity");
        let l = n - k - g;
        assert!(g + k <= 255, "Cauchy point budget exceeded");

        let xs: Vec<u8> = (0..g as u16).map(|i| i as u8).collect();
        let ys: Vec<u8> = (g as u16..(g + k) as u16).map(|i| i as u8).collect();
        let gmat = Matrix::cauchy(&xs, &ys);

        // Group sizes: n = l·⌊n/l⌋ + (n mod l); small groups first (matches
        // the paper's {8,8,8,9,9} ordering).
        let base = n / l;
        let extra = n % l;
        let sizes: Vec<usize> =
            (0..l).map(|i| if i < l - extra { base } else { base + 1 }).collect();

        // The non-lp pool in index order: data 0..k, globals k..k+g. Group i
        // takes sizes[i]−1 pool blocks plus its own local parity.
        let mut groups = Vec::with_capacity(l);
        let mut cursor = 0usize;
        for (i, &sz) in sizes.iter().enumerate() {
            let mut members: Vec<usize> = (cursor..cursor + sz - 1).collect();
            cursor += sz - 1;
            let lp = k + g + i;
            members.push(lp);
            groups.push(LocalGroup { members, local_parity: lp });
        }
        assert_eq!(cursor, k + g, "pool must be exactly consumed");

        // Local parity rows: XOR of the member generator rows (unit rows for
        // data members, Cauchy rows for global members).
        let mut lmat = Matrix::zero(l, k);
        for (i, grp) in groups.iter().enumerate() {
            for &m in &grp.members {
                if m < k {
                    let v = lmat.get(i, m) ^ 1;
                    lmat.set(i, m, v);
                } else if m < k + g {
                    for j in 0..k {
                        let v = lmat.get(i, j) ^ gmat.get(m - k, j);
                        lmat.set(i, j, v);
                    }
                }
            }
        }

        let parity = gmat.vstack(&lmat);
        let mut roles = vec![BlockRole::Data; k];
        roles.extend(vec![BlockRole::GlobalParity; g]);
        roles.extend(vec![BlockRole::LocalParity; l]);

        Code::assemble(
            CodeFamily::Ulrc,
            format!("ULRC({n},{k}) [l={l}, g={g}, sizes {:?}]", sizes),
            parity,
            roles,
            groups,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::tests::roundtrip_battery;
    use crate::prng::Prng;

    #[test]
    fn paper_example_42_30() {
        // Fig 1(c): ULRC(42, 30, {7, 8}) — g=7, l=5, sizes {8,8,8,9,9}
        let c = Ulrc::new(42, 30, 7);
        assert_eq!(c.global_parities().len(), 7);
        assert_eq!(c.local_parities().len(), 5);
        let sizes: Vec<usize> = c.groups().iter().map(|g| g.members.len()).collect();
        assert_eq!(sizes, vec![8, 8, 8, 9, 9]);
        // r̄ = (24·7 + 18·8)/42 = 7.43
        assert!((c.recovery_locality() - 7.4286).abs() < 1e-3);
    }

    #[test]
    fn every_block_in_exactly_one_group() {
        let c = Ulrc::new(42, 30, 7);
        let mut count = vec![0usize; c.n()];
        for g in c.groups() {
            for &m in &g.members {
                count[m] += 1;
            }
        }
        assert!(count.iter().all(|&c| c == 1));
    }

    #[test]
    fn all_repairs_are_xor() {
        // unlike ALRC, ULRC's globals sit inside local groups ⇒ XOR repair
        let c = Ulrc::new(42, 30, 7);
        for b in 0..c.n() {
            assert!(c.repair_plan(b).xor_only(), "block {b}");
        }
    }

    #[test]
    fn tolerates_f_sampled() {
        let c = Ulrc::new(42, 30, 7);
        let mut p = Prng::new(8);
        assert_eq!(c.tolerance_failures_sampled(7, 150, &mut p), 0);
    }

    #[test]
    fn roundtrip() {
        roundtrip_battery(&Ulrc::new(42, 30, 7), 70);
    }

    #[test]
    fn paper_schemes_shapes() {
        let c136 = Ulrc::new(136, 112, 17);
        assert_eq!(c136.local_parities().len(), 7);
        let sz: Vec<usize> = c136.groups().iter().map(|g| g.members.len()).collect();
        assert_eq!(sz.iter().sum::<usize>(), 136);
        assert!(sz.iter().all(|&s| s == 19 || s == 20));

        let c210 = Ulrc::new(210, 180, 21);
        assert_eq!(c210.local_parities().len(), 9);
        let sz: Vec<usize> = c210.groups().iter().map(|g| g.members.len()).collect();
        assert_eq!(sz.iter().sum::<usize>(), 210);
        assert!(sz.iter().all(|&s| s == 23 || s == 24));
    }

    #[test]
    fn mixed_failure_patterns_decode() {
        let c = Ulrc::new(42, 30, 7);
        let mut p = Prng::new(9);
        let data: Vec<Vec<u8>> = (0..30).map(|_| p.bytes(32)).collect();
        let drefs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
        let parities = c.encode_blocks(&drefs);
        let stripe: Vec<Vec<u8>> = data.into_iter().chain(parities).collect();
        // failure spanning two groups plus a global and a local parity
        for erased in [vec![0, 7, 30, 37], vec![1, 2, 3, 31, 38], vec![29, 36, 41]] {
            let plan = c.decode_plan(&erased).unwrap();
            let srcs: Vec<&[u8]> = plan.sources.iter().map(|&s| stripe[s].as_slice()).collect();
            let rebuilt = plan.execute(&srcs);
            for (i, &b) in plan.erased.iter().enumerate() {
                assert_eq!(rebuilt[i], stripe[b], "pattern {erased:?} block {b}");
            }
        }
    }
}
