//! Erasure-code constructions: UniLRC (§3) and the deployed baselines the
//! paper compares against (ALRC, OLRC, ULRC, plain Reed–Solomon).
//!
//! All codes are *systematic linear codes over GF(2^8)*: a stripe of `n`
//! blocks is `y = [I_k; A]·x` where `x` is the `k` data blocks. A [`Code`]
//! bundles the generator with its *locality structure* (the local groups of
//! Definition 2.2), from which everything else — repair plans, recovery
//! locality r̄, XOR locality, distance checks — is derived uniformly, so the
//! families are compared apples-to-apples.

pub mod alrc;
pub mod clrc;
pub mod decoder;
pub mod layout;
pub mod olrc;
pub mod plan_cache;
pub mod rs;
pub mod spec;
pub mod ulrc;
pub mod unilrc;

pub use decoder::DecodePlan;
pub use plan_cache::{CacheStats, CachedPlan, EntryStats, PlanCache};
pub use spec::{CodeFamily, Scheme};

use crate::gf::dispatch;
use crate::gf::pool;
use crate::gf::slice::{gf_matmul_blocks, xor_fold, NibbleTables};
use crate::gf::{GfEngine, Matrix};
use std::sync::Arc;

/// Role of a block within a stripe.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockRole {
    Data,
    GlobalParity,
    LocalParity,
}

/// A local (recovery) group: `members` includes the local parity block.
/// Invariant maintained by all constructors: XOR of the generator rows of
/// all members is the zero row, i.e. any member is the XOR of the others
/// — *except* for ALRC-style codes whose groups don't cover global parities;
/// there the group invariant holds too, but some blocks are in no group.
#[derive(Debug, Clone)]
pub struct LocalGroup {
    pub members: Vec<usize>,
    pub local_parity: usize,
}

impl LocalGroup {
    /// Repair sources for a member: every other member.
    pub fn others(&self, block: usize) -> Vec<usize> {
        self.members.iter().copied().filter(|&b| b != block).collect()
    }
}

/// How a single failed block is repaired.
#[derive(Debug, Clone)]
pub struct RepairPlan {
    pub target: usize,
    /// Surviving blocks read, parallel to `coeffs`.
    pub sources: Vec<usize>,
    /// GF(2^8) combination coefficients (all 1 ⇔ pure XOR repair).
    pub coeffs: Vec<u8>,
}

impl RepairPlan {
    /// True when the repair is computed with XOR only (§2.3.3 XOR locality).
    pub fn xor_only(&self) -> bool {
        self.coeffs.iter().all(|&c| c == 1)
    }

    /// Number of GF multiplications performed per byte (coefficients ∉ {0,1}).
    pub fn mul_ops(&self) -> usize {
        self.coeffs.iter().filter(|&&c| c > 1).count()
    }

    /// Number of XOR slice operations performed.
    pub fn xor_ops(&self) -> usize {
        self.sources.len().saturating_sub(1)
    }

    /// Execute on real blocks (sources given in plan order). The output is
    /// a 64-byte-aligned pooled buffer; repair-path callers should return
    /// it via [`crate::gf::pool::recycle`].
    pub fn execute(&self, sources: &[&[u8]]) -> pool::PooledBuf {
        assert_eq!(sources.len(), self.sources.len());
        let len = sources[0].len();
        // Both paths overwrite every output byte (fold copies, matmul
        // zero-fills), so the buffer's stale contents never leak.
        if self.xor_only() {
            let mut out = pool::take_for_overwrite(len);
            xor_fold(&mut out, sources);
            out
        } else {
            let mut outs = vec![pool::take_for_overwrite(len)];
            gf_matmul_blocks(&[&self.coeffs], sources, &mut outs);
            outs.pop().unwrap()
        }
    }
}

/// A fully constructed code instance.
#[derive(Clone)]
pub struct Code {
    pub family: CodeFamily,
    name: String,
    n: usize,
    k: usize,
    /// Parity submatrix `A` ((n−k) × k): rows k..n of the generator.
    parity: Matrix,
    /// Local groups (possibly not covering every block: ALRC/OLRC globals).
    groups: Vec<LocalGroup>,
    roles: Vec<BlockRole>,
    /// groups index per block (usize::MAX = none).
    group_of: Vec<usize>,
}

impl Code {
    /// Assemble a code from its parity matrix and locality structure.
    /// Constructors in the family modules call this; it validates the
    /// group invariant (XOR of member generator rows = 0).
    pub(crate) fn assemble(
        family: CodeFamily,
        name: String,
        parity: Matrix,
        roles: Vec<BlockRole>,
        groups: Vec<LocalGroup>,
    ) -> Code {
        let k = parity.cols();
        let n = k + parity.rows();
        assert_eq!(roles.len(), n);
        // Groups may overlap (OLRC's local parities all cover the global
        // parities); a block repairs via the first group listing it.
        let mut group_of = vec![usize::MAX; n];
        for (gi, g) in groups.iter().enumerate() {
            assert!(g.members.contains(&g.local_parity));
            for &m in &g.members {
                assert!(m < n, "group member out of range");
                if group_of[m] == usize::MAX {
                    group_of[m] = gi;
                }
            }
        }
        let code = Code { family, name, n, k, parity, groups, roles, group_of };
        // Group invariant: XOR of member rows of G = 0 (so intra-group
        // repair is pure XOR).
        for g in &code.groups {
            let mut acc = vec![0u8; k];
            for &m in &g.members {
                for (a, v) in acc.iter_mut().zip(code.generator_row(m)) {
                    *a ^= v;
                }
            }
            assert!(
                acc.iter().all(|&v| v == 0),
                "{}: group at lp {} violates the XOR invariant",
                code.name,
                g.local_parity
            );
        }
        code
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Number of parity blocks `n − k`.
    pub fn m(&self) -> usize {
        self.n - self.k
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn roles(&self) -> &[BlockRole] {
        &self.roles
    }

    pub fn role(&self, block: usize) -> BlockRole {
        self.roles[block]
    }

    pub fn groups(&self) -> &[LocalGroup] {
        &self.groups
    }

    /// Group containing `block`, if any.
    pub fn group_of(&self, block: usize) -> Option<&LocalGroup> {
        self.groups.get(*self.group_of.get(block)?)
    }

    /// Indices of global parity blocks.
    pub fn global_parities(&self) -> Vec<usize> {
        (0..self.n).filter(|&b| self.roles[b] == BlockRole::GlobalParity).collect()
    }

    /// Indices of local parity blocks.
    pub fn local_parities(&self) -> Vec<usize> {
        (0..self.n).filter(|&b| self.roles[b] == BlockRole::LocalParity).collect()
    }

    /// Parity submatrix `A` ((n−k) × k).
    pub fn parity_matrix(&self) -> &Matrix {
        &self.parity
    }

    /// Generator row of a block: unit vector for data, parity row otherwise.
    pub fn generator_row(&self, block: usize) -> Vec<u8> {
        if block < self.k {
            let mut r = vec![0u8; self.k];
            r[block] = 1;
            r
        } else {
            self.parity.row(block - self.k).to_vec()
        }
    }

    /// Full generator matrix `[I_k; A]` (n × k).
    pub fn generator(&self) -> Matrix {
        Matrix::identity(self.k).vstack(&self.parity)
    }

    /// Parity-check matrix `H = [A | I_{n−k}]` ((n−k) × n), block order
    /// (data…, parities…). Satisfies `H·y = 0` for every codeword.
    pub fn parity_check(&self) -> Matrix {
        self.parity.hstack(&Matrix::identity(self.m()))
    }

    /// Code rate `k/n`.
    pub fn rate(&self) -> f64 {
        self.k as f64 / self.n as f64
    }

    // ---------------------------------------------------------------- encode

    /// Encode: compute all `n−k` parity blocks from the `k` data blocks.
    pub fn encode_blocks(&self, data: &[&[u8]]) -> Vec<Vec<u8>> {
        assert_eq!(data.len(), self.k, "need exactly k data blocks");
        let len = data[0].len();
        let rows: Vec<&[u8]> = (0..self.m()).map(|i| self.parity.row(i)).collect();
        let mut outs = vec![vec![0u8; len]; self.m()];
        gf_matmul_blocks(&rows, data, &mut outs);
        outs
    }

    /// Batch encode: compute the parities of many stripes in one worker-pool
    /// submission wave. Equivalent to calling [`Self::encode_blocks`] per
    /// stripe (byte-identical — `tests/batch.rs` fuzzes this), but the
    /// per-coefficient nibble tables are built once and shared, and the
    /// pool schedules lane-tasks *across* stripes — so bulk ingest of small
    /// blocks parallelizes even though each block is below the intra-block
    /// striping threshold.
    pub fn encode_stripes(&self, stripes: &[Vec<&[u8]>]) -> Vec<Vec<pool::PooledBuf>> {
        self.encode_stripes_on(dispatch::engine(), stripes)
    }

    /// [`Self::encode_stripes`] on a specific engine (tests sweep thread
    /// counts through this).
    pub fn encode_stripes_on(
        &self,
        e: &GfEngine,
        stripes: &[Vec<&[u8]>],
    ) -> Vec<Vec<pool::PooledBuf>> {
        for data in stripes {
            assert_eq!(data.len(), self.k, "need exactly k data blocks per stripe");
        }
        let tables = NibbleTables::for_rows((0..self.m()).map(|i| self.parity.row(i)));
        e.matmul_stripes_t(&tables, stripes)
    }

    /// Symbol-level encode (one byte per block) — used by tests and the
    /// golden vectors shared with the Python oracle.
    pub fn encode_symbols(&self, data: &[u8]) -> Vec<u8> {
        assert_eq!(data.len(), self.k);
        let mut stripe = data.to_vec();
        stripe.extend(self.parity.mul_vec(data));
        stripe
    }

    // ---------------------------------------------------------------- repair

    /// Single-failure repair plan. Blocks inside a local group repair by
    /// XORing the rest of the group; blocks outside any group (ALRC/OLRC
    /// global parities, RS blocks) repair through the generic decoder,
    /// which resolves to their generator-row equation (MUL + XOR over the
    /// `k` data blocks for a global parity).
    pub fn repair_plan(&self, block: usize) -> RepairPlan {
        assert!(block < self.n);
        if let Some(g) = self.group_of(block) {
            let sources = g.others(block);
            let coeffs = vec![1u8; sources.len()];
            RepairPlan { target: block, sources, coeffs }
        } else {
            // Outside-group repairs need the generic decoder; the plan is
            // deterministic per (code, block), so reuse it from the cache.
            let plan = self
                .decode_plan_cached(&[block])
                .expect("single-block repair must always be possible");
            RepairPlan {
                target: block,
                coeffs: plan.plan.coeffs.row(0).to_vec(),
                sources: plan.plan.sources.clone(),
            }
        }
    }

    /// Average recovery locality r̄ over all n blocks (§2.3.1).
    pub fn recovery_locality(&self) -> f64 {
        let total: usize = (0..self.n).map(|b| self.repair_plan(b).sources.len()).sum();
        total as f64 / self.n as f64
    }

    // ---------------------------------------------------------------- decode

    /// Plan a multi-erasure decode; `None` if the pattern is unrecoverable.
    /// Always computes from scratch — the repair paths use
    /// [`Self::decode_plan_cached`] instead.
    pub fn decode_plan(&self, erased: &[usize]) -> Option<DecodePlan> {
        decoder::plan(self, erased)
    }

    /// [`Self::decode_plan`] through the process-wide [`PlanCache`]:
    /// repeated erasure patterns skip the rank test and matrix inversion
    /// and come back with the SIMD nibble tables prebuilt.
    pub fn decode_plan_cached(&self, erased: &[usize]) -> Option<Arc<CachedPlan>> {
        plan_cache::global().get_or_compute(self, erased)
    }

    /// True if the erasure pattern is recoverable.
    pub fn can_decode(&self, erased: &[usize]) -> bool {
        decoder::recoverable(self, erased)
    }

    /// Verify that *every* erasure pattern of size `t` decodes
    /// (exhaustive — use only for small `n`).
    pub fn tolerates_all_exhaustive(&self, t: usize) -> bool {
        let mut pattern: Vec<usize> = (0..t).collect();
        loop {
            if !self.can_decode(&pattern) {
                return false;
            }
            // next combination
            let mut i = t;
            loop {
                if i == 0 {
                    return true;
                }
                i -= 1;
                if pattern[i] != i + self.n - t {
                    pattern[i] += 1;
                    for j in i + 1..t {
                        pattern[j] = pattern[j - 1] + 1;
                    }
                    break;
                }
            }
        }
    }

    /// Randomized tolerance check: `samples` random erasure patterns of
    /// size `t`; returns the number that failed to decode.
    pub fn tolerance_failures_sampled(
        &self,
        t: usize,
        samples: usize,
        prng: &mut crate::prng::Prng,
    ) -> usize {
        (0..samples)
            .filter(|_| !self.can_decode(&prng.choose_distinct(self.n, t)))
            .count()
    }
}

impl std::fmt::Debug for Code {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} (n={}, k={}, groups={}, rate={:.4})",
            self.name,
            self.n,
            self.k,
            self.groups.len(),
            self.rate()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Prng;

    /// Shared battery run against every family (see family modules for
    /// construction-specific tests).
    pub(crate) fn roundtrip_battery(code: &Code, seed: u64) {
        let mut p = Prng::new(seed);
        let block = 64;
        let data: Vec<Vec<u8>> = (0..code.k()).map(|_| p.bytes(block)).collect();
        let drefs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
        let parities = code.encode_blocks(&drefs);
        let stripe: Vec<&[u8]> =
            drefs.iter().copied().chain(parities.iter().map(|v| v.as_slice())).collect();

        // symbol-level encode agrees with block-level encode per byte
        for b in 0..block.min(4) {
            let dsyms: Vec<u8> = data.iter().map(|d| d[b]).collect();
            let ssyms = code.encode_symbols(&dsyms);
            for (i, s) in stripe.iter().enumerate() {
                assert_eq!(ssyms[i], s[b], "block {i} byte {b}");
            }
        }

        // every single-block repair reconstructs the block
        for target in 0..code.n() {
            let plan = code.repair_plan(target);
            let srcs: Vec<&[u8]> = plan.sources.iter().map(|&s| stripe[s]).collect();
            let rebuilt = plan.execute(&srcs);
            assert_eq!(rebuilt.as_slice(), stripe[target], "repair of block {target}");
        }
    }

    #[test]
    fn repair_plan_cost_accounting() {
        let plan = RepairPlan { target: 0, sources: vec![1, 2, 3], coeffs: vec![1, 1, 1] };
        assert!(plan.xor_only());
        assert_eq!(plan.mul_ops(), 0);
        assert_eq!(plan.xor_ops(), 2);
        let plan2 = RepairPlan { target: 0, sources: vec![1, 2], coeffs: vec![3, 1] };
        assert!(!plan2.xor_only());
        assert_eq!(plan2.mul_ops(), 1);
    }
}
