//! Cascaded Parity LRC ("Making Wide Stripes Practical", 2025) — the
//! source paper's direct successor and ROADMAP item 4's fifth family.
//!
//! Structure: `g = f − 1` global parities are computed over all `k` data
//! blocks with Cauchy coefficients (as in ALRC), but the globals are then
//! *cascaded*: one extra parity — the XOR of the `g` globals — turns them
//! into a local group of their own. The `k` data blocks split into
//! `l = n − k − g − 1` equal groups with one XOR local parity each. Every
//! block therefore sits in exactly one group and every single-block repair
//! is pure XOR, collapsing ALRC's locality asymmetry (globals repaired by
//! reading all `k` data blocks) to a uniform `max(k/l, g)` — at (42, 30)
//! r̄ = 6.0 vs ALRC's 8.57 and ULRC's 7.43.
//!
//! Fault tolerance: puncturing the cascade parity leaves exactly
//! Azure-LRC(k, l, g), whose Cauchy construction decodes any `g + 1 = f`
//! erasures; the cascade row only adds equations, so CLRC tolerates ≥ f
//! node failures. The cascade additionally buys back patterns ALRC loses —
//! a whole data group plus one global (f + 1 erasures) still decodes,
//! because the cascade equation re-derives the missing Cauchy equation.

use super::{BlockRole, Code, CodeFamily, LocalGroup};
use crate::gf::Matrix;

pub struct Clrc;

impl Clrc {
    /// Build CLRC(n, k) for fault-tolerance target `f`: `g = f − 1`
    /// Cauchy globals + 1 cascade parity + `l = n − k − g − 1` XOR locals
    /// (`l | k`, `g + k ≤ 255` for Cauchy points).
    pub fn new(n: usize, k: usize, f: usize) -> Code {
        assert!(f >= 2, "cascading needs at least one global");
        let g = f - 1;
        assert!(n - k > g + 1, "need at least one local data group");
        let l = n - k - g - 1;
        assert!(k % l == 0, "l = n−k−g−1 must divide k");
        assert!(g + k <= 255, "Cauchy point budget exceeded");
        let seg = k / l;

        // Globals: Cauchy rows, same point sets as ALRC/ULRC.
        let xs: Vec<u8> = (0..g as u16).map(|i| i as u8).collect();
        let ys: Vec<u8> = (g as u16..(g + k) as u16).map(|i| i as u8).collect();
        let gmat = Matrix::cauchy(&xs, &ys);

        // Cascade parity: XOR of the g global generator rows, so the
        // globals + cascade form a local group satisfying the XOR invariant.
        let mut cascade = Matrix::zero(1, k);
        for i in 0..g {
            for j in 0..k {
                let v = cascade.get(0, j) ^ gmat.get(i, j);
                cascade.set(0, j, v);
            }
        }

        // Locals: ones over each data segment.
        let mut lmat = Matrix::zero(l, k);
        for i in 0..l {
            for j in i * seg..(i + 1) * seg {
                lmat.set(i, j, 1);
            }
        }

        // Block order: data, globals, cascade, locals.
        let parity = gmat.vstack(&cascade).vstack(&lmat);
        let mut roles = vec![BlockRole::Data; k];
        roles.extend(vec![BlockRole::GlobalParity; g]);
        roles.push(BlockRole::LocalParity); // the cascade parity
        roles.extend(vec![BlockRole::LocalParity; l]);

        let cascade_idx = k + g;
        let mut groups: Vec<LocalGroup> = (0..l)
            .map(|i| {
                let mut members: Vec<usize> = (i * seg..(i + 1) * seg).collect();
                let lp = cascade_idx + 1 + i;
                members.push(lp);
                LocalGroup { members, local_parity: lp }
            })
            .collect();
        let mut cascade_members: Vec<usize> = (k..k + g).collect();
        cascade_members.push(cascade_idx);
        groups.push(LocalGroup { members: cascade_members, local_parity: cascade_idx });

        Code::assemble(
            CodeFamily::Clrc,
            format!("CLRC({n},{k},{{{seg},{g}}}) [l={l}, g={g}]"),
            parity,
            roles,
            groups,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::tests::roundtrip_battery;
    use crate::prng::Prng;

    #[test]
    fn paper_example_42_30() {
        // f=7 ⇒ g=6 globals + cascade, l=5 groups of 6 data
        let c = Clrc::new(42, 30, 7);
        assert_eq!(c.global_parities().len(), 6);
        assert_eq!(c.local_parities().len(), 6); // 5 locals + cascade
        assert_eq!(c.groups().len(), 6);
        assert!(c.groups().iter().all(|g| g.members.len() == 7));
        // uniform locality 6 everywhere ⇒ r̄ = 6.0, the family's selling point
        assert!((c.recovery_locality() - 6.0).abs() < 1e-9);
    }

    #[test]
    fn every_block_in_exactly_one_group() {
        let c = Clrc::new(42, 30, 7);
        let mut count = vec![0usize; c.n()];
        for g in c.groups() {
            for &m in &g.members {
                count[m] += 1;
            }
        }
        assert!(count.iter().all(|&c| c == 1));
    }

    #[test]
    fn all_repairs_are_xor() {
        // the cascade group covers the globals ⇒ no k-wide MUL repairs left
        let c = Clrc::new(42, 30, 7);
        for b in 0..c.n() {
            let plan = c.repair_plan(b);
            assert!(plan.xor_only(), "block {b}");
            assert_eq!(plan.sources.len(), 6, "block {b}");
        }
    }

    #[test]
    fn tolerates_f_sampled() {
        let c = Clrc::new(42, 30, 7);
        let mut p = Prng::new(11);
        assert_eq!(c.tolerance_failures_sampled(7, 150, &mut p), 0);
    }

    #[test]
    fn tolerates_f_small_exhaustive() {
        // CLRC(12, 6, f=3): g=2 + cascade, l=3 groups of 2 ⇒ any 3 decode
        let c = Clrc::new(12, 6, 3);
        assert!(c.tolerates_all_exhaustive(3));
    }

    #[test]
    fn cascade_buys_back_group_plus_global() {
        // a whole data group (6+1) plus one global = 8 = f+1 erasures:
        // the cascade equation recovers the missing Cauchy row, so this
        // decodes where plain ALRC would not
        let c = Clrc::new(42, 30, 7);
        let mut pattern = c.groups()[0].members.clone();
        pattern.push(30); // first global
        assert_eq!(pattern.len(), 8);
        assert!(c.can_decode(&pattern));
    }

    #[test]
    fn beyond_tolerance_fails_somewhere() {
        // a whole data group + two globals: the survivors span only 8
        // equations over 9 unknowns ⇒ unrecoverable witness at 9 erasures
        let c = Clrc::new(42, 30, 7);
        let mut pattern = c.groups()[0].members.clone();
        pattern.push(30);
        pattern.push(31);
        assert_eq!(pattern.len(), 9);
        assert!(!c.can_decode(&pattern));
    }

    #[test]
    fn roundtrip() {
        roundtrip_battery(&Clrc::new(42, 30, 7), 55);
        roundtrip_battery(&Clrc::new(24, 16, 4), 56);
    }

    #[test]
    fn paper_schemes_shapes() {
        // g = f − 1, seg = k/l = g at all three Table 2 schemes
        let c136 = Clrc::new(136, 112, 17);
        assert_eq!(c136.groups().len(), 8);
        assert!(c136.groups().iter().all(|g| g.members.len() == 17));
        let c210 = Clrc::new(210, 180, 21);
        assert_eq!(c210.groups().len(), 10);
        assert!(c210.groups().iter().all(|g| g.members.len() == 21));
    }

    #[test]
    fn mixed_failure_patterns_decode() {
        let c = Clrc::new(42, 30, 7);
        let mut p = Prng::new(12);
        let data: Vec<Vec<u8>> = (0..30).map(|_| p.bytes(32)).collect();
        let drefs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
        let parities = c.encode_blocks(&drefs);
        let stripe: Vec<Vec<u8>> = data.into_iter().chain(parities).collect();
        // failures spanning data groups, globals, the cascade and locals
        for erased in [vec![0, 7, 30, 36], vec![1, 2, 3, 31, 37], vec![29, 35, 41]] {
            let plan = c.decode_plan(&erased).unwrap();
            let srcs: Vec<&[u8]> = plan.sources.iter().map(|&s| stripe[s].as_slice()).collect();
            let rebuilt = plan.execute(&srcs);
            for (i, &b) in plan.erased.iter().enumerate() {
                assert_eq!(rebuilt[i], stripe[b], "pattern {erased:?} block {b}");
            }
        }
    }
}
