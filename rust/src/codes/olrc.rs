//! Optimal Cauchy LRC (Kadekodi et al., "Practical Design Considerations
//! for Wide LRCs", FAST'23) — Google's distance-optimal wide LRC
//! (§2.3, Fig 1(b)).
//!
//! Structure (reverse-engineered from the paper's worked example, which it
//! matches exactly — see DESIGN.md §8): data ∪ global parities form a
//! Cauchy MDS code; each of the `l` local parities is the XOR of its
//! segment of `k/l` data blocks **plus all `g` global parities**. Every
//! block therefore has uniform locality `k/l + g` (all local groups share
//! the global parities), which for (42, 30) gives the paper's r̄ = 25.
//!
//! `l` is the largest integer satisfying the construction condition
//! `g·l² < k + g·l` (§2.3.1 Limitation #1) with `g = n − k − l`; the small
//! `l` ⇒ huge local groups is exactly the recovery-locality weakness the
//! paper criticizes.

use super::{BlockRole, Code, CodeFamily, LocalGroup};
use crate::gf::Matrix;

pub struct Olrc;

impl Olrc {
    /// Choose `l` per the construction condition.
    pub fn pick_l(n: usize, k: usize) -> usize {
        let m = n - k;
        let mut best = 1;
        for l in 1..m {
            let g = m - l;
            // gl² < k + gl  ⇔  g·l·(l−1) < k
            if g * l * l < k + g * l && k % l == 0 {
                best = l;
            }
        }
        best
    }

    /// Build OLRC(n, k).
    pub fn new(n: usize, k: usize) -> Code {
        let l = Self::pick_l(n, k);
        let g = n - k - l;
        assert!(g + k <= 255, "Cauchy point budget exceeded");
        let seg = k / l;

        let xs: Vec<u8> = (0..g as u16).map(|i| i as u8).collect();
        let ys: Vec<u8> = (g as u16..(g + k) as u16).map(|i| i as u8).collect();
        let gmat = Matrix::cauchy(&xs, &ys);

        // Local parity i = XOR(data segment i) ⊕ XOR(all globals): its
        // generator row is the segment indicator plus the XOR of all global
        // rows.
        let mut lmat = Matrix::zero(l, k);
        for i in 0..l {
            for j in i * seg..(i + 1) * seg {
                lmat.set(i, j, 1);
            }
            for gr in 0..g {
                for j in 0..k {
                    let v = lmat.get(i, j) ^ gmat.get(gr, j);
                    lmat.set(i, j, v);
                }
            }
        }

        let parity = gmat.vstack(&lmat);
        let mut roles = vec![BlockRole::Data; k];
        roles.extend(vec![BlockRole::GlobalParity; g]);
        roles.extend(vec![BlockRole::LocalParity; l]);

        // Each group: data segment + ALL globals + its local parity.
        // Groups overlap on the globals by construction.
        let groups: Vec<LocalGroup> = (0..l)
            .map(|i| {
                let mut members: Vec<usize> = (i * seg..(i + 1) * seg).collect();
                members.extend(k..k + g);
                let lp = k + g + i;
                members.push(lp);
                LocalGroup { members, local_parity: lp }
            })
            .collect();

        let r = seg + g;
        Code::assemble(
            CodeFamily::Olrc,
            format!("OLRC({n},{k},{r}) [l={l}, g={g}]"),
            parity,
            roles,
            groups,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::tests::roundtrip_battery;
    use crate::prng::Prng;

    #[test]
    fn paper_example_42_30() {
        // Fig 1(b): OLRC(42, 30, 25) — l=2, g=10, uniform locality 25
        assert_eq!(Olrc::pick_l(42, 30), 2);
        let c = Olrc::new(42, 30);
        assert_eq!(c.global_parities().len(), 10);
        assert_eq!(c.local_parities().len(), 2);
        for b in 0..c.n() {
            assert_eq!(c.repair_plan(b).sources.len(), 25, "block {b}");
        }
        assert!((c.recovery_locality() - 25.0).abs() < 1e-9);
    }

    #[test]
    fn construction_condition_other_schemes() {
        assert_eq!(Olrc::pick_l(136, 112), 2); // g=22: 22·4=88 < 112+44
        assert_eq!(Olrc::pick_l(210, 180), 3); // g=27: 27·9=243 < 180+81=261
    }

    #[test]
    fn no_xor_locality() {
        // Limitation #3: OLRC local repair mixes globals in ⇒ the group XOR
        // trick still works (group XORs to zero) but spans 25 blocks; global
        // rows themselves are MUL-heavy. The *repair* is XOR but huge.
        let c = Olrc::new(42, 30);
        let plan = c.repair_plan(0);
        assert_eq!(plan.sources.len(), 25);
        assert!(plan.xor_only(), "group-based repair is XOR of 25 blocks");
    }

    #[test]
    fn distance_larger_than_others() {
        // r = 25 ⇒ Singleton: d ≤ n−k−⌈k/r⌉+2 = 12; sample 11-erasure decode
        let c = Olrc::new(42, 30);
        let mut p = Prng::new(7);
        assert_eq!(c.tolerance_failures_sampled(11, 100, &mut p), 0);
    }

    #[test]
    fn roundtrip() {
        roundtrip_battery(&Olrc::new(42, 30), 60);
    }

    #[test]
    fn groups_share_globals() {
        let c = Olrc::new(42, 30);
        let g0 = &c.groups()[0];
        let g1 = &c.groups()[1];
        for gp in c.global_parities() {
            assert!(g0.members.contains(&gp));
            assert!(g1.members.contains(&gp));
        }
    }
}
