//! Figure 1-style textual layout rendering: show each code's local groups,
//! block roles and localities (used by `unilrc layout` and the docs).

use super::{BlockRole, Code};

/// Short label for a block: d1…, g1…, l1… (1-based like the paper figures).
pub fn block_label(code: &Code, block: usize) -> String {
    let k = code.k();
    let g = code.global_parities().len();
    match code.role(block) {
        BlockRole::Data => format!("d{}", block + 1),
        BlockRole::GlobalParity => format!("g{}", block - k + 1),
        BlockRole::LocalParity => format!("l{}", block - k - g + 1),
    }
}

/// Render the grouped layout of a code as text lines.
pub fn render(code: &Code) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{}  rate={:.4}  r̄={:.2}\n",
        code.name(),
        code.rate(),
        code.recovery_locality()
    ));
    let mut in_group = vec![false; code.n()];
    for (i, grp) in code.groups().iter().enumerate() {
        let labels: Vec<String> = grp.members.iter().map(|&m| block_label(code, m)).collect();
        out.push_str(&format!(
            "  group {:>2} (|{}| = {:>2}, repair = {} XORs): {}\n",
            i + 1,
            block_label(code, grp.local_parity),
            grp.members.len(),
            grp.members.len() - 1,
            labels.join(" ")
        ));
        for &m in &grp.members {
            in_group[m] = true;
        }
    }
    let ungrouped: Vec<String> = (0..code.n())
        .filter(|&b| !in_group[b])
        .map(|b| {
            let plan = code.repair_plan(b);
            format!("{} (repair = {} blocks, MUL)", block_label(code, b), plan.sources.len())
        })
        .collect();
    if !ungrouped.is_empty() {
        out.push_str(&format!("  ungrouped: {}\n", ungrouped.join(" ")));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::spec::{CodeFamily, Scheme};

    #[test]
    fn labels_match_paper_convention() {
        let c = Scheme::S42.build(CodeFamily::UniLrc);
        assert_eq!(block_label(&c, 0), "d1");
        assert_eq!(block_label(&c, 29), "d30");
        assert_eq!(block_label(&c, 30), "g1");
        assert_eq!(block_label(&c, 36), "l1");
        assert_eq!(block_label(&c, 41), "l6");
    }

    #[test]
    fn render_all_families() {
        for fam in CodeFamily::paper_baselines() {
            let c = Scheme::S42.build(fam);
            let text = render(&c);
            assert!(text.contains("group"), "{fam:?}");
            assert!(text.lines().count() >= 2);
        }
    }

    #[test]
    fn alrc_has_ungrouped_globals() {
        let c = Scheme::S42.build(CodeFamily::Alrc);
        let text = render(&c);
        assert!(text.contains("ungrouped"));
        assert!(text.contains("MUL"));
    }
}
