//! LRU cache of decode plans, with per-entry hit accounting, an optional
//! TTL, and a warm-up prefetch path for predicted failure patterns.
//!
//! Building a [`DecodePlan`] runs a rank test and a Gauss–Jordan solve over
//! the parity-check matrix — O((n−k)·n·|E|) field ops. Repairs repeat the
//! same erasure pattern constantly (every block of a failed node, every
//! stripe of a reconstruction drill), so the plan is worth caching: keyed
//! by (code name, sorted erasure pattern), the cache returns the previously
//! inverted plan — with the per-coefficient split-nibble tables the SIMD
//! kernels consume already built — and the repair skips matrix work
//! entirely. Unrecoverable patterns are cached too (as `None`), so repeated
//! rank-deficient probes are also free.
//!
//! Each entry tracks its own hit count and creation time; [`PlanCache::stats`]
//! surfaces them (shown by `unilrc engine`). A TTL ([`PlanCache::set_ttl`],
//! env `UNILRC_PLAN_TTL_MS`, config `[experiment] plan_ttl_ms`) expires
//! stale entries on lookup — long-running deployments whose failure
//! patterns drift don't pin dead plans in the LRU working set. Near-expiry
//! entries are proactively rebuilt on GF-worker idle time
//! ([`PlanCache::refresh_expiring`], wired into the pool's idle tick by
//! [`global`]), so TTL turnover rarely lands as a demand-path re-inversion.
//!
//! [`PlanCache::prefetch`] warms the cache with *predicted* erasure
//! patterns (the distinct per-stripe patterns a fault trace will produce —
//! `experiments::exp7_faults` with `--plan-warmup`) so the first failure
//! burst of a multi-tenant sim pays no inversion latency. Prefetched
//! entries are tracked separately from demand misses in [`CacheStats`]
//! (`prefetched` / `prefetch_hits`), and repairs are byte-identical warm
//! or cold — only where the inversion cost lands changes.
//!
//! Azure-LRC-style deployments do the same plan reuse; `tests/plan_cache.rs`
//! asserts cached plans are identical to freshly computed ones and that
//! repeated lookups do not re-invert.

use super::decoder::{self, DecodePlan};
use super::Code;
use crate::gf::dispatch;
use crate::gf::pool;
use crate::gf::slice::NibbleTables;
use crate::gf::GfEngine;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// A decode plan plus the precomputed per-coefficient nibble tables.
pub struct CachedPlan {
    pub plan: DecodePlan,
    /// `erased × sources` tables, parallel to `plan.coeffs`.
    tables: Vec<Vec<NibbleTables>>,
}

impl CachedPlan {
    fn new(plan: DecodePlan) -> CachedPlan {
        let tables = NibbleTables::for_rows((0..plan.coeffs.rows()).map(|i| plan.coeffs.row(i)));
        CachedPlan { plan, tables }
    }

    /// Execute on real blocks (`sources[i]` is block `plan.sources[i]`),
    /// using the prebuilt tables and pooled output buffers. Returns the
    /// reconstructed blocks in `plan.erased` order as 64-byte-aligned
    /// pooled buffers; callers should hand them back via
    /// [`crate::gf::pool::recycle`].
    pub fn execute(&self, sources: &[&[u8]]) -> Vec<pool::PooledBuf> {
        assert_eq!(sources.len(), self.plan.sources.len());
        let len = sources.first().map_or(0, |s| s.len());
        let mut outs: Vec<pool::PooledBuf> =
            (0..self.plan.erased.len()).map(|_| pool::take_for_overwrite(len)).collect();
        dispatch::engine().matmul_blocks_t(&self.tables, sources, &mut outs);
        outs
    }

    /// Execute the cached plan over many stripes in one worker-pool
    /// submission wave (`stripes[s][i]` is block `plan.sources[i]` of
    /// stripe `s`): the multi-stripe repair hot path. Byte-identical to
    /// per-stripe [`Self::execute`]; the prebuilt tables are shared and the
    /// pool schedules lane-tasks across stripes, so full-node recovery of
    /// small blocks parallelizes end to end.
    pub fn execute_batch(&self, stripes: &[Vec<&[u8]>]) -> Vec<Vec<pool::PooledBuf>> {
        self.execute_batch_on(dispatch::engine(), stripes)
    }

    /// [`Self::execute_batch`] on a specific engine.
    pub fn execute_batch_on(
        &self,
        e: &GfEngine,
        stripes: &[Vec<&[u8]>],
    ) -> Vec<Vec<pool::PooledBuf>> {
        for sources in stripes {
            assert_eq!(sources.len(), self.plan.sources.len());
        }
        e.matmul_stripes_t(&self.tables, stripes)
    }
}

type Key = (String, Vec<usize>);

struct Entry {
    stamp: u64,
    /// Lookups served by this entry since insertion.
    hits: u64,
    created: Instant,
    /// Inserted by [`PlanCache::prefetch`] rather than a demand miss.
    prefetched: bool,
    /// The code this plan was built for — kept so idle-time refresh
    /// ([`PlanCache::refresh_expiring`]) can rebuild the plan in place.
    code: Code,
    /// `None` caches "pattern is unrecoverable".
    val: Option<Arc<CachedPlan>>,
}

struct Inner {
    map: BTreeMap<Key, Entry>,
    tick: u64,
    /// Entries older than this are dropped on lookup (`None` = keep forever).
    ttl: Option<Duration>,
}

/// Per-entry view for introspection (`unilrc engine`).
#[derive(Debug, Clone)]
pub struct EntryStats {
    pub code: String,
    pub erased: Vec<usize>,
    pub hits: u64,
    pub age: Duration,
    pub recoverable: bool,
    /// Inserted by warm-up prefetch rather than a demand miss.
    pub prefetched: bool,
}

/// Aggregate cache statistics.
#[derive(Debug, Clone)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub expirations: u64,
    /// Plans inserted by [`PlanCache::prefetch`] (counted separately from
    /// demand `misses` — warm-up work is not demand-path latency).
    pub prefetched: u64,
    /// Demand lookups served by a prefetched entry (subset of `hits`).
    pub prefetch_hits: u64,
    /// Plans proactively rebuilt on idle worker time before their TTL
    /// expired ([`PlanCache::refresh_expiring`]).
    pub refreshed: u64,
    pub entries: usize,
    pub cap: usize,
    pub ttl: Option<Duration>,
    /// Entries sorted by hit count, hottest first.
    pub top: Vec<EntryStats>,
}

/// Bounded LRU plan cache (thread-safe; plan construction runs outside the
/// lock so a slow inversion never blocks concurrent hits).
pub struct PlanCache {
    cap: usize,
    inner: Mutex<Inner>,
    hits: AtomicU64,
    misses: AtomicU64,
    expirations: AtomicU64,
    prefetched: AtomicU64,
    prefetch_hits: AtomicU64,
    refreshed: AtomicU64,
}

impl PlanCache {
    pub const fn new(cap: usize) -> PlanCache {
        PlanCache {
            cap,
            inner: Mutex::new(Inner { map: BTreeMap::new(), tick: 0, ttl: None }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            expirations: AtomicU64::new(0),
            prefetched: AtomicU64::new(0),
            prefetch_hits: AtomicU64::new(0),
            refreshed: AtomicU64::new(0),
        }
    }

    /// Expire entries older than `ttl` on lookup (`None` disables expiry).
    /// Already-resident entries are judged by their original insertion
    /// time, so tightening the TTL takes effect immediately.
    pub fn set_ttl(&self, ttl: Option<Duration>) {
        self.inner.lock().unwrap().ttl = ttl;
    }

    pub fn ttl(&self) -> Option<Duration> {
        self.inner.lock().unwrap().ttl
    }

    /// The cached plan for `erased` on `code`, computing and inserting it
    /// on first sight. `None` means the pattern is unrecoverable.
    pub fn get_or_compute(&self, code: &Code, erased: &[usize]) -> Option<Arc<CachedPlan>> {
        let mut pattern = erased.to_vec();
        pattern.sort_unstable();
        pattern.dedup();
        let key: Key = (code.name().to_string(), pattern);
        {
            let mut inner = self.inner.lock().unwrap();
            inner.tick += 1;
            let tick = inner.tick;
            let ttl = inner.ttl;
            let expired = match inner.map.get_mut(&key) {
                Some(e) => {
                    if ttl.is_some_and(|t| e.created.elapsed() > t) {
                        true
                    } else {
                        e.stamp = tick;
                        e.hits += 1;
                        self.hits.fetch_add(1, Ordering::Relaxed);
                        if e.prefetched {
                            self.prefetch_hits.fetch_add(1, Ordering::Relaxed);
                        }
                        return e.val.clone();
                    }
                }
                None => false,
            };
            if expired {
                inner.map.remove(&key);
                self.expirations.fetch_add(1, Ordering::Relaxed);
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let val = decoder::plan(code, erased).map(|p| Arc::new(CachedPlan::new(p)));
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        // A racing compute may have inserted meanwhile; keep the first.
        let fresh = Entry {
            stamp: tick,
            hits: 0,
            created: Instant::now(),
            prefetched: false,
            code: code.clone(),
            val,
        };
        let entry = inner.map.entry(key).or_insert(fresh);
        entry.stamp = tick;
        let out = entry.val.clone();
        Self::evict_to_cap(&mut inner, self.cap);
        out
    }

    /// Warm the cache with predicted erasure `patterns` for `code` ahead of
    /// demand (failure-trace warm-up, `--plan-warmup`). Patterns already
    /// resident are left untouched; newly built plans are tagged so
    /// [`CacheStats`] separates warm-up work (`prefetched`) from demand
    /// `misses`, and later demand hits on them count as `prefetch_hits`.
    /// Unrecoverable patterns are cached as `None`, exactly like the demand
    /// path. Returns the number of entries inserted.
    pub fn prefetch(&self, code: &Code, patterns: &[Vec<usize>]) -> usize {
        let mut inserted = 0usize;
        for pat in patterns {
            let mut pattern = pat.clone();
            pattern.sort_unstable();
            pattern.dedup();
            let key: Key = (code.name().to_string(), pattern.clone());
            {
                // TTL-expired residents count as absent (like the demand
                // path), so warm-up re-builds them instead of leaving the
                // first post-expiry burst cold.
                let mut inner = self.inner.lock().unwrap();
                let ttl = inner.ttl;
                match inner.map.get(&key) {
                    Some(e) if ttl.is_some_and(|t| e.created.elapsed() > t) => {
                        inner.map.remove(&key);
                        self.expirations.fetch_add(1, Ordering::Relaxed);
                    }
                    Some(_) => continue,
                    None => {}
                }
            }
            // Plan construction runs outside the lock, like the demand path.
            let val = decoder::plan(code, &pattern).map(|p| Arc::new(CachedPlan::new(p)));
            let mut inner = self.inner.lock().unwrap();
            inner.tick += 1;
            let tick = inner.tick;
            let fresh = Entry {
                stamp: tick,
                hits: 0,
                created: Instant::now(),
                prefetched: true,
                code: code.clone(),
                val,
            };
            if let std::collections::btree_map::Entry::Vacant(slot) = inner.map.entry(key) {
                slot.insert(fresh);
                inserted += 1;
                self.prefetched.fetch_add(1, Ordering::Relaxed);
            }
            Self::evict_to_cap(&mut inner, self.cap);
        }
        inserted
    }

    /// Proactively rebuild recoverable entries that will hit the TTL within
    /// `margin`, resetting their age so the next demand lookup stays a hit
    /// instead of paying an expiration + re-inversion. Runs plan
    /// construction outside the lock (like the demand path); per-entry hit
    /// counts, prefetch tags, and LRU stamps are preserved. Returns the
    /// number of entries refreshed. A cache without a TTL never expires, so
    /// this is a no-op there.
    ///
    /// The process-wide cache wires this into the worker pool's idle tick
    /// ([`crate::gf::workpool::add_idle_hook`]) — refresh happens on
    /// otherwise wasted worker time, not on the repair path.
    pub fn refresh_expiring(&self, margin: Duration) -> usize {
        let Some(ttl) = self.ttl() else { return 0 };
        let deadline = ttl.saturating_sub(margin);
        // Snapshot the expiring keys + codes under the lock; invert outside.
        let stale: Vec<(Key, Code)> = {
            let inner = self.inner.lock().unwrap();
            inner
                .map
                .iter()
                .filter(|(_, e)| e.val.is_some() && e.created.elapsed() >= deadline)
                .map(|(k, e)| (k.clone(), e.code.clone()))
                .collect()
        };
        let mut refreshed = 0usize;
        for (key, code) in stale {
            let val = decoder::plan(&code, &key.1).map(|p| Arc::new(CachedPlan::new(p)));
            let mut inner = self.inner.lock().unwrap();
            // Re-arm only if still resident (eviction or expiry may race).
            if let Some(e) = inner.map.get_mut(&key) {
                e.val = val;
                e.created = Instant::now();
                refreshed += 1;
            }
        }
        if refreshed > 0 {
            self.refreshed.fetch_add(refreshed as u64, Ordering::Relaxed);
        }
        refreshed
    }

    fn evict_to_cap(inner: &mut Inner, cap: usize) {
        while inner.map.len() > cap {
            match inner.map.iter().min_by_key(|(_, e)| e.stamp).map(|(k, _)| k.clone()) {
                Some(oldest) => inner.map.remove(&oldest),
                None => break,
            };
        }
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Entries dropped because they outlived the TTL.
    pub fn expirations(&self) -> u64 {
        self.expirations.load(Ordering::Relaxed)
    }

    /// Plans inserted by [`Self::prefetch`].
    pub fn prefetched(&self) -> u64 {
        self.prefetched.load(Ordering::Relaxed)
    }

    /// Demand lookups served by a prefetched entry.
    pub fn prefetch_hits(&self) -> u64 {
        self.prefetch_hits.load(Ordering::Relaxed)
    }

    /// Plans proactively rebuilt by [`Self::refresh_expiring`].
    pub fn refreshed(&self) -> u64 {
        self.refreshed.load(Ordering::Relaxed)
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of aggregate and per-entry statistics; `top_n` bounds the
    /// per-entry listing (hottest first).
    pub fn stats(&self, top_n: usize) -> CacheStats {
        let inner = self.inner.lock().unwrap();
        let mut top: Vec<EntryStats> = inner
            .map
            .iter()
            .map(|((code, erased), e)| EntryStats {
                code: code.clone(),
                erased: erased.clone(),
                hits: e.hits,
                age: e.created.elapsed(),
                recoverable: e.val.is_some(),
                prefetched: e.prefetched,
            })
            .collect();
        top.sort_by(|a, b| b.hits.cmp(&a.hits));
        top.truncate(top_n);
        CacheStats {
            hits: self.hits(),
            misses: self.misses(),
            expirations: self.expirations(),
            prefetched: self.prefetched(),
            prefetch_hits: self.prefetch_hits(),
            refreshed: self.refreshed(),
            entries: inner.map.len(),
            cap: self.cap,
            ttl: inner.ttl,
            top,
        }
    }

    /// Drop every cached plan (stats are preserved).
    pub fn clear(&self) {
        self.inner.lock().unwrap().map.clear();
    }
}

/// Worst-case working set: one entry per block of the widest paper scheme
/// per family, plus room for multi-failure patterns.
const GLOBAL_CAP: usize = 1024;

static GLOBAL: PlanCache = PlanCache::new(GLOBAL_CAP);

/// How far before TTL expiry an entry becomes eligible for idle-time
/// refresh. Generous relative to plan-inversion cost, small relative to
/// any production TTL.
const REFRESH_MARGIN: Duration = Duration::from_millis(500);

/// The process-wide plan cache used by [`Code::decode_plan_cached`] and the
/// proxy repair path. First use registers its proactive TTL refresh on the
/// GF worker pool's idle tick, so near-expiry plans are rebuilt on idle
/// worker time instead of as demand-path misses.
pub fn global() -> &'static PlanCache {
    static REGISTER: std::sync::Once = std::sync::Once::new();
    REGISTER.call_once(|| {
        crate::gf::workpool::add_idle_hook(|| {
            GLOBAL.refresh_expiring(REFRESH_MARGIN);
        });
    });
    &GLOBAL
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::rs::Rs;
    use crate::codes::spec::{CodeFamily, Scheme};

    #[test]
    fn hit_returns_same_plan_without_reinversion() {
        let cache = PlanCache::new(16);
        let code = Rs::new(10, 6);
        let a = cache.get_or_compute(&code, &[1, 3]).unwrap();
        assert_eq!((cache.hits(), cache.misses()), (0, 1));
        let b = cache.get_or_compute(&code, &[3, 1, 3]).unwrap(); // same normalized pattern
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
        assert!(Arc::ptr_eq(&a, &b), "hit must return the cached Arc, not a recompute");
    }

    #[test]
    fn cached_equals_fresh() {
        let cache = PlanCache::new(16);
        let code = Scheme::S42.build(CodeFamily::UniLrc);
        for pattern in [vec![0], vec![0, 1], vec![5, 17, 40], vec![2, 9]] {
            let cached = cache.get_or_compute(&code, &pattern).unwrap();
            let fresh = decoder::plan(&code, &pattern).unwrap();
            assert_eq!(cached.plan, fresh, "pattern {pattern:?}");
        }
    }

    #[test]
    fn unrecoverable_is_cached_as_none() {
        let cache = PlanCache::new(16);
        let code = Rs::new(10, 6);
        assert!(cache.get_or_compute(&code, &[0, 1, 2, 3, 4]).is_none());
        assert!(cache.get_or_compute(&code, &[0, 1, 2, 3, 4]).is_none());
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
    }

    #[test]
    fn distinct_codes_do_not_collide() {
        let cache = PlanCache::new(16);
        let a = Rs::new(10, 6);
        let b = Rs::new(8, 5);
        let pa = cache.get_or_compute(&a, &[0]).unwrap();
        let pb = cache.get_or_compute(&b, &[0]).unwrap();
        assert_eq!(cache.misses(), 2);
        assert_ne!(pa.plan.sources.len(), pb.plan.sources.len());
    }

    #[test]
    fn eviction_bounds_len() {
        let cache = PlanCache::new(4);
        let code = Rs::new(10, 6);
        for b in 0..10 {
            cache.get_or_compute(&code, &[b]);
        }
        assert!(cache.len() <= 4);
        // the most recent entry survived
        cache.get_or_compute(&code, &[9]);
        assert_eq!(cache.hits(), 1);
    }

    #[test]
    fn per_entry_hits_tracked_in_stats() {
        let cache = PlanCache::new(16);
        let code = Rs::new(10, 6);
        cache.get_or_compute(&code, &[0]);
        for _ in 0..3 {
            cache.get_or_compute(&code, &[1]);
        }
        let stats = cache.stats(8);
        assert_eq!(stats.entries, 2);
        assert_eq!(stats.hits, 2);
        assert_eq!(stats.misses, 2);
        assert_eq!(stats.top[0].erased, vec![1], "hottest entry first");
        assert_eq!(stats.top[0].hits, 2);
        assert!(stats.top[0].recoverable);
        let capped = cache.stats(1);
        assert_eq!(capped.top.len(), 1);
    }

    #[test]
    fn ttl_expires_entries() {
        let cache = PlanCache::new(16);
        let code = Rs::new(10, 6);
        cache.set_ttl(Some(Duration::ZERO));
        cache.get_or_compute(&code, &[0]);
        std::thread::sleep(Duration::from_millis(2));
        // expired on lookup: recomputed, counted as expiration + miss
        cache.get_or_compute(&code, &[0]);
        assert_eq!(cache.expirations(), 1);
        assert_eq!((cache.hits(), cache.misses()), (0, 2));
        // disabling the TTL makes entries stick again
        cache.set_ttl(None);
        cache.get_or_compute(&code, &[0]);
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.ttl(), None);
    }

    #[test]
    fn prefetch_counts_separately_from_demand_misses() {
        let cache = PlanCache::new(16);
        let code = Rs::new(10, 6);
        let inserted = cache.prefetch(&code, &[vec![0, 1], vec![2], vec![1, 0]]);
        assert_eq!(inserted, 2, "duplicate normalized pattern inserted once");
        assert_eq!(cache.prefetched(), 2);
        assert_eq!((cache.hits(), cache.misses()), (0, 0), "warm-up is not demand traffic");
        // demand lookup of a prefetched pattern: a hit, tagged prefetch_hit
        let warm = cache.get_or_compute(&code, &[1, 0]).unwrap();
        assert_eq!((cache.hits(), cache.misses()), (1, 0));
        assert_eq!(cache.prefetch_hits(), 1);
        // demand miss on an unseen pattern stays a plain miss
        cache.get_or_compute(&code, &[5]).unwrap();
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
        assert_eq!(cache.prefetch_hits(), 1);
        // prefetching an already-resident pattern is a no-op
        assert_eq!(cache.prefetch(&code, &[vec![5]]), 0);
        assert_eq!(cache.prefetched(), 2);
        // the warm plan is exactly what a fresh inversion produces
        let fresh = decoder::plan(&code, &[0, 1]).unwrap();
        assert_eq!(warm.plan, fresh);
        let stats = cache.stats(8);
        assert_eq!(stats.prefetched, 2);
        assert_eq!(stats.prefetch_hits, 1);
        assert!(stats.top.iter().any(|e| e.prefetched));
    }

    #[test]
    fn prefetch_caches_unrecoverable_and_respects_cap() {
        let cache = PlanCache::new(3);
        let code = Rs::new(10, 6);
        let inserted = cache.prefetch(&code, &[vec![0, 1, 2, 3, 4]]);
        assert_eq!(inserted, 1);
        assert!(cache.get_or_compute(&code, &[0, 1, 2, 3, 4]).is_none());
        assert_eq!((cache.hits(), cache.misses()), (1, 0), "unrecoverable served from warm-up");
        let many: Vec<Vec<usize>> = (0..8).map(|b| vec![b]).collect();
        cache.prefetch(&code, &many);
        assert!(cache.len() <= 3, "prefetch respects the LRU cap");
    }

    #[test]
    fn prefetch_rebuilds_ttl_expired_entries() {
        let cache = PlanCache::new(16);
        let code = Rs::new(10, 6);
        cache.set_ttl(Some(Duration::ZERO));
        assert_eq!(cache.prefetch(&code, &[vec![0, 1]]), 1);
        std::thread::sleep(Duration::from_millis(2));
        // an expired resident counts as absent: rebuilt, not skipped
        assert_eq!(cache.prefetch(&code, &[vec![0, 1]]), 1);
        assert_eq!(cache.expirations(), 1);
        assert_eq!(cache.prefetched(), 2);
        cache.set_ttl(None);
        assert_eq!(cache.prefetch(&code, &[vec![0, 1]]), 0, "live residents are skipped");
    }

    #[test]
    fn refresh_expiring_rebuilds_before_ttl() {
        let cache = PlanCache::new(16);
        let code = Rs::new(10, 6);
        cache.set_ttl(Some(Duration::from_secs(3600)));
        let a = cache.get_or_compute(&code, &[0, 1]).unwrap();
        // far from expiry with a zero margin: nothing to do
        assert_eq!(cache.refresh_expiring(Duration::ZERO), 0);
        // a margin spanning the whole TTL treats every entry as expiring
        assert_eq!(cache.refresh_expiring(Duration::from_secs(3600)), 1);
        assert_eq!(cache.refreshed(), 1);
        // the next demand lookup is a *hit* on the rebuilt (identical) plan
        let b = cache.get_or_compute(&code, &[0, 1]).unwrap();
        assert!(!Arc::ptr_eq(&a, &b), "refresh rebuilt the plan in place");
        assert_eq!(b.plan, a.plan);
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
        assert_eq!(cache.expirations(), 0, "refresh pre-empted the expiration");
        assert_eq!(cache.stats(4).refreshed, 1);
        // without a TTL nothing ever expires, so refresh is a no-op
        cache.set_ttl(None);
        assert_eq!(cache.refresh_expiring(Duration::from_secs(3600)), 0);
    }

    #[test]
    fn cached_execute_reconstructs() {
        let cache = PlanCache::new(8);
        let code = Rs::new(10, 6);
        let mut p = crate::prng::Prng::new(11);
        let data: Vec<Vec<u8>> = (0..code.k()).map(|_| p.bytes(333)).collect();
        let drefs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
        let parities = code.encode_blocks(&drefs);
        let stripe: Vec<Vec<u8>> = data.into_iter().chain(parities).collect();
        let plan = cache.get_or_compute(&code, &[2, 7]).unwrap();
        let srcs: Vec<&[u8]> = plan.plan.sources.iter().map(|&s| stripe[s].as_slice()).collect();
        let rebuilt = plan.execute(&srcs);
        assert_eq!(rebuilt[0], stripe[2]);
        assert_eq!(rebuilt[1], stripe[7]);
    }
}
