//! LRU cache of decode plans.
//!
//! Building a [`DecodePlan`] runs a rank test and a Gauss–Jordan solve over
//! the parity-check matrix — O((n−k)·n·|E|) field ops. Repairs repeat the
//! same erasure pattern constantly (every block of a failed node, every
//! stripe of a reconstruction drill), so the plan is worth caching: keyed
//! by (code name, sorted erasure pattern), the cache returns the previously
//! inverted plan — with the per-coefficient split-nibble tables the SIMD
//! kernels consume already built — and the repair skips matrix work
//! entirely. Unrecoverable patterns are cached too (as `None`), so repeated
//! rank-deficient probes are also free.
//!
//! Azure-LRC-style deployments do the same plan reuse; `tests/plan_cache.rs`
//! asserts cached plans are identical to freshly computed ones and that
//! repeated lookups do not re-invert.

use super::decoder::{self, DecodePlan};
use super::Code;
use crate::gf::dispatch;
use crate::gf::pool;
use crate::gf::slice::NibbleTables;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// A decode plan plus the precomputed per-coefficient nibble tables.
pub struct CachedPlan {
    pub plan: DecodePlan,
    /// `erased × sources` tables, parallel to `plan.coeffs`.
    tables: Vec<Vec<NibbleTables>>,
}

impl CachedPlan {
    fn new(plan: DecodePlan) -> CachedPlan {
        let tables = (0..plan.coeffs.rows())
            .map(|i| plan.coeffs.row(i).iter().map(|&c| NibbleTables::new(c)).collect())
            .collect();
        CachedPlan { plan, tables }
    }

    /// Execute on real blocks (`sources[i]` is block `plan.sources[i]`),
    /// using the prebuilt tables and pooled output buffers. Returns the
    /// reconstructed blocks in `plan.erased` order; callers may hand the
    /// buffers back via [`crate::gf::pool::recycle`].
    pub fn execute(&self, sources: &[&[u8]]) -> Vec<Vec<u8>> {
        assert_eq!(sources.len(), self.plan.sources.len());
        let len = sources.first().map_or(0, |s| s.len());
        let mut outs: Vec<Vec<u8>> =
            (0..self.plan.erased.len()).map(|_| pool::take_zeroed(len)).collect();
        dispatch::engine().matmul_blocks_t(&self.tables, sources, &mut outs);
        outs
    }
}

type Key = (String, Vec<usize>);

struct Entry {
    stamp: u64,
    /// `None` caches "pattern is unrecoverable".
    val: Option<Arc<CachedPlan>>,
}

struct Inner {
    map: BTreeMap<Key, Entry>,
    tick: u64,
}

/// Bounded LRU plan cache (thread-safe; plan construction runs outside the
/// lock so a slow inversion never blocks concurrent hits).
pub struct PlanCache {
    cap: usize,
    inner: Mutex<Inner>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl PlanCache {
    pub const fn new(cap: usize) -> PlanCache {
        PlanCache {
            cap,
            inner: Mutex::new(Inner { map: BTreeMap::new(), tick: 0 }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// The cached plan for `erased` on `code`, computing and inserting it
    /// on first sight. `None` means the pattern is unrecoverable.
    pub fn get_or_compute(&self, code: &Code, erased: &[usize]) -> Option<Arc<CachedPlan>> {
        let mut pattern = erased.to_vec();
        pattern.sort_unstable();
        pattern.dedup();
        let key: Key = (code.name().to_string(), pattern);
        {
            let mut inner = self.inner.lock().unwrap();
            inner.tick += 1;
            let tick = inner.tick;
            if let Some(e) = inner.map.get_mut(&key) {
                e.stamp = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                return e.val.clone();
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let val = decoder::plan(code, erased).map(|p| Arc::new(CachedPlan::new(p)));
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        // A racing compute may have inserted meanwhile; keep the first.
        let entry = inner.map.entry(key).or_insert(Entry { stamp: tick, val });
        entry.stamp = tick;
        let out = entry.val.clone();
        if inner.map.len() > self.cap {
            if let Some(oldest) =
                inner.map.iter().min_by_key(|(_, e)| e.stamp).map(|(k, _)| k.clone())
            {
                inner.map.remove(&oldest);
            }
        }
        out
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every cached plan (stats are preserved).
    pub fn clear(&self) {
        self.inner.lock().unwrap().map.clear();
    }
}

/// Worst-case working set: one entry per block of the widest paper scheme
/// per family, plus room for multi-failure patterns.
const GLOBAL_CAP: usize = 1024;

static GLOBAL: PlanCache = PlanCache::new(GLOBAL_CAP);

/// The process-wide plan cache used by [`Code::decode_plan_cached`] and the
/// proxy repair path.
pub fn global() -> &'static PlanCache {
    &GLOBAL
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::rs::Rs;
    use crate::codes::spec::{CodeFamily, Scheme};

    #[test]
    fn hit_returns_same_plan_without_reinversion() {
        let cache = PlanCache::new(16);
        let code = Rs::new(10, 6);
        let a = cache.get_or_compute(&code, &[1, 3]).unwrap();
        assert_eq!((cache.hits(), cache.misses()), (0, 1));
        let b = cache.get_or_compute(&code, &[3, 1, 3]).unwrap(); // same normalized pattern
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
        assert!(Arc::ptr_eq(&a, &b), "hit must return the cached Arc, not a recompute");
    }

    #[test]
    fn cached_equals_fresh() {
        let cache = PlanCache::new(16);
        let code = Scheme::S42.build(CodeFamily::UniLrc);
        for pattern in [vec![0], vec![0, 1], vec![5, 17, 40], vec![2, 9]] {
            let cached = cache.get_or_compute(&code, &pattern).unwrap();
            let fresh = decoder::plan(&code, &pattern).unwrap();
            assert_eq!(cached.plan, fresh, "pattern {pattern:?}");
        }
    }

    #[test]
    fn unrecoverable_is_cached_as_none() {
        let cache = PlanCache::new(16);
        let code = Rs::new(10, 6);
        assert!(cache.get_or_compute(&code, &[0, 1, 2, 3, 4]).is_none());
        assert!(cache.get_or_compute(&code, &[0, 1, 2, 3, 4]).is_none());
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
    }

    #[test]
    fn distinct_codes_do_not_collide() {
        let cache = PlanCache::new(16);
        let a = Rs::new(10, 6);
        let b = Rs::new(8, 5);
        let pa = cache.get_or_compute(&a, &[0]).unwrap();
        let pb = cache.get_or_compute(&b, &[0]).unwrap();
        assert_eq!(cache.misses(), 2);
        assert_ne!(pa.plan.sources.len(), pb.plan.sources.len());
    }

    #[test]
    fn eviction_bounds_len() {
        let cache = PlanCache::new(4);
        let code = Rs::new(10, 6);
        for b in 0..10 {
            cache.get_or_compute(&code, &[b]);
        }
        assert!(cache.len() <= 4);
        // the most recent entry survived
        cache.get_or_compute(&code, &[9]);
        assert_eq!(cache.hits(), 1);
    }

    #[test]
    fn cached_execute_reconstructs() {
        let cache = PlanCache::new(8);
        let code = Rs::new(10, 6);
        let mut p = crate::prng::Prng::new(11);
        let data: Vec<Vec<u8>> = (0..code.k()).map(|_| p.bytes(333)).collect();
        let drefs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
        let parities = code.encode_blocks(&drefs);
        let stripe: Vec<Vec<u8>> = data.into_iter().chain(parities).collect();
        let plan = cache.get_or_compute(&code, &[2, 7]).unwrap();
        let srcs: Vec<&[u8]> = plan.plan.sources.iter().map(|&s| stripe[s].as_slice()).collect();
        let rebuilt = plan.execute(&srcs);
        assert_eq!(rebuilt[0], stripe[2]);
        assert_eq!(rebuilt[1], stripe[7]);
    }
}
