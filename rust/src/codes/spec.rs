//! Scheme presets — the paper's Table 2 parameter sets, plus constructors
//! that map a (family, scheme) pair to a concrete [`Code`].

use super::{alrc::Alrc, clrc::Clrc, olrc::Olrc, rs::Rs, ulrc::Ulrc, unilrc::UniLrc, Code};

/// The code families compared throughout the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CodeFamily {
    /// This paper's contribution (§3).
    UniLrc,
    /// Azure-LRC (Huang et al., ATC'12).
    Alrc,
    /// Optimal Cauchy LRC (Google, FAST'23).
    Olrc,
    /// Uniform Cauchy LRC (Google, FAST'23).
    Ulrc,
    /// Cascaded Parity LRC ("Making Wide Stripes Practical", 2025).
    Clrc,
    /// Reed–Solomon (MDS reference, no locality).
    Rs,
}

impl CodeFamily {
    pub fn name(&self) -> &'static str {
        match self {
            CodeFamily::UniLrc => "UniLRC",
            CodeFamily::Alrc => "ALRC",
            CodeFamily::Olrc => "OLRC",
            CodeFamily::Ulrc => "ULRC",
            CodeFamily::Clrc => "CLRC",
            CodeFamily::Rs => "RS",
        }
    }

    /// The LRC families compared in every experiment (excludes RS): the
    /// paper's four plus the Cascaded Parity successor construction.
    pub fn paper_baselines() -> [CodeFamily; 5] {
        [
            CodeFamily::UniLrc,
            CodeFamily::Alrc,
            CodeFamily::Olrc,
            CodeFamily::Ulrc,
            CodeFamily::Clrc,
        ]
    }

    pub fn parse(s: &str) -> Option<CodeFamily> {
        match s.to_ascii_lowercase().as_str() {
            "unilrc" | "uni" => Some(CodeFamily::UniLrc),
            "alrc" | "azure" => Some(CodeFamily::Alrc),
            "olrc" | "optimal" => Some(CodeFamily::Olrc),
            "ulrc" | "uniform" => Some(CodeFamily::Ulrc),
            "clrc" | "cascaded" => Some(CodeFamily::Clrc),
            "rs" | "reed-solomon" => Some(CodeFamily::Rs),
            _ => None,
        }
    }
}

/// A `k`-of-`n` evaluation scheme (paper Table 2): fixes (n, k) and the
/// fault-tolerance requirement `f` (tolerate ≥ f node failures plus one
/// cluster failure); UniLRC realizes it with the given (α, z).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Scheme {
    pub n: usize,
    pub k: usize,
    /// Node-failure tolerance target (d = f + 1 for UniLRC/ALRC/ULRC).
    pub f: usize,
    /// UniLRC scale coefficient.
    pub alpha: usize,
    /// UniLRC cluster count.
    pub z: usize,
}

impl Scheme {
    pub const fn new(n: usize, k: usize, f: usize, alpha: usize, z: usize) -> Scheme {
        Scheme { n, k, f, alpha, z }
    }

    /// Table 2, row 1: (42, 30), f=7, α=1, z=6.
    pub const S42: Scheme = Scheme::new(42, 30, 7, 1, 6);
    /// Table 2, row 2: (136, 112), f=17, α=2, z=8.
    pub const S136: Scheme = Scheme::new(136, 112, 17, 2, 8);
    /// Table 2, row 3: (210, 180), f=21, α=2, z=10.
    pub const S210: Scheme = Scheme::new(210, 180, 21, 2, 10);

    /// The paper's three evaluation schemes.
    pub fn paper_schemes() -> [Scheme; 3] {
        [Scheme::S42, Scheme::S136, Scheme::S210]
    }

    pub fn label(&self) -> String {
        format!("{}-of-{}", self.k, self.n)
    }

    pub fn rate(&self) -> f64 {
        self.k as f64 / self.n as f64
    }

    /// Instantiate a family at this scheme's parameters.
    pub fn build(&self, family: CodeFamily) -> Code {
        match family {
            CodeFamily::UniLrc => {
                let c = UniLrc::new(self.alpha, self.z);
                assert_eq!(c.n(), self.n, "UniLRC(α={},z={}) n mismatch", self.alpha, self.z);
                assert_eq!(c.k(), self.k);
                c
            }
            CodeFamily::Alrc => {
                // g = f − 1 globals (d = g + 2 = f + 1), rest local groups.
                let g = self.f - 1;
                let l = self.n - self.k - g;
                Alrc::new(self.n, self.k, l, g)
            }
            CodeFamily::Olrc => Olrc::new(self.n, self.k),
            CodeFamily::Ulrc => Ulrc::new(self.n, self.k, self.f),
            CodeFamily::Clrc => Clrc::new(self.n, self.k, self.f),
            CodeFamily::Rs => Rs::new(self.n, self.k),
        }
    }

    pub fn parse(s: &str) -> Option<Scheme> {
        match s {
            "42" | "30-of-42" => Some(Scheme::S42),
            "136" | "112-of-136" => Some(Scheme::S136),
            "210" | "180-of-210" => Some(Scheme::S210),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_rates() {
        assert!((Scheme::S42.rate() - 0.7143).abs() < 1e-3);
        assert!((Scheme::S136.rate() - 0.8235).abs() < 1e-3);
        assert!((Scheme::S210.rate() - 0.8571).abs() < 1e-3);
    }

    #[test]
    fn all_families_build_all_schemes() {
        for s in Scheme::paper_schemes() {
            for fam in CodeFamily::paper_baselines() {
                let c = s.build(fam);
                assert_eq!(c.n(), s.n, "{fam:?} {}", s.label());
                assert_eq!(c.k(), s.k, "{fam:?} {}", s.label());
            }
        }
    }

    #[test]
    fn family_parse() {
        assert_eq!(CodeFamily::parse("UniLRC"), Some(CodeFamily::UniLrc));
        assert_eq!(CodeFamily::parse("azure"), Some(CodeFamily::Alrc));
        assert_eq!(CodeFamily::parse("cascaded"), Some(CodeFamily::Clrc));
        assert_eq!(CodeFamily::parse("nope"), None);
    }

    #[test]
    fn scheme_parse() {
        assert_eq!(Scheme::parse("42"), Some(Scheme::S42));
        assert_eq!(Scheme::parse("180-of-210"), Some(Scheme::S210));
        assert_eq!(Scheme::parse("13"), None);
    }
}
