//! UniLRC — the paper's construction (§3.2), verbatim four-step recipe.
//!
//! Parameters: scale coefficient `α` and cluster count `z` give
//! `(n, k, r) = (αz² + z, αz(z−1), αz)` with `g = αz` global parities and
//! `l = z` local parities.
//!
//! 1. Start from a Vandermonde matrix `O` of order `(αz+1) × k` and split it
//!    into the all-ones row `l` and the `αz × k` Vandermonde `𝒢` (rows
//!    `g_j^1 .. g_j^{αz}`) — `𝒢` generates the global parities.
//! 2. Split the ones row into `z` segment indicators → block-diagonal `L`.
//! 3. Fold `𝒢` into `𝒢*` (`z × k`) by XOR-summing each run of `α` rows —
//!    this couples the `α` global parities of each group together.
//! 4. `𝓛 = 𝒢* + L` generates the local parities.
//!
//! The resulting locality structure (§3.1): local group `i` holds its
//! `α(z−1)` data blocks, its `α` global parities, and its local parity —
//! `r + 1 = αz + 1` blocks that XOR to zero, so *every* block (data, local
//! or global parity) repairs with `r` XORs inside one group = one cluster.

use super::{BlockRole, Code, CodeFamily, LocalGroup};
use crate::gf::matrix::distinct_nonzero_points;
use crate::gf::Matrix;

pub struct UniLrc;

impl UniLrc {
    /// Build UniLRC(α, z). Requires `z ≥ 2` and `k = αz(z−1) ≤ 255`.
    pub fn new(alpha: usize, z: usize) -> Code {
        assert!(alpha >= 1, "scale coefficient α must be ≥ 1");
        assert!(z >= 2, "need at least two clusters");
        let k = alpha * z * (z - 1);
        let g = alpha * z;
        let n = k + g + z;
        assert!(k <= 255, "k = αz(z−1) = {k} exceeds GF(2^8) point budget");

        // Step 1: Vandermonde rows g_j^1 .. g_j^{αz} (the ones row of O is
        // conceptually split off here as `l`).
        let points = distinct_nonzero_points(k);
        let gmat = Matrix::vandermonde(g, &points, 1);

        // Step 3: fold every α consecutive rows of 𝒢 into one row of 𝒢*.
        let seg = k / z; // α(z−1) data blocks per group
        let mut lmat = Matrix::zero(z, k);
        for i in 0..z {
            for row in i * alpha..(i + 1) * alpha {
                for j in 0..k {
                    let v = lmat.get(i, j) ^ gmat.get(row, j);
                    lmat.set(i, j, v);
                }
            }
            // Step 2+4: couple with the group's segment of the ones row.
            for j in i * seg..(i + 1) * seg {
                let v = lmat.get(i, j) ^ 1;
                lmat.set(i, j, v);
            }
        }

        // Generator = [I_k; 𝒢; 𝓛]; block order: data, globals, locals.
        let parity = gmat.vstack(&lmat);

        let mut roles = vec![BlockRole::Data; k];
        roles.extend(vec![BlockRole::GlobalParity; g]);
        roles.extend(vec![BlockRole::LocalParity; z]);

        let groups: Vec<LocalGroup> = (0..z)
            .map(|i| {
                let mut members: Vec<usize> = (i * seg..(i + 1) * seg).collect();
                members.extend(k + i * alpha..k + (i + 1) * alpha); // α globals
                let lp = k + g + i;
                members.push(lp);
                LocalGroup { members, local_parity: lp }
            })
            .collect();

        Code::assemble(
            CodeFamily::UniLrc,
            format!("UniLRC({n},{k},{g}) [α={alpha}, z={z}]"),
            parity,
            roles,
            groups,
        )
    }

    /// The §3.3 *Discussion* relaxation for small-scale DSSs: "one local
    /// group, `t` clusters". With `t | z`, the `z` per-cluster segments are
    /// grouped `t` at a time into `l = z/t` local groups (each folding its
    /// `αt` global parities), trading `z − z/t` local parity blocks for a
    /// higher code rate at the cost of `t−1` cross-cluster blocks per
    /// repair (with gateway aggregation).
    ///
    /// Parameters: `n = αz² − αz + αz + z/t = αz² + z/t`,
    /// `k = αz(z−1)`, locality `r = αtz`.
    /// `t = 1` is exactly [`UniLrc::new`].
    pub fn new_relaxed(alpha: usize, z: usize, t: usize) -> Code {
        assert!(t >= 1 && z % t == 0, "t must divide z");
        if t == 1 {
            return Self::new(alpha, z);
        }
        let k = alpha * z * (z - 1);
        let g = alpha * z;
        let l = z / t;
        let n = k + g + l;
        assert!(k <= 255, "k = αz(z−1) = {k} exceeds GF(2^8) point budget");

        let points = distinct_nonzero_points(k);
        let gmat = Matrix::vandermonde(g, &points, 1);

        // Fold αt consecutive global rows per group; couple with the
        // group's k/l data segment.
        let seg = k / l;
        let fold = alpha * t;
        let mut lmat = Matrix::zero(l, k);
        for i in 0..l {
            for row in i * fold..(i + 1) * fold {
                for j in 0..k {
                    let v = lmat.get(i, j) ^ gmat.get(row, j);
                    lmat.set(i, j, v);
                }
            }
            for j in i * seg..(i + 1) * seg {
                let v = lmat.get(i, j) ^ 1;
                lmat.set(i, j, v);
            }
        }

        let parity = gmat.vstack(&lmat);
        let mut roles = vec![BlockRole::Data; k];
        roles.extend(vec![BlockRole::GlobalParity; g]);
        roles.extend(vec![BlockRole::LocalParity; l]);

        let groups: Vec<LocalGroup> = (0..l)
            .map(|i| {
                let mut members: Vec<usize> = (i * seg..(i + 1) * seg).collect();
                members.extend(k + i * fold..k + (i + 1) * fold);
                let lp = k + g + i;
                members.push(lp);
                LocalGroup { members, local_parity: lp }
            })
            .collect();

        Code::assemble(
            CodeFamily::UniLrc,
            format!("UniLRC-relaxed({n},{k},{g}) [α={alpha}, z={z}, t={t}]"),
            parity,
            roles,
            groups,
        )
    }

    /// The locality parameter `r = αz`.
    pub fn locality(alpha: usize, z: usize) -> usize {
        alpha * z
    }

    /// Theoretical minimum distance `d = r + 2` (Theorem 3.2).
    pub fn distance(alpha: usize, z: usize) -> usize {
        alpha * z + 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::tests::roundtrip_battery;
    use crate::prng::Prng;

    #[test]
    fn parameters_match_theorem() {
        for (alpha, z) in [(1, 3), (1, 6), (2, 4), (2, 8), (2, 10), (3, 5)] {
            let c = UniLrc::new(alpha, z);
            assert_eq!(c.n(), alpha * z * z + z);
            assert_eq!(c.k(), alpha * z * z - alpha * z);
            assert_eq!(c.groups().len(), z);
            for g in c.groups() {
                assert_eq!(g.members.len(), alpha * z + 1, "group size must be r+1");
            }
            // Theorem 3.1 code-rate identity
            let r = (alpha * z) as f64;
            let expect = r / (r + 1.0) * (1.0 - 1.0 / z as f64);
            assert!((c.rate() - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn paper_example_42_30() {
        let c = UniLrc::new(1, 6);
        assert_eq!((c.n(), c.k()), (42, 30));
        assert_eq!(c.global_parities().len(), 6);
        assert_eq!(c.local_parities().len(), 6);
        // §3.1: each group = 5 data + 1 global + 1 local
        for g in c.groups() {
            let data = g.members.iter().filter(|&&b| b < 30).count();
            assert_eq!(data, 5);
            assert_eq!(g.members.len(), 7);
        }
        // recovery locality r̄ = r = 6 (Theorem 3.4)
        assert!((c.recovery_locality() - 6.0).abs() < 1e-9);
    }

    #[test]
    fn unified_xor_locality() {
        // every block — data, local AND global parity — has a pure-XOR repair
        let c = UniLrc::new(2, 4);
        for b in 0..c.n() {
            let plan = c.repair_plan(b);
            assert!(plan.xor_only(), "block {b} repair is not XOR-only");
            assert_eq!(plan.sources.len(), 8, "block {b} locality != r");
        }
    }

    #[test]
    fn local_parity_is_xor_of_data_and_globals() {
        // §3.1: l_1 = XOR{d_1..d_5, g_1} for UniLRC(42,30,6)
        let c = UniLrc::new(1, 6);
        let mut p = Prng::new(3);
        let data: Vec<u8> = (0..30).map(|_| p.next_u32() as u8).collect();
        let stripe = c.encode_symbols(&data);
        for i in 0..6 {
            let lp = stripe[36 + i];
            let mut x = stripe[30 + i]; // global parity g_{i+1}
            for j in i * 5..(i + 1) * 5 {
                x ^= stripe[j];
            }
            assert_eq!(lp, x, "group {i}");
        }
    }

    #[test]
    fn distance_exhaustive_small() {
        // UniLRC(1,3): n=12, k=6, r=3, d=5 ⇒ all 4-erasure patterns decode,
        // and at least one 5-erasure pattern does not.
        // Theorem 3.2 claims d = r+2 = 5; our construction measurably does
        // *better*: every 5-erasure pattern decodes (d ≥ 6, the ⌈k/r⌉
        // Singleton value), and dependent 6-sets exist (d = 6 exactly —
        // e.g. the data of two groups plus two globals). The paper's
        // guarantee (any r+1 failures) holds a fortiori; see EXPERIMENTS.md.
        let c = UniLrc::new(1, 3);
        assert!(c.tolerates_all_exhaustive(4)); // paper guarantee d−1 = 4
        assert!(c.tolerates_all_exhaustive(5)); // measured: d ≥ 6
        assert!(!c.can_decode(&[0, 1, 2, 3, 6, 7]), "weight-6 dependency");
        // a whole local group (r+1 = 4 blocks) decodes (one-cluster failure)
        let grp: Vec<usize> = c.groups()[0].members.clone();
        assert!(c.can_decode(&grp));
    }

    #[test]
    fn distance_sampled_paper_schemes() {
        let mut p = Prng::new(11);
        for (alpha, z) in [(1usize, 6usize), (2, 8), (2, 10)] {
            let c = UniLrc::new(alpha, z);
            let d_minus_1 = alpha * z + 1;
            let fails = c.tolerance_failures_sampled(d_minus_1, 60, &mut p);
            assert_eq!(fails, 0, "UniLRC(α={alpha},z={z}) failed {fails} samples");
        }
    }

    #[test]
    fn whole_cluster_failure_decodes() {
        // one local group == one cluster == d−1 erasures (§3.1)
        for (alpha, z) in [(1, 6), (2, 8)] {
            let c = UniLrc::new(alpha, z);
            for g in c.groups() {
                assert!(c.can_decode(&g.members), "cluster loss must decode");
            }
        }
    }

    #[test]
    fn distance_optimality_condition() {
        // Theorem 3.3: n − k − n/(r+1) = d − 2 with (r+1) | n
        for (alpha, z) in [(1, 6), (2, 8), (2, 10)] {
            let c = UniLrc::new(alpha, z);
            let r = alpha * z;
            assert_eq!(c.n() % (r + 1), 0);
            assert_eq!(c.n() - c.k() - c.n() / (r + 1), UniLrc::distance(alpha, z) - 2);
        }
    }

    #[test]
    fn relaxed_construction_properties() {
        // §3.3 Discussion: z=6, t=2 ⇒ l=3 groups spanning 2 clusters each
        let c = UniLrc::new_relaxed(1, 6, 2);
        assert_eq!(c.n(), 30 + 6 + 3);
        assert_eq!(c.k(), 30);
        // higher rate than the strict construction
        let strict = UniLrc::new(1, 6);
        assert!(c.rate() > strict.rate());
        assert_eq!(c.groups().len(), 3);
        for g in c.groups() {
            assert_eq!(g.members.len(), 10 + 2 + 1); // seg + αt globals + lp
        }
        // unified XOR locality survives the relaxation
        for b in 0..c.n() {
            assert!(c.repair_plan(b).xor_only(), "block {b}");
        }
        roundtrip_battery(&c, 77);
    }

    #[test]
    fn relaxed_tolerates_cluster_and_f_failures() {
        let c = UniLrc::new_relaxed(1, 6, 2);
        // one cluster = α(z−1) data + α globals (+ maybe lp) ≤ 7 blocks; the
        // per-cluster slice of each group must decode
        let mut p = Prng::new(21);
        assert_eq!(c.tolerance_failures_sampled(7, 60, &mut p), 0);
        // and a whole group (13 blocks) must NOT decode (only 9 parities)
        assert!(!c.can_decode(&c.groups()[0].members));
    }

    #[test]
    fn relaxed_t1_is_strict() {
        let a = UniLrc::new_relaxed(2, 4, 1);
        let b = UniLrc::new(2, 4);
        assert_eq!(a.parity_matrix(), b.parity_matrix());
    }

    #[test]
    fn roundtrip_paper_scheme() {
        roundtrip_battery(&UniLrc::new(1, 6), 42);
        roundtrip_battery(&UniLrc::new(2, 4), 43);
    }

    #[test]
    fn multi_failure_decode_within_and_across_groups() {
        let c = UniLrc::new(1, 6);
        let mut p = Prng::new(9);
        let data: Vec<Vec<u8>> = (0..30).map(|_| p.bytes(48)).collect();
        let drefs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
        let parities = c.encode_blocks(&drefs);
        let stripe: Vec<Vec<u8>> = data.into_iter().chain(parities).collect();
        // mixed pattern: 2 data from one group, 1 global, 1 local parity
        let erased = vec![0, 1, 30, 37];
        let plan = c.decode_plan(&erased).unwrap();
        let srcs: Vec<&[u8]> = plan.sources.iter().map(|&s| stripe[s].as_slice()).collect();
        let rebuilt = plan.execute(&srcs);
        for (i, &b) in plan.erased.iter().enumerate() {
            assert_eq!(rebuilt[i], stripe[b]);
        }
    }
}
