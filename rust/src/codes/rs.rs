//! Systematic Reed–Solomon (MDS) code — the no-locality reference point
//! (§2.1) and the foundation the wide-LRC discussion starts from.
//!
//! Construction: take an `n × k` Vandermonde matrix `V` on `n` distinct
//! points and right-multiply by the inverse of its top `k × k` block. The
//! result is systematic `[I_k; A]` and inherits the MDS property (every
//! `k × k` submatrix of `V` is invertible because the points are distinct),
//! so any `n − k` erasures are recoverable.

use super::{BlockRole, Code, CodeFamily};
use crate::gf::matrix::distinct_nonzero_points;
use crate::gf::Matrix;

pub struct Rs;

impl Rs {
    /// Build a systematic `(n, k)` Reed–Solomon code (`k < n ≤ 255`).
    pub fn new(n: usize, k: usize) -> Code {
        assert!(k < n, "k must be < n");
        assert!(n <= 255, "GF(2^8) RS supports n ≤ 255");
        let pts = distinct_nonzero_points(n);
        let v = Matrix::vandermonde(k, &pts, 0); // k × n, columns = points
        // transpose-view: we want rows=blocks; build V' as n × k
        let mut vt = Matrix::zero(n, k);
        for i in 0..n {
            for j in 0..k {
                vt.set(i, j, v.get(j, i));
            }
        }
        let top = vt.select_rows(&(0..k).collect::<Vec<_>>());
        let top_inv = top.invert().expect("Vandermonde top block is invertible");
        let sys = vt.mul(&top_inv); // n × k, top block = I
        let parity = sys.select_rows(&(k..n).collect::<Vec<_>>());

        let mut roles = vec![BlockRole::Data; k];
        roles.extend(vec![BlockRole::GlobalParity; n - k]);
        Code::assemble(CodeFamily::Rs, format!("RS({n},{k})"), parity, roles, vec![])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::tests::roundtrip_battery;
    use crate::prng::Prng;

    #[test]
    fn systematic_top_is_identity() {
        let code = Rs::new(9, 6);
        // encode_symbols keeps data in place
        let data: Vec<u8> = (1..=6).collect();
        let stripe = code.encode_symbols(&data);
        assert_eq!(&stripe[..6], &data[..]);
    }

    #[test]
    fn mds_property_small_exhaustive() {
        let code = Rs::new(9, 6);
        assert!(code.tolerates_all_exhaustive(3));
        // and 4 erasures must fail somewhere (in fact everywhere)
        assert!(!code.can_decode(&[0, 1, 2, 3]));
    }

    #[test]
    fn mds_property_sampled_wide() {
        let code = Rs::new(60, 50);
        let mut p = Prng::new(1);
        assert_eq!(code.tolerance_failures_sampled(10, 200, &mut p), 0);
    }

    #[test]
    fn roundtrip() {
        roundtrip_battery(&Rs::new(12, 8), 7);
    }

    #[test]
    fn repair_cost_is_k() {
        let code = Rs::new(9, 6);
        for b in 0..9 {
            assert_eq!(code.repair_plan(b).sources.len(), 6);
        }
        assert!((code.recovery_locality() - 6.0).abs() < 1e-9);
    }

    #[test]
    fn no_locality() {
        let code = Rs::new(9, 6);
        assert!(code.groups().is_empty());
        assert_eq!(code.global_parities().len(), 3);
        assert!(code.local_parities().is_empty());
    }
}
