//! Client side: request drivers and the production object-store workload
//! of Experiment 6 (EC-Cache / Facebook object mix).

pub mod workload;

pub use workload::{ObjectId, Workload, WorkloadSpec};

/// Percentile over a latency sample (`p` in 0..=100).
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    assert!(!samples.is_empty());
    let mut s = samples.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
    s[idx.min(s.len() - 1)]
}

/// Mean of a sample.
pub fn mean(samples: &[f64]) -> f64 {
    samples.iter().sum::<f64>() / samples.len() as f64
}

/// Render a CDF as (latency, fraction) points for EXPERIMENTS.md plots.
pub fn cdf_points(samples: &[f64], points: usize) -> Vec<(f64, f64)> {
    let mut s = samples.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (1..=points)
        .map(|i| {
            let frac = i as f64 / points as f64;
            let idx = ((frac * s.len() as f64).ceil() as usize).clamp(1, s.len()) - 1;
            (s[idx], frac)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_basics() {
        let s: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&s, 0.0), 1.0);
        assert_eq!(percentile(&s, 100.0), 100.0);
        assert!((percentile(&s, 50.0) - 50.0).abs() <= 1.0);
    }

    #[test]
    fn mean_basics() {
        assert!((mean(&[1.0, 2.0, 3.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn cdf_monotone() {
        let s = vec![5.0, 1.0, 3.0, 2.0, 4.0];
        let c = cdf_points(&s, 5);
        for w in c.windows(2) {
            assert!(w[1].0 >= w[0].0);
            assert!(w[1].1 > w[0].1);
        }
        assert_eq!(c.last().unwrap().1, 1.0);
    }
}
