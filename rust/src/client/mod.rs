//! Client side: request drivers and the production object-store workload
//! of Experiment 6 (EC-Cache / Facebook object mix).

pub mod workload;

pub use workload::{ObjectId, Workload, WorkloadSpec};

// Latency percentiles live in [`crate::stats`] — the crate-wide single
// implementation (`q` in 0.0..=1.0, `Option` on empty). The old
// `p` in 0..=100 helper that used to live here is gone.

/// Mean of a sample.
pub fn mean(samples: &[f64]) -> f64 {
    samples.iter().sum::<f64>() / samples.len() as f64
}

/// Render a CDF as (latency, fraction) points for EXPERIMENTS.md plots.
pub fn cdf_points(samples: &[f64], points: usize) -> Vec<(f64, f64)> {
    let mut s = samples.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (1..=points)
        .map(|i| {
            let frac = i as f64 / points as f64;
            let idx = ((frac * s.len() as f64).ceil() as usize).clamp(1, s.len()) - 1;
            (s[idx], frac)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basics() {
        assert!((mean(&[1.0, 2.0, 3.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn cdf_monotone() {
        let s = vec![5.0, 1.0, 3.0, 2.0, 4.0];
        let c = cdf_points(&s, 5);
        for w in c.windows(2) {
            assert!(w[1].0 >= w[0].0);
            assert!(w[1].1 > w[0].1);
        }
        assert_eq!(c.last().unwrap().1, 1.0);
    }
}
