//! Production object-store workload (§6 Experiment 6): objects of
//! medium (1 MB), medium/large (32 MB) and large (64 MB) sizes in
//! proportions 82.5% / 10% / 7.5% (EC-Cache's Facebook analytics mix),
//! laid out over stripes block by block.
//!
//! Object sizes are expressed in *blocks* (1 block = 1 MB at the paper's
//! block size); with a smaller configured block size the mix scales down
//! proportionally, preserving the access pattern.

use crate::coordinator::{Dss, OpResult, StripeId};
use crate::prng::Prng;

pub type ObjectId = usize;

/// The size mix of Experiment 6.
#[derive(Debug, Clone, Copy)]
pub struct WorkloadSpec {
    /// (size_in_blocks, probability) triples.
    pub mix: [(usize, f64); 3],
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec { mix: [(1, 0.825), (32, 0.10), (64, 0.075)] }
    }
}

impl WorkloadSpec {
    /// Small-file-heavy tenant (metadata / config object stores).
    pub fn small_files() -> WorkloadSpec {
        WorkloadSpec { mix: [(1, 0.95), (8, 0.04), (32, 0.01)] }
    }

    /// Scan-heavy tenant (analytics backfill: mostly large objects).
    pub fn scan_heavy() -> WorkloadSpec {
        WorkloadSpec { mix: [(1, 0.40), (32, 0.35), (64, 0.25)] }
    }

    /// The canonical multi-tenant mix cycle used by the fault-injection
    /// scenarios: the EC-Cache production mix plus a small-file tenant and
    /// a scan-heavy tenant, so one failure burst hits requests of very
    /// different fan-out widths at once.
    pub fn tenant_mixes() -> [WorkloadSpec; 3] {
        [WorkloadSpec::default(), WorkloadSpec::small_files(), WorkloadSpec::scan_heavy()]
    }

    /// Draw an object size (in blocks).
    pub fn draw(&self, prng: &mut Prng) -> usize {
        let x = prng.gen_f64();
        let mut acc = 0.0;
        for &(size, p) in &self.mix {
            acc += p;
            if x < acc {
                return size;
            }
        }
        self.mix[self.mix.len() - 1].0
    }
}

/// A placed workload: each object is a list of (stripe, data-block) pairs.
#[derive(Debug, Clone)]
pub struct Workload {
    pub objects: Vec<Vec<(StripeId, usize)>>,
}

impl Workload {
    /// Place `count` objects onto the DSS's existing stripes, packing data
    /// blocks sequentially and spilling across stripe boundaries
    /// (round-robin stripe placement, §6 Exp 6). Panics if the system has
    /// too little capacity.
    pub fn place(dss: &Dss, spec: WorkloadSpec, count: usize, prng: &mut Prng) -> Workload {
        let k = dss.code.k();
        let stripes = dss.metadata().stripe_count();
        let capacity = stripes * k;
        let mut cursor = 0usize;
        let mut objects = Vec::with_capacity(count);
        for _ in 0..count {
            let size = spec.draw(prng);
            assert!(
                cursor + size <= capacity,
                "workload needs {} blocks, capacity {capacity}",
                cursor + size
            );
            let blocks: Vec<(StripeId, usize)> =
                (cursor..cursor + size).map(|i| (i / k, i % k)).collect();
            cursor += size;
            objects.push(blocks);
        }
        Workload { objects }
    }

    /// Place as many objects as fit (up to `max_objects`) instead of
    /// panicking on overflow — used by experiment drivers whose stripe
    /// budget is a config knob.
    pub fn place_fit(
        dss: &Dss,
        spec: WorkloadSpec,
        max_objects: usize,
        prng: &mut Prng,
    ) -> Workload {
        let k = dss.code.k();
        let capacity = dss.metadata().stripe_count() * k;
        let mut cursor = 0usize;
        let mut objects = Vec::new();
        for _ in 0..max_objects {
            let size = spec.draw(prng);
            if cursor + size > capacity {
                break;
            }
            let blocks: Vec<(StripeId, usize)> =
                (cursor..cursor + size).map(|i| (i / k, i % k)).collect();
            cursor += size;
            objects.push(blocks);
        }
        assert!(!objects.is_empty(), "no capacity for even one object");
        Workload { objects }
    }

    /// Place `tenants` co-resident workloads over the DSS's stripes, each
    /// drawing from its own [`WorkloadSpec::tenant_mixes`] entry, packing
    /// block ranges back to back so tenants share stripes (and therefore
    /// failure domains) the way a multi-tenant cluster does. Tenants that
    /// no longer fit get fewer (possibly zero) objects instead of
    /// panicking — capacity is a config knob in the fault scenarios.
    pub fn place_tenants(
        dss: &Dss,
        tenants: usize,
        objects_per_tenant: usize,
        prng: &mut Prng,
    ) -> Vec<Workload> {
        assert!(tenants > 0);
        let k = dss.code.k();
        let capacity = dss.metadata().stripe_count() * k;
        let mixes = WorkloadSpec::tenant_mixes();
        let mut cursor = 0usize;
        let mut out = Vec::with_capacity(tenants);
        for t in 0..tenants {
            let spec = mixes[t % mixes.len()];
            let mut objects = Vec::new();
            for _ in 0..objects_per_tenant {
                // truncate to the remaining capacity so small test systems
                // still host every tenant (a 64-block object on a 30-block
                // system becomes a 30-block object, not a panic)
                let size = spec.draw(prng).min(capacity - cursor);
                if size == 0 {
                    break;
                }
                let blocks: Vec<(StripeId, usize)> =
                    (cursor..cursor + size).map(|i| (i / k, i % k)).collect();
                cursor += size;
                objects.push(blocks);
            }
            out.push(Workload { objects });
        }
        assert!(
            out.iter().any(|w| !w.objects.is_empty()),
            "no capacity for even one object across {tenants} tenants"
        );
        out
    }

    /// Objects with at least one block hosted on `node` — the requests a
    /// failure of that node degrades.
    pub fn objects_touching(&self, dss: &Dss, node: usize) -> Vec<ObjectId> {
        self.objects
            .iter()
            .enumerate()
            .filter(|(_, blocks)| blocks.iter().any(|&(s, b)| dss.metadata().node_of(s, b) == node))
            .map(|(i, _)| i)
            .collect()
    }

    /// Total data blocks across all objects.
    pub fn total_blocks(&self) -> usize {
        self.objects.iter().map(|o| o.len()).sum()
    }

    /// Read an object: all its blocks fan out in parallel at the same
    /// instant; failed blocks go down the degraded path. Completion is the
    /// slowest block's arrival — so cluster load imbalance (Fig 2(b))
    /// directly shows in object latency.
    pub fn read_object(&self, dss: &mut Dss, obj: ObjectId) -> anyhow::Result<OpResult> {
        dss.parallel_read(&self.objects[obj])
    }

    /// Read a burst of objects issued at the same instant (one multi-tenant
    /// event's worth of work): every block of every object fans out at t0,
    /// and all degraded repairs across the burst's stripes are batched
    /// through the proxy's worker pool in one wave. Completion is the
    /// slowest block of the burst.
    pub fn read_objects(&self, dss: &mut Dss, objs: &[ObjectId]) -> anyhow::Result<OpResult> {
        let blocks: Vec<(StripeId, usize)> =
            objs.iter().flat_map(|&o| self.objects[o].iter().copied()).collect();
        dss.parallel_read(&blocks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tenant_mixes_partition_capacity_deterministically() {
        use crate::codes::spec::{CodeFamily, Scheme};
        use crate::coordinator::{Dss, DssConfig};
        use crate::placement::{Topology, UniLrcPlace};
        use crate::runtime::NativeCoder;
        use crate::sim::NetConfig;
        use std::sync::Arc;
        let code = Scheme::S42.build(CodeFamily::UniLrc);
        let mut dss = Dss::new(
            code,
            Box::new(UniLrcPlace),
            Topology::new(6, 9),
            NetConfig::default(),
            Arc::new(NativeCoder),
            DssConfig { block_size: 1024, aggregated: true, time_compute: false },
        );
        let mut p = Prng::new(3);
        dss.ingest_random_stripes(3, &mut p).unwrap();
        let a = Workload::place_tenants(&dss, 3, 6, &mut Prng::new(9));
        let b = Workload::place_tenants(&dss, 3, 6, &mut Prng::new(9));
        assert_eq!(a.len(), 3);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.objects, y.objects, "same seed ⇒ same tenant placement");
        }
        // block ranges are disjoint across tenants and objects
        let mut seen = std::collections::HashSet::new();
        for wl in &a {
            for o in &wl.objects {
                for &blk in o {
                    assert!(seen.insert(blk), "block {blk:?} double-assigned");
                }
            }
        }
        // objects_touching finds the owner of a known block
        let (s0, b0) = a[0].objects[0][0];
        let node = dss.metadata().node_of(s0, b0);
        assert!(a[0].objects_touching(&dss, node).contains(&0));
    }

    #[test]
    fn mix_draw_distribution() {
        let spec = WorkloadSpec::default();
        let mut p = Prng::new(1);
        let mut counts = std::collections::HashMap::new();
        for _ in 0..10_000 {
            *counts.entry(spec.draw(&mut p)).or_insert(0usize) += 1;
        }
        let frac1 = counts[&1] as f64 / 10_000.0;
        let frac32 = counts[&32] as f64 / 10_000.0;
        let frac64 = counts[&64] as f64 / 10_000.0;
        assert!((frac1 - 0.825).abs() < 0.02, "{frac1}");
        assert!((frac32 - 0.10).abs() < 0.02);
        assert!((frac64 - 0.075).abs() < 0.02);
    }
}
