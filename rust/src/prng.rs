//! Small deterministic PRNG (xoshiro256**) used by tests, benches and the
//! workload generator.
//!
//! The `rand` crate is unavailable in this offline build, and we want
//! reproducible experiments anyway: every workload and property test takes an
//! explicit seed.

/// xoshiro256** by Blackman & Vigna (public domain reference implementation).
#[derive(Debug, Clone)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    /// Seed via splitmix64 so that small/consecutive seeds give uncorrelated
    /// streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Prng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` (Lemire's method; `bound` must be nonzero).
    #[inline]
    pub fn gen_range(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as usize
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fill a byte slice with pseudorandom data.
    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        let mut chunks = buf.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let b = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&b[..rem.len()]);
        }
    }

    /// Random bytes convenience.
    pub fn bytes(&mut self, len: usize) -> Vec<u8> {
        let mut v = vec![0u8; len];
        self.fill_bytes(&mut v);
        v
    }

    /// Choose `m` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn choose_distinct(&mut self, n: usize, m: usize) -> Vec<usize> {
        assert!(m <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..m {
            let j = i + self.gen_range(n - i);
            idx.swap(i, j);
        }
        idx.truncate(m);
        idx
    }

    /// Shuffle a slice in place.
    pub fn shuffle<T>(&mut self, s: &mut [T]) {
        for i in (1..s.len()).rev() {
            let j = self.gen_range(i + 1);
            s.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn gen_range_bounds() {
        let mut p = Prng::new(7);
        for _ in 0..10_000 {
            assert!(p.gen_range(13) < 13);
        }
    }

    #[test]
    fn gen_range_covers_all_values() {
        let mut p = Prng::new(9);
        let mut seen = [false; 13];
        for _ in 0..2_000 {
            seen[p.gen_range(13)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn choose_distinct_is_distinct() {
        let mut p = Prng::new(3);
        for _ in 0..100 {
            let sel = p.choose_distinct(20, 10);
            let mut sorted = sel.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 10);
            assert!(sel.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn fill_bytes_nonzero() {
        let mut p = Prng::new(5);
        let b = p.bytes(1000);
        assert!(b.iter().any(|&x| x != 0));
        // remainder path
        let c = p.bytes(7);
        assert_eq!(c.len(), 7);
    }

    #[test]
    fn gen_f64_in_unit_interval() {
        let mut p = Prng::new(11);
        for _ in 0..1000 {
            let f = p.gen_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut p = Prng::new(13);
        let mut v: Vec<usize> = (0..50).collect();
        p.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
