//! Minimal benchmarking harness.
//!
//! `criterion` is unavailable in this offline build, so `rust/benches/*` use
//! this instead: warmup, timed iterations, and median/mean/σ reporting with
//! derived throughput. Output is line-oriented so EXPERIMENTS.md tables can
//! be pasted straight from `cargo bench` logs.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub stddev: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Sample {
    /// Throughput given bytes processed per iteration.
    pub fn gib_per_s(&self, bytes_per_iter: usize) -> f64 {
        bytes_per_iter as f64 / self.median.as_secs_f64() / (1u64 << 30) as f64
    }

    pub fn mib_per_s(&self, bytes_per_iter: usize) -> f64 {
        bytes_per_iter as f64 / self.median.as_secs_f64() / (1u64 << 20) as f64
    }
}

/// Benchmark runner with a time budget per case.
pub struct Bencher {
    warmup: Duration,
    budget: Duration,
    min_iters: usize,
    max_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(150),
            budget: Duration::from_millis(800),
            min_iters: 5,
            max_iters: 10_000,
        }
    }
}

impl Bencher {
    pub fn new(warmup_ms: u64, budget_ms: u64) -> Self {
        Bencher {
            warmup: Duration::from_millis(warmup_ms),
            budget: Duration::from_millis(budget_ms),
            ..Default::default()
        }
    }

    /// Honor `UNILRC_BENCH_FAST=1` for CI-style quick runs.
    pub fn from_env() -> Self {
        if std::env::var("UNILRC_BENCH_FAST").as_deref() == Ok("1") {
            Bencher::new(30, 150)
        } else {
            Bencher::default()
        }
    }

    /// Run `f` repeatedly; `f` must do one unit of work per call.
    pub fn bench<F: FnMut()>(&self, name: &str, mut f: F) -> Sample {
        // Warmup and calibration.
        let w0 = Instant::now();
        let mut calib_iters = 0usize;
        while w0.elapsed() < self.warmup || calib_iters < 2 {
            f();
            calib_iters += 1;
        }
        let per_iter = w0.elapsed() / calib_iters.max(1) as u32;
        let target = (self.budget.as_secs_f64() / per_iter.as_secs_f64().max(1e-9)) as usize;
        let iters = target.clamp(self.min_iters, self.max_iters);

        let mut times = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            f();
            times.push(t.elapsed());
        }
        times.sort_unstable();
        let median = times[times.len() / 2];
        let total: Duration = times.iter().sum();
        let mean = total / iters as u32;
        let mean_s = mean.as_secs_f64();
        let var = times
            .iter()
            .map(|t| {
                let d = t.as_secs_f64() - mean_s;
                d * d
            })
            .sum::<f64>()
            / iters as f64;
        Sample {
            name: name.to_string(),
            iters,
            mean,
            median,
            stddev: Duration::from_secs_f64(var.sqrt()),
            min: times[0],
            max: *times.last().unwrap(),
        }
    }

    /// Run and immediately report with byte-throughput.
    pub fn bench_throughput<F: FnMut()>(&self, name: &str, bytes: usize, f: F) -> Sample {
        let s = self.bench(name, f);
        println!(
            "{:<44} {:>10.3} ms/iter   {:>9.2} MiB/s   (n={}, σ={:.3} ms)",
            s.name,
            s.median.as_secs_f64() * 1e3,
            s.mib_per_s(bytes),
            s.iters,
            s.stddev.as_secs_f64() * 1e3,
        );
        s
    }

    /// Run and report latency only.
    pub fn bench_latency<F: FnMut()>(&self, name: &str, f: F) -> Sample {
        let s = self.bench(name, f);
        println!(
            "{:<44} {:>10.3} ms/iter   (n={}, σ={:.3} ms)",
            s.name,
            s.median.as_secs_f64() * 1e3,
            s.iters,
            s.stddev.as_secs_f64() * 1e3,
        );
        s
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Machine-readable bench artifact (`serde` is unavailable offline, and the
/// schema is flat): collects samples and writes them as JSON to the path in
/// `UNILRC_BENCH_JSON`, so CI can archive a throughput trajectory.
pub struct JsonReport {
    bench: String,
    meta: Vec<(String, String)>,
    rows: Vec<String>,
}

impl JsonReport {
    pub fn new(bench: &str) -> JsonReport {
        JsonReport { bench: bench.to_string(), meta: Vec::new(), rows: Vec::new() }
    }

    /// Attach a free-form context field (engine description, CPU, …).
    pub fn meta(&mut self, key: &str, value: &str) {
        self.meta.push((key.to_string(), value.to_string()));
    }

    /// Record a sample with its per-iteration byte count.
    pub fn add(&mut self, s: &Sample, bytes_per_iter: usize) {
        self.rows.push(format!(
            r#"{{"name":{},"median_ms":{:.6},"mib_per_s":{:.3},"iters":{}}}"#,
            json_str(&s.name),
            s.median.as_secs_f64() * 1e3,
            s.mib_per_s(bytes_per_iter),
            s.iters
        ));
    }

    /// Record a directly measured value (not a timed loop) — e.g. a
    /// simulated latency percentile from a throttle interference sweep.
    pub fn add_value(&mut self, name: &str, value: f64, unit: &str) {
        self.rows.push(format!(
            r#"{{"name":{},"value":{:.6},"unit":{}}}"#,
            json_str(name),
            value,
            json_str(unit)
        ));
    }

    /// [`Self::add_value`] with an explicit gate direction (`"higher"` or
    /// `"lower"`) for tools/bench_compare.py — bare `add_value` rows are
    /// assumed lower-is-better there, so rows whose unit does not make the
    /// direction obvious (ns/op, ratios) should declare it.
    pub fn add_value_directed(&mut self, name: &str, value: f64, unit: &str, better: &str) {
        self.rows.push(format!(
            r#"{{"name":{},"value":{:.6},"unit":{},"better":{}}}"#,
            json_str(name),
            value,
            json_str(unit),
            json_str(better)
        ));
    }

    /// Write to `$UNILRC_BENCH_JSON` if set; returns the path written.
    pub fn write_if_requested(&self) -> Option<String> {
        let path = std::env::var("UNILRC_BENCH_JSON").ok()?;
        let mut out = String::from("{\n");
        out.push_str(&format!("  \"bench\": {},\n", json_str(&self.bench)));
        for (k, v) in &self.meta {
            out.push_str(&format!("  {}: {},\n", json_str(k), json_str(v)));
        }
        out.push_str("  \"results\": [\n    ");
        out.push_str(&self.rows.join(",\n    "));
        out.push_str("\n  ]\n}\n");
        match std::fs::write(&path, out) {
            Ok(()) => {
                println!("\nwrote {path}");
                Some(path)
            }
            Err(e) => {
                eprintln!("failed to write {path}: {e}");
                None
            }
        }
    }
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let b = Bencher::new(5, 20);
        let mut acc = 0u64;
        let s = b.bench("noop-ish", || {
            acc = acc.wrapping_add(black_box(1));
        });
        assert!(s.iters >= 5);
        assert!(s.median <= s.max);
        assert!(s.min <= s.median);
    }

    #[test]
    fn json_report_escapes_and_writes() {
        assert_eq!(json_str("a\"b\\c"), r#""a\"b\\c""#);
        let mut r = JsonReport::new("unit");
        r.meta("engine", "scalar");
        r.add(
            &Sample {
                name: "x".into(),
                iters: 1,
                mean: Duration::from_secs(1),
                median: Duration::from_secs(1),
                stddev: Duration::ZERO,
                min: Duration::from_secs(1),
                max: Duration::from_secs(1),
            },
            1 << 20,
        );
        // no env var → no write, no panic
        assert!(r.write_if_requested().is_none() || std::env::var("UNILRC_BENCH_JSON").is_ok());
    }

    #[test]
    fn value_rows_carry_direction() {
        let mut r = JsonReport::new("unit");
        r.add_value("a", 1.0, "ms");
        r.add_value_directed("b", 2.0, "ns", "lower");
        assert!(r.rows[0].contains(r#""unit":"ms""#));
        assert!(!r.rows[0].contains("better"));
        assert!(r.rows[1].contains(r#""better":"lower""#));
    }

    #[test]
    fn throughput_math() {
        let s = Sample {
            name: "x".into(),
            iters: 1,
            mean: Duration::from_secs(1),
            median: Duration::from_secs(1),
            stddev: Duration::ZERO,
            min: Duration::from_secs(1),
            max: Duration::from_secs(1),
        };
        assert!((s.gib_per_s(1 << 30) - 1.0).abs() < 1e-9);
        assert!((s.mib_per_s(1 << 20) - 1.0).abs() < 1e-9);
    }
}
