//! The migration scheduler: given a topology event, plan the minimal set
//! of topology-aware block moves that keeps every placement invariant
//! true, for the coordinator to execute as batched coding + transfer
//! waves ([`crate::coordinator::Dss::apply_topology_event`]).
//!
//! Two move shapes cover all four events:
//!
//! * **Intra-cluster reassignment** (add-node rebalance, drain with local
//!   spare capacity): the per-stripe per-cluster block sets are untouched,
//!   so every cluster-level invariant holds trivially; only the
//!   distinct-node-per-stripe rule must be respected.
//! * **Unit relocation** (add-cluster rebalance, decommission): *all*
//!   blocks of one (stripe, cluster) pair move together to a cluster that
//!   hosts none of that stripe — the per-stripe cluster sets are a
//!   permutation of before, so one-cluster-failure tolerance, ECWide's
//!   `≤ g+1` cap and UniLRC's one-group-one-cluster all carry over
//!   exactly.
//!
//! Drains that must scatter single blocks across clusters (no local
//! spare) additionally pass a per-strategy structural check
//! ([`MigrationPolicy`]) *and* the universal safety gate: the target
//! cluster's post-move block set must still decode
//! ([`Code::can_decode`]).
//!
//! Everything is deterministic: candidate orders are (load, id)-sorted,
//! scratch state is updated as moves are decided, and the planner is a
//! pure function of `(code, topology, block map, failed set, event)`.

use crate::codes::Code;
use crate::coordinator::block_map::{BlockMap, StripeId};
use crate::placement::Topology;
use std::cmp::Reverse;
use std::collections::HashSet;
use std::fmt;

/// Typed planning/scheduling failure. [`MigrationError::retryable`]
/// separates transient contention (retry after backoff, or after the
/// conflicting event commits) from permanently unplannable events (the
/// topology itself lacks an invariant-satisfying home — only adding
/// capacity can help).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MigrationError {
    /// No invariant-preserving plan exists on the current topology.
    /// Permanent until the topology changes.
    Unplannable { reason: String },
    /// Another in-flight event already claims a block (or target slot)
    /// this plan needs; the events serialize — retry after it commits.
    Conflicting { stripe: StripeId, block: usize },
    /// A move's source died mid-transfer and the stripe's erasure pattern
    /// is (currently) not rebuildable; retryable once repairs land.
    SourceDown { node: usize },
}

impl MigrationError {
    /// `true` for transient failures worth retrying with backoff.
    pub fn retryable(&self) -> bool {
        !matches!(self, MigrationError::Unplannable { .. })
    }
}

impl fmt::Display for MigrationError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MigrationError::Unplannable { reason } => write!(f, "{reason}"),
            MigrationError::Conflicting { stripe, block } => write!(
                f,
                "stripe {stripe} block {block} is claimed by another in-flight event \
                 (retryable)"
            ),
            MigrationError::SourceDown { node } => {
                write!(f, "source node {node} is down and not yet rebuildable (retryable)")
            }
        }
    }
}

impl std::error::Error for MigrationError {}

/// Retry discipline for failed background moves: capped exponential
/// backoff, then park the event as retryable
/// (`--backoff-base-ms` / `--backoff-cap-ms` / `--max-attempts`,
/// `[migration]` config keys).
#[derive(Debug, Clone, Copy)]
pub struct BackoffPolicy {
    /// First retry delay in virtual milliseconds.
    pub base_ms: f64,
    /// Ceiling on any single delay (caps the exponential).
    pub cap_ms: f64,
    /// Attempts before the event parks as retryable.
    pub max_attempts: usize,
}

impl Default for BackoffPolicy {
    fn default() -> Self {
        BackoffPolicy { base_ms: 10.0, cap_ms: 1_000.0, max_attempts: 5 }
    }
}

impl BackoffPolicy {
    /// Delay before retry number `attempt` (0-based):
    /// `min(base · 2^attempt, cap)` milliseconds.
    pub fn delay_ms(&self, attempt: usize) -> f64 {
        (self.base_ms * 2f64.powi(attempt.min(30) as i32)).min(self.cap_ms)
    }
}

/// Background-migration counters, printed like `PlanCache::stats()`
/// (`Dss::migration_stats`).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct MigrationStats {
    /// Events admitted into the in-flight queue.
    pub submitted: usize,
    /// Events whose every move committed.
    pub completed: usize,
    /// Submissions rejected with [`MigrationError::Conflicting`].
    pub conflicts: usize,
    /// Submissions rejected with [`MigrationError::Unplannable`].
    pub unplannable: usize,
    /// Move attempts that failed and were re-scheduled with backoff.
    pub retries: usize,
    /// Moves whose source died mid-event and flipped onto the batched
    /// rebuild path.
    pub source_flips: usize,
    /// Moves re-planned onto a new target after their destination died.
    pub dest_replans: usize,
    /// Events parked as retryable after exhausting their attempts.
    pub parked: usize,
    /// Events resumed from a recovered WAL (crash-mid-wave).
    pub resumed: usize,
    /// Individual block moves committed to the map.
    pub moves_committed: usize,
}

impl MigrationStats {
    /// One-line-per-counter report (the `PlanCache::stats()` idiom).
    pub fn render(&self) -> String {
        format!(
            "migration stats:\n  events submitted {} completed {} parked {} resumed {}\n  \
             rejections: conflicts {} unplannable {}\n  moves committed {} \
             (source-flips {} dest-replans {} retries {})",
            self.submitted,
            self.completed,
            self.parked,
            self.resumed,
            self.conflicts,
            self.unplannable,
            self.moves_committed,
            self.source_flips,
            self.dest_replans,
            self.retries,
        )
    }
}

/// One planned block move.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockMove {
    pub stripe: StripeId,
    pub block: usize,
    pub from_node: usize,
    pub to_cluster: usize,
    pub to_node: usize,
}

/// A deterministic, invariant-preserving move schedule.
#[derive(Debug, Clone, Default)]
pub struct MigrationPlan {
    pub moves: Vec<BlockMove>,
}

impl MigrationPlan {
    pub fn len(&self) -> usize {
        self.moves.len()
    }

    pub fn is_empty(&self) -> bool {
        self.moves.is_empty()
    }

    /// Moves whose source crosses a cluster boundary.
    pub fn cross_cluster_moves(&self, map: &BlockMap) -> usize {
        self.moves
            .iter()
            .filter(|m| map.cluster_of(m.stripe, m.block) != m.to_cluster)
            .count()
    }
}

/// Per-strategy structural constraint for *single-block* cross-cluster
/// moves (unit relocations never need one — they permute cluster sets).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MigrationPolicy {
    /// UniLRC native: a cluster only ever hosts blocks of one local group.
    GroupPerCluster,
    /// ECWide combined locality: same group per cluster, at most `g+1`
    /// stripe blocks per cluster.
    EcWideCaps,
    /// Only the universal can-decode gate.
    Generic,
}

impl MigrationPolicy {
    /// Map a placement-strategy report name to its policy.
    pub fn for_strategy(name: &str) -> MigrationPolicy {
        match name {
            "one-group-one-cluster" => MigrationPolicy::GroupPerCluster,
            "ecwide" => MigrationPolicy::EcWideCaps,
            _ => MigrationPolicy::Generic,
        }
    }

    fn allows(&self, code: &Code, resident: &[usize], block: usize) -> bool {
        match self {
            MigrationPolicy::Generic => true,
            MigrationPolicy::GroupPerCluster => {
                let g = group_idx(code, block);
                resident.iter().all(|&r| group_idx(code, r) == g)
            }
            MigrationPolicy::EcWideCaps => {
                let cap = code.global_parities().len() + 1;
                let g = group_idx(code, block);
                resident.len() + 1 <= cap && resident.iter().all(|&r| group_idx(code, r) == g)
            }
        }
    }
}

/// Index of the first local group containing `block` (`None` for
/// exclusively-global blocks — ECWide packs those as their own chunks).
fn group_idx(code: &Code, block: usize) -> Option<usize> {
    code.groups().iter().position(|g| g.members.contains(&block))
}

/// Sum of blocks hosted by a cluster's members (the planner's cluster
/// load metric).
fn cluster_load(map: &BlockMap, topo: &Topology, cluster: usize) -> usize {
    topo.nodes_of(cluster).iter().map(|&n| map.node_load(n)).sum()
}

/// Least-loaded migratable node of `cluster` that is not failed and hosts
/// no block of `stripe`; ties break on the lower node id. Also the
/// dest-death re-planning primitive of the online scheduler
/// ([`crate::coordinator::Dss::pump_migrations`]).
pub(crate) fn target_in_cluster(
    map: &BlockMap,
    topo: &Topology,
    failed: &HashSet<usize>,
    stripe: StripeId,
    cluster: usize,
) -> Option<usize> {
    let occupied: HashSet<usize> = map.placement(stripe).node_of.iter().copied().collect();
    topo.migratable_nodes_of(cluster)
        .into_iter()
        .filter(|n| !failed.contains(n) && !occupied.contains(n))
        .min_by_key(|&n| (map.node_load(n), n))
}

/// `count` distinct targets in `cluster` for one stripe unit, least
/// loaded first; `None` when the cluster lacks capacity.
fn unit_targets(
    map: &BlockMap,
    topo: &Topology,
    failed: &HashSet<usize>,
    stripe: StripeId,
    cluster: usize,
    count: usize,
) -> Option<Vec<usize>> {
    let occupied: HashSet<usize> = map.placement(stripe).node_of.iter().copied().collect();
    let mut cands: Vec<usize> = topo
        .migratable_nodes_of(cluster)
        .into_iter()
        .filter(|n| !failed.contains(n) && !occupied.contains(n))
        .collect();
    if cands.len() < count {
        return None;
    }
    cands.sort_by_key(|&n| (map.node_load(n), n));
    cands.truncate(count);
    Some(cands)
}

/// Rebalance after a scale-out: pull blocks from the cluster's loaded
/// nodes onto the fresh (joining) node until it carries its fair share.
pub fn plan_add_node(
    topo: &Topology,
    map: &BlockMap,
    failed: &HashSet<usize>,
    cluster: usize,
    new_node: usize,
) -> MigrationPlan {
    let mut scratch = map.clone();
    let mut moves = Vec::new();
    let members = topo.migratable_nodes_of(cluster);
    let total: usize = members.iter().map(|&n| scratch.node_load(n)).sum();
    let fair = total / members.len().max(1);
    'fill: while scratch.node_load(new_node) < fair {
        let mut donors: Vec<usize> = members
            .iter()
            .copied()
            .filter(|&n| n != new_node && !failed.contains(&n))
            .collect();
        donors.sort_by_key(|&n| (Reverse(scratch.node_load(n)), n));
        for d in donors {
            if scratch.node_load(d) <= fair {
                break;
            }
            let mut items = scratch.blocks_on_node(d).to_vec();
            items.sort_unstable();
            for (s, b) in items {
                // an intra-cluster reassignment only needs the
                // distinct-node-per-stripe rule
                if !scratch.placement(s).node_of.contains(&new_node) {
                    moves.push(BlockMove {
                        stripe: s,
                        block: b,
                        from_node: d,
                        to_cluster: cluster,
                        to_node: new_node,
                    });
                    scratch.move_block(s, b, cluster, new_node);
                    continue 'fill;
                }
            }
        }
        break; // no donor has an eligible block left
    }
    MigrationPlan { moves }
}

/// Empty a draining node: local spare first (invariants untouched), then
/// policy-checked single-block relocation to the least-loaded eligible
/// cluster. [`MigrationError::Unplannable`] when some block has no valid
/// home anywhere.
pub fn plan_drain(
    code: &Code,
    policy: MigrationPolicy,
    topo: &Topology,
    map: &BlockMap,
    failed: &HashSet<usize>,
    node: usize,
) -> Result<MigrationPlan, MigrationError> {
    let mut scratch = map.clone();
    let mut moves = Vec::new();
    let mut items = scratch.blocks_on_node(node).to_vec();
    items.sort_unstable();
    for (s, b) in items {
        let home = scratch.cluster_of(s, b);
        if let Some(t) = target_in_cluster(&scratch, topo, failed, s, home) {
            moves.push(BlockMove {
                stripe: s,
                block: b,
                from_node: node,
                to_cluster: home,
                to_node: t,
            });
            scratch.move_block(s, b, home, t);
            continue;
        }
        // cross-cluster scatter: structural policy + can-decode gate
        let mut best: Option<(usize, usize, usize)> = None; // (load, cluster, node)
        for c in 0..topo.clusters() {
            if c == home || topo.is_retired(c) {
                continue;
            }
            let resident = scratch.blocks_in_cluster(s, c);
            if !policy.allows(code, resident, b) {
                continue;
            }
            let mut lost = resident.to_vec();
            lost.push(b);
            lost.sort_unstable();
            if !code.can_decode(&lost) {
                continue;
            }
            if let Some(t) = target_in_cluster(&scratch, topo, failed, s, c) {
                let load = cluster_load(&scratch, topo, c);
                if best.is_none_or(|(bl, bc, _)| (load, c) < (bl, bc)) {
                    best = Some((load, c, t));
                }
            }
        }
        match best {
            Some((_, c, t)) => {
                moves.push(BlockMove {
                    stripe: s,
                    block: b,
                    from_node: node,
                    to_cluster: c,
                    to_node: t,
                });
                scratch.move_block(s, b, c, t);
            }
            None => {
                return Err(MigrationError::Unplannable {
                    reason: format!(
                        "cannot drain node {node}: no invariant-preserving target for \
                         stripe {s} block {b}"
                    ),
                })
            }
        }
    }
    Ok(MigrationPlan { moves })
}

/// Rebalance onto a freshly added cluster: relocate whole (stripe,
/// donor-cluster) units — largest-load donors first — until the new
/// cluster carries its fair share of blocks. Permutation-safe by
/// construction (the target hosts none of the stripe before the unit
/// arrives).
pub fn plan_add_cluster(
    topo: &Topology,
    map: &BlockMap,
    failed: &HashSet<usize>,
    new_cluster: usize,
) -> MigrationPlan {
    let mut scratch = map.clone();
    let mut moves = Vec::new();
    let open: Vec<usize> = topo.open_clusters();
    let total: usize = (0..scratch.stripe_count())
        .map(|s| scratch.placement(s).node_of.len())
        .sum();
    let fair = total / open.len().max(1);
    let capacity = topo.migratable_nodes_of(new_cluster).len();
    let mut new_load = cluster_load(&scratch, topo, new_cluster);
    'fill: while new_load < fair {
        let mut donors: Vec<(usize, usize)> = open
            .iter()
            .filter(|&&c| c != new_cluster)
            .map(|&c| (cluster_load(&scratch, topo, c), c))
            .collect();
        donors.sort_by_key(|&(load, c)| (Reverse(load), c));
        for (donor_load, dc) in donors {
            if donor_load <= fair {
                break;
            }
            for s in 0..scratch.stripe_count() {
                let unit = scratch.blocks_in_cluster(s, dc).to_vec();
                if unit.is_empty()
                    || unit.len() > capacity
                    || !scratch.blocks_in_cluster(s, new_cluster).is_empty()
                {
                    continue;
                }
                let Some(targets) =
                    unit_targets(&scratch, topo, failed, s, new_cluster, unit.len())
                else {
                    continue;
                };
                for (&b, &t) in unit.iter().zip(&targets) {
                    moves.push(BlockMove {
                        stripe: s,
                        block: b,
                        from_node: scratch.node_of(s, b),
                        to_cluster: new_cluster,
                        to_node: t,
                    });
                    scratch.move_block(s, b, new_cluster, t);
                }
                new_load += unit.len();
                continue 'fill;
            }
        }
        break; // no relocatable unit left
    }
    MigrationPlan { moves }
}

/// Retire a cluster: every (stripe, cluster) unit relocates to a cluster
/// hosting none of that stripe, least-loaded first.
/// [`MigrationError::Unplannable`] when a unit has no eligible home (the
/// system is too full to decommission).
pub fn plan_decommission(
    topo: &Topology,
    map: &BlockMap,
    failed: &HashSet<usize>,
    cluster: usize,
) -> Result<MigrationPlan, MigrationError> {
    let mut scratch = map.clone();
    let mut moves = Vec::new();
    for s in 0..scratch.stripe_count() {
        let unit = scratch.blocks_in_cluster(s, cluster).to_vec();
        if unit.is_empty() {
            continue;
        }
        let mut best: Option<(usize, usize, Vec<usize>)> = None; // (load, cluster, targets)
        for c in topo.open_clusters() {
            if c == cluster || !scratch.blocks_in_cluster(s, c).is_empty() {
                continue;
            }
            let Some(targets) = unit_targets(&scratch, topo, failed, s, c, unit.len()) else {
                continue;
            };
            let load = cluster_load(&scratch, topo, c);
            if best.as_ref().is_none_or(|(bl, bc, _)| (load, c) < (*bl, *bc)) {
                best = Some((load, c, targets));
            }
        }
        match best {
            Some((_, c, targets)) => {
                for (&b, &t) in unit.iter().zip(&targets) {
                    moves.push(BlockMove {
                        stripe: s,
                        block: b,
                        from_node: scratch.node_of(s, b),
                        to_cluster: c,
                        to_node: t,
                    });
                    scratch.move_block(s, b, c, t);
                }
            }
            None => {
                return Err(MigrationError::Unplannable {
                    reason: format!(
                        "cannot decommission cluster {cluster}: stripe {s}'s \
                         {}-block unit has no eligible home",
                        unit.len()
                    ),
                })
            }
        }
    }
    Ok(MigrationPlan { moves })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::spec::{CodeFamily, Scheme};
    use crate::placement::{PlacementStrategy, UniLrcPlace};

    fn setup() -> (Code, Topology, BlockMap) {
        let code = Scheme::S42.build(CodeFamily::UniLrc);
        let topo = Topology::new(6, 9);
        let mut map = BlockMap::new();
        for s in 0..3 {
            map.insert_stripe(UniLrcPlace.place(&code, &topo, s), topo.clusters());
        }
        (code, topo, map)
    }

    #[test]
    fn add_node_rebalances_within_cluster() {
        let (_code, mut topo, map) = setup();
        let new = topo.add_node(0);
        let plan = plan_add_node(&topo, &map, &HashSet::new(), 0, new);
        assert!(!plan.is_empty(), "loaded cluster must shed blocks onto the new node");
        assert_eq!(plan.cross_cluster_moves(&map), 0, "add-node stays intra-cluster");
        for m in &plan.moves {
            assert_eq!(m.to_node, new);
            assert_eq!(m.to_cluster, 0);
            assert_eq!(map.cluster_of(m.stripe, m.block), 0);
        }
        // distinct stripes only — one stripe never lands twice on one node
        let mut stripes: Vec<_> = plan.moves.iter().map(|m| m.stripe).collect();
        stripes.sort_unstable();
        stripes.dedup();
        assert_eq!(stripes.len(), plan.len());
    }

    #[test]
    fn drain_prefers_local_spares_and_preserves_invariants() {
        let (code, mut topo, map) = setup();
        let victim = map.node_of(0, 0);
        topo.set_state(victim, crate::placement::NodeState::Draining);
        let policy = MigrationPolicy::GroupPerCluster;
        let plan =
            plan_drain(&code, policy, &topo, &map, &HashSet::new(), victim).unwrap();
        let hosted = map.blocks_on_node(victim).len();
        assert_eq!(plan.len(), hosted, "every hosted block must move");
        // 9-node clusters with 7 blocks per stripe leave local spares
        assert_eq!(plan.cross_cluster_moves(&map), 0);
        for m in &plan.moves {
            assert_ne!(m.to_node, victim);
        }
    }

    #[test]
    fn add_cluster_relocates_whole_units() {
        let (_code, mut topo, map) = setup();
        let nc = topo.add_cluster(9);
        let plan = plan_add_cluster(&topo, &map, &HashSet::new(), nc);
        assert!(!plan.is_empty(), "rebalance must pull units onto the new cluster");
        // whole-unit property: for every (stripe, donor) pair either all or
        // none of the donor's blocks moved
        let mut scratch = map.clone();
        for m in &plan.moves {
            scratch.move_block(m.stripe, m.block, m.to_cluster, m.to_node);
        }
        for s in 0..map.stripe_count() {
            for c in 0..topo.clusters() {
                let before = map.blocks_in_cluster(s, c).len();
                let after = scratch.blocks_in_cluster(s, c).len();
                assert!(
                    after == before || after == 0 || (c == nc && before == 0),
                    "stripe {s} cluster {c}: partial unit ({before} -> {after})"
                );
            }
        }
    }

    #[test]
    fn decommission_moves_everything_or_errors() {
        let (_code, mut topo, map) = setup();
        // enough spare capacity: decommission cluster 5 relocates its units
        topo.retire_cluster(5);
        match plan_decommission(&topo, &map, &HashSet::new(), 5) {
            Ok(plan) => {
                let hosted: usize =
                    (0..map.stripe_count()).map(|s| map.blocks_in_cluster(s, 5).len()).sum();
                assert_eq!(plan.len(), hosted);
                // targets host none of the stripe beforehand (permutation)
                for m in &plan.moves {
                    assert_ne!(m.to_cluster, 5);
                }
            }
            Err(e) => {
                // acceptable only if genuinely out of room — 6→5 clusters
                // for a 6-group UniLRC placement is exactly that case
                assert!(e.to_string().contains("no eligible home"), "{e}");
                assert!(!e.retryable(), "an unplannable event is permanent");
            }
        }
    }

    #[test]
    fn migration_error_retryability_and_display() {
        let unplannable = MigrationError::Unplannable { reason: "no eligible home".into() };
        assert!(!unplannable.retryable());
        assert!(unplannable.to_string().contains("no eligible home"));
        let conflict = MigrationError::Conflicting { stripe: 3, block: 1 };
        assert!(conflict.retryable());
        assert!(conflict.to_string().contains("stripe 3 block 1"));
        let down = MigrationError::SourceDown { node: 7 };
        assert!(down.retryable());
        assert!(down.to_string().contains("node 7"));
    }

    #[test]
    fn backoff_doubles_and_caps() {
        let p = BackoffPolicy { base_ms: 10.0, cap_ms: 100.0, max_attempts: 5 };
        assert_eq!(p.delay_ms(0), 10.0);
        assert_eq!(p.delay_ms(1), 20.0);
        assert_eq!(p.delay_ms(2), 40.0);
        assert_eq!(p.delay_ms(3), 80.0);
        assert_eq!(p.delay_ms(4), 100.0, "capped");
        assert_eq!(p.delay_ms(60), 100.0, "huge attempts do not overflow");
    }

    #[test]
    fn stats_render_lists_every_counter() {
        let s = MigrationStats { submitted: 4, completed: 3, retries: 2, ..Default::default() };
        let r = s.render();
        assert!(r.contains("submitted 4"), "{r}");
        assert!(r.contains("completed 3"), "{r}");
        assert!(r.contains("retries 2"), "{r}");
    }
}
