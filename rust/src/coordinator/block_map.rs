//! The coordinator-owned **block map**: the single mutable source of truth
//! for "where does every block of every stripe live".
//!
//! Placement used to be a pure recomputed function
//! `(strategy, topology, stripe_idx) → Placement` over a frozen topology —
//! which cannot express a node joining, draining, or a cluster growing.
//! The [`BlockMap`] inverts that dataflow: placements are *state*, seeded
//! by a [`crate::placement::PlacementStrategy`] at ingest and mutated by
//! the migration scheduler ([`crate::coordinator::migrate`]) when topology
//! events fire. Every layer (coordinator ops, proxy repair, fault sim,
//! experiments) consults this map instead of recomputing placements.
//!
//! Three indexes are kept in lockstep by [`BlockMap::move_block`]:
//!
//! * stripe → per-block `(cluster, node)` (the [`Placement`] rows),
//! * stripe × cluster → sorted block list (the precomputed per-cluster
//!   index that replaces the O(n) `Placement::blocks_in_cluster` scans in
//!   per-event sim loops),
//! * node → `(stripe, block)` reverse index (whole-node recovery, drains).

use crate::placement::Placement;
use std::collections::HashMap;

/// Stripe identifier.
pub type StripeId = usize;

/// Per-block migration state (undermoon's per-slot migrating/stable tags,
/// at block grain). A `Migrating` block still *lives* on its source node —
/// every read/repair path keeps resolving through [`BlockMap::node_of`]
/// until the move commits, so an in-flight migration never opens a
/// phantom unavailability window.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockState {
    /// Not part of any in-flight move.
    Stable,
    /// Claimed by an in-flight topology event: bytes are being copied
    /// (or rebuilt) from `from` onto `to`, but the map still points at
    /// `from` until [`BlockMap::commit_move`].
    Migrating { from: usize, to: usize },
}

/// Internal record of one claimed move (the commit target includes the
/// destination cluster, which [`BlockState`] does not need to expose).
#[derive(Debug, Clone, Copy)]
struct MoveClaim {
    from_node: usize,
    to_cluster: usize,
    to_node: usize,
}

/// Mutable stripe → block → (cluster, node) state with per-cluster and
/// per-node indexes. `Clone` is cheap enough at prototype scale that the
/// migration planner works on a scratch copy while deciding moves.
#[derive(Debug, Default, Clone)]
pub struct BlockMap {
    placements: Vec<Placement>,
    /// `[stripe][cluster]` → sorted blocks of that stripe in that cluster.
    per_cluster: Vec<Vec<Vec<usize>>>,
    /// node → (stripe, block) reverse index.
    by_node: HashMap<usize, Vec<(StripeId, usize)>>,
    /// Blocks claimed by in-flight moves; absent ⇒ [`BlockState::Stable`].
    migrating: HashMap<(StripeId, usize), MoveClaim>,
}

impl BlockMap {
    pub fn new() -> BlockMap {
        BlockMap::default()
    }

    pub fn stripe_count(&self) -> usize {
        self.placements.len()
    }

    /// Register a stripe's placement; returns its id.
    pub fn insert_stripe(&mut self, placement: Placement, clusters: usize) -> StripeId {
        let id = self.placements.len();
        let mut row: Vec<Vec<usize>> = vec![Vec::new(); clusters];
        for (b, (&c, &node)) in
            placement.cluster_of.iter().zip(&placement.node_of).enumerate()
        {
            row[c].push(b);
            self.by_node.entry(node).or_default().push((id, b));
        }
        self.per_cluster.push(row);
        self.placements.push(placement);
        id
    }

    pub fn placement(&self, stripe: StripeId) -> &Placement {
        &self.placements[stripe]
    }

    /// Node hosting a block.
    pub fn node_of(&self, stripe: StripeId, block: usize) -> usize {
        self.placements[stripe].node_of[block]
    }

    /// Cluster hosting a block.
    pub fn cluster_of(&self, stripe: StripeId, block: usize) -> usize {
        self.placements[stripe].cluster_of[block]
    }

    /// Blocks of `stripe` hosted in `cluster`, sorted — the precomputed
    /// index (no scan). Clusters added after the stripe was placed simply
    /// return empty.
    pub fn blocks_in_cluster(&self, stripe: StripeId, cluster: usize) -> &[usize] {
        self.per_cluster[stripe].get(cluster).map_or(&[], |v| v.as_slice())
    }

    /// Number of distinct clusters hosting blocks of `stripe`.
    pub fn clusters_used(&self, stripe: StripeId) -> usize {
        self.per_cluster[stripe].iter().filter(|v| !v.is_empty()).count()
    }

    /// All (stripe, block) pairs on a node (unsorted insertion order; the
    /// list for a node never contains duplicates).
    pub fn blocks_on_node(&self, node: usize) -> &[(StripeId, usize)] {
        self.by_node.get(&node).map_or(&[], |v| v.as_slice())
    }

    /// Blocks hosted on a node (count only — the load metric the migration
    /// planner balances).
    pub fn node_load(&self, node: usize) -> usize {
        self.by_node.get(&node).map_or(0, |v| v.len())
    }

    /// Reassign one block to `(to_cluster, to_node)`, updating all three
    /// indexes. The caller (the migration executor) is responsible for
    /// having moved the bytes.
    pub fn move_block(
        &mut self,
        stripe: StripeId,
        block: usize,
        to_cluster: usize,
        to_node: usize,
    ) {
        let from_node = self.placements[stripe].node_of[block];
        let from_cluster = self.placements[stripe].cluster_of[block];
        if from_node == to_node {
            return;
        }
        self.placements[stripe].node_of[block] = to_node;
        self.placements[stripe].cluster_of[block] = to_cluster;
        // per-cluster index
        let row = &mut self.per_cluster[stripe];
        if row.len() <= to_cluster {
            row.resize(to_cluster + 1, Vec::new());
        }
        let from = &mut row[from_cluster];
        let pos = from.iter().position(|&b| b == block).expect("block indexed");
        from.remove(pos);
        let to = &mut row[to_cluster];
        let at = to.partition_point(|&b| b < block);
        to.insert(at, block);
        // reverse index
        let src = self.by_node.get_mut(&from_node).expect("node indexed");
        let pos = src.iter().position(|&e| e == (stripe, block)).expect("entry indexed");
        src.swap_remove(pos);
        self.by_node.entry(to_node).or_default().push((stripe, block));
    }

    // ------------------------------------------------ migration claims

    /// Migration state of one block.
    pub fn state_of(&self, stripe: StripeId, block: usize) -> BlockState {
        match self.migrating.get(&(stripe, block)) {
            Some(c) => BlockState::Migrating { from: c.from_node, to: c.to_node },
            None => BlockState::Stable,
        }
    }

    /// Claim `block` for an in-flight move onto `(to_cluster, to_node)`.
    /// Returns `false` (and changes nothing) when another event already
    /// holds the block — the conflict-serialization primitive: a claim is
    /// all-or-nothing, so two overlapping plans can never interleave into
    /// a corrupt map.
    pub fn begin_move(
        &mut self,
        stripe: StripeId,
        block: usize,
        to_cluster: usize,
        to_node: usize,
    ) -> bool {
        let from_node = self.placements[stripe].node_of[block];
        match self.migrating.entry((stripe, block)) {
            std::collections::hash_map::Entry::Occupied(_) => false,
            std::collections::hash_map::Entry::Vacant(v) => {
                v.insert(MoveClaim { from_node, to_cluster, to_node });
                true
            }
        }
    }

    /// Re-point an in-flight claim at a new destination (destination died
    /// mid-move, the event re-planned). Panics if the block is not
    /// migrating — re-targeting an unclaimed block is a scheduler bug.
    pub fn retarget_move(
        &mut self,
        stripe: StripeId,
        block: usize,
        to_cluster: usize,
        to_node: usize,
    ) {
        let claim = self.migrating.get_mut(&(stripe, block)).expect("block is migrating");
        claim.to_cluster = to_cluster;
        claim.to_node = to_node;
    }

    /// Commit an in-flight move: the bytes landed (and verified), so the
    /// map finally re-points the block at the claim's target and the
    /// block returns to [`BlockState::Stable`].
    pub fn commit_move(&mut self, stripe: StripeId, block: usize) {
        let claim = self.migrating.remove(&(stripe, block)).expect("block is migrating");
        self.move_block(stripe, block, claim.to_cluster, claim.to_node);
    }

    /// Release a claim without moving anything (event aborted/unwound).
    pub fn abort_move(&mut self, stripe: StripeId, block: usize) {
        self.migrating.remove(&(stripe, block));
    }

    /// Blocks currently claimed by in-flight moves.
    pub fn migrating_count(&self) -> usize {
        self.migrating.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn placement() -> Placement {
        // 4 blocks over 2 clusters of 2 nodes each
        Placement { cluster_of: vec![0, 0, 1, 1], node_of: vec![0, 1, 2, 3] }
    }

    #[test]
    fn indexes_agree_after_insert() {
        let mut m = BlockMap::new();
        let s = m.insert_stripe(placement(), 2);
        assert_eq!(s, 0);
        assert_eq!(m.stripe_count(), 1);
        assert_eq!(m.blocks_in_cluster(0, 0), &[0, 1]);
        assert_eq!(m.blocks_in_cluster(0, 1), &[2, 3]);
        assert_eq!(m.blocks_in_cluster(0, 7), &[] as &[usize]);
        assert_eq!(m.clusters_used(0), 2);
        assert_eq!(m.blocks_on_node(1), &[(0, 1)]);
        assert_eq!(m.node_load(3), 1);
        assert_eq!(m.node_of(0, 2), 2);
        assert_eq!(m.cluster_of(0, 2), 1);
    }

    #[test]
    fn move_block_updates_all_indexes() {
        let mut m = BlockMap::new();
        m.insert_stripe(placement(), 2);
        // move block 1 from (cluster 0, node 1) to a brand-new cluster 2
        m.move_block(0, 1, 2, 9);
        assert_eq!(m.node_of(0, 1), 9);
        assert_eq!(m.cluster_of(0, 1), 2);
        assert_eq!(m.blocks_in_cluster(0, 0), &[0]);
        assert_eq!(m.blocks_in_cluster(0, 2), &[1]);
        assert_eq!(m.clusters_used(0), 3);
        assert!(m.blocks_on_node(1).is_empty());
        assert_eq!(m.blocks_on_node(9), &[(0, 1)]);
        // moving back restores sorted order in the per-cluster list
        m.move_block(0, 1, 0, 1);
        assert_eq!(m.blocks_in_cluster(0, 0), &[0, 1]);
        assert_eq!(m.clusters_used(0), 2);
    }

    #[test]
    fn self_move_is_a_noop() {
        let mut m = BlockMap::new();
        m.insert_stripe(placement(), 2);
        m.move_block(0, 0, 0, 0);
        assert_eq!(m.blocks_in_cluster(0, 0), &[0, 1]);
        assert_eq!(m.blocks_on_node(0), &[(0, 0)]);
    }

    #[test]
    fn migrating_block_stays_readable_from_source_until_commit() {
        let mut m = BlockMap::new();
        m.insert_stripe(placement(), 2);
        assert_eq!(m.state_of(0, 1), BlockState::Stable);
        assert!(m.begin_move(0, 1, 1, 3));
        // satellite-2 pin: the claim changes *state*, not residency — every
        // index keeps resolving to the source until the commit
        assert_eq!(m.state_of(0, 1), BlockState::Migrating { from: 1, to: 3 });
        assert_eq!(m.node_of(0, 1), 1);
        assert_eq!(m.blocks_on_node(1), &[(0, 1)]);
        assert_eq!(m.blocks_in_cluster(0, 0), &[0, 1]);
        assert_eq!(m.migrating_count(), 1);
        // a second event claiming the same block serializes
        assert!(!m.begin_move(0, 1, 0, 0));
        assert_eq!(m.state_of(0, 1), BlockState::Migrating { from: 1, to: 3 });
        m.commit_move(0, 1);
        assert_eq!(m.state_of(0, 1), BlockState::Stable);
        assert_eq!(m.node_of(0, 1), 3);
        assert_eq!(m.cluster_of(0, 1), 1);
        assert_eq!(m.migrating_count(), 0);
    }

    #[test]
    fn abort_and_retarget_claims() {
        let mut m = BlockMap::new();
        m.insert_stripe(placement(), 2);
        assert!(m.begin_move(0, 0, 1, 2));
        m.abort_move(0, 0);
        assert_eq!(m.state_of(0, 0), BlockState::Stable);
        assert_eq!(m.node_of(0, 0), 0, "abort commits nothing");
        // retarget: destination died, the event re-planned onto node 3
        assert!(m.begin_move(0, 0, 1, 2));
        m.retarget_move(0, 0, 1, 3);
        assert_eq!(m.state_of(0, 0), BlockState::Migrating { from: 0, to: 3 });
        m.commit_move(0, 0);
        assert_eq!(m.node_of(0, 0), 3);
    }
}
