//! Crash-restart recovery: rebuild the coordinator's durable state from
//! the newest decodable manifest snapshot plus WAL replay.
//!
//! Failure tolerance:
//!
//! * **torn tails** — a crash mid-append leaves an incomplete record at
//!   the end of a segment; the intact prefix replays, the tail is
//!   discarded (it never committed);
//! * **truncated / bit-flipped snapshots** — the current manifest
//!   generation fails its CRC or framing and recovery falls back to the
//!   previous generation, replaying the older (longer) WAL suffix;
//! * **interrupted topology events** — a `BeginEvent` group without its
//!   `CommitEvent` is discarded atomically and surfaced as
//!   [`Recovered::pending_event`] so the driver can re-plan the
//!   migration from the consistent pre-event state.
//!
//! Anything else — a bit-flipped *committed* record, a sequence gap, a
//! semantically impossible mutation, a replayed state that fails the
//! structural invariant proof — is a typed [`RecoveryError`]. Recovery
//! never panics on arbitrary bytes and never silently drops committed
//! state: an unreplayable log fails loudly instead of shrinking the map.

use crate::coordinator::block_map::BlockMap;
use crate::coordinator::manifest::{CoordinatorState, ManifestLoadError, ManifestStore};
use crate::coordinator::migrate::BlockMove;
use crate::coordinator::wal::{list_segments, scan_segment, ScanEnd, WalRecord};
use crate::placement::{NodeState, Placement, Topology, TopologyEvent};
use std::collections::{BTreeMap, HashSet};
use std::fmt;
use std::path::{Path, PathBuf};

/// Typed recovery failure. Every variant is a loud, diagnosable stop —
/// the caller decides whether to retry, fall back, or page a human.
#[derive(Debug)]
pub enum RecoveryError {
    /// No manifest generation exists — the directory holds no journal.
    NoManifest { dir: PathBuf },
    /// Manifest files exist but no generation decodes.
    CorruptManifest { detail: String },
    /// A committed WAL record is corrupt (bad CRC, bad framing, sequence
    /// gap) at a known position.
    CorruptWal { path: PathBuf, offset: usize, detail: String },
    /// A record decoded cleanly but describes an impossible mutation
    /// against the replayed state (unplannable-state detection).
    Unreplayable { seq: u64, detail: String },
    /// The fully replayed state fails the structural invariant proof.
    InvariantViolation { detail: String },
    /// Filesystem error while reading the journal.
    Io(std::io::Error),
}

impl fmt::Display for RecoveryError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RecoveryError::NoManifest { dir } => {
                write!(f, "no manifest in {}", dir.display())
            }
            RecoveryError::CorruptManifest { detail } => {
                write!(f, "all manifest generations corrupt: {detail}")
            }
            RecoveryError::CorruptWal { path, offset, detail } => {
                write!(f, "corrupt WAL record in {} at byte {offset}: {detail}", path.display())
            }
            RecoveryError::Unreplayable { seq, detail } => {
                write!(f, "WAL record seq {seq} is unreplayable: {detail}")
            }
            RecoveryError::InvariantViolation { detail } => {
                write!(f, "recovered state fails invariant proof: {detail}")
            }
            RecoveryError::Io(e) => write!(f, "journal I/O error: {e}"),
        }
    }
}

impl std::error::Error for RecoveryError {}

impl From<std::io::Error> for RecoveryError {
    fn from(e: std::io::Error) -> Self {
        RecoveryError::Io(e)
    }
}

/// Outcome of a successful recovery.
#[derive(Debug)]
pub struct Recovered {
    /// The recovered coordinator state (invariant-proven).
    pub state: CoordinatorState,
    /// Committed logical operations reflected in `state` — a
    /// deterministic driver resumes its op list from here.
    pub committed_ops: u64,
    /// Last WAL sequence number folded into `state`.
    pub last_seq: u64,
    /// Records replayed on top of the snapshot.
    pub replayed_records: usize,
    /// A topology event was mid-flight (logged but uncommitted) at the
    /// crash; its migration must be re-planned from `state`.
    pub pending_event: Option<TopologyEvent>,
    /// Online (background) migrations open at the crash: admission and
    /// any completed moves are already folded into `state`; `remaining`
    /// is the recorded plan's uncommitted tail, resumable via
    /// [`crate::coordinator::Dss::resume_online`]. Sorted by `event_id`.
    pub pending_online: Vec<PendingOnline>,
    /// Recovered metadata epoch: the max over the snapshot's epoch and
    /// every replayed `Epoch` record. A restarted server must resume at
    /// an epoch **greater** than this so no routing table a client
    /// cached before the crash can ever validate as current again.
    pub epoch: u64,
    /// The final segment ended in an incomplete record (crash mid-append).
    pub torn_tail: bool,
    /// The current manifest generation was unreadable and the previous
    /// one was used.
    pub used_fallback: bool,
}

/// One online migration event that was open (admitted, not committed) at
/// the crash. Its admission topology mutation and every `done` move are
/// already part of the recovered state; `remaining` is the logged plan's
/// tail in plan order — resuming executes exactly these moves, which is
/// what makes a crash-interrupted wave digest-identical to a never-crashed
/// oracle instead of merely re-plannable.
#[derive(Debug, Clone)]
pub struct PendingOnline {
    pub event_id: u32,
    pub event: TopologyEvent,
    /// Node ids the admission mutation allocated (AddNode/AddCluster) —
    /// resume needs them to apply the completion mutation.
    pub admitted: Vec<usize>,
    /// Pre-admission node states (drain/decommission cancel rollback).
    pub prior: Vec<(usize, NodeState)>,
    pub remaining: Vec<BlockMove>,
}

/// Replay-side staging of one open online event. The admission topology
/// mutation is applied *lazily* — only once all `declared` planned-move
/// records have been replayed — so a crash that tears the admission
/// append (BeginOnline plus a prefix of the plan) recovers as if the
/// event was never submitted instead of resuming a truncated plan.
struct OnlineStage {
    event: TopologyEvent,
    /// Plan length the `BeginOnline` record declared.
    declared: usize,
    /// `Some((admitted, prior))` once the full plan has been seen and the
    /// admission mutation applied: node ids the mutation allocated
    /// (AddNode/AddCluster) and pre-admission node states
    /// (drain/decommission abort rollback).
    applied: Option<(Vec<usize>, Vec<(usize, NodeState)>)>,
    planned: Vec<BlockMove>,
    done: HashSet<(usize, usize)>,
}

/// Mutable replay state: the same structures the live coordinator owns,
/// minus block bytes and the network.
struct Replayer {
    topo: Topology,
    map: BlockMap,
    failed: HashSet<usize>,
    /// Blocks per stripe (fixed by the code; 0 until the first stripe).
    width: usize,
}

impl Replayer {
    fn from_state(state: &CoordinatorState) -> Replayer {
        Replayer {
            topo: state.restore_topology(),
            map: state.restore_block_map(),
            failed: state.failed.iter().map(|&n| n as usize).collect(),
            width: state.placements.first().map_or(0, |(c, _)| c.len()),
        }
    }

    /// Apply one committed record; semantic violations return a
    /// description (mapped to [`RecoveryError::Unreplayable`]).
    fn apply(&mut self, rec: &WalRecord) -> Result<(), String> {
        match rec {
            WalRecord::AddStripe { cluster_of, node_of } => {
                if cluster_of.len() != node_of.len() {
                    return Err("placement rows differ in length".into());
                }
                if self.width != 0 && cluster_of.len() != self.width {
                    return Err(format!(
                        "stripe width {} != established width {}",
                        cluster_of.len(),
                        self.width
                    ));
                }
                let mut seen = HashSet::with_capacity(node_of.len());
                for (b, (&c, &node)) in cluster_of.iter().zip(node_of).enumerate() {
                    let (c, node) = (c as usize, node as usize);
                    if node >= self.topo.total_nodes() {
                        return Err(format!("block {b} on unknown node {node}"));
                    }
                    if self.topo.cluster_of_node(node) != c {
                        return Err(format!("block {b}: node {node} not in cluster {c}"));
                    }
                    if !seen.insert(node) {
                        return Err(format!("two blocks share node {node}"));
                    }
                }
                let placement = Placement {
                    cluster_of: cluster_of.iter().map(|&c| c as usize).collect(),
                    node_of: node_of.iter().map(|&n| n as usize).collect(),
                };
                self.width = placement.cluster_of.len();
                self.map.insert_stripe(placement, self.topo.clusters());
                Ok(())
            }
            WalRecord::SetFailed { node, down } => {
                let node = *node as usize;
                if node >= self.topo.total_nodes() {
                    return Err(format!("failure mark on unknown node {node}"));
                }
                if *down {
                    self.failed.insert(node);
                } else {
                    self.failed.remove(&node);
                }
                Ok(())
            }
            WalRecord::TopoAddNode { cluster } => {
                let cluster = *cluster as usize;
                if cluster >= self.topo.clusters() {
                    return Err(format!("add-node to unknown cluster {cluster}"));
                }
                if self.topo.is_retired(cluster) {
                    return Err(format!("add-node to retired cluster {cluster}"));
                }
                self.topo.add_node(cluster);
                Ok(())
            }
            WalRecord::TopoAddCluster { nodes } => {
                if *nodes == 0 {
                    return Err("add-cluster with zero nodes".into());
                }
                self.topo.add_cluster(*nodes as usize);
                Ok(())
            }
            WalRecord::TopoSetState { node, state } => {
                let node = *node as usize;
                if node >= self.topo.total_nodes() {
                    return Err(format!("state change on unknown node {node}"));
                }
                let Some(state) = NodeState::from_tag(*state) else {
                    return Err(format!("unknown node-state tag {state}"));
                };
                self.topo.set_state(node, state);
                Ok(())
            }
            WalRecord::TopoRetire { cluster } => {
                let cluster = *cluster as usize;
                if cluster >= self.topo.clusters() {
                    return Err(format!("retire of unknown cluster {cluster}"));
                }
                self.topo.retire_cluster(cluster);
                Ok(())
            }
            WalRecord::MoveBlock { stripe, block, to_cluster, to_node } => {
                let (stripe, block) = (*stripe as usize, *block as usize);
                let (to_cluster, to_node) = (*to_cluster as usize, *to_node as usize);
                if stripe >= self.map.stripe_count() {
                    return Err(format!("move in unknown stripe {stripe}"));
                }
                if block >= self.width {
                    return Err(format!("move of out-of-range block {block}"));
                }
                if to_node >= self.topo.total_nodes()
                    || to_cluster >= self.topo.clusters()
                    || self.topo.cluster_of_node(to_node) != to_cluster
                {
                    return Err(format!("move target ({to_cluster}, {to_node}) is invalid"));
                }
                let row = &self.map.placement(stripe).node_of;
                if row.iter().enumerate().any(|(b, &n)| n == to_node && b != block) {
                    return Err(format!(
                        "move would co-locate two blocks of stripe {stripe} on node {to_node}"
                    ));
                }
                self.map.move_block(stripe, block, to_cluster, to_node);
                Ok(())
            }
            WalRecord::BeginEvent { .. }
            | WalRecord::CommitEvent
            | WalRecord::BeginOnline { .. }
            | WalRecord::OnlineMove { .. }
            | WalRecord::CommitOnline { .. }
            | WalRecord::AbortOnline { .. }
            | WalRecord::Epoch { .. } => {
                Err("group marker cannot be applied as a mutation".into())
            }
        }
    }

    /// Re-apply the admission mutation of an online event (what the live
    /// coordinator did before logging `BeginOnline`). Returns the node ids
    /// the mutation allocated plus the prior states it overwrote, so a
    /// later `AbortOnline` can roll it back exactly.
    fn admit_online(
        &mut self,
        ev: TopologyEvent,
    ) -> Result<(Vec<usize>, Vec<(usize, NodeState)>), String> {
        match ev {
            TopologyEvent::AddNode { cluster } => {
                if cluster >= self.topo.clusters() {
                    return Err(format!("online add-node to unknown cluster {cluster}"));
                }
                if self.topo.is_retired(cluster) {
                    return Err(format!("online add-node to retired cluster {cluster}"));
                }
                let n = self.topo.add_node(cluster);
                Ok((vec![n], Vec::new()))
            }
            TopologyEvent::AddCluster { nodes } => {
                if nodes == 0 {
                    return Err("online add-cluster with zero nodes".into());
                }
                let c = self.topo.add_cluster(nodes);
                Ok((self.topo.nodes_of(c).to_vec(), Vec::new()))
            }
            TopologyEvent::DrainNode { node } => {
                if node >= self.topo.total_nodes() {
                    return Err(format!("online drain of unknown node {node}"));
                }
                let prior = vec![(node, self.topo.state(node))];
                self.topo.set_state(node, NodeState::Draining);
                Ok((Vec::new(), prior))
            }
            TopologyEvent::DecommissionCluster { cluster } => {
                if cluster >= self.topo.clusters() {
                    return Err(format!("online decommission of unknown cluster {cluster}"));
                }
                if self.topo.is_retired(cluster) {
                    return Err(format!("online decommission of retired cluster {cluster}"));
                }
                let members = self.topo.nodes_of(cluster).to_vec();
                let prior: Vec<_> =
                    members.iter().map(|&n| (n, self.topo.state(n))).collect();
                for &n in &members {
                    if self.topo.is_live(n) {
                        self.topo.set_state(n, NodeState::Draining);
                    }
                }
                Ok((Vec::new(), prior))
            }
        }
    }

    /// Apply the completion mutation of an online event (the counterpart
    /// of `CommitOnline`): joiners go active, drained nodes die, retired
    /// clusters retire.
    fn commit_online(&mut self, ev: TopologyEvent, admitted: &[usize]) {
        match ev {
            TopologyEvent::AddNode { .. } | TopologyEvent::AddCluster { .. } => {
                for &n in admitted {
                    self.topo.set_state(n, NodeState::Active);
                }
            }
            TopologyEvent::DrainNode { node } => {
                self.topo.set_state(node, NodeState::Dead);
                self.failed.remove(&node);
            }
            TopologyEvent::DecommissionCluster { cluster } => {
                self.topo.retire_cluster(cluster);
                for n in self.topo.nodes_of(cluster).to_vec() {
                    self.topo.set_state(n, NodeState::Dead);
                    self.failed.remove(&n);
                }
            }
        }
    }

    /// Roll back the admission mutation of a cancelled online event. Any
    /// `done` moves stay where they landed (each was invariant-checked),
    /// so only the topology bookkeeping unwinds.
    fn abort_online(
        &mut self,
        ev: TopologyEvent,
        admitted: &[usize],
        prior: &[(usize, NodeState)],
    ) {
        match ev {
            TopologyEvent::AddNode { .. } => {
                for &n in admitted {
                    self.topo.set_state(n, NodeState::Dead);
                }
            }
            TopologyEvent::AddCluster { .. } => {
                if let Some(&n0) = admitted.first() {
                    let c = self.topo.cluster_of_node(n0);
                    self.topo.retire_cluster(c);
                }
                for &n in admitted {
                    self.topo.set_state(n, NodeState::Dead);
                }
            }
            TopologyEvent::DrainNode { .. } | TopologyEvent::DecommissionCluster { .. } => {
                for &(n, s) in prior {
                    self.topo.set_state(n, s);
                }
            }
        }
    }
}

/// Recover the coordinator state from a journal directory: load the best
/// manifest generation, replay the committed WAL suffix, prove
/// invariants. See the module docs for the tolerance/fail-loudly policy.
pub fn recover(dir: &Path) -> Result<Recovered, RecoveryError> {
    let store = ManifestStore::new(dir);
    let loaded = match store.load() {
        Ok(l) => l,
        Err(ManifestLoadError::Missing) => {
            return Err(RecoveryError::NoManifest { dir: dir.to_path_buf() })
        }
        Err(ManifestLoadError::Corrupt(detail)) => {
            return Err(RecoveryError::CorruptManifest { detail })
        }
    };
    let manifest = loaded.manifest;
    manifest
        .state
        .prove_invariants()
        .map_err(|detail| RecoveryError::InvariantViolation { detail })?;

    // Pick the replay window: the segment containing `last_seq + 1` and
    // everything after it. Older segments are fully covered by the
    // snapshot; a missing *start* segment while later ones exist is a
    // hole we must not paper over.
    let segments = list_segments(dir)?;
    let start = segments
        .iter()
        .rposition(|&(first_seq, _)| first_seq <= manifest.last_seq + 1)
        .unwrap_or(0);
    if let Some((first_seq, path)) = segments.get(start) {
        if *first_seq > manifest.last_seq + 1 {
            return Err(RecoveryError::CorruptWal {
                path: path.clone(),
                offset: 0,
                detail: format!(
                    "log starts at seq {first_seq} but snapshot covers only up to {}",
                    manifest.last_seq
                ),
            });
        }
    }

    let mut replayer = Replayer::from_state(&manifest.state);
    let mut committed_ops = manifest.committed_ops;
    let mut max_epoch = manifest.epoch;
    let mut expected_seq = manifest.last_seq + 1;
    let mut replayed = 0usize;
    let mut torn_tail = false;
    let mut staged: Option<(TopologyEvent, Vec<WalRecord>)> = None;
    let mut online: BTreeMap<u32, OnlineStage> = BTreeMap::new();

    for (si, (_, path)) in segments.iter().enumerate().skip(start) {
        let bytes = std::fs::read(path)?;
        let (records, end) = scan_segment(&bytes);
        for sr in records {
            let (seq, offset, record) = (sr.seq, sr.offset, sr.record);
            if seq < expected_seq {
                continue; // covered by the snapshot
            }
            if seq > expected_seq {
                return Err(RecoveryError::CorruptWal {
                    path: path.clone(),
                    offset,
                    detail: format!("sequence gap: expected {expected_seq}, found {seq}"),
                });
            }
            expected_seq += 1;
            replayed += 1;
            let unreplayable = |detail: String| RecoveryError::Unreplayable { seq, detail };
            match record {
                // Epoch advances are never operations themselves — they
                // ride standalone or inside any group and fold into a
                // running max regardless of whether their group commits
                // (monotonicity is the only contract; a client that saw
                // epoch E must never see it current again after a crash,
                // even if E's mutation itself rolled back).
                WalRecord::Epoch { epoch } => {
                    max_epoch = max_epoch.max(epoch);
                }
                WalRecord::BeginEvent { event } => {
                    if staged.is_some() {
                        return Err(unreplayable("nested BeginEvent".into()));
                    }
                    let ev = event
                        .to_event()
                        .ok_or_else(|| unreplayable(format!("unknown event tag {}", event.tag)))?;
                    staged = Some((ev, Vec::new()));
                }
                WalRecord::CommitEvent => {
                    let Some((_, group)) = staged.take() else {
                        return Err(unreplayable("CommitEvent outside a group".into()));
                    };
                    for rec in &group {
                        replayer.apply(rec).map_err(&unreplayable)?;
                    }
                    committed_ops += 1;
                }
                // Online (background) migration records interleave with
                // standalone ops but never sit inside a stop-the-world
                // event group — the live coordinator forbids both modes
                // at once for the same wave.
                WalRecord::BeginOnline { event_id, event, moves } => {
                    if staged.is_some() {
                        return Err(unreplayable("BeginOnline inside an event group".into()));
                    }
                    if online.contains_key(&event_id) {
                        return Err(unreplayable(format!(
                            "duplicate online event id {event_id}"
                        )));
                    }
                    let ev = event
                        .to_event()
                        .ok_or_else(|| unreplayable(format!("unknown event tag {}", event.tag)))?;
                    // Admission applies only once the full declared plan
                    // has been replayed (immediately for an empty plan).
                    let applied = if moves == 0 {
                        Some(replayer.admit_online(ev).map_err(&unreplayable)?)
                    } else {
                        None
                    };
                    online.insert(
                        event_id,
                        OnlineStage {
                            event: ev,
                            declared: moves as usize,
                            applied,
                            planned: Vec::new(),
                            done: HashSet::new(),
                        },
                    );
                }
                WalRecord::OnlineMove { event_id, done, stripe, block, from_node, to_cluster, to_node } => {
                    let Some(stage) = online.get_mut(&event_id) else {
                        return Err(unreplayable(format!(
                            "OnlineMove for unknown event {event_id}"
                        )));
                    };
                    if done {
                        if stage.applied.is_none() {
                            return Err(unreplayable(format!(
                                "done move for event {event_id} before its plan completed"
                            )));
                        }
                        // A committed move was byte-verified live; fold it
                        // in now with full MoveBlock validation. The
                        // target may differ from the planned twin — that
                        // is the durable trace of a destination re-plan.
                        stage.done.insert((stripe as usize, block as usize));
                        replayer
                            .apply(&WalRecord::MoveBlock { stripe, block, to_cluster, to_node })
                            .map_err(&unreplayable)?;
                    } else {
                        if stage.planned.len() >= stage.declared {
                            return Err(unreplayable(format!(
                                "event {event_id} has more planned moves than the {} declared",
                                stage.declared
                            )));
                        }
                        stage.planned.push(BlockMove {
                            stripe: stripe as usize,
                            block: block as usize,
                            from_node: from_node as usize,
                            to_cluster: to_cluster as usize,
                            to_node: to_node as usize,
                        });
                        if stage.planned.len() == stage.declared {
                            let ev = stage.event;
                            stage.applied =
                                Some(replayer.admit_online(ev).map_err(&unreplayable)?);
                        }
                    }
                }
                WalRecord::CommitOnline { event_id } => {
                    let Some(stage) = online.remove(&event_id) else {
                        return Err(unreplayable(format!(
                            "CommitOnline for unknown event {event_id}"
                        )));
                    };
                    let Some((admitted, _)) = stage.applied else {
                        return Err(unreplayable(format!(
                            "CommitOnline {event_id} before its plan completed"
                        )));
                    };
                    if let Some(mv) = stage
                        .planned
                        .iter()
                        .find(|m| !stage.done.contains(&(m.stripe, m.block)))
                    {
                        return Err(unreplayable(format!(
                            "CommitOnline {event_id} with unfinished move of stripe {} block {}",
                            mv.stripe, mv.block
                        )));
                    }
                    replayer.commit_online(stage.event, &admitted);
                    committed_ops += 1;
                }
                WalRecord::AbortOnline { event_id } => {
                    let Some(stage) = online.remove(&event_id) else {
                        return Err(unreplayable(format!(
                            "AbortOnline for unknown event {event_id}"
                        )));
                    };
                    let Some((admitted, prior)) = stage.applied else {
                        return Err(unreplayable(format!(
                            "AbortOnline {event_id} before its plan completed"
                        )));
                    };
                    replayer.abort_online(stage.event, &admitted, &prior);
                    committed_ops += 1;
                }
                rec @ (WalRecord::TopoAddNode { .. }
                | WalRecord::TopoAddCluster { .. }
                | WalRecord::TopoSetState { .. }
                | WalRecord::TopoRetire { .. }
                | WalRecord::MoveBlock { .. }) => {
                    let Some((_, group)) = staged.as_mut() else {
                        return Err(unreplayable(format!(
                            "{rec:?} outside a BeginEvent group"
                        )));
                    };
                    group.push(rec);
                }
                // Failure-set changes are standalone committed ops on
                // their own, but also ride inside event groups (a drain
                // clears the victim's failure mark atomically with it).
                rec @ WalRecord::SetFailed { .. } => {
                    if let Some((_, group)) = staged.as_mut() {
                        group.push(rec);
                    } else {
                        replayer.apply(&rec).map_err(&unreplayable)?;
                        committed_ops += 1;
                    }
                }
                rec @ WalRecord::AddStripe { .. } => {
                    if staged.is_some() {
                        return Err(unreplayable(format!("{rec:?} inside an event group")));
                    }
                    replayer.apply(&rec).map_err(&unreplayable)?;
                    committed_ops += 1;
                }
            }
        }
        match end {
            ScanEnd::Clean => {}
            ScanEnd::TornTail { .. } => {
                // A torn tail in a non-final segment leaves a hole; the
                // next segment's first record will trip the sequence-gap
                // check above, so just note it here.
                torn_tail = si == segments.len() - 1;
            }
            ScanEnd::Corrupt { offset, detail } => {
                // Committed (fully written) record that no longer
                // verifies: records after it exist but are unreachable —
                // refusing loudly beats silently dropping them.
                return Err(RecoveryError::CorruptWal { path: path.clone(), offset, detail });
            }
        }
    }

    // An open group at end-of-log is the crash-mid-event case: the event
    // never committed; surface it for re-planning.
    let pending_event = staged.map(|(ev, _)| ev);

    // Online events still open at end-of-log resume from the logged
    // plan's uncommitted tail — in plan order, so the resumed run is
    // move-for-move identical to a never-crashed one. A stage whose plan
    // never completed (torn admission append) was never applied and is
    // dropped: the crash predates the submit's durability point, so the
    // driver simply re-submits the event.
    let pending_online: Vec<PendingOnline> = online
        .into_iter()
        .filter_map(|(event_id, stage)| {
            let OnlineStage { event, declared: _, applied, planned, done } = stage;
            let (admitted, prior) = applied?;
            Some(PendingOnline {
                event_id,
                event,
                admitted,
                prior,
                remaining: planned
                    .into_iter()
                    .filter(|m| !done.contains(&(m.stripe, m.block)))
                    .collect(),
            })
        })
        .collect();

    let state = CoordinatorState::capture(
        &manifest.state.code_name,
        &manifest.state.strategy,
        &replayer.topo,
        &replayer.map,
        &replayer.failed,
    );
    state
        .prove_invariants()
        .map_err(|detail| RecoveryError::InvariantViolation { detail })?;

    Ok(Recovered {
        state,
        committed_ops,
        last_seq: expected_seq - 1,
        replayed_records: replayed,
        pending_event,
        pending_online,
        epoch: max_epoch,
        torn_tail,
        used_fallback: loaded.used_fallback,
    })
}
