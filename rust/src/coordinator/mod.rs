//! The coordinator — §4.2's prototype brain, and the assembly point of the
//! whole DSS: metadata (stripe → placement, block → node), failure state,
//! and the [`Dss`] facade the client drives.
//!
//! The data plane is real (blocks are real buffers, coding runs through a
//! [`CodingEngine`] — PJRT artifacts or native GF); the network is the
//! virtual-time [`NetSim`] (DESIGN.md §5 substitution).
//! Operations return latencies on the virtual clock with the measured
//! coding time folded in.
//!
//! The coordinator also owns the **elastic-topology control loop**:
//! [`Dss::apply_topology_event`] mutates the live [`Topology`], asks the
//! migration scheduler ([`migrate`]) for an invariant-preserving move
//! plan, and executes it as batched transfer + coding waves on the
//! virtual clock — dead-source moves rebuild through the same batched
//! [`ProxyCtx::repair_node`] pipeline every repair uses.

pub mod block_map;
pub mod manifest;
pub mod metadata;
pub mod migrate;
pub mod recovery;
pub mod wal;

pub use block_map::{BlockMap, BlockState};
pub use manifest::{CoordinatorState, Manifest, ManifestStore};
pub use metadata::{Metadata, StripeId};
pub use migrate::{
    BackoffPolicy, BlockMove, MigrationError, MigrationPlan, MigrationPolicy, MigrationStats,
};
pub use recovery::{recover, PendingOnline, Recovered, RecoveryError};
pub use wal::{DurabilityOptions, Journal, WalRecord};

use crate::codes::Code;
use crate::placement::{NodeState, PlacementStrategy, Topology, TopologyEvent};
use crate::proxy::{OpOutcome, ProxyCtx, RepairRequest};
use crate::prng::Prng;
use crate::runtime::CodingEngine;
use crate::sim::{Endpoint, NetConfig, NetSim, TrafficClass};
use std::collections::{HashMap, HashSet};
use std::path::Path;
use std::sync::Arc;

/// System-level configuration (§6 Setup).
#[derive(Debug, Clone, Copy)]
pub struct DssConfig {
    /// Block size in bytes (paper: 1 MB; benches default smaller).
    pub block_size: usize,
    /// ECWide-style gateway aggregation of cross-cluster repair traffic.
    pub aggregated: bool,
    /// Fold measured (real) coding time into the virtual clock. On for
    /// experiments; off for deterministic tests.
    pub time_compute: bool,
}

impl Default for DssConfig {
    fn default() -> Self {
        DssConfig { block_size: 1 << 20, aggregated: true, time_compute: true }
    }
}

/// Result of a timed client operation.
#[derive(Debug, Clone, Copy)]
pub struct OpResult {
    /// Virtual seconds from issue to completion.
    pub latency: f64,
    /// Bytes delivered to the requester.
    pub bytes: usize,
    /// Cross-cluster bytes moved by this op.
    pub cross_bytes: u64,
}

/// Full-node recovery summary.
#[derive(Debug, Clone, Copy)]
pub struct RecoveryResult {
    pub blocks: usize,
    pub bytes: usize,
    pub seconds: f64,
    pub cross_bytes: u64,
}

impl RecoveryResult {
    pub fn throughput_mib_s(&self) -> f64 {
        self.bytes as f64 / self.seconds / (1 << 20) as f64
    }
}

/// The assembled distributed storage system.
pub struct Dss {
    pub code: Code,
    pub topo: Topology,
    pub net: NetSim,
    pub cfg: DssConfig,
    engine: Arc<dyn CodingEngine>,
    meta: Metadata,
    failed: HashSet<usize>,
    clock: f64,
    /// Durability journal (WAL + manifest snapshots). `None` = the
    /// original in-memory-only coordinator; enabled via
    /// [`Dss::enable_durability`]. When present, every durable mutation
    /// is logged **before** the in-memory state commits.
    journal: Option<Journal>,
    /// In-flight background (online) migrations — see
    /// [`Dss::submit_topology_event`] / [`Dss::pump_migrations`].
    online: OnlineMigrations,
    /// Metadata epoch: bumped on every committed routing mutation
    /// (stripe ingest, failure-set change, migration commit/abort) and
    /// persisted as `WalRecord::Epoch` / `Manifest::epoch` so the
    /// serving plane's `StaleEpoch` protocol survives a crash. Starts
    /// at 1; deliberately **not** part of [`CoordinatorState`] — the
    /// exp9 oracle compares digests of logical state, and a generation
    /// counter differing between a crashed run and its never-crashed
    /// oracle is expected, not a divergence.
    epoch: u64,
}

impl Dss {
    /// Build a DSS for `code` placed by `strategy` on `topo`. The strategy
    /// is owned: new stripes (and only new stripes) are placed by it
    /// against the *current* topology; existing placements live in the
    /// coordinator's [`BlockMap`] and only move through migration.
    pub fn new(
        code: Code,
        strategy: Box<dyn PlacementStrategy>,
        topo: Topology,
        net_cfg: NetConfig,
        engine: Arc<dyn CodingEngine>,
        cfg: DssConfig,
    ) -> Dss {
        let meta = Metadata::new(&code, strategy);
        let net = NetSim::new(&topo, net_cfg);
        Dss {
            code,
            topo,
            net,
            cfg,
            engine,
            meta,
            failed: HashSet::new(),
            clock: 0.0,
            journal: None,
            online: OnlineMigrations::default(),
            epoch: 1,
        }
    }

    /// Rebuild a coordinator from a recovered [`CoordinatorState`] plus
    /// the surviving block store (crash model: block bytes are
    /// node-resident and survive the coordinator's death). Fails loudly
    /// on any inconsistency — a missing block or mismatched strategy
    /// must never be papered over as silent data loss. The restored
    /// coordinator starts without a journal; call
    /// [`Dss::enable_durability`] on a fresh directory to resume logging.
    pub fn restore(
        code: Code,
        strategy: Box<dyn PlacementStrategy>,
        state: &CoordinatorState,
        blocks: HashMap<(StripeId, usize), Arc<Vec<u8>>>,
        net_cfg: NetConfig,
        engine: Arc<dyn CodingEngine>,
        cfg: DssConfig,
    ) -> anyhow::Result<Dss> {
        state
            .prove_invariants()
            .map_err(|d| anyhow::anyhow!("recovered state fails invariant proof: {d}"))?;
        anyhow::ensure!(
            state.strategy == strategy.name(),
            "manifest was written under strategy '{}', not '{}'",
            state.strategy,
            strategy.name()
        );
        if let Some((clusters, _)) = state.placements.first() {
            anyhow::ensure!(
                clusters.len() == code.n(),
                "manifest stripes are {} blocks wide but the code has n = {}",
                clusters.len(),
                code.n()
            );
        }
        let topo = state.restore_topology();
        let map = state.restore_block_map();
        for s in 0..map.stripe_count() {
            for b in 0..code.n() {
                let data = blocks.get(&(s, b)).ok_or_else(|| {
                    anyhow::anyhow!(
                        "block store is missing stripe {s} block {b} — refusing to restore \
                         a map that silently drops blocks"
                    )
                })?;
                anyhow::ensure!(
                    data.len() == cfg.block_size,
                    "stripe {s} block {b} has {} bytes, expected {}",
                    data.len(),
                    cfg.block_size
                );
            }
        }
        let failed = state.failed.iter().map(|&f| f as usize).collect();
        let net = NetSim::new(&topo, net_cfg);
        let meta = Metadata::restore(map, blocks, strategy, code.n());
        Ok(Dss {
            code,
            topo,
            net,
            cfg,
            engine,
            meta,
            failed,
            clock: 0.0,
            journal: None,
            online: OnlineMigrations::default(),
            epoch: 1,
        })
    }

    pub fn metadata(&self) -> &Metadata {
        &self.meta
    }

    pub fn engine(&self) -> &Arc<dyn CodingEngine> {
        &self.engine
    }

    pub fn clock(&self) -> f64 {
        self.clock
    }

    /// Reset the virtual clock and network meters (between experiment
    /// phases); stored data and failure state are preserved.
    pub fn quiesce(&mut self) {
        self.clock = 0.0;
        self.net.reset();
    }

    // ---------------------------------------------------------- durability

    /// Turn on the durability layer: write an initial manifest snapshot
    /// of the current state into `dir` and open a WAL. From here on,
    /// every durable mutation (stripe registration, failure-set change,
    /// topology event with its block moves) is logged before it commits
    /// in memory, and the manifest is re-snapshotted (with log
    /// truncation) every `opts.snapshot_every` committed operations.
    pub fn enable_durability(&mut self, dir: &Path, opts: DurabilityOptions) -> anyhow::Result<()> {
        anyhow::ensure!(self.journal.is_none(), "durability already enabled");
        // An in-flight online event's Begin/plan records live only in the
        // *previous* journal; a fresh journal's snapshot would not carry
        // the claims and its WAL would see done-moves for an event it
        // never admitted. Finish or cancel in-flight work first.
        anyhow::ensure!(
            self.online.events.is_empty(),
            "cannot enable durability with {} online migration(s) in flight",
            self.online.events.len()
        );
        let state = self.capture_state();
        self.journal = Some(Journal::create(dir, &state, self.epoch, opts)?);
        Ok(())
    }

    /// Current metadata epoch (see the `epoch` field).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Override the epoch — the restore path after crash recovery must
    /// resume *past* [`crate::coordinator::recovery::Recovered::epoch`]
    /// (callers pass `recovered.epoch + 1`) so no pre-crash routing
    /// table ever validates as current again.
    pub fn set_epoch(&mut self, epoch: u64) {
        self.epoch = epoch;
    }

    /// Bump the epoch and return the WAL record carrying the new value.
    /// Callers append the record in the same group as the mutation it
    /// stamps, keeping bump-and-log atomic under group replay.
    fn bump_epoch(&mut self) -> WalRecord {
        self.epoch += 1;
        WalRecord::Epoch { epoch: self.epoch }
    }

    /// The journal, when durability is enabled (report metrics: WAL
    /// bytes/records, snapshot count, committed operations).
    pub fn journal(&self) -> Option<&Journal> {
        self.journal.as_ref()
    }

    /// Snapshot the durable logical state (topology + block map +
    /// failure set). This is what the manifest persists and what the
    /// exp9 oracle digests.
    pub fn capture_state(&self) -> CoordinatorState {
        CoordinatorState::capture(
            self.code.name(),
            self.meta.strategy_name(),
            &self.topo,
            self.meta.block_map(),
            &self.failed,
        )
    }

    /// Export the block store (`Arc` clones) — the node-resident bytes
    /// that survive a simulated coordinator crash.
    pub fn export_blocks(&self) -> HashMap<(StripeId, usize), Arc<Vec<u8>>> {
        self.meta.export_blocks()
    }

    /// Corruption-injection hook (tests): flip the ground-truth bytes of
    /// one block so every later byte-verification of it fails.
    pub fn corrupt_block_data(&mut self, stripe: StripeId, block: usize) {
        self.meta.corrupt_block_data(stripe, block);
    }

    /// Append one committed operation to the WAL (no-op without a
    /// journal). Durability failures are fatal: continuing after a lost
    /// log write would silently break the crash-consistency contract.
    fn log_op(&mut self, records: &[WalRecord]) {
        if let Some(j) = self.journal.as_mut() {
            j.commit_op(records).expect("WAL append failed — cannot keep durability promise");
        }
    }

    /// Re-snapshot the manifest when the cadence is due. Gated off while
    /// any online migration is open: a snapshot rotates and truncates the
    /// WAL, and an open event's `BeginOnline`/plan records must survive
    /// until its commit or abort marker lands.
    fn maybe_snapshot(&mut self) {
        if !self.online.events.is_empty() {
            return;
        }
        if self.journal.as_ref().is_some_and(|j| j.snapshot_due()) {
            let state = self.capture_state();
            let epoch = self.epoch;
            self.journal
                .as_mut()
                .expect("journal checked above")
                .snapshot(&state, epoch)
                .expect("manifest snapshot failed — cannot keep durability promise");
        }
    }

    // ------------------------------------------------------------- ingest

    /// Create `count` stripes of random data; encode and store (setup path,
    /// untimed — the experiments of §6 measure reads and recovery).
    pub fn ingest_random_stripes(&mut self, count: usize, prng: &mut Prng) -> anyhow::Result<()> {
        for _ in 0..count {
            let data: Vec<Vec<u8>> =
                (0..self.code.k()).map(|_| prng.bytes(self.cfg.block_size)).collect();
            self.ingest_stripe(data)?;
        }
        Ok(())
    }

    /// Encode one stripe of `k` data blocks and store all `n` blocks.
    pub fn ingest_stripe(&mut self, data: Vec<Vec<u8>>) -> anyhow::Result<StripeId> {
        anyhow::ensure!(data.len() == self.code.k(), "need k data blocks");
        anyhow::ensure!(
            data.iter().all(|b| b.len() == self.cfg.block_size),
            "blocks must match configured block size"
        );
        let drefs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
        let parities = self.engine.encode(&self.code, &drefs)?;
        let blocks: Vec<Arc<Vec<u8>>> = data.into_iter().chain(parities).map(Arc::new).collect();
        // Log-then-apply: the placement is computed (pure), journaled as
        // an `AddStripe` record, and only then committed to the map.
        let placement = self.meta.place_next_stripe(&self.code, &self.topo);
        let epoch = self.bump_epoch();
        self.log_op(&[
            WalRecord::AddStripe {
                cluster_of: placement.cluster_of.iter().map(|&c| c as u32).collect(),
                node_of: placement.node_of.iter().map(|&n| n as u32).collect(),
            },
            epoch,
        ]);
        let id = self.meta.add_stripe_with_placement(blocks, placement, self.topo.clusters());
        self.maybe_snapshot();
        Ok(id)
    }

    // ------------------------------------------------------------ failures

    /// Mark a node failed. Block buffers stay in the metadata store — they
    /// are the ground truth every repair is verified against; a failed
    /// node's blocks are simply unreadable by operations.
    pub fn fail_node(&mut self, node: usize) {
        assert!(node < self.topo.total_nodes());
        let epoch = self.bump_epoch();
        self.log_op(&[WalRecord::SetFailed { node: node as u32, down: true }, epoch]);
        self.failed.insert(node);
        self.maybe_snapshot();
    }

    pub fn heal_node(&mut self, node: usize) {
        assert!(node < self.topo.total_nodes());
        let epoch = self.bump_epoch();
        self.log_op(&[WalRecord::SetFailed { node: node as u32, down: false }, epoch]);
        self.failed.remove(&node);
        self.maybe_snapshot();
    }

    pub fn failed_nodes(&self) -> &HashSet<usize> {
        &self.failed
    }

    fn is_failed(&self, stripe: StripeId, block: usize) -> bool {
        self.failed.contains(&self.meta.node_of(stripe, block))
    }

    /// Failed block indices of a stripe.
    pub fn failed_blocks(&self, stripe: StripeId) -> Vec<usize> {
        (0..self.code.n()).filter(|&b| self.is_failed(stripe, b)).collect()
    }

    /// Availability snapshot under the current failure set:
    /// `(degraded, unavailable)` — degraded when any stripe has ≥ 1 failed
    /// block, unavailable when some stripe's erasure pattern is
    /// unrecoverable (a data-unavailability window in the fault scenarios).
    /// Recoverability goes through the decode-plan cache, so sweeping the
    /// same failure state between events is a map hit, not a rank test.
    pub fn availability(&self) -> (bool, bool) {
        let mut degraded = false;
        for s in 0..self.meta.stripe_count() {
            let failed = self.failed_blocks(s);
            if failed.is_empty() {
                continue;
            }
            degraded = true;
            if self.code.decode_plan_cached(&failed).is_none() {
                return (true, true);
            }
        }
        (degraded, false)
    }

    /// True when `stripe`'s current erasure pattern is recoverable.
    pub fn stripe_recoverable(&self, stripe: StripeId) -> bool {
        let failed = self.failed_blocks(stripe);
        failed.is_empty() || self.code.decode_plan_cached(&failed).is_some()
    }

    /// Warm the global decode-plan cache with predicted erasure patterns
    /// (fault-trace warm-up, `--plan-warmup`): the first failure burst then
    /// pays map hits instead of rank tests + inversions. Returns the
    /// number of plans inserted ([`crate::codes::PlanCache::prefetch`]).
    pub fn prefetch_plans(&mut self, patterns: &[Vec<usize>]) -> usize {
        self.proxy_ctx().warm_plans(patterns)
    }

    fn proxy_ctx(&mut self) -> ProxyCtx<'_> {
        ProxyCtx {
            code: &self.code,
            meta: &self.meta,
            net: &mut self.net,
            engine: &*self.engine,
            aggregated: self.cfg.aggregated,
            block_size: self.cfg.block_size,
            time_compute: self.cfg.time_compute,
        }
    }

    // ------------------------------------------------------------- reads

    /// Normal read (§4.1): fetch all `k` data blocks of a stripe to the
    /// client, in parallel. Returns completion latency.
    pub fn normal_read(&mut self, stripe: StripeId) -> anyhow::Result<OpResult> {
        let t0 = self.clock;
        let cross0 = self.net.cross_bytes;
        let bs = self.cfg.block_size;
        anyhow::ensure!(
            self.failed_blocks(stripe).iter().all(|&b| b >= self.code.k()),
            "normal read on a stripe with failed data blocks — use degraded_read"
        );
        let mut done = t0;
        for b in 0..self.code.k() {
            let node = self.meta.node_of(stripe, b);
            let t = self.net.transfer(t0, Endpoint::Node(node), Endpoint::Client, bs);
            done = done.max(t);
        }
        self.clock = done;
        Ok(OpResult {
            latency: done - t0,
            bytes: bs * self.code.k(),
            cross_bytes: self.net.cross_bytes - cross0,
        })
    }

    /// Read an arbitrary subset of live blocks to the client in parallel
    /// (object reads of Experiment 6).
    pub fn read_blocks(&mut self, stripe: StripeId, blocks: &[usize]) -> anyhow::Result<OpResult> {
        let t0 = self.clock;
        let cross0 = self.net.cross_bytes;
        let bs = self.cfg.block_size;
        let mut done = t0;
        for &b in blocks {
            anyhow::ensure!(!self.is_failed(stripe, b), "block {b} is failed");
            let node = self.meta.node_of(stripe, b);
            let t = self.net.transfer(t0, Endpoint::Node(node), Endpoint::Client, bs);
            done = done.max(t);
        }
        self.clock = done;
        Ok(OpResult {
            latency: done - t0,
            bytes: bs * blocks.len(),
            cross_bytes: self.net.cross_bytes - cross0,
        })
    }

    /// Degraded read (§4.1): client requests one *unavailable* data block;
    /// the home proxy repairs it from surviving blocks and ships it.
    pub fn degraded_read(&mut self, stripe: StripeId, block: usize) -> anyhow::Result<OpResult> {
        let t0 = self.clock;
        let cross0 = self.net.cross_bytes;
        let done = self.degraded_read_at(t0, stripe, block)?;
        self.clock = done;
        Ok(OpResult {
            latency: done - t0,
            bytes: self.cfg.block_size,
            cross_bytes: self.net.cross_bytes - cross0,
        })
    }

    /// Degraded-read path starting at a fixed virtual instant; returns the
    /// completion time (used by [`Self::parallel_read`] fan-outs and the
    /// fixed-schedule foreground probes of the exp10 interference curve).
    pub(crate) fn degraded_read_at(
        &mut self,
        t0: f64,
        stripe: StripeId,
        block: usize,
    ) -> anyhow::Result<f64> {
        anyhow::ensure!(block < self.code.k(), "degraded read targets a data block");
        let bs = self.cfg.block_size;
        let erased = self.failed_blocks(stripe);
        anyhow::ensure!(erased.contains(&block), "block {block} is not failed");

        let mut ctx = self.proxy_ctx();
        let OpOutcome { ready_at, rebuilt, home } = ctx.repair_block(t0, stripe, block, &erased)?;
        // verify against ground truth, then ship to the client
        anyhow::ensure!(
            rebuilt.as_slice() == self.meta.block_data(stripe, block).as_slice(),
            "degraded read returned corrupt bytes"
        );
        crate::gf::pool::recycle(rebuilt);
        Ok(self.net.transfer(ready_at, Endpoint::Proxy(home), Endpoint::Client, bs))
    }

    /// Parallel object read (Experiment 6): fetch every listed block at the
    /// same instant — healthy blocks straight from their nodes, failed data
    /// blocks through the degraded path — and complete when the slowest
    /// arrives. This is where placement load-imbalance shows up.
    ///
    /// All degraded repairs of the fan-out are submitted as *one* batched
    /// event ([`ProxyCtx::repair_node`]): the engine's worker pool overlaps
    /// their combines instead of repairing stripe by stripe, and the batch
    /// sizes its task granularity to the event (a burst of thousands of
    /// small blocks lands ~2–4 tasks per worker, not thousands of
    /// lane-sized ones — `GfEngine::batch_chunk`).
    pub fn parallel_read(&mut self, blocks: &[(StripeId, usize)]) -> anyhow::Result<OpResult> {
        let t0 = self.clock;
        let cross0 = self.net.cross_bytes;
        let bs = self.cfg.block_size;
        let mut done = t0;
        let mut degraded: Vec<RepairRequest> = Vec::new();
        for &(stripe, block) in blocks {
            if self.is_failed(stripe, block) {
                anyhow::ensure!(block < self.code.k(), "degraded read targets a data block");
                degraded.push(RepairRequest {
                    stripe,
                    block,
                    erased: self.failed_blocks(stripe),
                });
            } else {
                let node = self.meta.node_of(stripe, block);
                let t = self.net.transfer(t0, Endpoint::Node(node), Endpoint::Client, bs);
                done = done.max(t);
            }
        }
        if !degraded.is_empty() {
            let outcomes = {
                let mut ctx = self.proxy_ctx();
                ctx.repair_node(t0, &degraded)?
            };
            for (req, oc) in degraded.iter().zip(outcomes) {
                let OpOutcome { ready_at, rebuilt, home } = oc;
                anyhow::ensure!(
                    rebuilt.as_slice() == self.meta.block_data(req.stripe, req.block).as_slice(),
                    "degraded read returned corrupt bytes"
                );
                crate::gf::pool::recycle(rebuilt);
                let t = self.net.transfer(ready_at, Endpoint::Proxy(home), Endpoint::Client, bs);
                done = done.max(t);
            }
        }
        self.clock = done;
        Ok(OpResult {
            latency: done - t0,
            bytes: bs * blocks.len(),
            cross_bytes: self.net.cross_bytes - cross0,
        })
    }

    /// Reconstruction (§4.1): rebuild one failed block (data or parity)
    /// onto a live spare node in its home cluster.
    pub fn reconstruct(&mut self, stripe: StripeId, block: usize) -> anyhow::Result<OpResult> {
        let t0 = self.clock;
        let r = self.reconstruct_at(t0, stripe, block)?;
        self.clock = t0 + r.latency;
        Ok(r)
    }

    fn reconstruct_at(
        &mut self,
        t0: f64,
        stripe: StripeId,
        block: usize,
    ) -> anyhow::Result<OpResult> {
        let cross0 = self.net.cross_bytes;
        let bs = self.cfg.block_size;
        let erased = self.failed_blocks(stripe);
        anyhow::ensure!(erased.contains(&block), "block {block} is not failed");

        let mut ctx = self.proxy_ctx();
        let OpOutcome { ready_at, rebuilt, home } = ctx.repair_block(t0, stripe, block, &erased)?;
        anyhow::ensure!(
            rebuilt.as_slice() == self.meta.block_data(stripe, block).as_slice(),
            "reconstruction produced corrupt bytes"
        );
        crate::gf::pool::recycle(rebuilt);
        // write to a live spare node in the home cluster (or any cluster)
        let spare = self.spare_node(stripe, home)?;
        let done = self.net.transfer(ready_at, Endpoint::Proxy(home), Endpoint::Node(spare), bs);
        Ok(OpResult { latency: done - t0, bytes: bs, cross_bytes: self.net.cross_bytes - cross0 })
    }

    /// Pick a live *active* node in `cluster` not already hosting a block
    /// of the stripe; falls back to any active node elsewhere.
    fn spare_node(&self, stripe: StripeId, cluster: usize) -> anyhow::Result<usize> {
        let used: HashSet<usize> =
            (0..self.code.n()).map(|b| self.meta.node_of(stripe, b)).collect();
        let free =
            |n: &usize| !used.contains(n) && !self.failed.contains(n) && self.topo.is_active(*n);
        self.topo
            .nodes_of(cluster)
            .iter()
            .copied()
            .find(free)
            .or_else(|| (0..self.topo.total_nodes()).find(free))
            .ok_or_else(|| anyhow::anyhow!("no spare node available"))
    }

    /// Full-node recovery (§6 Exp 3): reconstruct every block the failed
    /// node hosted, all repairs issued in parallel at t=0 as one batched
    /// event — the engine's worker pool schedules every stripe's combines
    /// together ([`ProxyCtx::repair_node`]) instead of stripe by stripe,
    /// at a task granularity adapted to the event size
    /// (`GfEngine::batch_chunk`, knob `--gf-chunk-kb`).
    pub fn recover_node(&mut self, node: usize) -> anyhow::Result<RecoveryResult> {
        self.recover_nodes(&[node])
    }

    /// Recover several failed nodes as **one** batched repair event (the
    /// correlated-burst shape of the fault scenarios: a whole-cluster
    /// repair lands many replacement nodes at the same instant). Every
    /// lost block across all nodes goes through a single
    /// [`ProxyCtx::repair_node`] submission, so the engine's batched
    /// pipeline sizes its task granularity to the entire burst.
    pub fn recover_nodes(&mut self, nodes: &[usize]) -> anyhow::Result<RecoveryResult> {
        let mut lost: Vec<(StripeId, usize)> = Vec::new();
        for &node in nodes {
            anyhow::ensure!(self.failed.contains(&node), "node {node} is not failed");
            lost.extend(self.meta.blocks_on_node(node));
        }
        lost.sort_unstable();
        self.recover_blocks(&lost)
    }

    /// Rebuild an arbitrary set of lost blocks as one batched repair event
    /// and write each onto a live spare node. Callers pass blocks whose
    /// stripes are currently recoverable (the fault-scenario runner skips —
    /// and counts — stripes that are not; see [`Self::stripe_recoverable`]).
    pub fn recover_blocks(&mut self, lost: &[(StripeId, usize)]) -> anyhow::Result<RecoveryResult> {
        let t0 = self.clock;
        let cross0 = self.net.cross_bytes;
        let bs = self.cfg.block_size;
        let reqs: Vec<RepairRequest> = lost
            .iter()
            .map(|&(stripe, block)| RepairRequest {
                stripe,
                block,
                erased: self.failed_blocks(stripe),
            })
            .collect();
        let outcomes = {
            let mut ctx = self.proxy_ctx();
            ctx.repair_node(t0, &reqs)?
        };
        let mut done = t0;
        let mut bytes = 0usize;
        for (req, oc) in reqs.iter().zip(outcomes) {
            let OpOutcome { ready_at, rebuilt, home } = oc;
            anyhow::ensure!(
                rebuilt.as_slice() == self.meta.block_data(req.stripe, req.block).as_slice(),
                "reconstruction produced corrupt bytes"
            );
            crate::gf::pool::recycle(rebuilt);
            // write to a live spare node in the home cluster (or any cluster)
            let spare = self.spare_node(req.stripe, home)?;
            let t = self.net.transfer(ready_at, Endpoint::Proxy(home), Endpoint::Node(spare), bs);
            done = done.max(t);
            bytes += bs;
        }
        self.clock = done;
        Ok(RecoveryResult {
            blocks: lost.len(),
            bytes,
            seconds: done - t0,
            cross_bytes: self.net.cross_bytes - cross0,
        })
    }

    // ----------------------------------------------------- elastic topology

    /// Apply a topology event: mutate the live [`Topology`], plan the
    /// minimal invariant-preserving block migration
    /// ([`migrate`]), execute it as batched transfer/coding waves on the
    /// virtual clock, and commit the moves to the coordinator's
    /// [`BlockMap`]. Returns the migration metrics.
    ///
    /// Commit discipline (the WAL contract): transfers run and every
    /// rebuilt block is **byte-verified** first; only then is the event
    /// group (topology transitions + block moves) appended to the WAL,
    /// and only after that does the in-memory [`BlockMap`] mutate. A
    /// failure anywhere before the WAL commit rolls the topology back
    /// and leaves the map untouched — verified by
    /// `tests/recovery.rs::failed_event_commits_nothing`.
    pub fn apply_topology_event(
        &mut self,
        ev: TopologyEvent,
    ) -> anyhow::Result<MigrationReport> {
        // Stop-the-world and online migration never mix in one wave: the
        // stop-the-world committer writes through `BlockMap::move_block`,
        // which must not race an open claim.
        anyhow::ensure!(
            self.online.events.is_empty(),
            "stop-the-world topology event while {} online migration(s) are in flight",
            self.online.events.len()
        );
        let wall0 = std::time::Instant::now();
        let mut report = self.apply_topology_event_inner(ev)?;
        report.wall_ms = wall0.elapsed().as_secs_f64() * 1e3;
        self.maybe_snapshot();
        Ok(report)
    }

    fn apply_topology_event_inner(
        &mut self,
        ev: TopologyEvent,
    ) -> anyhow::Result<MigrationReport> {
        match ev {
            TopologyEvent::AddNode { cluster } => {
                anyhow::ensure!(cluster < self.topo.clusters(), "no such cluster {cluster}");
                anyhow::ensure!(!self.topo.is_retired(cluster), "cluster {cluster} is retired");
                let node = self.topo.add_node(cluster);
                self.net.sync(&self.topo);
                let plan = migrate::plan_add_node(
                    &self.topo,
                    self.meta.block_map(),
                    &self.failed,
                    cluster,
                    node,
                );
                let exec = self.transfer_and_verify(&plan).and_then(|exec| {
                    self.log_event(
                        ev,
                        vec![WalRecord::TopoAddNode { cluster: cluster as u32 }],
                        &plan,
                        vec![WalRecord::TopoSetState {
                            node: node as u32,
                            state: NodeState::Active.tag(),
                        }],
                    )?;
                    Ok(exec)
                });
                let exec = match exec {
                    Ok(exec) => exec,
                    Err(e) => {
                        // Node ids are never reused: the failed scale-out
                        // leaves a dead id behind, the map untouched.
                        self.topo.set_state(node, NodeState::Dead);
                        return Err(e);
                    }
                };
                let report = self.commit_migration(ev, &plan, exec);
                self.topo.set_state(node, NodeState::Active);
                Ok(report)
            }
            TopologyEvent::DrainNode { node } => {
                anyhow::ensure!(node < self.topo.total_nodes(), "no such node {node}");
                anyhow::ensure!(self.topo.is_live(node), "node {node} is already dead");
                // Plan before touching lifecycle state, so an unplannable
                // drain leaves the system exactly as it was. Planning with
                // the victim still Active is sound: every move the plan
                // contains is for a stripe the victim hosts, and a stripe's
                // own nodes are never target-eligible.
                let policy = MigrationPolicy::for_strategy(self.meta.strategy_name());
                let plan = migrate::plan_drain(
                    &self.code,
                    policy,
                    &self.topo,
                    self.meta.block_map(),
                    &self.failed,
                    node,
                )?;
                let prior = self.topo.state(node);
                self.topo.set_state(node, NodeState::Draining);
                let mut post = vec![WalRecord::TopoSetState {
                    node: node as u32,
                    state: NodeState::Dead.tag(),
                }];
                if self.failed.contains(&node) {
                    post.push(WalRecord::SetFailed { node: node as u32, down: false });
                }
                let exec = self.transfer_and_verify(&plan).and_then(|exec| {
                    self.log_event(ev, Vec::new(), &plan, post)?;
                    Ok(exec)
                });
                let exec = match exec {
                    Ok(exec) => exec,
                    Err(e) => {
                        self.topo.set_state(node, prior);
                        return Err(e);
                    }
                };
                let report = self.commit_migration(ev, &plan, exec);
                self.topo.set_state(node, NodeState::Dead);
                self.failed.remove(&node); // dead ≠ failed: nothing left to repair
                Ok(report)
            }
            TopologyEvent::AddCluster { nodes } => {
                anyhow::ensure!(nodes > 0, "a cluster needs at least one node");
                let cluster = self.topo.add_cluster(nodes);
                self.net.sync(&self.topo);
                let plan = migrate::plan_add_cluster(
                    &self.topo,
                    self.meta.block_map(),
                    &self.failed,
                    cluster,
                );
                let members = self.topo.nodes_of(cluster).to_vec();
                let post = members
                    .iter()
                    .map(|&n| WalRecord::TopoSetState {
                        node: n as u32,
                        state: NodeState::Active.tag(),
                    })
                    .collect();
                let exec = self.transfer_and_verify(&plan).and_then(|exec| {
                    self.log_event(
                        ev,
                        vec![WalRecord::TopoAddCluster { nodes: nodes as u32 }],
                        &plan,
                        post,
                    )?;
                    Ok(exec)
                });
                let exec = match exec {
                    Ok(exec) => exec,
                    Err(e) => {
                        // Retire the stillborn cluster; its joining nodes
                        // die with it (ids are never reused).
                        self.topo.retire_cluster(cluster);
                        for &n in &members {
                            self.topo.set_state(n, NodeState::Dead);
                        }
                        return Err(e);
                    }
                };
                let report = self.commit_migration(ev, &plan, exec);
                for n in members {
                    self.topo.set_state(n, NodeState::Active);
                }
                Ok(report)
            }
            TopologyEvent::DecommissionCluster { cluster } => {
                anyhow::ensure!(cluster < self.topo.clusters(), "no such cluster {cluster}");
                anyhow::ensure!(!self.topo.is_retired(cluster), "cluster {cluster} is retired");
                // Plan first: an undecommissionable cluster (no eligible
                // homes) must leave the topology untouched and the event
                // retryable. The planner already skips the retiring
                // cluster as a relocation target, so planning while it is
                // still open/active is sound.
                let plan = migrate::plan_decommission(
                    &self.topo,
                    self.meta.block_map(),
                    &self.failed,
                    cluster,
                )?;
                let members = self.topo.nodes_of(cluster).to_vec();
                let prior: Vec<NodeState> =
                    members.iter().map(|&n| self.topo.state(n)).collect();
                for &n in &members {
                    if self.topo.is_live(n) {
                        self.topo.set_state(n, NodeState::Draining);
                    }
                }
                let mut post = vec![WalRecord::TopoRetire { cluster: cluster as u32 }];
                for &n in &members {
                    post.push(WalRecord::TopoSetState {
                        node: n as u32,
                        state: NodeState::Dead.tag(),
                    });
                    if self.failed.contains(&n) {
                        post.push(WalRecord::SetFailed { node: n as u32, down: false });
                    }
                }
                let exec = self.transfer_and_verify(&plan).and_then(|exec| {
                    self.log_event(ev, Vec::new(), &plan, post)?;
                    Ok(exec)
                });
                let exec = match exec {
                    Ok(exec) => exec,
                    Err(e) => {
                        for (&n, &s) in members.iter().zip(&prior) {
                            self.topo.set_state(n, s);
                        }
                        return Err(e);
                    }
                };
                let report = self.commit_migration(ev, &plan, exec);
                self.topo.retire_cluster(cluster);
                for &n in &members {
                    self.topo.set_state(n, NodeState::Dead);
                    self.failed.remove(&n);
                }
                Ok(report)
            }
        }
    }

    /// Append one topology event's WAL group:
    /// `BeginEvent · pre · MoveBlock* · post · CommitEvent`. Replay
    /// applies the group atomically at the commit marker, so the record
    /// order mirrors replay needs (e.g. `TopoAddNode` precedes the moves
    /// that target the new node), not in-memory mutation order.
    fn log_event(
        &mut self,
        ev: TopologyEvent,
        pre: Vec<WalRecord>,
        plan: &MigrationPlan,
        post: Vec<WalRecord>,
    ) -> anyhow::Result<()> {
        if self.journal.is_none() {
            return Ok(());
        }
        let mut records = Vec::with_capacity(pre.len() + plan.len() + post.len() + 2);
        records.push(WalRecord::BeginEvent { event: wal::WalEvent::from_event(ev) });
        records.extend(pre);
        records.extend(plan.moves.iter().map(|mv| WalRecord::MoveBlock {
            stripe: mv.stripe as u32,
            block: mv.block as u32,
            to_cluster: mv.to_cluster as u32,
            to_node: mv.to_node as u32,
        }));
        records.extend(post);
        // Peek, don't bump: the in-memory epoch advances exactly once in
        // `commit_migration` (which runs with or without a journal); the
        // log carries the value it will advance to.
        records.push(WalRecord::Epoch { epoch: self.epoch + 1 });
        records.push(WalRecord::CommitEvent);
        self.journal
            .as_mut()
            .expect("journal checked above")
            .commit_op(&records)
            .map_err(|e| anyhow::anyhow!("WAL commit of {ev:?} failed: {e}"))
    }

    /// Run a migration plan's data movement as one event on the virtual
    /// clock — **without committing anything to the map**:
    ///
    /// * moves whose source is readable are direct node→node transfers
    ///   (gateway-metered when they cross clusters), all issued at `t0`;
    /// * moves whose source is failed/dead rebuild through **one** batched
    ///   [`ProxyCtx::repair_node`] submission — the same
    ///   `GfEngine::batch`-backed pipeline every repair burst uses, so
    ///   migration coding never spawns per-move threads or falls back to
    ///   scalar paths — then ship proxy→target.
    ///
    /// Every rebuilt block is byte-verified against ground truth here;
    /// an error return leaves the [`BlockMap`] untouched. The caller
    /// commits via [`Dss::commit_migration`] only after the event's WAL
    /// group is down.
    fn transfer_and_verify(&mut self, plan: &MigrationPlan) -> anyhow::Result<MigrationExec> {
        let t0 = self.clock;
        let cross0 = self.net.cross_bytes;
        let bs = self.cfg.block_size;
        let mut done = t0;
        let mut direct: Vec<&BlockMove> = Vec::new();
        let mut rebuild: Vec<&BlockMove> = Vec::new();
        for mv in &plan.moves {
            let src_dead =
                self.failed.contains(&mv.from_node) || !self.topo.is_live(mv.from_node);
            if src_dead {
                rebuild.push(mv);
            } else {
                direct.push(mv);
            }
        }
        for mv in &direct {
            let t = self.net.transfer(
                t0,
                Endpoint::Node(mv.from_node),
                Endpoint::Node(mv.to_node),
                bs,
            );
            done = done.max(t);
        }
        if !rebuild.is_empty() {
            let reqs: Vec<RepairRequest> = rebuild
                .iter()
                .map(|mv| RepairRequest {
                    stripe: mv.stripe,
                    block: mv.block,
                    erased: self.failed_blocks(mv.stripe),
                })
                .collect();
            let outcomes = {
                let mut ctx = self.proxy_ctx();
                ctx.repair_node(t0, &reqs)?
            };
            for (mv, oc) in rebuild.iter().zip(outcomes) {
                let OpOutcome { ready_at, rebuilt, home } = oc;
                anyhow::ensure!(
                    rebuilt.as_slice() == self.meta.block_data(mv.stripe, mv.block).as_slice(),
                    "migration rebuild produced corrupt bytes"
                );
                crate::gf::pool::recycle(rebuilt);
                let t = self.net.transfer(
                    ready_at,
                    Endpoint::Proxy(home),
                    Endpoint::Node(mv.to_node),
                    bs,
                );
                done = done.max(t);
            }
        }
        Ok(MigrationExec { t0, done, cross0, repaired_moves: rebuild.len() })
    }

    /// Commit half of a migration: apply the plan's moves to the
    /// [`BlockMap`], advance the clock, and report. Runs only after
    /// byte-verification succeeded and the WAL group committed.
    fn commit_migration(
        &mut self,
        event: TopologyEvent,
        plan: &MigrationPlan,
        exec: MigrationExec,
    ) -> MigrationReport {
        for mv in &plan.moves {
            self.meta.move_block(mv.stripe, mv.block, mv.to_cluster, mv.to_node);
        }
        self.epoch += 1; // matches the Epoch record log_event staged
        self.clock = exec.done;
        MigrationReport {
            event,
            moves: plan.len(),
            repaired_moves: exec.repaired_moves,
            bytes_moved: plan.len() * self.cfg.block_size,
            cross_bytes: self.net.cross_bytes - exec.cross0,
            seconds: exec.done - exec.t0,
            wall_ms: 0.0,
        }
    }

    // ----------------------------------------------------- online migration

    /// Admit a topology event into the background-migration queue without
    /// moving a byte. The admission mutation (new node/cluster joins, the
    /// drain victim turns Draining) happens now; every planned move claims
    /// its block (`BlockState::Migrating`) and reserves its target slot,
    /// and the full plan is journaled as an **open** `BeginOnline` group.
    /// Data moves only when [`Dss::pump_migrations`] runs.
    ///
    /// Conflict discipline: a plan that touches a block another in-flight
    /// event already claims — or targets a `(stripe, node)` slot another
    /// in-flight move reserves, or drains a node an in-flight move is
    /// landing on — is rejected with [`MigrationError::Conflicting`]
    /// (retryable after the holder commits) and the admission mutation is
    /// rolled back exactly like a failed stop-the-world event. The map is
    /// never left half-claimed.
    pub fn submit_topology_event(&mut self, ev: TopologyEvent) -> Result<u32, MigrationError> {
        let (plan, admitted, prior) = match self.admit_event(ev) {
            Ok(parts) => parts,
            Err(e) => {
                match &e {
                    MigrationError::Conflicting { .. } => self.online.stats.conflicts += 1,
                    MigrationError::Unplannable { .. } => self.online.stats.unplannable += 1,
                    MigrationError::SourceDown { .. } => {}
                }
                return Err(e);
            }
        };
        let id = self.online.next_id;
        self.online.next_id += 1;
        for mv in &plan.moves {
            let claimed = self.meta.begin_move(mv.stripe, mv.block, mv.to_cluster, mv.to_node);
            debug_assert!(claimed, "conflict check precedes claims");
            self.online.reserved.insert((mv.stripe, mv.to_node));
        }
        // Admission mutates routing state (topology joins, Migrating
        // claims), so it advances the epoch — this is what makes the
        // serving plane's stale-epoch redirect deterministic right after
        // a topology submission, before any move commits.
        let epoch = self.bump_epoch();
        if self.journal.is_some() {
            let mut records = Vec::with_capacity(plan.len() + 2);
            records.push(WalRecord::BeginOnline {
                event_id: id,
                event: wal::WalEvent::from_event(ev),
                moves: plan.len() as u32,
            });
            records.extend(plan.moves.iter().map(|mv| WalRecord::OnlineMove {
                event_id: id,
                done: false,
                stripe: mv.stripe as u32,
                block: mv.block as u32,
                from_node: mv.from_node as u32,
                to_cluster: mv.to_cluster as u32,
                to_node: mv.to_node as u32,
            }));
            records.push(epoch);
            self.journal
                .as_mut()
                .expect("journal checked above")
                .append_op_part(&records)
                .expect("WAL append failed — cannot keep durability promise");
        }
        self.online.events.push(OnlineEvent {
            id,
            event: ev,
            admitted,
            prior,
            remaining: plan.moves,
            done: Vec::new(),
            attempts: 0,
            next_retry_at: self.clock,
            parked: None,
            t_admit: self.clock,
            repaired_moves: 0,
            cross_bytes: 0,
        });
        self.online.stats.submitted += 1;
        Ok(id)
    }

    /// Validate + apply the admission mutation and plan one event.
    /// Mirrors [`Dss::apply_topology_event_inner`]'s admission order:
    /// scale-outs mutate the topology first (the planner needs the new
    /// node) and roll back on conflict; drains plan first, so a rejected
    /// drain leaves the system untouched.
    fn admit_event(
        &mut self,
        ev: TopologyEvent,
    ) -> Result<(MigrationPlan, Vec<usize>, Vec<(usize, NodeState)>), MigrationError> {
        let unplannable = |reason: String| MigrationError::Unplannable { reason };
        match ev {
            TopologyEvent::AddNode { cluster } => {
                if cluster >= self.topo.clusters() {
                    return Err(unplannable(format!("no such cluster {cluster}")));
                }
                if self.topo.is_retired(cluster) {
                    return Err(unplannable(format!("cluster {cluster} is retired")));
                }
                let node = self.topo.add_node(cluster);
                self.net.sync(&self.topo);
                let plan = migrate::plan_add_node(
                    &self.topo,
                    self.meta.block_map(),
                    &self.failed,
                    cluster,
                    node,
                );
                if let Err(e) = self.check_conflicts(&plan) {
                    // node ids are never reused: the rejected scale-out
                    // leaves a dead id behind, the map untouched
                    self.topo.set_state(node, NodeState::Dead);
                    return Err(e);
                }
                Ok((plan, vec![node], Vec::new()))
            }
            TopologyEvent::AddCluster { nodes } => {
                if nodes == 0 {
                    return Err(unplannable("a cluster needs at least one node".into()));
                }
                let cluster = self.topo.add_cluster(nodes);
                self.net.sync(&self.topo);
                let plan = migrate::plan_add_cluster(
                    &self.topo,
                    self.meta.block_map(),
                    &self.failed,
                    cluster,
                );
                let members = self.topo.nodes_of(cluster).to_vec();
                if let Err(e) = self.check_conflicts(&plan) {
                    self.topo.retire_cluster(cluster);
                    for &n in &members {
                        self.topo.set_state(n, NodeState::Dead);
                    }
                    return Err(e);
                }
                Ok((plan, members, Vec::new()))
            }
            TopologyEvent::DrainNode { node } => {
                if node >= self.topo.total_nodes() {
                    return Err(unplannable(format!("no such node {node}")));
                }
                if !self.topo.is_live(node) {
                    return Err(unplannable(format!("node {node} is already dead")));
                }
                if let Some((stripe, block)) = self.inflight_target_conflict(&[node]) {
                    return Err(MigrationError::Conflicting { stripe, block });
                }
                let policy = MigrationPolicy::for_strategy(self.meta.strategy_name());
                let plan = migrate::plan_drain(
                    &self.code,
                    policy,
                    &self.topo,
                    self.meta.block_map(),
                    &self.failed,
                    node,
                )?;
                self.check_conflicts(&plan)?;
                let prior = vec![(node, self.topo.state(node))];
                self.topo.set_state(node, NodeState::Draining);
                Ok((plan, Vec::new(), prior))
            }
            TopologyEvent::DecommissionCluster { cluster } => {
                if cluster >= self.topo.clusters() {
                    return Err(unplannable(format!("no such cluster {cluster}")));
                }
                if self.topo.is_retired(cluster) {
                    return Err(unplannable(format!("cluster {cluster} is retired")));
                }
                let members = self.topo.nodes_of(cluster).to_vec();
                if let Some((stripe, block)) = self.inflight_target_conflict(&members) {
                    return Err(MigrationError::Conflicting { stripe, block });
                }
                let plan = migrate::plan_decommission(
                    &self.topo,
                    self.meta.block_map(),
                    &self.failed,
                    cluster,
                )?;
                self.check_conflicts(&plan)?;
                let prior: Vec<(usize, NodeState)> =
                    members.iter().map(|&n| (n, self.topo.state(n))).collect();
                for &n in &members {
                    if self.topo.is_live(n) {
                        self.topo.set_state(n, NodeState::Draining);
                    }
                }
                Ok((plan, Vec::new(), prior))
            }
        }
    }

    /// Reject a plan that crosses any in-flight claim. Two grains:
    ///
    /// * **block** — the plan moves a block another event already claims;
    /// * **(stripe, target cluster)** — an in-flight move is landing a
    ///   block of the same stripe in the same cluster the plan targets.
    ///   The planner's cluster-level safety checks (unit-permutation,
    ///   policy caps, can-decode) read committed residency only, so an
    ///   incoming uncommitted block would silently invalidate them; moves
    ///   *out* of a cluster only make those checks conservative and need
    ///   no serialization.
    fn check_conflicts(&self, plan: &MigrationPlan) -> Result<(), MigrationError> {
        let mut incoming: HashSet<(StripeId, usize)> = HashSet::new();
        for ev in &self.online.events {
            for m in &ev.remaining {
                incoming.insert((m.stripe, m.to_cluster));
            }
        }
        for mv in &plan.moves {
            if self.meta.block_state(mv.stripe, mv.block) != BlockState::Stable
                || incoming.contains(&(mv.stripe, mv.to_cluster))
            {
                return Err(MigrationError::Conflicting { stripe: mv.stripe, block: mv.block });
            }
        }
        Ok(())
    }

    /// First in-flight move landing on any of `nodes` (draining a node an
    /// open event is migrating *onto* must serialize behind that event).
    fn inflight_target_conflict(&self, nodes: &[usize]) -> Option<(StripeId, usize)> {
        for ev in &self.online.events {
            for m in &ev.remaining {
                if nodes.contains(&m.to_node) {
                    return Some((m.stripe, m.block));
                }
            }
        }
        None
    }

    /// Run up to `max_moves` background block moves, oldest-deadline event
    /// first, and complete events whose plans drain. Only events whose
    /// retry deadline is `<= until` are touched, so a caller interleaving
    /// foreground work can hold back throttled or backed-off events.
    ///
    /// Per move, at pump time (not admission time):
    /// * a dead **destination** re-plans onto a fresh invariant-satisfying
    ///   target in the same cluster (`dest_replans`);
    /// * a dead **source** flips the event's dead-source moves onto one
    ///   batched [`ProxyCtx::repair_node`] rebuild (`source_flips`), each
    ///   rebuilt block byte-verified before it ships;
    /// * a move that cannot run now (unrecoverable stripe, no replacement
    ///   target) re-schedules the event with capped exponential backoff
    ///   (`retries`) until [`BackoffPolicy::max_attempts`], then parks it
    ///   as retryable (`parked`; see [`Dss::retry_parked`]) with its
    ///   claims held.
    ///
    /// Move commit discipline mirrors the stop-the-world path: bytes move
    /// and verify on the virtual clock, the `OnlineMove{done}` record is
    /// journaled, and only then does the claim commit to the map. Crash
    /// anywhere → recovery replays exactly the committed moves and
    /// resumes the rest ([`Dss::resume_online`]).
    pub fn pump_migrations(
        &mut self,
        until: f64,
        max_moves: usize,
    ) -> anyhow::Result<Vec<MigrationReport>> {
        let mut reports = Vec::new();
        let mut budget = max_moves;
        while budget > 0 {
            let Some(idx) = self
                .online
                .events
                .iter()
                .enumerate()
                .filter(|(_, e)| e.parked.is_none() && e.next_retry_at <= until)
                .min_by(|(_, a), (_, b)| {
                    a.next_retry_at
                        .partial_cmp(&b.next_retry_at)
                        .expect("retry deadlines are finite")
                        .then(a.id.cmp(&b.id))
                })
                .map(|(i, _)| i)
            else {
                break;
            };
            let t0 = self.clock.max(self.online.events[idx].next_retry_at);
            if let Some(report) = self.pump_one(idx, t0, &mut budget)? {
                reports.push(report);
            }
        }
        Ok(reports)
    }

    /// One scheduling round for event `idx`: retarget dead destinations,
    /// then run either the head move (live source, one throttled direct
    /// copy) or the batched rebuild of every dead-source move.
    fn pump_one(
        &mut self,
        idx: usize,
        t0: f64,
        budget: &mut usize,
    ) -> anyhow::Result<Option<MigrationReport>> {
        if self.online.events[idx].remaining.is_empty() {
            // resume path: the crash fell between the last move's commit
            // and the event's commit marker
            return Ok(Some(self.complete_online(idx)));
        }
        if let Err(e) = self.retarget_dead_destinations(idx) {
            self.reschedule(idx, t0, e);
            return Ok(None);
        }
        let bs = self.cfg.block_size;
        let head = self.online.events[idx].remaining[0];
        let dead =
            |dss: &Dss, n: usize| dss.failed.contains(&n) || !dss.topo.is_live(n);
        if dead(self, head.from_node) {
            let batch: Vec<BlockMove> = self.online.events[idx]
                .remaining
                .iter()
                .filter(|m| dead(self, m.from_node))
                .take(*budget)
                .copied()
                .collect();
            for mv in &batch {
                if !self.stripe_recoverable(mv.stripe) {
                    self.reschedule(idx, t0, MigrationError::SourceDown { node: mv.from_node });
                    return Ok(None);
                }
            }
            let cross0 = self.net.cross_bytes;
            let reqs: Vec<RepairRequest> = batch
                .iter()
                .map(|mv| RepairRequest {
                    stripe: mv.stripe,
                    block: mv.block,
                    erased: self.failed_blocks(mv.stripe),
                })
                .collect();
            let outcomes = {
                let mut ctx = self.proxy_ctx();
                ctx.repair_node(t0, &reqs)?
            };
            for (mv, oc) in batch.iter().zip(outcomes) {
                let OpOutcome { ready_at, rebuilt, home } = oc;
                anyhow::ensure!(
                    rebuilt.as_slice() == self.meta.block_data(mv.stripe, mv.block).as_slice(),
                    "online migration rebuild produced corrupt bytes"
                );
                crate::gf::pool::recycle(rebuilt);
                let t = self.net.transfer_class(
                    ready_at,
                    Endpoint::Proxy(home),
                    Endpoint::Node(mv.to_node),
                    bs,
                    TrafficClass::Migration,
                );
                self.commit_online_move(idx, mv, t, true);
            }
            self.online.events[idx].cross_bytes += self.net.cross_bytes - cross0;
            *budget = budget.saturating_sub(batch.len().max(1));
        } else {
            let cross0 = self.net.cross_bytes;
            let t = self.net.transfer_class(
                t0,
                Endpoint::Node(head.from_node),
                Endpoint::Node(head.to_node),
                bs,
                TrafficClass::Migration,
            );
            self.commit_online_move(idx, &head, t, false);
            self.online.events[idx].cross_bytes += self.net.cross_bytes - cross0;
            *budget -= 1;
        }
        if self.online.events[idx].remaining.is_empty() {
            return Ok(Some(self.complete_online(idx)));
        }
        Ok(None)
    }

    /// Re-point every pending move of event `idx` whose destination died
    /// onto a fresh target in the same cluster (same-cluster keeps every
    /// cluster-level invariant the planner proved).
    fn retarget_dead_destinations(&mut self, idx: usize) -> Result<(), MigrationError> {
        let stale: Vec<(usize, BlockMove)> = self.online.events[idx]
            .remaining
            .iter()
            .enumerate()
            .filter(|(_, m)| self.failed.contains(&m.to_node) || !self.topo.is_live(m.to_node))
            .map(|(i, m)| (i, *m))
            .collect();
        for (i, mv) in stale {
            let Some(t) = self.replan_target(mv.stripe, mv.to_cluster) else {
                return Err(MigrationError::Unplannable {
                    reason: format!(
                        "no replacement target in cluster {} for stripe {} block {} after \
                         destination {} died",
                        mv.to_cluster, mv.stripe, mv.block, mv.to_node
                    ),
                });
            };
            self.meta.retarget_move(mv.stripe, mv.block, mv.to_cluster, t);
            self.online.reserved.remove(&(mv.stripe, mv.to_node));
            self.online.reserved.insert((mv.stripe, t));
            self.online.events[idx].remaining[i].to_node = t;
            self.online.stats.dest_replans += 1;
        }
        Ok(())
    }

    /// Least-loaded live target in `cluster` that hosts no block of
    /// `stripe` and no in-flight reservation for it.
    fn replan_target(&self, stripe: StripeId, cluster: usize) -> Option<usize> {
        let map = self.meta.block_map();
        let occupied: HashSet<usize> = map.placement(stripe).node_of.iter().copied().collect();
        self.topo
            .migratable_nodes_of(cluster)
            .into_iter()
            .filter(|n| {
                !self.failed.contains(n)
                    && !occupied.contains(n)
                    && !self.online.reserved.contains(&(stripe, *n))
            })
            .min_by_key(|&n| (map.node_load(n), n))
    }

    /// Commit one executed move: journal the `done` record, re-point the
    /// claim in the map, release the reservation, advance the clock.
    fn commit_online_move(&mut self, idx: usize, mv: &BlockMove, done_at: f64, rebuilt: bool) {
        let id = self.online.events[idx].id;
        let epoch = self.bump_epoch();
        if let Some(j) = self.journal.as_mut() {
            j.append_op_part(&[
                WalRecord::OnlineMove {
                    event_id: id,
                    done: true,
                    stripe: mv.stripe as u32,
                    block: mv.block as u32,
                    from_node: mv.from_node as u32,
                    to_cluster: mv.to_cluster as u32,
                    to_node: mv.to_node as u32,
                },
                epoch,
            ])
            .expect("WAL append failed — cannot keep durability promise");
        }
        self.meta.commit_move(mv.stripe, mv.block);
        self.online.reserved.remove(&(mv.stripe, mv.to_node));
        let ev = &mut self.online.events[idx];
        let pos = ev
            .remaining
            .iter()
            .position(|m| m.stripe == mv.stripe && m.block == mv.block)
            .expect("committed move was pending");
        ev.remaining.remove(pos);
        ev.done.push(*mv);
        ev.attempts = 0;
        if rebuilt {
            ev.repaired_moves += 1;
            self.online.stats.source_flips += 1;
        }
        self.online.stats.moves_committed += 1;
        self.clock = self.clock.max(done_at);
    }

    /// Finish a drained event: journal `CommitOnline` (one committed op),
    /// apply the completion topology mutation, report.
    fn complete_online(&mut self, idx: usize) -> MigrationReport {
        let ev = self.online.events.remove(idx);
        let epoch = self.bump_epoch();
        if let Some(j) = self.journal.as_mut() {
            j.commit_op(&[WalRecord::CommitOnline { event_id: ev.id }, epoch])
                .expect("WAL append failed — cannot keep durability promise");
        }
        match ev.event {
            TopologyEvent::AddNode { .. } | TopologyEvent::AddCluster { .. } => {
                for &n in &ev.admitted {
                    self.topo.set_state(n, NodeState::Active);
                }
            }
            TopologyEvent::DrainNode { node } => {
                self.topo.set_state(node, NodeState::Dead);
                self.failed.remove(&node); // dead ≠ failed: nothing left to repair
            }
            TopologyEvent::DecommissionCluster { cluster } => {
                self.topo.retire_cluster(cluster);
                for n in self.topo.nodes_of(cluster).to_vec() {
                    self.topo.set_state(n, NodeState::Dead);
                    self.failed.remove(&n);
                }
            }
        }
        self.online.stats.completed += 1;
        let report = MigrationReport {
            event: ev.event,
            moves: ev.done.len(),
            repaired_moves: ev.repaired_moves,
            bytes_moved: ev.done.len() * self.cfg.block_size,
            cross_bytes: ev.cross_bytes,
            seconds: self.clock - ev.t_admit,
            wall_ms: 0.0,
        };
        self.maybe_snapshot();
        report
    }

    /// Record a failed scheduling round: capped exponential backoff, then
    /// park the event as retryable with its claims held.
    fn reschedule(&mut self, idx: usize, t0: f64, err: MigrationError) {
        let o = &mut self.online;
        let ev = &mut o.events[idx];
        ev.attempts += 1;
        o.stats.retries += 1;
        if ev.attempts >= o.backoff.max_attempts {
            ev.parked = Some(err);
            o.stats.parked += 1;
        } else {
            ev.next_retry_at = t0 + o.backoff.delay_ms(ev.attempts - 1) / 1e3;
        }
    }

    /// Re-install crash-interrupted online events from recovery
    /// ([`Recovered::pending_online`]): re-claim each remaining move and
    /// queue the event for [`Dss::pump_migrations`]. The admission
    /// mutation and all committed moves are already in the restored state.
    pub fn resume_online(&mut self, pending: &[PendingOnline]) {
        for p in pending {
            for mv in &p.remaining {
                let claimed = self.meta.begin_move(mv.stripe, mv.block, mv.to_cluster, mv.to_node);
                assert!(claimed, "recovered claim must be re-installable");
                self.online.reserved.insert((mv.stripe, mv.to_node));
            }
            self.online.events.push(OnlineEvent {
                id: p.event_id,
                event: p.event,
                admitted: p.admitted.clone(),
                prior: p.prior.clone(),
                remaining: p.remaining.clone(),
                done: Vec::new(),
                attempts: 0,
                next_retry_at: self.clock,
                parked: None,
                t_admit: self.clock,
                repaired_moves: 0,
                cross_bytes: 0,
            });
            self.online.next_id = self.online.next_id.max(p.event_id + 1);
            self.online.stats.resumed += 1;
        }
    }

    /// Un-park every parked event (operator retry after fixing capacity);
    /// returns how many re-entered the queue.
    pub fn retry_parked(&mut self) -> usize {
        let clock = self.clock;
        let mut n = 0;
        for ev in &mut self.online.events {
            if ev.parked.take().is_some() {
                ev.attempts = 0;
                ev.next_retry_at = clock;
                n += 1;
            }
        }
        n
    }

    /// Cancel an in-flight online event: release its claims, roll back
    /// its admission mutation, journal `AbortOnline` (one committed op).
    /// Moves already committed stay — each was individually
    /// invariant-checked — so a scale-out that has landed blocks on its
    /// new node(s) refuses to cancel (the blocks would strand on a node
    /// about to die).
    pub fn cancel_online(&mut self, event_id: u32) -> Result<(), MigrationError> {
        let Some(idx) = self.online.events.iter().position(|e| e.id == event_id) else {
            return Err(MigrationError::Unplannable {
                reason: format!("no in-flight online event {event_id}"),
            });
        };
        let scale_out = matches!(
            self.online.events[idx].event,
            TopologyEvent::AddNode { .. } | TopologyEvent::AddCluster { .. }
        );
        if scale_out && !self.online.events[idx].done.is_empty() {
            return Err(MigrationError::Unplannable {
                reason: format!(
                    "cannot cancel event {event_id}: blocks already landed on its new node(s)"
                ),
            });
        }
        let epoch = self.bump_epoch();
        if let Some(j) = self.journal.as_mut() {
            j.commit_op(&[WalRecord::AbortOnline { event_id }, epoch])
                .expect("WAL append failed — cannot keep durability promise");
        }
        let ev = self.online.events.remove(idx);
        for mv in &ev.remaining {
            self.meta.abort_move(mv.stripe, mv.block);
            self.online.reserved.remove(&(mv.stripe, mv.to_node));
        }
        match ev.event {
            TopologyEvent::AddNode { .. } => {
                for &n in &ev.admitted {
                    self.topo.set_state(n, NodeState::Dead);
                }
            }
            TopologyEvent::AddCluster { .. } => {
                if let Some(&n0) = ev.admitted.first() {
                    let c = self.topo.cluster_of_node(n0);
                    self.topo.retire_cluster(c);
                }
                for &n in &ev.admitted {
                    self.topo.set_state(n, NodeState::Dead);
                }
            }
            TopologyEvent::DrainNode { .. } | TopologyEvent::DecommissionCluster { .. } => {
                for &(n, s) in &ev.prior {
                    self.topo.set_state(n, s);
                }
            }
        }
        self.maybe_snapshot();
        Ok(())
    }

    /// Background-migration counters (the `PlanCache::stats()` idiom —
    /// print with [`MigrationStats::render`]).
    pub fn migration_stats(&self) -> MigrationStats {
        self.online.stats
    }

    /// Open (admitted, uncommitted) online events.
    pub fn online_in_flight(&self) -> usize {
        self.online.events.len()
    }

    /// `(event id, error)` for every parked event.
    pub fn parked_events(&self) -> Vec<(u32, MigrationError)> {
        self.online
            .events
            .iter()
            .filter_map(|e| e.parked.clone().map(|err| (e.id, err)))
            .collect()
    }

    /// Retry discipline for failed background moves
    /// (`--backoff-base-ms` / `--backoff-cap-ms` / `--max-attempts`).
    pub fn set_migration_backoff(&mut self, policy: BackoffPolicy) {
        self.online.backoff = policy;
    }

    /// Cap background-move bandwidth with a token bucket shared across
    /// all in-flight events (`--migrate-rate-mbps` / `--migrate-burst`).
    pub fn set_migration_throttle(&mut self, rate_bps: f64, burst: f64) {
        self.net.set_migration_throttle(rate_bps, burst);
    }
}

/// Virtual-clock outcome of a migration's transfer/verify phase, held
/// until the WAL group commits.
struct MigrationExec {
    t0: f64,
    done: f64,
    cross0: u64,
    repaired_moves: usize,
}

/// The background-migration queue: every in-flight online event plus the
/// cross-event conflict state and counters.
#[derive(Default)]
struct OnlineMigrations {
    events: Vec<OnlineEvent>,
    next_id: u32,
    /// `(stripe, target node)` slots claimed by in-flight moves — the
    /// conflict grain (alongside per-block claims) that keeps two plans
    /// from landing two blocks of one stripe on one node.
    reserved: HashSet<(StripeId, usize)>,
    stats: MigrationStats,
    backoff: BackoffPolicy,
}

/// One admitted, uncommitted online topology event.
struct OnlineEvent {
    id: u32,
    event: TopologyEvent,
    /// Node ids the admission mutation allocated (AddNode/AddCluster).
    admitted: Vec<usize>,
    /// Pre-admission node states (drain/decommission cancel rollback).
    prior: Vec<(usize, NodeState)>,
    /// Planned moves not yet committed, in plan order.
    remaining: Vec<BlockMove>,
    /// Committed moves (targets reflect any dest-death re-plan).
    done: Vec<BlockMove>,
    /// Consecutive failed scheduling rounds (reset on any progress).
    attempts: usize,
    /// Virtual instant before which the scheduler will not retry.
    next_retry_at: f64,
    /// Set when attempts exhausted; cleared by [`Dss::retry_parked`].
    parked: Option<MigrationError>,
    t_admit: f64,
    repaired_moves: usize,
    cross_bytes: u64,
}

/// Metrics of one executed topology event.
#[derive(Debug, Clone, Copy)]
pub struct MigrationReport {
    pub event: TopologyEvent,
    /// Blocks moved (direct + rebuilt).
    pub moves: usize,
    /// Moves whose source was failed/dead and went through the batched
    /// repair pipeline instead of a direct copy.
    pub repaired_moves: usize,
    pub bytes_moved: usize,
    /// Cross-cluster bytes this event pushed through gateways.
    pub cross_bytes: u64,
    /// Virtual seconds from event start to the last block landing.
    pub seconds: f64,
    /// Real (wall-clock) milliseconds spent planning + executing +
    /// logging the event — the exp8 baseline row exp9 compares its
    /// recovery-replay timing against. Not part of any digest.
    pub wall_ms: f64,
}
