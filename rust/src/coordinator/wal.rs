//! Write-ahead log + journal orchestration for the durable coordinator.
//!
//! Every mutation of the coordinator's durable state ([`BlockMap`]
//! moves, stripe registrations, failure-set changes, topology
//! lifecycle transitions) is encoded as a length-prefixed, CRC32'd,
//! sequence-stamped [`WalRecord`] and appended to a segment file
//! **before** the in-memory state commits. Records belonging to one
//! topology event form a *group* (`BeginEvent … CommitEvent`) written
//! with a single buffered append — replay applies a group atomically at
//! its commit record, so a crash anywhere inside the group recovers to
//! the consistent pre-event state (and reports the interrupted event for
//! re-planning).
//!
//! Record framing: `[len: u32 LE][crc32(payload): u32 LE][payload]`,
//! payload = `[seq: u64 LE][kind: u8][body]`. Sequence numbers are
//! global and contiguous across segment files; segments are named
//! `wal-<first_seq>.log` and rotate at each snapshot, which lets
//! truncation ([`Journal::snapshot`]) delete every segment already
//! covered by the *previous* manifest generation while keeping enough
//! log to replay on top of either surviving snapshot.
//!
//! Durability knobs: `sync_every` batches fsyncs across committed
//! groups (group commit); `snapshot_every` bounds replay length by
//! snapshotting the manifest every N committed operations.

use crate::coordinator::manifest::{
    crc32, put_u32, put_u64, CoordinatorState, Cursor, Manifest, ManifestStore,
};
use crate::placement::TopologyEvent;
use std::fs::{self, File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};

/// Maximum record payload accepted by the reader. A bit-flipped length
/// field beyond this is rejected immediately instead of being chased as
/// a torn tail across megabytes.
pub const MAX_RECORD_LEN: usize = 1 << 22;

/// Prefix of WAL segment file names: `wal-<first_seq>.log`.
pub const SEGMENT_PREFIX: &str = "wal-";
pub const SEGMENT_SUFFIX: &str = ".log";

// ---------------------------------------------------------------- records

/// One durable mutation. `Topo*` and `MoveBlock` records are only valid
/// inside a `BeginEvent … CommitEvent` group; `AddStripe` and
/// `SetFailed` are standalone committed operations.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WalRecord {
    /// A new stripe was placed: per-block cluster and node rows.
    AddStripe { cluster_of: Vec<u32>, node_of: Vec<u32> },
    /// Failure-set change: `down = true` marks failed, `false` heals.
    SetFailed { node: u32, down: bool },
    /// A topology event starts; everything up to `CommitEvent` commits
    /// atomically.
    BeginEvent { event: WalEvent },
    /// `Topology::add_node(cluster)` — allocates the next node id.
    TopoAddNode { cluster: u32 },
    /// `Topology::add_cluster(nodes)` — allocates the next cluster id.
    TopoAddCluster { nodes: u32 },
    /// Node lifecycle transition ([`crate::placement::NodeState::tag`]).
    TopoSetState { node: u32, state: u8 },
    /// Cluster closed to placement.
    TopoRetire { cluster: u32 },
    /// One committed block move (post byte-verification).
    MoveBlock { stripe: u32, block: u32, to_cluster: u32, to_node: u32 },
    /// Group commit marker.
    CommitEvent,
    /// An *online* (background) topology event was admitted: the event
    /// itself rides in the record so recovery can re-apply the admission
    /// topology mutation deterministically. Unlike `BeginEvent` groups,
    /// online records are spread across many appends — planned moves land
    /// at admission, each completed move as it commits, and
    /// `CommitOnline` when the event drains. `event_id` correlates them.
    /// `moves` declares the plan length: replay applies the admission
    /// mutation only after seeing that many planned-move records, so a
    /// crash that tears the admission append recovers as if the event
    /// was never submitted (no half-planned claims, no orphan node ids).
    BeginOnline { event_id: u32, event: WalEvent, moves: u32 },
    /// One move of an online event. `done = false` records the *plan* at
    /// admission (replayed only as a pending claim); `done = true` is a
    /// committed, byte-verified move applied on replay. The `(to_cluster,
    /// to_node)` of a done record may differ from its planned twin — that
    /// is the durable trace of a destination-death re-plan.
    OnlineMove {
        event_id: u32,
        done: bool,
        stripe: u32,
        block: u32,
        from_node: u32,
        to_cluster: u32,
        to_node: u32,
    },
    /// Online event fully drained: replay applies its completion topology
    /// mutation (drain → Dead, decommission → retire) and counts one
    /// committed operation.
    CommitOnline { event_id: u32 },
    /// Online event unwound before completion: replay rolls back its
    /// admission mutation and forgets its claims.
    AbortOnline { event_id: u32 },
    /// Metadata-epoch advance. Appended alongside every committed
    /// routing mutation (stripe ingest, failure-set change, migration
    /// commit) so the serving plane's `StaleEpoch` protocol survives a
    /// crash: recovery takes the max over the manifest epoch and every
    /// replayed `Epoch` record. Never a committed operation by itself
    /// and valid both standalone and inside a group.
    Epoch { epoch: u64 },
}

/// Encodable mirror of [`TopologyEvent`] for `BeginEvent` records.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WalEvent {
    pub tag: u8,
    pub arg: u32,
}

impl WalEvent {
    pub fn from_event(ev: TopologyEvent) -> WalEvent {
        match ev {
            TopologyEvent::AddNode { cluster } => WalEvent { tag: 0, arg: cluster as u32 },
            TopologyEvent::DrainNode { node } => WalEvent { tag: 1, arg: node as u32 },
            TopologyEvent::AddCluster { nodes } => WalEvent { tag: 2, arg: nodes as u32 },
            TopologyEvent::DecommissionCluster { cluster } => {
                WalEvent { tag: 3, arg: cluster as u32 }
            }
        }
    }

    pub fn to_event(self) -> Option<TopologyEvent> {
        let arg = self.arg as usize;
        match self.tag {
            0 => Some(TopologyEvent::AddNode { cluster: arg }),
            1 => Some(TopologyEvent::DrainNode { node: arg }),
            2 => Some(TopologyEvent::AddCluster { nodes: arg }),
            3 => Some(TopologyEvent::DecommissionCluster { cluster: arg }),
            _ => None,
        }
    }
}

impl WalRecord {
    fn kind(&self) -> u8 {
        match self {
            WalRecord::AddStripe { .. } => 1,
            WalRecord::SetFailed { .. } => 2,
            WalRecord::BeginEvent { .. } => 3,
            WalRecord::TopoAddNode { .. } => 4,
            WalRecord::TopoAddCluster { .. } => 5,
            WalRecord::TopoSetState { .. } => 6,
            WalRecord::TopoRetire { .. } => 7,
            WalRecord::MoveBlock { .. } => 8,
            WalRecord::CommitEvent => 9,
            WalRecord::BeginOnline { .. } => 10,
            WalRecord::OnlineMove { .. } => 11,
            WalRecord::CommitOnline { .. } => 12,
            WalRecord::AbortOnline { .. } => 13,
            WalRecord::Epoch { .. } => 14,
        }
    }

    fn encode_body(&self, buf: &mut Vec<u8>) {
        match self {
            WalRecord::AddStripe { cluster_of, node_of } => {
                put_u32(buf, cluster_of.len() as u32);
                for &c in cluster_of {
                    put_u32(buf, c);
                }
                for &n in node_of {
                    put_u32(buf, n);
                }
            }
            WalRecord::SetFailed { node, down } => {
                put_u32(buf, *node);
                buf.push(*down as u8);
            }
            WalRecord::BeginEvent { event } => {
                buf.push(event.tag);
                put_u32(buf, event.arg);
            }
            WalRecord::TopoAddNode { cluster } => put_u32(buf, *cluster),
            WalRecord::TopoAddCluster { nodes } => put_u32(buf, *nodes),
            WalRecord::TopoSetState { node, state } => {
                put_u32(buf, *node);
                buf.push(*state);
            }
            WalRecord::TopoRetire { cluster } => put_u32(buf, *cluster),
            WalRecord::MoveBlock { stripe, block, to_cluster, to_node } => {
                put_u32(buf, *stripe);
                put_u32(buf, *block);
                put_u32(buf, *to_cluster);
                put_u32(buf, *to_node);
            }
            WalRecord::CommitEvent => {}
            WalRecord::BeginOnline { event_id, event, moves } => {
                put_u32(buf, *event_id);
                buf.push(event.tag);
                put_u32(buf, event.arg);
                put_u32(buf, *moves);
            }
            WalRecord::OnlineMove { event_id, done, stripe, block, from_node, to_cluster, to_node } => {
                put_u32(buf, *event_id);
                buf.push(*done as u8);
                put_u32(buf, *stripe);
                put_u32(buf, *block);
                put_u32(buf, *from_node);
                put_u32(buf, *to_cluster);
                put_u32(buf, *to_node);
            }
            WalRecord::CommitOnline { event_id } => put_u32(buf, *event_id),
            WalRecord::AbortOnline { event_id } => put_u32(buf, *event_id),
            WalRecord::Epoch { epoch } => put_u64(buf, *epoch),
        }
    }

    fn decode_body(kind: u8, cur: &mut Cursor<'_>) -> Result<WalRecord, String> {
        let rec = match kind {
            1 => {
                let width = cur.u32()? as usize;
                if width == 0 || width > 1 << 12 {
                    return Err(format!("AddStripe width {width} out of range"));
                }
                let mut cluster_of = Vec::with_capacity(width);
                for _ in 0..width {
                    cluster_of.push(cur.u32()?);
                }
                let mut node_of = Vec::with_capacity(width);
                for _ in 0..width {
                    node_of.push(cur.u32()?);
                }
                WalRecord::AddStripe { cluster_of, node_of }
            }
            2 => WalRecord::SetFailed { node: cur.u32()?, down: cur.u8()? != 0 },
            3 => WalRecord::BeginEvent { event: WalEvent { tag: cur.u8()?, arg: cur.u32()? } },
            4 => WalRecord::TopoAddNode { cluster: cur.u32()? },
            5 => WalRecord::TopoAddCluster { nodes: cur.u32()? },
            6 => WalRecord::TopoSetState { node: cur.u32()?, state: cur.u8()? },
            7 => WalRecord::TopoRetire { cluster: cur.u32()? },
            8 => WalRecord::MoveBlock {
                stripe: cur.u32()?,
                block: cur.u32()?,
                to_cluster: cur.u32()?,
                to_node: cur.u32()?,
            },
            9 => WalRecord::CommitEvent,
            10 => WalRecord::BeginOnline {
                event_id: cur.u32()?,
                event: WalEvent { tag: cur.u8()?, arg: cur.u32()? },
                moves: cur.u32()?,
            },
            11 => WalRecord::OnlineMove {
                event_id: cur.u32()?,
                done: cur.u8()? != 0,
                stripe: cur.u32()?,
                block: cur.u32()?,
                from_node: cur.u32()?,
                to_cluster: cur.u32()?,
                to_node: cur.u32()?,
            },
            12 => WalRecord::CommitOnline { event_id: cur.u32()? },
            13 => WalRecord::AbortOnline { event_id: cur.u32()? },
            14 => WalRecord::Epoch { epoch: cur.u64()? },
            k => return Err(format!("unknown record kind {k}")),
        };
        cur.done()?;
        Ok(rec)
    }

    /// Frame one record: `[len][crc][seq · kind · body]`.
    pub fn encode(&self, seq: u64) -> Vec<u8> {
        let mut payload = Vec::with_capacity(32);
        put_u64(&mut payload, seq);
        payload.push(self.kind());
        self.encode_body(&mut payload);
        let mut out = Vec::with_capacity(payload.len() + 8);
        put_u32(&mut out, payload.len() as u32);
        put_u32(&mut out, crc32(&payload));
        out.extend_from_slice(&payload);
        out
    }
}

// ---------------------------------------------------------------- reader

/// A decoded record with its sequence number and byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SequencedRecord {
    pub seq: u64,
    /// Byte offset of the record's frame within its segment.
    pub offset: usize,
    pub record: WalRecord,
}

/// Why a segment scan stopped.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ScanEnd {
    /// Clean end: the file ends exactly on a record boundary.
    Clean,
    /// The file ends inside a record (crash mid-append). The incomplete
    /// tail is discarded; everything before it is intact.
    TornTail { offset: usize },
    /// A *complete* record failed its checksum or decoded inconsistently
    /// — corruption, not a crash artifact.
    Corrupt { offset: usize, detail: String },
}

/// Scan one segment file: returns every intact record in order plus how
/// the scan ended. Never panics on arbitrary bytes.
pub fn scan_segment(bytes: &[u8]) -> (Vec<SequencedRecord>, ScanEnd) {
    let mut records = Vec::new();
    let mut pos = 0usize;
    while pos < bytes.len() {
        if pos + 8 > bytes.len() {
            return (records, ScanEnd::TornTail { offset: pos });
        }
        let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()) as usize;
        if len < 9 || len > MAX_RECORD_LEN {
            return (
                records,
                ScanEnd::Corrupt { offset: pos, detail: format!("record length {len} invalid") },
            );
        }
        let crc = u32::from_le_bytes(bytes[pos + 4..pos + 8].try_into().unwrap());
        if pos + 8 + len > bytes.len() {
            return (records, ScanEnd::TornTail { offset: pos });
        }
        let payload = &bytes[pos + 8..pos + 8 + len];
        if crc32(payload) != crc {
            return (
                records,
                ScanEnd::Corrupt { offset: pos, detail: "record CRC mismatch".into() },
            );
        }
        let mut cur = Cursor::new(payload);
        let seq = cur.u64().expect("length checked above");
        let kind = cur.u8().expect("length checked above");
        match WalRecord::decode_body(kind, &mut cur) {
            Ok(record) => records.push(SequencedRecord { seq, offset: pos, record }),
            Err(detail) => return (records, ScanEnd::Corrupt { offset: pos, detail }),
        }
        pos += 8 + len;
    }
    (records, ScanEnd::Clean)
}

/// List segment files in a journal directory, sorted by first sequence
/// number: `(first_seq, path)`.
pub fn list_segments(dir: &Path) -> std::io::Result<Vec<(u64, PathBuf)>> {
    let mut segs = Vec::new();
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if let Some(mid) =
            name.strip_prefix(SEGMENT_PREFIX).and_then(|s| s.strip_suffix(SEGMENT_SUFFIX))
        {
            if let Ok(first_seq) = mid.parse::<u64>() {
                segs.push((first_seq, entry.path()));
            }
        }
    }
    segs.sort_unstable_by_key(|&(s, _)| s);
    Ok(segs)
}

fn segment_path(dir: &Path, first_seq: u64) -> PathBuf {
    dir.join(format!("{SEGMENT_PREFIX}{first_seq:012}{SEGMENT_SUFFIX}"))
}

// ---------------------------------------------------------------- writer

/// Append side of one segment with group commit: each
/// [`WalWriter::append_group`] is a single buffered `write`, fsynced
/// once every `sync_every` groups (and always on rotation/snapshot).
#[derive(Debug)]
pub struct WalWriter {
    file: File,
    next_seq: u64,
    sync_every: usize,
    unsynced_groups: usize,
    bytes_written: u64,
    records_written: u64,
}

impl WalWriter {
    fn open(dir: &Path, first_seq: u64, sync_every: usize) -> std::io::Result<WalWriter> {
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(segment_path(dir, first_seq))?;
        Ok(WalWriter {
            file,
            next_seq: first_seq,
            sync_every: sync_every.max(1),
            unsynced_groups: 0,
            bytes_written: 0,
            records_written: 0,
        })
    }

    /// Append `records` as one atomic group (single buffered write),
    /// stamping contiguous sequence numbers. Returns the last sequence
    /// number written.
    pub fn append_group(&mut self, records: &[WalRecord]) -> std::io::Result<u64> {
        assert!(!records.is_empty(), "empty WAL group");
        let mut buf = Vec::with_capacity(records.len() * 32);
        for rec in records {
            buf.extend_from_slice(&rec.encode(self.next_seq));
            self.next_seq += 1;
        }
        self.file.write_all(&buf)?;
        self.bytes_written += buf.len() as u64;
        self.records_written += records.len() as u64;
        self.unsynced_groups += 1;
        if self.unsynced_groups >= self.sync_every {
            self.file.sync_data()?;
            self.unsynced_groups = 0;
        }
        Ok(self.next_seq - 1)
    }

    /// Force outstanding appends to disk (pre-snapshot barrier).
    pub fn sync(&mut self) -> std::io::Result<()> {
        if self.unsynced_groups > 0 {
            self.file.sync_data()?;
            self.unsynced_groups = 0;
        }
        Ok(())
    }
}

// --------------------------------------------------------------- journal

/// Durability knobs (`[durability]` config section / `--wal-sync-every`).
#[derive(Debug, Clone, Copy)]
pub struct DurabilityOptions {
    /// fsync once per this many committed groups (1 = every commit).
    pub sync_every: usize,
    /// Snapshot the manifest (and truncate the log) every this many
    /// committed operations. `usize::MAX` disables periodic snapshots.
    pub snapshot_every: usize,
}

impl Default for DurabilityOptions {
    fn default() -> Self {
        DurabilityOptions { sync_every: 8, snapshot_every: 64 }
    }
}

/// The coordinator's journal: manifest store + active WAL segment +
/// group/snapshot bookkeeping. Owned by [`crate::coordinator::Dss`] when
/// durability is enabled; every mutation is logged through here before
/// the in-memory state commits.
#[derive(Debug)]
pub struct Journal {
    dir: PathBuf,
    store: ManifestStore,
    writer: WalWriter,
    opts: DurabilityOptions,
    /// Sequence number of the last record appended (0 = none yet).
    last_seq: u64,
    /// Committed logical operations since journal creation.
    committed_ops: u64,
    /// Operations since the last snapshot.
    ops_since_snapshot: usize,
    /// `last_seq` of the previous manifest generation (truncation bound).
    prev_manifest_seq: u64,
    /// Snapshots written (including the initial one).
    snapshots: usize,
    /// Total WAL bytes/records appended across segments (report metric).
    total_bytes: u64,
    total_records: u64,
}

impl Journal {
    /// Initialize a fresh journal: write the initial manifest for
    /// `state` and open the first segment. The directory is created;
    /// pre-existing journal files in it are an error (refuse to clobber
    /// a previous incarnation's history silently).
    pub fn create(
        dir: &Path,
        state: &CoordinatorState,
        epoch: u64,
        opts: DurabilityOptions,
    ) -> anyhow::Result<Journal> {
        fs::create_dir_all(dir)?;
        let store = ManifestStore::new(dir);
        anyhow::ensure!(
            !store.current_path().exists() && list_segments(dir)?.is_empty(),
            "journal directory {} already holds a journal — recover or clear it first",
            dir.display()
        );
        store.write(&Manifest { state: state.clone(), last_seq: 0, committed_ops: 0, epoch })?;
        let writer = WalWriter::open(dir, 1, opts.sync_every)?;
        Ok(Journal {
            dir: dir.to_path_buf(),
            store,
            writer,
            opts,
            last_seq: 0,
            committed_ops: 0,
            ops_since_snapshot: 0,
            prev_manifest_seq: 0,
            snapshots: 1,
            total_bytes: 0,
            total_records: 0,
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn committed_ops(&self) -> u64 {
        self.committed_ops
    }

    pub fn last_seq(&self) -> u64 {
        self.last_seq
    }

    pub fn snapshots(&self) -> usize {
        self.snapshots
    }

    pub fn wal_bytes(&self) -> u64 {
        self.total_bytes
    }

    pub fn wal_records(&self) -> u64 {
        self.total_records
    }

    /// Commit one logical operation: append its records as one group.
    pub fn commit_op(&mut self, records: &[WalRecord]) -> std::io::Result<()> {
        self.append_op_part(records)?;
        self.committed_ops += 1;
        self.ops_since_snapshot += 1;
        Ok(())
    }

    /// Append records durably **without** counting a committed operation —
    /// the incremental-progress side of an online migration (admission,
    /// per-move completions). The operation only counts when its
    /// `CommitOnline` lands via [`Journal::commit_op`].
    pub fn append_op_part(&mut self, records: &[WalRecord]) -> std::io::Result<()> {
        let b0 = self.writer.bytes_written;
        let r0 = self.writer.records_written;
        self.last_seq = self.writer.append_group(records)?;
        self.total_bytes += self.writer.bytes_written - b0;
        self.total_records += self.writer.records_written - r0;
        Ok(())
    }

    /// True when the snapshot cadence is due.
    pub fn snapshot_due(&self) -> bool {
        self.opts.snapshot_every != usize::MAX
            && self.ops_since_snapshot >= self.opts.snapshot_every
    }

    /// Snapshot `state` as the new current manifest, rotate to a fresh
    /// segment, and truncate: delete every segment fully covered by the
    /// *previous* manifest generation (so either surviving snapshot can
    /// still replay to the tip).
    pub fn snapshot(&mut self, state: &CoordinatorState, epoch: u64) -> anyhow::Result<()> {
        self.writer.sync()?;
        self.store.write(&Manifest {
            state: state.clone(),
            last_seq: self.last_seq,
            committed_ops: self.committed_ops,
            epoch,
        })?;
        // Rotate: next record starts a fresh segment aligned with this
        // snapshot's high-water mark.
        self.writer = WalWriter::open(&self.dir, self.last_seq + 1, self.opts.sync_every)?;
        // Truncate: segments whose first record the previous generation
        // already covers are unreachable from both snapshots.
        for (first_seq, path) in list_segments(&self.dir)? {
            if first_seq <= self.prev_manifest_seq {
                fs::remove_file(path)?;
            }
        }
        self.prev_manifest_seq = self.last_seq;
        self.ops_since_snapshot = 0;
        self.snapshots += 1;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_records() -> Vec<WalRecord> {
        vec![
            WalRecord::AddStripe { cluster_of: vec![0, 0, 1], node_of: vec![0, 1, 2] },
            WalRecord::SetFailed { node: 7, down: true },
            WalRecord::BeginEvent {
                event: WalEvent::from_event(TopologyEvent::DrainNode { node: 7 }),
            },
            WalRecord::TopoAddNode { cluster: 1 },
            WalRecord::TopoAddCluster { nodes: 4 },
            WalRecord::TopoSetState { node: 7, state: 3 },
            WalRecord::TopoRetire { cluster: 0 },
            WalRecord::MoveBlock { stripe: 2, block: 5, to_cluster: 1, to_node: 9 },
            WalRecord::CommitEvent,
            WalRecord::BeginOnline {
                event_id: 3,
                event: WalEvent::from_event(TopologyEvent::AddNode { cluster: 2 }),
                moves: 1,
            },
            WalRecord::OnlineMove {
                event_id: 3,
                done: false,
                stripe: 1,
                block: 4,
                from_node: 6,
                to_cluster: 2,
                to_node: 11,
            },
            WalRecord::OnlineMove {
                event_id: 3,
                done: true,
                stripe: 1,
                block: 4,
                from_node: 6,
                to_cluster: 2,
                to_node: 12,
            },
            WalRecord::CommitOnline { event_id: 3 },
            WalRecord::AbortOnline { event_id: 4 },
            WalRecord::Epoch { epoch: 17 },
        ]
    }

    #[test]
    fn records_round_trip() {
        for (i, rec) in sample_records().into_iter().enumerate() {
            let framed = rec.encode(i as u64 + 1);
            let (decoded, end) = scan_segment(&framed);
            assert_eq!(end, ScanEnd::Clean);
            assert_eq!(decoded.len(), 1);
            assert_eq!(decoded[0].seq, i as u64 + 1);
            assert_eq!(decoded[0].record, rec);
        }
    }

    #[test]
    fn wal_event_round_trips() {
        for ev in [
            TopologyEvent::AddNode { cluster: 3 },
            TopologyEvent::DrainNode { node: 11 },
            TopologyEvent::AddCluster { nodes: 5 },
            TopologyEvent::DecommissionCluster { cluster: 2 },
        ] {
            assert_eq!(WalEvent::from_event(ev).to_event(), Some(ev));
        }
        assert_eq!(WalEvent { tag: 9, arg: 0 }.to_event(), None);
    }

    #[test]
    fn scan_stops_clean_on_torn_tail() {
        let mut bytes = Vec::new();
        for (i, rec) in sample_records().into_iter().enumerate() {
            bytes.extend_from_slice(&rec.encode(i as u64 + 1));
        }
        let (full, end) = scan_segment(&bytes);
        assert_eq!(end, ScanEnd::Clean);
        assert_eq!(full.len(), 15);
        // every strict prefix is either clean at a boundary or torn
        for cut in 0..bytes.len() {
            let (recs, end) = scan_segment(&bytes[..cut]);
            match end {
                ScanEnd::Clean => assert_eq!(bytes[..cut].len(), recs_len(&bytes, recs.len())),
                ScanEnd::TornTail { offset } => {
                    assert_eq!(offset, recs_len(&bytes, recs.len()))
                }
                ScanEnd::Corrupt { .. } => panic!("truncation reported as corruption at {cut}"),
            }
        }
    }

    /// Byte length of the first `n` records of an encoded stream.
    fn recs_len(bytes: &[u8], n: usize) -> usize {
        let mut pos = 0;
        for _ in 0..n {
            let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()) as usize;
            pos += 8 + len;
        }
        pos
    }

    #[test]
    fn flipped_payload_byte_is_corrupt_not_torn() {
        let rec = WalRecord::MoveBlock { stripe: 1, block: 2, to_cluster: 3, to_node: 4 };
        let mut bytes = rec.encode(1);
        let at = 12; // inside the payload
        bytes[at] ^= 0x01;
        let (recs, end) = scan_segment(&bytes);
        assert!(recs.is_empty());
        assert!(matches!(end, ScanEnd::Corrupt { .. }), "got {end:?}");
    }

    #[test]
    fn writer_groups_are_contiguous_and_replayable() {
        let dir = std::env::temp_dir().join(format!("unilrc-wal-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let mut w = WalWriter::open(&dir, 1, 2).unwrap();
        let last = w.append_group(&sample_records()).unwrap();
        assert_eq!(last, 15);
        let last = w
            .append_group(&[WalRecord::SetFailed { node: 1, down: false }])
            .unwrap();
        assert_eq!(last, 16);
        w.sync().unwrap();
        let segs = list_segments(&dir).unwrap();
        assert_eq!(segs.len(), 1);
        assert_eq!(segs[0].0, 1);
        let (recs, end) = scan_segment(&fs::read(&segs[0].1).unwrap());
        assert_eq!(end, ScanEnd::Clean);
        assert_eq!(recs.len(), 16);
        assert!(recs.windows(2).all(|pair| pair[1].seq == pair[0].seq + 1));
        let _ = fs::remove_dir_all(&dir);
    }
}
