//! Coordinator metadata: the mutable [`BlockMap`] (stripe → per-block
//! `(cluster, node)`, the single source of truth every layer consults)
//! plus the (ground-truth) block store. In the paper's prototype this is
//! the stripe-to-file and block-to-node mapping the coordinator manages
//! (§4.2) — here made *stateful* so topology events can migrate blocks.

use crate::codes::Code;
use crate::coordinator::block_map::{BlockMap, BlockState};
use crate::placement::{Placement, PlacementStrategy, Topology};
use std::collections::HashMap;
use std::sync::Arc;

pub use crate::coordinator::block_map::StripeId;

/// Block map + block data. Blocks are `Arc`'d so ops can hold references
/// while the virtual network "moves" them. New stripes are placed by the
/// owned strategy against the *current* topology; existing placements are
/// mutated only through [`Metadata::move_block`] (the migration executor).
pub struct Metadata {
    map: BlockMap,
    /// (stripe, block) → bytes. Ground truth for verification; a failed
    /// node's blocks are unreadable through ops but remain here.
    blocks: HashMap<(StripeId, usize), Arc<Vec<u8>>>,
    strategy: Box<dyn PlacementStrategy>,
    n: usize,
}

impl Metadata {
    pub fn new(code: &Code, strategy: Box<dyn PlacementStrategy>) -> Metadata {
        Metadata { map: BlockMap::new(), blocks: HashMap::new(), strategy, n: code.n() }
    }

    /// Rebuild metadata from recovered parts: a restored [`BlockMap`]
    /// plus the surviving block store (crash model: block bytes are
    /// node-resident and survive a coordinator crash).
    pub fn restore(
        map: BlockMap,
        blocks: HashMap<(StripeId, usize), Arc<Vec<u8>>>,
        strategy: Box<dyn PlacementStrategy>,
        n: usize,
    ) -> Metadata {
        Metadata { map, blocks, strategy, n }
    }

    pub fn strategy_name(&self) -> &'static str {
        self.strategy.name()
    }

    pub fn stripe_count(&self) -> usize {
        self.map.stripe_count()
    }

    /// The coordinator-owned block map (read view; mutations go through
    /// [`Metadata::move_block`]).
    pub fn block_map(&self) -> &BlockMap {
        &self.map
    }

    /// Register a new stripe with its block data, placed by the strategy
    /// on the current topology; returns its id.
    pub fn add_stripe(
        &mut self,
        blocks: Vec<Arc<Vec<u8>>>,
        code: &Code,
        topo: &Topology,
    ) -> StripeId {
        let placement = self.place_next_stripe(code, topo);
        self.add_stripe_with_placement(blocks, placement, topo.clusters())
    }

    /// Placement the strategy would assign to the *next* stripe — pure:
    /// computes without registering, so the durable coordinator can log
    /// the placement to the WAL before committing it
    /// ([`crate::coordinator::Dss::ingest_stripe`]).
    pub fn place_next_stripe(&self, code: &Code, topo: &Topology) -> Placement {
        self.strategy.place(code, topo, self.map.stripe_count())
    }

    /// Register a stripe under an already-computed placement (the commit
    /// half of the log-then-apply ingest path).
    pub fn add_stripe_with_placement(
        &mut self,
        blocks: Vec<Arc<Vec<u8>>>,
        placement: Placement,
        clusters: usize,
    ) -> StripeId {
        assert_eq!(blocks.len(), self.n, "stripe must have n blocks");
        let id = self.map.insert_stripe(placement, clusters);
        for (b, data) in blocks.into_iter().enumerate() {
            self.blocks.insert((id, b), data);
        }
        id
    }

    pub fn placement(&self, stripe: StripeId) -> &Placement {
        self.map.placement(stripe)
    }

    /// Node hosting a block.
    pub fn node_of(&self, stripe: StripeId, block: usize) -> usize {
        self.map.node_of(stripe, block)
    }

    /// Cluster hosting a block.
    pub fn cluster_of(&self, stripe: StripeId, block: usize) -> usize {
        self.map.cluster_of(stripe, block)
    }

    /// Blocks of `stripe` in `cluster` — the precomputed per-cluster index
    /// (replaces the O(n) `Placement::blocks_in_cluster` scan in per-event
    /// sim loops).
    pub fn blocks_in_cluster(&self, stripe: StripeId, cluster: usize) -> &[usize] {
        self.map.blocks_in_cluster(stripe, cluster)
    }

    /// Block bytes (ground truth).
    pub fn block_data(&self, stripe: StripeId, block: usize) -> Arc<Vec<u8>> {
        self.blocks[&(stripe, block)].clone()
    }

    /// All (stripe, block) pairs on a node.
    pub fn blocks_on_node(&self, node: usize) -> Vec<(StripeId, usize)> {
        self.map.blocks_on_node(node).to_vec()
    }

    /// Snapshot of the whole block store (`Arc` clones — cheap). The
    /// exp9 crash harness uses this as the surviving node-resident data
    /// handed to [`Metadata::restore`] after a simulated coordinator
    /// death.
    pub fn export_blocks(&self) -> HashMap<(StripeId, usize), Arc<Vec<u8>>> {
        self.blocks.clone()
    }

    /// Fault-injection hook: overwrite one block's ground-truth bytes.
    /// Only for corruption-injection tests — a mismatch here makes every
    /// downstream byte-verification of the block fail.
    pub fn corrupt_block_data(&mut self, stripe: StripeId, block: usize) {
        let data = self.blocks.get_mut(&(stripe, block)).expect("block exists");
        let mut flipped = data.as_ref().clone();
        flipped[0] ^= 0xFF;
        *data = Arc::new(flipped);
    }

    /// Reassign one block (migration executor only — the bytes must have
    /// been moved/rebuilt by the caller).
    pub fn move_block(
        &mut self,
        stripe: StripeId,
        block: usize,
        to_cluster: usize,
        to_node: usize,
    ) {
        self.map.move_block(stripe, block, to_cluster, to_node);
    }

    // Claim passthroughs for the online (background) migration scheduler:
    // a claimed block keeps serving reads from its source until
    // `commit_move` re-points it.

    /// Migration state of a block.
    pub fn block_state(&self, stripe: StripeId, block: usize) -> BlockState {
        self.map.state_of(stripe, block)
    }

    /// Claim a block for an in-flight move; `false` if already claimed.
    pub fn begin_move(
        &mut self,
        stripe: StripeId,
        block: usize,
        to_cluster: usize,
        to_node: usize,
    ) -> bool {
        self.map.begin_move(stripe, block, to_cluster, to_node)
    }

    /// Point an in-flight claim at a new target (dest-death re-plan).
    pub fn retarget_move(
        &mut self,
        stripe: StripeId,
        block: usize,
        to_cluster: usize,
        to_node: usize,
    ) {
        self.map.retarget_move(stripe, block, to_cluster, to_node);
    }

    /// Commit an in-flight claim: re-point the block at its target.
    pub fn commit_move(&mut self, stripe: StripeId, block: usize) {
        self.map.commit_move(stripe, block);
    }

    /// Drop an in-flight claim, leaving the block where it is.
    pub fn abort_move(&mut self, stripe: StripeId, block: usize) {
        self.map.abort_move(stripe, block);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::spec::{CodeFamily, Scheme};
    use crate::placement::UniLrcPlace;

    fn meta() -> (Metadata, Code, Topology) {
        let code = Scheme::S42.build(CodeFamily::UniLrc);
        let topo = Topology::new(6, 16);
        let mut m = Metadata::new(&code, Box::new(UniLrcPlace));
        for s in 0..4 {
            let blocks: Vec<Arc<Vec<u8>>> =
                (0..42).map(|b| Arc::new(vec![(s * 42 + b) as u8; 8])).collect();
            m.add_stripe(blocks, &code, &topo);
        }
        (m, code, topo)
    }

    #[test]
    fn stripes_register_and_lookup() {
        let (m, _, _) = meta();
        assert_eq!(m.stripe_count(), 4);
        assert_eq!(m.block_data(2, 5)[0], (2 * 42 + 5) as u8);
        let node = m.node_of(1, 3);
        assert!(m.blocks_on_node(node).contains(&(1, 3)));
    }

    #[test]
    fn rotation_spreads_stripes() {
        let (m, _, _) = meta();
        // stripe 0 and 1 place block 0 in different clusters
        assert_ne!(m.cluster_of(0, 0), m.cluster_of(1, 0));
        assert_eq!(m.cluster_of(0, 0), m.placement(0).cluster_of[0]);
    }

    #[test]
    fn reverse_index_complete() {
        let (m, _, _) = meta();
        let total: usize = (0..6 * 16).map(|n| m.blocks_on_node(n).len()).sum();
        assert_eq!(total, 4 * 42);
    }

    #[test]
    fn cluster_index_matches_placement_scan() {
        let (m, _, topo) = meta();
        for s in 0..m.stripe_count() {
            for c in 0..topo.clusters() {
                assert_eq!(
                    m.blocks_in_cluster(s, c),
                    m.placement(s).blocks_in_cluster(c).as_slice(),
                    "stripe {s} cluster {c}"
                );
            }
        }
    }

    #[test]
    fn move_block_rehomes_across_indexes() {
        let (mut m, _, topo) = meta();
        let old_node = m.node_of(0, 0);
        let old_cluster = m.cluster_of(0, 0);
        // free slot in another cluster
        let to_cluster = (old_cluster + 1) % topo.clusters();
        let used: Vec<usize> = m.blocks_in_cluster(0, to_cluster).to_vec();
        let to_node = *topo
            .nodes_of(to_cluster)
            .iter()
            .find(|&&n| !used.iter().any(|&b| m.node_of(0, b) == n))
            .unwrap();
        m.move_block(0, 0, to_cluster, to_node);
        assert_eq!(m.node_of(0, 0), to_node);
        assert_eq!(m.cluster_of(0, 0), to_cluster);
        assert!(!m.blocks_on_node(old_node).contains(&(0, 0)));
        assert!(m.blocks_on_node(to_node).contains(&(0, 0)));
        assert!(m.blocks_in_cluster(0, to_cluster).contains(&0));
        assert!(!m.blocks_in_cluster(0, old_cluster).contains(&0));
        // data is keyed by (stripe, block) — untouched by the move
        assert_eq!(m.block_data(0, 0)[0], 0);
    }
}
