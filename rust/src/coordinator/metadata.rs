//! Coordinator metadata: stripe placements and the (ground-truth) block
//! store. In the paper's prototype this is the stripe-to-file and
//! block-to-node mapping the coordinator manages (§4.2).

use crate::codes::Code;
use crate::placement::{Placement, PlacementStrategy, Topology};
use std::collections::HashMap;
use std::sync::Arc;

/// Stripe identifier.
pub type StripeId = usize;

/// Stripe placements + block data. Blocks are `Arc`'d so ops can hold
/// references while the virtual network "moves" them.
pub struct Metadata {
    placements: Vec<Placement>,
    /// (stripe, block) → bytes. Ground truth for verification; a failed
    /// node's blocks are unreadable through ops but remain here.
    blocks: HashMap<(StripeId, usize), Arc<Vec<u8>>>,
    /// node → (stripe, block) reverse index.
    by_node: HashMap<usize, Vec<(StripeId, usize)>>,
    strategy_name: &'static str,
    template: PlacementTemplate,
}

struct PlacementTemplate {
    n: usize,
    placements_fn: Box<dyn Fn(usize) -> Placement>,
}

impl Metadata {
    pub fn new(code: &Code, strategy: &dyn PlacementStrategy, topo: Topology) -> Metadata {
        let code_cl = code.clone();
        let n = code.n();
        // Pre-compute a rotation cycle of placements; stripes reuse
        // placements cyclically (strategies rotate by stripe index).
        let cycle: Vec<Placement> = (0..topo.clusters.max(1))
            .map(|i| strategy.place(&code_cl, &topo, i))
            .collect();
        let name = strategy.name();
        Metadata {
            placements: Vec::new(),
            blocks: HashMap::new(),
            by_node: HashMap::new(),
            strategy_name: name,
            template: PlacementTemplate {
                n,
                placements_fn: Box::new(move |idx| cycle[idx % cycle.len()].clone()),
            },
        }
    }

    pub fn strategy_name(&self) -> &'static str {
        self.strategy_name
    }

    pub fn stripe_count(&self) -> usize {
        self.placements.len()
    }

    /// Register a new stripe with its block data; returns its id.
    pub fn add_stripe(&mut self, blocks: Vec<Arc<Vec<u8>>>) -> StripeId {
        assert_eq!(blocks.len(), self.template.n, "stripe must have n blocks");
        let id = self.placements.len();
        let placement = (self.template.placements_fn)(id);
        for (b, data) in blocks.into_iter().enumerate() {
            let node = placement.node_of[b];
            self.blocks.insert((id, b), data);
            self.by_node.entry(node).or_default().push((id, b));
        }
        self.placements.push(placement);
        id
    }

    pub fn placement(&self, stripe: StripeId) -> &Placement {
        &self.placements[stripe]
    }

    /// Node hosting a block.
    pub fn node_of(&self, stripe: StripeId, block: usize) -> usize {
        self.placements[stripe].node_of[block]
    }

    /// Cluster hosting a block.
    pub fn cluster_of(&self, stripe: StripeId, block: usize) -> usize {
        self.placements[stripe].cluster_of[block]
    }

    /// Block bytes (ground truth).
    pub fn block_data(&self, stripe: StripeId, block: usize) -> Arc<Vec<u8>> {
        self.blocks[&(stripe, block)].clone()
    }

    /// All (stripe, block) pairs on a node.
    pub fn blocks_on_node(&self, node: usize) -> Vec<(StripeId, usize)> {
        self.by_node.get(&node).cloned().unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::spec::{CodeFamily, Scheme};
    use crate::placement::UniLrcPlace;

    fn meta() -> Metadata {
        let code = Scheme::S42.build(CodeFamily::UniLrc);
        let topo = Topology::new(6, 16);
        let mut m = Metadata::new(&code, &UniLrcPlace, topo);
        for s in 0..4 {
            let blocks: Vec<Arc<Vec<u8>>> =
                (0..42).map(|b| Arc::new(vec![(s * 42 + b) as u8; 8])).collect();
            m.add_stripe(blocks);
        }
        m
    }

    #[test]
    fn stripes_register_and_lookup() {
        let m = meta();
        assert_eq!(m.stripe_count(), 4);
        assert_eq!(m.block_data(2, 5)[0], (2 * 42 + 5) as u8);
        let node = m.node_of(1, 3);
        assert!(m.blocks_on_node(node).contains(&(1, 3)));
    }

    #[test]
    fn rotation_spreads_stripes() {
        let m = meta();
        // stripe 0 and 1 place block 0 in different clusters
        assert_ne!(m.cluster_of(0, 0), m.cluster_of(1, 0));
        // rotation cycle wraps: 0 and 6-th would match (we made 4 stripes)
        assert_eq!(m.cluster_of(0, 0), m.placement(0).cluster_of[0]);
    }

    #[test]
    fn reverse_index_complete() {
        let m = meta();
        let total: usize = (0..6 * 16).map(|n| m.blocks_on_node(n).len()).sum();
        assert_eq!(total, 4 * 42);
    }
}
