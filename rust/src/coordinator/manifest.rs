//! Checksummed, versioned **manifest snapshots** of the coordinator's
//! durable state.
//!
//! A [`CoordinatorState`] is the *logical* state that must survive a
//! coordinator crash: the [`crate::placement::Topology`] parts (cluster
//! ownership, lifecycle states, retired clusters), every stripe's
//! placement rows from the [`crate::coordinator::BlockMap`], and the
//! failure set. Block *bytes* are node-resident in the crash model and
//! are re-attached at restore time ([`crate::coordinator::Dss::restore`]);
//! derived indexes (per-cluster, per-node) are rebuilt, not stored.
//!
//! The on-disk [`Manifest`] wraps a state with the WAL high-water mark
//! (`last_seq`) and the committed-operation counter, framed as
//! `magic · version · length · CRC32 · payload`. [`ManifestStore`] writes
//! snapshots with the classic write-temp → fsync → rename protocol and
//! keeps **two generations** (`MANIFEST.bin` + `MANIFEST.prev.bin`) so
//! recovery can fall back across one corrupt or torn snapshot.

use crate::coordinator::block_map::BlockMap;
use crate::placement::{NodeState, Placement, Topology};
use crate::sim::faults::{digest_mix, DIGEST_SEED};
use std::collections::HashSet;
use std::fs::{self, File};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

/// File magic: identifies a UniLRC manifest.
pub const MANIFEST_MAGIC: &[u8; 8] = b"UNILRCMF";
/// On-disk format version. Bump on any encoding change.
/// v2: metadata epoch added to the payload header (serving plane).
pub const MANIFEST_VERSION: u32 = 2;
/// Current-generation snapshot file name.
pub const MANIFEST_CURRENT: &str = "MANIFEST.bin";
/// Previous-generation snapshot file name (fallback).
pub const MANIFEST_PREV: &str = "MANIFEST.prev.bin";

// ---------------------------------------------------------------- CRC32

/// CRC32 (IEEE, reflected polynomial 0xEDB88320) lookup table, built at
/// compile time — the checksum every manifest payload and WAL record
/// carries. Hand-rolled: no checksum crates in this offline build.
const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

const CRC32_TABLE: [u32; 256] = crc32_table();

/// CRC32 (IEEE) of a byte slice.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = CRC32_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ------------------------------------------------------- binary encoding

/// Little-endian append helpers shared by the manifest and WAL encoders.
pub(crate) fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Bounds-checked little-endian cursor over an encoded payload. Every
/// read can fail — decode paths must survive arbitrary corruption.
pub(crate) struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    pub fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, pos: 0 }
    }

    pub fn u8(&mut self) -> Result<u8, String> {
        if self.pos >= self.buf.len() {
            return Err("payload truncated (u8)".into());
        }
        let v = self.buf[self.pos];
        self.pos += 1;
        Ok(v)
    }

    pub fn u32(&mut self) -> Result<u32, String> {
        if self.pos + 4 > self.buf.len() {
            return Err("payload truncated (u32)".into());
        }
        let v = u32::from_le_bytes(self.buf[self.pos..self.pos + 4].try_into().unwrap());
        self.pos += 4;
        Ok(v)
    }

    pub fn u64(&mut self) -> Result<u64, String> {
        if self.pos + 8 > self.buf.len() {
            return Err("payload truncated (u64)".into());
        }
        let v = u64::from_le_bytes(self.buf[self.pos..self.pos + 8].try_into().unwrap());
        self.pos += 8;
        Ok(v)
    }

    /// Length-prefixed id list; `limit` caps the count so a corrupt
    /// length can never drive an over-allocation.
    pub fn u32_vec(&mut self, limit: usize) -> Result<Vec<u32>, String> {
        let len = self.u32()? as usize;
        if len > limit {
            return Err(format!("list length {len} exceeds limit {limit}"));
        }
        let mut v = Vec::with_capacity(len);
        for _ in 0..len {
            v.push(self.u32()?);
        }
        Ok(v)
    }

    pub fn str(&mut self, limit: usize) -> Result<String, String> {
        let len = self.u32()? as usize;
        if len > limit {
            return Err(format!("string length {len} exceeds limit {limit}"));
        }
        if self.pos + len > self.buf.len() {
            return Err("payload truncated (str)".into());
        }
        let s = std::str::from_utf8(&self.buf[self.pos..self.pos + len])
            .map_err(|_| "string is not UTF-8".to_string())?
            .to_string();
        self.pos += len;
        Ok(s)
    }

    pub fn done(&self) -> Result<(), String> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(format!("{} trailing bytes after payload", self.buf.len() - self.pos))
        }
    }
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

fn put_u32_vec(buf: &mut Vec<u8>, v: &[u32]) {
    put_u32(buf, v.len() as u32);
    for &x in v {
        put_u32(buf, x);
    }
}

/// Caps on decoded list lengths — generous versus any prototype scale,
/// tight enough that a bit-flipped length field cannot drive a huge
/// allocation before the CRC or bounds checks reject the record.
const MAX_NODES: usize = 1 << 20;
const MAX_CLUSTERS: usize = 1 << 16;
const MAX_STRIPES: usize = 1 << 24;
const MAX_BLOCKS: usize = 1 << 12;

// ------------------------------------------------------ coordinator state

/// The coordinator's durable logical state: everything needed to rebuild
/// [`crate::placement::Topology`] + [`BlockMap`] + the failure set after
/// a crash. This is also the unit the exp9 oracle digests: two runs agree
/// iff their `CoordinatorState`s are bit-identical.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CoordinatorState {
    /// Code label (report/diagnostic only — the restore caller supplies
    /// the actual `Code`).
    pub code_name: String,
    /// Placement-strategy name — checked at restore so a manifest is
    /// never replayed under a different placement policy.
    pub strategy: String,
    /// node id → owning cluster.
    pub cluster_of: Vec<u32>,
    /// node id → lifecycle state tag ([`NodeState::tag`]).
    pub states: Vec<u8>,
    /// cluster id → retired flag.
    pub retired: Vec<bool>,
    /// Per stripe: (per-block cluster, per-block node) placement rows.
    pub placements: Vec<(Vec<u32>, Vec<u32>)>,
    /// Failed node ids, sorted ascending.
    pub failed: Vec<u32>,
}

impl CoordinatorState {
    /// Snapshot the live coordinator structures.
    pub fn capture(
        code_name: &str,
        strategy: &str,
        topo: &Topology,
        map: &BlockMap,
        failed: &HashSet<usize>,
    ) -> CoordinatorState {
        let cluster_of =
            (0..topo.total_nodes()).map(|n| topo.cluster_of_node(n) as u32).collect();
        let states = (0..topo.total_nodes()).map(|n| topo.state(n).tag()).collect();
        let retired = (0..topo.clusters()).map(|c| topo.is_retired(c)).collect();
        let placements = (0..map.stripe_count())
            .map(|s| {
                let p = map.placement(s);
                (
                    p.cluster_of.iter().map(|&c| c as u32).collect(),
                    p.node_of.iter().map(|&n| n as u32).collect(),
                )
            })
            .collect();
        let mut failed: Vec<u32> = failed.iter().map(|&n| n as u32).collect();
        failed.sort_unstable();
        CoordinatorState {
            code_name: code_name.to_string(),
            strategy: strategy.to_string(),
            cluster_of,
            states,
            retired,
            placements,
            failed,
        }
    }

    /// FNV-1a digest over every field — the exp9 oracle comparator (same
    /// chain discipline as exp7/exp8 digests).
    pub fn digest(&self) -> u64 {
        let mut h = DIGEST_SEED;
        for b in self.code_name.bytes().chain(self.strategy.bytes()) {
            h = digest_mix(h, b as u64);
        }
        h = digest_mix(h, self.cluster_of.len() as u64);
        for &c in &self.cluster_of {
            h = digest_mix(h, c as u64);
        }
        for &s in &self.states {
            h = digest_mix(h, s as u64);
        }
        h = digest_mix(h, self.retired.len() as u64);
        for &r in &self.retired {
            h = digest_mix(h, r as u64);
        }
        h = digest_mix(h, self.placements.len() as u64);
        for (clusters, nodes) in &self.placements {
            for &c in clusters {
                h = digest_mix(h, c as u64);
            }
            for &n in nodes {
                h = digest_mix(h, n as u64);
            }
        }
        h = digest_mix(h, self.failed.len() as u64);
        for &f in &self.failed {
            h = digest_mix(h, f as u64);
        }
        h
    }

    /// Structural invariant proof — the gate every recovered state must
    /// pass before it is allowed to become a live coordinator. Checks the
    /// exact properties `Placement::validate` asserts at ingest, plus
    /// topology-shape and failure-set consistency; returns a description
    /// of the first violation.
    pub fn prove_invariants(&self) -> Result<(), String> {
        let nodes = self.cluster_of.len();
        let clusters = self.retired.len();
        if self.states.len() != nodes {
            return Err(format!(
                "state count {} != node count {nodes}",
                self.states.len()
            ));
        }
        if clusters == 0 {
            return Err("no clusters".into());
        }
        for (n, &c) in self.cluster_of.iter().enumerate() {
            if c as usize >= clusters {
                return Err(format!("node {n} owned by out-of-range cluster {c}"));
            }
        }
        for (n, &s) in self.states.iter().enumerate() {
            if NodeState::from_tag(s).is_none() {
                return Err(format!("node {n} has unknown state tag {s}"));
            }
        }
        let width = self.placements.first().map_or(0, |(c, _)| c.len());
        for (s, (p_clusters, p_nodes)) in self.placements.iter().enumerate() {
            if p_clusters.len() != p_nodes.len() || p_clusters.len() != width || width == 0 {
                return Err(format!("stripe {s} has malformed placement row"));
            }
            let mut seen = HashSet::with_capacity(width);
            for (b, (&c, &node)) in p_clusters.iter().zip(p_nodes).enumerate() {
                if node as usize >= nodes {
                    return Err(format!("stripe {s} block {b} on out-of-range node {node}"));
                }
                if self.cluster_of[node as usize] != c {
                    return Err(format!(
                        "stripe {s} block {b}: node {node} is in cluster {} not {c}",
                        self.cluster_of[node as usize]
                    ));
                }
                if !seen.insert(node) {
                    return Err(format!("stripe {s}: two blocks share node {node}"));
                }
            }
        }
        let mut prev: Option<u32> = None;
        for &f in &self.failed {
            if f as usize >= nodes {
                return Err(format!("failed set names out-of-range node {f}"));
            }
            if prev.is_some_and(|p| p >= f) {
                return Err("failed set is not sorted-unique".into());
            }
            prev = Some(f);
        }
        Ok(())
    }

    /// Rebuild the live [`Topology`]. Call [`Self::prove_invariants`]
    /// first — this conversion asserts rather than checks.
    pub fn restore_topology(&self) -> Topology {
        let cluster_of = self.cluster_of.iter().map(|&c| c as usize).collect();
        let states = self
            .states
            .iter()
            .map(|&s| NodeState::from_tag(s).expect("state tags proven by invariants"))
            .collect();
        Topology::from_parts(cluster_of, states, self.retired.clone())
    }

    /// Rebuild the live [`BlockMap`] (derived indexes recomputed). Call
    /// [`Self::prove_invariants`] first.
    pub fn restore_block_map(&self) -> BlockMap {
        let mut map = BlockMap::new();
        for (clusters, nodes) in &self.placements {
            let placement = Placement {
                cluster_of: clusters.iter().map(|&c| c as usize).collect(),
                node_of: nodes.iter().map(|&n| n as usize).collect(),
            };
            map.insert_stripe(placement, self.retired.len());
        }
        map
    }

    fn encode_into(&self, buf: &mut Vec<u8>) {
        put_str(buf, &self.code_name);
        put_str(buf, &self.strategy);
        put_u32_vec(buf, &self.cluster_of);
        put_u32(buf, self.states.len() as u32);
        buf.extend_from_slice(&self.states);
        put_u32(buf, self.retired.len() as u32);
        buf.extend(self.retired.iter().map(|&r| r as u8));
        put_u32(buf, self.placements.len() as u32);
        for (clusters, nodes) in &self.placements {
            put_u32_vec(buf, clusters);
            put_u32_vec(buf, nodes);
        }
        put_u32_vec(buf, &self.failed);
    }

    fn decode_from(cur: &mut Cursor<'_>) -> Result<CoordinatorState, String> {
        let code_name = cur.str(256)?;
        let strategy = cur.str(256)?;
        let cluster_of = cur.u32_vec(MAX_NODES)?;
        let n_states = cur.u32()? as usize;
        if n_states > MAX_NODES {
            return Err(format!("state count {n_states} exceeds limit"));
        }
        let mut states = Vec::with_capacity(n_states);
        for _ in 0..n_states {
            states.push(cur.u8()?);
        }
        let n_retired = cur.u32()? as usize;
        if n_retired > MAX_CLUSTERS {
            return Err(format!("cluster count {n_retired} exceeds limit"));
        }
        let mut retired = Vec::with_capacity(n_retired);
        for _ in 0..n_retired {
            retired.push(cur.u8()? != 0);
        }
        let n_stripes = cur.u32()? as usize;
        if n_stripes > MAX_STRIPES {
            return Err(format!("stripe count {n_stripes} exceeds limit"));
        }
        let mut placements = Vec::with_capacity(n_stripes);
        for _ in 0..n_stripes {
            let clusters = cur.u32_vec(MAX_BLOCKS)?;
            let nodes = cur.u32_vec(MAX_BLOCKS)?;
            placements.push((clusters, nodes));
        }
        let failed = cur.u32_vec(MAX_NODES)?;
        Ok(CoordinatorState {
            code_name,
            strategy,
            cluster_of,
            states,
            retired,
            placements,
            failed,
        })
    }
}

// -------------------------------------------------------------- manifest

/// One snapshot generation: a [`CoordinatorState`] plus the WAL position
/// it covers. Replay resumes at `last_seq + 1`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Manifest {
    pub state: CoordinatorState,
    /// Sequence number of the last WAL record folded into this snapshot
    /// (0 = fresh journal, nothing logged yet).
    pub last_seq: u64,
    /// Committed logical operations folded into this snapshot — lets a
    /// deterministic driver resume its op list after recovery.
    pub committed_ops: u64,
    /// Metadata epoch at snapshot time (version 2). Recovery seeds the
    /// serving plane's epoch as the max of this and every replayed
    /// [`super::wal::WalRecord::Epoch`] record, so a crash can never
    /// resurrect an epoch a client already saw superseded.
    pub epoch: u64,
}

impl Manifest {
    /// Serialize: `magic · version · payload_len · crc32 · payload`.
    pub fn encode(&self) -> Vec<u8> {
        let mut payload = Vec::with_capacity(256);
        put_u64(&mut payload, self.last_seq);
        put_u64(&mut payload, self.committed_ops);
        put_u64(&mut payload, self.epoch);
        self.state.encode_into(&mut payload);
        let mut out = Vec::with_capacity(payload.len() + 20);
        out.extend_from_slice(MANIFEST_MAGIC);
        put_u32(&mut out, MANIFEST_VERSION);
        put_u32(&mut out, payload.len() as u32);
        put_u32(&mut out, crc32(&payload));
        out.extend_from_slice(&payload);
        out
    }

    /// Decode and verify a manifest image. Any framing, checksum,
    /// length, or field-level inconsistency is an error — a torn or
    /// bit-flipped snapshot must never decode to a plausible state.
    pub fn decode(bytes: &[u8]) -> Result<Manifest, String> {
        if bytes.len() < 20 {
            return Err(format!("file too short ({} bytes)", bytes.len()));
        }
        if &bytes[..8] != MANIFEST_MAGIC {
            return Err("bad magic".into());
        }
        let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
        if version != MANIFEST_VERSION {
            return Err(format!("unsupported version {version}"));
        }
        let len = u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(bytes[16..20].try_into().unwrap());
        if bytes.len() != 20 + len {
            return Err(format!("payload length {len} != {} file bytes", bytes.len() - 20));
        }
        let payload = &bytes[20..];
        if crc32(payload) != crc {
            return Err("payload CRC mismatch".into());
        }
        let mut cur = Cursor::new(payload);
        let last_seq = cur.u64()?;
        let committed_ops = cur.u64()?;
        let epoch = cur.u64()?;
        let state = CoordinatorState::decode_from(&mut cur)?;
        cur.done()?;
        Ok(Manifest { state, last_seq, committed_ops, epoch })
    }
}

// --------------------------------------------------------- manifest store

/// Atomic two-generation snapshot store.
///
/// Write protocol: encode to `MANIFEST.tmp`, fsync the file, rotate
/// `MANIFEST.bin` → `MANIFEST.prev.bin`, rename the temp into place,
/// fsync the directory. A crash at any step leaves at least one intact
/// generation on disk; [`ManifestStore::load`] prefers the current file
/// and reports whether the previous generation had to be used.
#[derive(Debug)]
pub struct ManifestStore {
    dir: PathBuf,
}

/// A successfully loaded snapshot, tagged with its provenance.
#[derive(Debug)]
pub struct LoadedManifest {
    pub manifest: Manifest,
    /// True when `MANIFEST.bin` was missing/corrupt and the previous
    /// generation was used instead.
    pub used_fallback: bool,
    /// Human-readable reason the current generation was rejected (when
    /// `used_fallback`).
    pub fallback_reason: Option<String>,
}

/// Load failure: distinguishes "never initialized" from "present but
/// unreadable" so recovery can type its errors.
#[derive(Debug)]
pub enum ManifestLoadError {
    /// Neither generation exists — the directory was never initialized.
    Missing,
    /// At least one generation exists but none decodes; the payload lists
    /// each candidate's failure.
    Corrupt(String),
}

impl ManifestStore {
    pub fn new(dir: &Path) -> ManifestStore {
        ManifestStore { dir: dir.to_path_buf() }
    }

    pub fn current_path(&self) -> PathBuf {
        self.dir.join(MANIFEST_CURRENT)
    }

    pub fn prev_path(&self) -> PathBuf {
        self.dir.join(MANIFEST_PREV)
    }

    /// Atomically persist `manifest` as the current generation.
    pub fn write(&self, manifest: &Manifest) -> std::io::Result<()> {
        let tmp = self.dir.join("MANIFEST.tmp");
        let current = self.current_path();
        {
            let mut f = File::create(&tmp)?;
            f.write_all(&manifest.encode())?;
            f.sync_all()?;
        }
        if current.exists() {
            fs::rename(&current, self.prev_path())?;
        }
        fs::rename(&tmp, &current)?;
        // Persist the renames themselves.
        File::open(&self.dir)?.sync_all()?;
        Ok(())
    }

    /// Load the best available generation: current first, then previous.
    pub fn load(&self) -> Result<LoadedManifest, ManifestLoadError> {
        let mut reasons = Vec::new();
        let mut any_present = false;
        for (path, fallback) in [(self.current_path(), false), (self.prev_path(), true)] {
            let mut bytes = Vec::new();
            match File::open(&path).and_then(|mut f| f.read_to_end(&mut bytes)) {
                Ok(_) => any_present = true,
                Err(_) => {
                    reasons.push(format!("{}: missing/unreadable", path.display()));
                    continue;
                }
            }
            match Manifest::decode(&bytes) {
                Ok(manifest) => {
                    return Ok(LoadedManifest {
                        manifest,
                        used_fallback: fallback,
                        fallback_reason: fallback.then(|| reasons.join("; ")),
                    })
                }
                Err(e) => reasons.push(format!("{}: {e}", path.display())),
            }
        }
        if any_present {
            Err(ManifestLoadError::Corrupt(reasons.join("; ")))
        } else {
            Err(ManifestLoadError::Missing)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Metadata;
    use crate::codes::spec::{CodeFamily, Scheme};
    use crate::placement::UniLrcPlace;
    use std::sync::Arc;

    fn sample_state() -> CoordinatorState {
        let code = Scheme::S42.build(CodeFamily::UniLrc);
        let mut topo = Topology::new(6, 16);
        let mut meta = Metadata::new(&code, Box::new(UniLrcPlace));
        for s in 0..3 {
            let blocks: Vec<Arc<Vec<u8>>> =
                (0..code.n()).map(|b| Arc::new(vec![(s * 7 + b) as u8; 16])).collect();
            meta.add_stripe(blocks, &code, &topo);
        }
        topo.add_node(2);
        topo.set_state(5, NodeState::Draining);
        let failed: HashSet<usize> = [3, 40].into_iter().collect();
        CoordinatorState::capture("unilrc-s42", "one-group-one-cluster", &topo, meta.block_map(), &failed)
    }

    #[test]
    fn crc32_known_vector() {
        // IEEE CRC32 of "123456789" — the standard check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn state_round_trips_through_manifest() {
        let state = sample_state();
        assert!(state.prove_invariants().is_ok());
        let m = Manifest { state, last_seq: 17, committed_ops: 5, epoch: 12 };
        let decoded = Manifest::decode(&m.encode()).unwrap();
        assert_eq!(decoded, m);
        assert_eq!(decoded.state.digest(), m.state.digest());
    }

    #[test]
    fn topology_and_map_restore_bit_exact() {
        let state = sample_state();
        let topo = state.restore_topology();
        assert_eq!(topo.total_nodes(), state.cluster_of.len());
        let map = state.restore_block_map();
        let recaptured = CoordinatorState::capture(
            &state.code_name,
            &state.strategy,
            &topo,
            &map,
            &state.failed.iter().map(|&f| f as usize).collect(),
        );
        assert_eq!(recaptured, state);
    }

    #[test]
    fn every_flipped_byte_is_rejected_or_equal() {
        let m = Manifest { state: sample_state(), last_seq: 3, committed_ops: 2, epoch: 4 };
        let good = m.encode();
        for at in 0..good.len() {
            let mut bad = good.clone();
            bad[at] ^= 0x40;
            // A single bit flip must never decode to a *different* manifest.
            if let Ok(d) = Manifest::decode(&bad) {
                assert_eq!(d, m, "flip at {at} decoded to a different manifest");
            }
        }
    }

    #[test]
    fn truncations_are_rejected() {
        let m = Manifest { state: sample_state(), last_seq: 3, committed_ops: 2, epoch: 4 };
        let good = m.encode();
        for len in 0..good.len() {
            assert!(Manifest::decode(&good[..len]).is_err(), "truncation to {len} accepted");
        }
    }

    #[test]
    fn invariant_proof_catches_violations() {
        let mut s = sample_state();
        s.placements[0].1[0] = s.placements[0].1[1]; // two blocks on one node
        assert!(s.prove_invariants().is_err());
        let mut s = sample_state();
        s.cluster_of[0] = 999;
        assert!(s.prove_invariants().is_err());
        let mut s = sample_state();
        s.failed = vec![2, 1];
        assert!(s.prove_invariants().is_err());
        let mut s = sample_state();
        s.states[0] = 7;
        assert!(s.prove_invariants().is_err());
    }

    #[test]
    fn store_rotates_generations_and_falls_back() {
        let dir = std::env::temp_dir().join(format!("unilrc-manifest-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let store = ManifestStore::new(&dir);
        assert!(matches!(store.load(), Err(ManifestLoadError::Missing)));

        let m1 = Manifest { state: sample_state(), last_seq: 1, committed_ops: 1, epoch: 2 };
        let mut m2 = m1.clone();
        m2.last_seq = 9;
        store.write(&m1).unwrap();
        store.write(&m2).unwrap();
        let loaded = store.load().unwrap();
        assert!(!loaded.used_fallback);
        assert_eq!(loaded.manifest.last_seq, 9);

        // Corrupt the current generation: load falls back to m1.
        let mut bytes = fs::read(store.current_path()).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        fs::write(store.current_path(), &bytes).unwrap();
        let loaded = store.load().unwrap();
        assert!(loaded.used_fallback);
        assert_eq!(loaded.manifest.last_seq, 1);

        // Corrupt both: typed corruption error, not a panic.
        fs::write(store.prev_path(), b"garbage").unwrap();
        assert!(matches!(store.load(), Err(ManifestLoadError::Corrupt(_))));
        let _ = fs::remove_dir_all(&dir);
    }
}
