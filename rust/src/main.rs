//! `unilrc` — CLI entry point. See `unilrc help`.

fn main() {
    std::process::exit(unilrc::cli::run());
}
