//! Command-line interface (hand-rolled: `clap` is unavailable offline).
//!
//! ```text
//! unilrc layout  [--scheme 42|136|210]           Fig 1-style layouts
//! unilrc analyze [--fig5|--fig8|--fig3b|--table2|--table4|--all]
//! unilrc experiment <1..10> [options]            §6 experiments + faults
//!                                                + elastic topology
//!                                                + durable coordinator
//!                                                + online migration
//! unilrc golden  [--out FILE]                    cross-language vectors
//! unilrc help
//! ```

use crate::analysis::markov::{mttdl_years, MttdlParams};
use crate::analysis::metrics::{evaluate, CrossModel};
use crate::analysis::tradeoff;
use crate::codes::layout;
use crate::codes::spec::{CodeFamily, Scheme};
use crate::experiments::{self, ExpConfig};
use crate::gf::dispatch::{self, Kernel};
use std::collections::HashMap;

/// Run the CLI; returns the process exit code.
pub fn run() -> i32 {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn dispatch(args: &[String]) -> anyhow::Result<()> {
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let flags = parse_flags(&args[1..]);
    match cmd {
        "layout" => cmd_layout(&flags),
        "analyze" => cmd_analyze(&flags),
        "experiment" => cmd_experiment(args.get(1).map(|s| s.as_str()), &flags),
        "engine" => cmd_engine(&flags),
        "golden" => cmd_golden(&flags),
        "serve" => cmd_serve(&flags),
        "loadgen" => cmd_loadgen(&flags),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => anyhow::bail!("unknown command {other:?} (try `unilrc help`)"),
    }
}

const HELP: &str = "\
unilrc — Wide LRCs with Unified Locality (paper reproduction)

USAGE:
  unilrc layout  [--scheme 42|136|210]
  unilrc analyze [--fig3b] [--fig5] [--fig8] [--table2] [--table4] [--all]
  unilrc experiment <1..11> [--config FILE] [--scheme S] [--block-kb N]
                    [--stripes N] [--cross-gbps X] [--backend native|pjrt] [--raw]
                    [--topology N,N,...] (asymmetric per-cluster node counts)
                    [--gf-kernel auto|scalar|ssse3|avx2|avx512|gfni|neon]
                    [--gf-threads N] [--gf-chunk-kb N]
                    [--gf-nt-kb N|auto|off] [--gf-pin [on|off]]
                    [--plan-ttl-ms N] [--plan-warmup [trace|learned|off]]
                    [--cache-stats]
  unilrc engine [--check TIER]        show GF engine tiers + pool + plan cache
                                      (--check exits non-zero if TIER cannot
                                      run on this CPU — the CI matrix probe)
  unilrc serve   [--data-addr H:P] [--http-addr H:P] [--stripes N]
                 [--block-kb N] [--seed N] [--fail-nodes N] [--per-tenant N]
                 [--repair-mbps X] [--repair-burst-kb N] [--wal-dir DIR]
  unilrc loadgen [--data-addr H:P] [--http-addr H:P] [--sessions N]
                 [--duration-s X] [--pipeline N] [--seed N]
                 [--topology-at-s X] [--assert-p99-ms X] [--expect-redirect]
  unilrc golden  [--out FILE]
  unilrc help

Experiments (paper §6): 1 normal read · 2 degraded read (single + batched
burst) · 3 recovery (single-block + full-node) · 4 bandwidth sweep ·
5 decode throughput · 6 production workload · 7 fault injection
(deterministic seeded failure schedule; extra knobs: --horizon-hours
--mttf-hours --mttr-hours --cluster-mttf-hours --cluster-mttr-hours
--tenants --measure-cap; --plan-warmup trace prefetches decode plans for
the trace's predicted failure patterns, --plan-warmup learned derives
them online from the observed failure history) · 8 elastic topology
(deterministic scale-out/drain scenario with coordinator-planned block
migration; knobs: --add-nodes --drain-nodes --add-clusters
--cluster-nodes --fault-horizon-hours, [elastic] config section) ·
9 durable coordinator (checksummed manifest + write-ahead log; kills the
coordinator at every distinct WAL position of a scale-out/drain/fault
scenario, recovers, and proves the recovered block map byte-identical to
the never-crashed oracle; knobs: --wal-sync-every (group-commit fsync
cadence, also UNILRC_WAL_SYNC_EVERY or the [durability] config section)
--snapshot-every --crash-cap --add-nodes --drain-nodes --add-clusters
--fault-ops; see PERF.md on durability overhead) · 10 online migration
under load (concurrent topology events with typed conflict
serialization, token-bucket-throttled background moves sharing the
network with foreground reads, source/destination death mid-move, and a
crash-at-every-WAL-position sweep over open migration waves; knobs:
--migrate-rate-mbps --migrate-burst (KiB) --backoff-base-ms
--backoff-cap-ms --max-attempts --add-nodes --drain-nodes
--add-clusters --crash-cap --fg-reads, [migration] config section; see
PERF.md on reading the throttle interference curve) · 11 latent sector
errors vs background scrub (seeded silent-corruption streams layered on
the exp7 node/cluster schedule; a periodic scrub pass drains a token
bucket shared with background traffic, visits clusters with a down
member first, and the per-family sweep over scrub interval ×
sector-error rate is differentially checked against the closed-form
latent-error chain in analysis/markov; knobs: --scrub-intervals-hours
--sector-mtte-hours (comma lists) --scrub-node-kb --scrub-rate-mb-h
--scrub-burst-kb --scrub-tick-hours plus the exp7 clock flags, [scrub]
config section; see PERF.md on choosing the scrub budget).

The GF engine tier defaults to the best the CPU supports; override with
--gf-kernel / --gf-threads or UNILRC_GF_KERNEL / UNILRC_GF_THREADS.
Multi-stripe repairs run batched on the engine's persistent worker pool;
--gf-threads sizes it, --gf-chunk-kb / UNILRC_GF_CHUNK_KB pins the batch
task granularity (default: adaptive from event size vs. workers).
Outputs wider than the streaming-store threshold (--gf-nt-kb /
UNILRC_GF_NT_KB; default auto = the detected LLC, 0 = always, off =
never) are written with non-temporal stores; --gf-pin / UNILRC_GF_PIN
pins pool workers to distinct CPUs (package-major; see PERF.md §memory).
--plan-ttl-ms / UNILRC_PLAN_TTL_MS expires cached decode plans (PERF.md).

Serving plane (PERF.md §serving): `serve` boots the pipelined proxy
front end over real sockets (length-prefixed binary data plane +
HTTP/JSON control plane with epoch-versioned routing); `loadgen` drives
it closed-loop with the multi-tenant WorkloadSpec mixes, verifies
in-order pipelining and stale-epoch redirect recovery, and emits
latency percentiles (UNILRC_BENCH_JSON=BENCH_serve.json for the CI
serve-smoke gate).
";

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut m = HashMap::new();
    let mut it = args.iter().peekable();
    while let Some(a) = it.next() {
        if let Some(key) = a.strip_prefix("--") {
            let val = match it.peek() {
                Some(v) if !v.starts_with("--") => it.next().unwrap().clone(),
                _ => "true".to_string(),
            };
            m.insert(key.to_string(), val);
        }
    }
    m
}

/// Boolean flag values: a bare `--flag` parses as true (`parse_flags`
/// maps it to "true"); an explicit operand accepts on/off spellings.
fn parse_bool_flag(name: &str, v: &str) -> anyhow::Result<bool> {
    match v {
        "true" | "1" | "on" | "yes" => Ok(true),
        "false" | "0" | "off" | "no" => Ok(false),
        other => anyhow::bail!("bad {name} value {other:?} (want on|off)"),
    }
}

fn scheme_of(flags: &HashMap<String, String>) -> anyhow::Result<Scheme> {
    match flags.get("scheme") {
        None => Ok(Scheme::S42),
        Some(s) => Scheme::parse(s).ok_or_else(|| anyhow::anyhow!("bad scheme {s:?}")),
    }
}

fn exp_config(flags: &HashMap<String, String>) -> anyhow::Result<ExpConfig> {
    // GF engine flags install first so the CLI wins over config-file keys
    // (the process-wide engine freezes at first install).
    crate::config::install_gf_engine(
        flags.get("gf-kernel").map(|s| s.as_str()),
        flags.get("gf-threads").map(|t| t.parse()).transpose()?,
        flags.get("gf-chunk-kb").map(|t| t.parse()).transpose()?,
        flags.get("gf-nt-kb").map(|s| s.as_str()),
        flags.get("gf-pin").map(|v| parse_bool_flag("--gf-pin", v)).transpose()?,
        "--gf-kernel/--gf-threads/--gf-chunk-kb/--gf-nt-kb/--gf-pin",
    )?;
    // --config FILE loads a TOML-subset base; explicit flags override it.
    let mut cfg = match flags.get("config") {
        Some(path) => {
            let file = crate::config::Config::load(path)?;
            crate::config::experiment_config(&file)?
        }
        None => ExpConfig::default(),
    };
    // Plan-cache TTL, applied after the config file so the explicit flag
    // (or environment) wins over `[experiment] plan_ttl_ms`.
    let ttl_ms = match flags.get("plan-ttl-ms") {
        Some(v) => Some(v.parse::<u64>()?),
        None => std::env::var("UNILRC_PLAN_TTL_MS").ok().and_then(|v| v.parse().ok()),
    };
    if let Some(ms) = ttl_ms {
        crate::config::apply_plan_ttl(ms);
    }
    if flags.contains_key("scheme") {
        cfg.scheme = scheme_of(flags)?;
    }
    if let Some(kb) = flags.get("block-kb") {
        cfg.block_size = kb.parse::<usize>()? * 1024;
    }
    if let Some(s) = flags.get("stripes") {
        cfg.stripes = s.parse()?;
    }
    if let Some(g) = flags.get("cross-gbps") {
        cfg.cross_gbps = g.parse()?;
    }
    if flags.contains_key("raw") {
        cfg.aggregated = false;
    }
    if let Some(s) = flags.get("seed") {
        cfg.seed = s.parse()?;
    }
    if let Some(v) = flags.get("plan-warmup") {
        cfg.plan_warmup = experiments::WarmupMode::parse(v)
            .ok_or_else(|| anyhow::anyhow!("bad --plan-warmup {v:?} (off|trace|learned)"))?;
    }
    if let Some(t) = flags.get("topology") {
        cfg.topology = Some(experiments::parse_topology_spec(t)?);
    }
    // validate the (possibly config-file-sourced) topology against the
    // final scheme for every family up front — a clean error here instead
    // of a panic deep inside build_dss
    if let Some(sizes) = &cfg.topology {
        experiments::validate_topology(cfg.scheme, sizes)?;
    }
    if flags.get("backend").map(|s| s.as_str()) == Some("pjrt") {
        cfg = cfg.with_pjrt()?;
    }
    Ok(cfg)
}

/// Experiment 7 knobs: config-file `[faults]` section first, explicit
/// flags override.
fn fault_sim_config(
    flags: &HashMap<String, String>,
) -> anyhow::Result<experiments::FaultSimConfig> {
    let mut fc = experiments::FaultSimConfig::default();
    if let Some(path) = flags.get("config") {
        let file = crate::config::Config::load(path)?;
        crate::config::apply_fault_keys(&file, &mut fc);
    }
    if let Some(v) = flags.get("horizon-hours") {
        fc.fault.horizon_hours = v.parse()?;
    }
    if let Some(v) = flags.get("mttf-hours") {
        fc.fault.node_mttf_hours = v.parse()?;
    }
    if let Some(v) = flags.get("mttr-hours") {
        fc.fault.node_mttr_hours = v.parse()?;
    }
    if let Some(v) = flags.get("cluster-mttf-hours") {
        fc.fault.cluster_mttf_hours = v.parse()?;
    }
    if let Some(v) = flags.get("cluster-mttr-hours") {
        fc.fault.cluster_mttr_hours = v.parse()?;
    }
    if let Some(v) = flags.get("tenants") {
        fc.tenants = v.parse()?;
    }
    if let Some(v) = flags.get("measure-cap") {
        fc.measure_cap = v.parse()?;
    }
    anyhow::ensure!(fc.tenants > 0, "--tenants must be at least 1");
    anyhow::ensure!(fc.objects_per_tenant > 0, "objects_per_tenant must be at least 1");
    anyhow::ensure!(fc.fault.horizon_hours > 0.0, "--horizon-hours must be positive");
    // a zero MTTF deliberately disables an event class; a zero/negative
    // MTTR with failures enabled would silently disable them too — reject
    anyhow::ensure!(
        fc.fault.node_mttf_hours <= 0.0 || fc.fault.node_mttr_hours > 0.0,
        "--mttr-hours must be positive while node failures are enabled (--mttf-hours > 0)"
    );
    anyhow::ensure!(
        fc.fault.cluster_mttf_hours <= 0.0 || fc.fault.cluster_mttr_hours > 0.0,
        "--cluster-mttr-hours must be positive while cluster events are enabled"
    );
    Ok(fc)
}

/// Experiment 11 knobs: the base node/cluster clocks ride on the exp7
/// `[faults]` plumbing (config-file section + `--horizon-hours` etc.);
/// the scrub grid and budget come from the `[scrub]` section, explicit
/// flags override.
fn scrub_sim_config(
    flags: &HashMap<String, String>,
) -> anyhow::Result<experiments::ScrubSimConfig> {
    let mut sc = experiments::ScrubSimConfig::default();
    if let Some(path) = flags.get("config") {
        let file = crate::config::Config::load(path)?;
        // borrow the exp7 [faults] hour keys for the base clocks, on top
        // of the accelerated default (the paper-scale exp7 defaults would
        // make the 0.25 h replay ticks pointless)
        let mut fc = experiments::FaultSimConfig { fault: sc.fault, ..Default::default() };
        crate::config::apply_fault_keys(&file, &mut fc);
        sc.fault = fc.fault;
        crate::config::apply_scrub_keys(&file, &mut sc)?;
    }
    // explicit flags override both config-file sections; clock flags
    // reuse the exp7 names
    if let Some(v) = flags.get("horizon-hours") {
        sc.fault.horizon_hours = v.parse()?;
    }
    if let Some(v) = flags.get("mttf-hours") {
        sc.fault.node_mttf_hours = v.parse()?;
    }
    if let Some(v) = flags.get("mttr-hours") {
        sc.fault.node_mttr_hours = v.parse()?;
    }
    if let Some(v) = flags.get("cluster-mttf-hours") {
        sc.fault.cluster_mttf_hours = v.parse()?;
    }
    if let Some(v) = flags.get("cluster-mttr-hours") {
        sc.fault.cluster_mttr_hours = v.parse()?;
    }
    anyhow::ensure!(sc.fault.horizon_hours > 0.0, "--horizon-hours must be positive");
    anyhow::ensure!(
        sc.fault.node_mttf_hours <= 0.0 || sc.fault.node_mttr_hours > 0.0,
        "--mttr-hours must be positive while node failures are enabled (--mttf-hours > 0)"
    );
    anyhow::ensure!(
        sc.fault.cluster_mttf_hours <= 0.0 || sc.fault.cluster_mttr_hours > 0.0,
        "--cluster-mttr-hours must be positive while cluster events are enabled"
    );
    if let Some(v) = flags.get("scrub-intervals-hours") {
        sc.intervals_hours = crate::config::parse_hour_list(v, "--scrub-intervals-hours")?;
    }
    if let Some(v) = flags.get("sector-mtte-hours") {
        sc.sector_mtte_hours = crate::config::parse_hour_list(v, "--sector-mtte-hours")?;
    }
    if let Some(v) = flags.get("scrub-node-kb") {
        sc.node_bytes = v.parse::<u64>()? * 1024;
    }
    if let Some(v) = flags.get("scrub-rate-mb-h") {
        sc.rate_bytes_per_hour = v.parse::<f64>()? * (1 << 20) as f64;
    }
    if let Some(v) = flags.get("scrub-burst-kb") {
        sc.burst_bytes = v.parse::<f64>()? * 1024.0;
    }
    if let Some(v) = flags.get("scrub-tick-hours") {
        sc.tick_hours = v.parse()?;
    }
    anyhow::ensure!(
        sc.intervals_hours.iter().all(|&t| t > 0.0),
        "--scrub-intervals-hours entries must be positive"
    );
    anyhow::ensure!(
        sc.sector_mtte_hours.iter().all(|&t| t > 0.0),
        "--sector-mtte-hours entries must be positive"
    );
    anyhow::ensure!(sc.node_bytes > 0, "--scrub-node-kb must be at least 1 KiB");
    anyhow::ensure!(sc.rate_bytes_per_hour > 0.0, "--scrub-rate-mb-h must be positive");
    anyhow::ensure!(sc.burst_bytes > 0.0, "--scrub-burst-kb must be positive");
    anyhow::ensure!(sc.tick_hours > 0.0, "--scrub-tick-hours must be positive");
    Ok(sc)
}

/// Experiment 8 knobs: config-file `[elastic]` section first, explicit
/// flags override.
fn elastic_config(
    flags: &HashMap<String, String>,
) -> anyhow::Result<experiments::ElasticConfig> {
    let mut ec = experiments::ElasticConfig::default();
    if let Some(path) = flags.get("config") {
        let file = crate::config::Config::load(path)?;
        crate::config::apply_elastic_keys(&file, &mut ec);
    }
    if let Some(v) = flags.get("add-nodes") {
        ec.add_nodes = v.parse()?;
    }
    if let Some(v) = flags.get("drain-nodes") {
        ec.drain_nodes = v.parse()?;
    }
    if let Some(v) = flags.get("add-clusters") {
        ec.add_clusters = v.parse()?;
    }
    if let Some(v) = flags.get("cluster-nodes") {
        ec.cluster_nodes = v.parse()?;
    }
    if let Some(v) = flags.get("fault-horizon-hours") {
        ec.fault_horizon_hours = v.parse()?;
    }
    anyhow::ensure!(
        ec.add_nodes + ec.drain_nodes + ec.add_clusters > 0,
        "experiment 8 needs at least one topology event"
    );
    anyhow::ensure!(ec.fault_horizon_hours >= 0.0, "--fault-horizon-hours must be ≥ 0");
    Ok(ec)
}

/// Experiment 9 knobs, later sources overriding earlier ones: defaults,
/// then the config-file `[durability]` section, then the
/// `UNILRC_WAL_SYNC_EVERY` environment variable, then explicit flags.
fn durability_config(
    flags: &HashMap<String, String>,
) -> anyhow::Result<experiments::DurabilitySimConfig> {
    let mut dc = experiments::DurabilitySimConfig::default();
    if let Some(path) = flags.get("config") {
        let file = crate::config::Config::load(path)?;
        crate::config::apply_durability_keys(&file, &mut dc);
    }
    if let Ok(v) = std::env::var("UNILRC_WAL_SYNC_EVERY") {
        dc.wal_sync_every = v
            .parse()
            .map_err(|_| anyhow::anyhow!("bad UNILRC_WAL_SYNC_EVERY {v:?} (want an integer)"))?;
    }
    if let Some(v) = flags.get("wal-sync-every") {
        dc.wal_sync_every = v.parse()?;
    }
    if let Some(v) = flags.get("snapshot-every") {
        dc.snapshot_every = v.parse()?;
    }
    if let Some(v) = flags.get("add-nodes") {
        dc.add_nodes = v.parse()?;
    }
    if let Some(v) = flags.get("drain-nodes") {
        dc.drain_nodes = v.parse()?;
    }
    if let Some(v) = flags.get("add-clusters") {
        dc.add_clusters = v.parse()?;
    }
    if let Some(v) = flags.get("fault-ops") {
        dc.fault_ops = v.parse()?;
    }
    if let Some(v) = flags.get("crash-cap") {
        dc.crash_cap = v.parse()?;
    }
    anyhow::ensure!(dc.wal_sync_every > 0, "--wal-sync-every must be at least 1");
    anyhow::ensure!(dc.snapshot_every > 0, "--snapshot-every must be at least 1");
    Ok(dc)
}

/// Experiment 10 knobs: config-file `[migration]` section first, explicit
/// flags override.
fn migration_config(
    flags: &HashMap<String, String>,
) -> anyhow::Result<experiments::MigrationSimConfig> {
    let mut mc = experiments::MigrationSimConfig::default();
    if let Some(path) = flags.get("config") {
        let file = crate::config::Config::load(path)?;
        crate::config::apply_migration_keys(&file, &mut mc);
    }
    if let Some(v) = flags.get("migrate-rate-mbps") {
        mc.rate_mbps = v.parse()?;
    }
    if let Some(v) = flags.get("migrate-burst") {
        mc.burst_kb = v.parse()?;
    }
    if let Some(v) = flags.get("backoff-base-ms") {
        mc.backoff_base_ms = v.parse()?;
    }
    if let Some(v) = flags.get("backoff-cap-ms") {
        mc.backoff_cap_ms = v.parse()?;
    }
    if let Some(v) = flags.get("max-attempts") {
        mc.max_attempts = v.parse()?;
    }
    if let Some(v) = flags.get("add-nodes") {
        mc.add_nodes = v.parse()?;
    }
    if let Some(v) = flags.get("drain-nodes") {
        mc.drain_nodes = v.parse()?;
    }
    if let Some(v) = flags.get("add-clusters") {
        mc.add_clusters = v.parse()?;
    }
    if let Some(v) = flags.get("crash-cap") {
        mc.crash_cap = v.parse()?;
    }
    if let Some(v) = flags.get("fg-reads") {
        mc.fg_reads = v.parse()?;
    }
    anyhow::ensure!(mc.rate_mbps > 0.0, "--migrate-rate-mbps must be positive");
    anyhow::ensure!(mc.burst_kb > 0, "--migrate-burst must be at least 1 KiB");
    anyhow::ensure!(mc.backoff_base_ms > 0.0, "--backoff-base-ms must be positive");
    anyhow::ensure!(
        mc.backoff_cap_ms >= mc.backoff_base_ms,
        "--backoff-cap-ms must be at least the base delay"
    );
    anyhow::ensure!(mc.max_attempts > 0, "--max-attempts must be at least 1");
    anyhow::ensure!(mc.fg_reads > 0, "--fg-reads must be at least 1");
    Ok(mc)
}

/// `unilrc engine` — report detected and available GF kernel tiers, the
/// worker pool, and plan-cache statistics. With `--check TIER`, probe a
/// single tier instead: exit 0 iff this CPU can run it (the CI
/// kernel-matrix uses this to skip tiers the runner lacks).
fn cmd_engine(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    if let Some(tier) = flags.get("check") {
        let k = Kernel::parse(tier)
            .ok_or_else(|| anyhow::anyhow!("unknown kernel tier {tier:?} (try `unilrc engine`)"))?;
        anyhow::ensure!(k.available(), "kernel tier '{k}' unavailable on this CPU");
        println!("{k}: available");
        return Ok(());
    }
    println!("=== GF(2^8) engine ===");
    println!("detected best tier : {}", Kernel::detect());
    for k in Kernel::all() {
        println!("  {:<8} {}", k.name(), if k.available() { "available" } else { "-" });
    }
    let e = dispatch::engine();
    println!("active engine      : {}", e.describe());
    println!("memory system      : {}", crate::gf::topo::describe());
    println!(
        "nt store threshold : {}",
        match e.nt_threshold() {
            0 => "0 (every output streamed)".to_string(),
            usize::MAX => "off (regular stores only)".to_string(),
            n => format!("{} KiB (outputs past this stream around the cache)", n / 1024),
        }
    );
    println!("override via --gf-* flags or UNILRC_GF_* env (see `unilrc help`)");

    print_pool_stats();
    print_plan_cache_stats();
    Ok(())
}

/// Process-wide buffer-pool counters: the 64-byte-aligned size-classed
/// pool the decode, proxy, and batch scratch paths allocate from.
fn print_pool_stats() {
    let s = crate::gf::pool::global().stats();
    println!("\n=== buffer pool ===");
    println!(
        "hits {} / misses {} / drops {}   recycled {}   retained {:.1} MiB in {} buffers",
        s.hits,
        s.misses,
        s.drops,
        s.recycled,
        s.retained_bytes as f64 / (1 << 20) as f64,
        s.buffers
    );
}

/// Decode-plan cache statistics for the *current process* (also printed
/// after `unilrc experiment … --cache-stats`, where the cache has just
/// been exercised by the run).
fn print_plan_cache_stats() {
    let stats = crate::codes::plan_cache::global().stats(8);
    println!("\n=== decode-plan cache ===");
    println!(
        "hits {} / misses {} / expired {} / refreshed {}   entries {}/{}   ttl {}",
        stats.hits,
        stats.misses,
        stats.expirations,
        stats.refreshed,
        stats.entries,
        stats.cap,
        match stats.ttl {
            Some(t) => format!("{}ms", t.as_millis()),
            None => "off".to_string(),
        }
    );
    println!(
        "warm-up: prefetched {} plans, {} demand hits served warm (--plan-warmup)",
        stats.prefetched, stats.prefetch_hits
    );
    if !stats.top.is_empty() {
        println!("hottest entries:");
        for e in &stats.top {
            println!(
                "  {:<38} erased={:?} hits={} age={:.1}s{}",
                e.code,
                e.erased,
                e.hits,
                e.age.as_secs_f64(),
                if e.recoverable { "" } else { " (unrecoverable)" }
            );
        }
    }
}

fn cmd_layout(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let scheme = scheme_of(flags)?;
    println!("=== Figure 1 — wide LRC layouts, {} ===\n", scheme.label());
    for fam in CodeFamily::paper_baselines() {
        println!("{}", layout::render(&scheme.build(fam)));
    }
    Ok(())
}

fn cmd_analyze(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let all = flags.contains_key("all") || flags.is_empty();
    if all || flags.contains_key("table2") {
        table2();
    }
    if all || flags.contains_key("fig5") {
        fig5();
    }
    if all || flags.contains_key("fig8") {
        fig8();
    }
    if all || flags.contains_key("fig3b") {
        fig3b();
    }
    if all || flags.contains_key("table4") {
        table4();
    }
    Ok(())
}

/// (code, placement) metric sets for a scheme, ECWide for baselines.
fn metric_rows(scheme: Scheme) -> Vec<(CodeFamily, crate::analysis::metrics::MetricSet)> {
    CodeFamily::paper_baselines()
        .iter()
        .map(|&fam| {
            let code = scheme.build(fam);
            let (strategy, topo) = experiments::strategy_and_topo(fam, &code);
            let p = strategy.place(&code, &topo, 0);
            (fam, evaluate(&code, &p, CrossModel::Raw, 0.1))
        })
        .collect()
}

fn table2() {
    println!("=== Table 2 — code parameters ===");
    println!("{:<12} {:>4} {:>4} {:>3} {:>7}  UniLRC", "scheme", "n", "k", "f", "rate");
    for s in Scheme::paper_schemes() {
        println!(
            "{:<12} {:>4} {:>4} {:>3} {:>7.4}  α={}, z={}",
            s.label(),
            s.n,
            s.k,
            s.f,
            s.rate(),
            s.alpha,
            s.z
        );
    }
    println!();
}

fn fig5() {
    println!(
        "=== Figure 5 — z/α vs code rate & stripe width (feasible: rate ≥ 0.85, n ∈ [25,504]) ==="
    );
    println!(
        "{:>3} {:>3} {:>5} {:>5} {:>4} {:>8} {:>9}",
        "α", "z", "n", "k", "r", "rate", "feasible"
    );
    for p in tradeoff::sweep(20, &[1, 2, 3]) {
        println!(
            "{:>3} {:>3} {:>5} {:>5} {:>4} {:>8.4} {:>9}",
            p.alpha,
            p.z,
            p.n,
            p.k,
            p.r,
            p.rate,
            if p.feasible() { "yes" } else { "-" }
        );
    }
    println!();
}

fn fig8() {
    println!("=== Figure 8 — ADRC / CDRC / ARC / CARC / LBNR (raw cross model) ===");
    for scheme in Scheme::paper_schemes() {
        println!("--- {} ---", scheme.label());
        println!(
            "{:<38} {:>7} {:>7} {:>7} {:>7} {:>6}",
            "code", "ADRC", "CDRC", "ARC", "CARC", "LBNR"
        );
        for (_, m) in metric_rows(scheme) {
            println!(
                "{:<38} {:>7.2} {:>7.2} {:>7.2} {:>7.2} {:>6.2}",
                m.code_name, m.adrc, m.cdrc, m.arc, m.carc, m.lbnr
            );
        }
    }
    println!();
}

fn fig3b() {
    println!("=== Figure 3(b) — avg XOR / MUL slice-ops per single-block decode ===");
    for scheme in Scheme::paper_schemes() {
        println!("--- {} ---", scheme.label());
        println!("{:<38} {:>9} {:>9}", "code", "XOR ops", "MUL ops");
        for (_, m) in metric_rows(scheme) {
            println!("{:<38} {:>9.2} {:>9.2}", m.code_name, m.avg_xor_ops, m.avg_mul_ops);
        }
    }
    println!();
}

fn table4() {
    println!("=== Table 4 — MTTDL (years, exact absorption time; see EXPERIMENTS.md on scale) ===");
    let params = MttdlParams::default();
    println!(
        "{:<12} {:>12} {:>12} {:>12} {:>12} {:>12}",
        "scheme", "ALRC", "OLRC", "ULRC", "CLRC", "UniLRC"
    );
    for scheme in Scheme::paper_schemes() {
        let mut vals = HashMap::new();
        for (fam, m) in metric_rows(scheme) {
            let f_tol = experiments::family_tolerance(scheme, fam);
            let code = scheme.build(fam);
            vals.insert(fam, mttdl_years(code.n(), f_tol, m.mttdl_c.max(0.05), &params));
        }
        println!(
            "{:<12} {:>12.2e} {:>12.2e} {:>12.2e} {:>12.2e} {:>12.2e}",
            scheme.label(),
            vals[&CodeFamily::Alrc],
            vals[&CodeFamily::Olrc],
            vals[&CodeFamily::Ulrc],
            vals[&CodeFamily::Clrc],
            vals[&CodeFamily::UniLrc],
        );
    }
    println!();
}

fn cmd_experiment(which: Option<&str>, flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let cfg = exp_config(flags)?;
    let print_rows = |title: &str, rows: &[experiments::Row]| {
        println!("=== {title} [{}] ===", cfg.scheme.label());
        for r in rows {
            println!("  {:<8} {:>12.2} {}", r.family.name(), r.value, r.unit);
        }
    };
    match which {
        Some("1") => {
            print_rows(
                "Experiment 1 — normal read throughput",
                &experiments::exp1_normal_read(&cfg)?,
            )
        }
        Some("2") => {
            print_rows(
                "Experiment 2 — degraded read latency",
                &experiments::exp2_degraded_read(&cfg)?,
            );
            print_rows(
                "Experiment 2 — batched degraded burst (whole node, one event)",
                &experiments::exp2_degraded_burst(&cfg)?,
            );
        }
        Some("3") => {
            print_rows(
                "Experiment 3 — single-block recovery throughput",
                &experiments::exp3_reconstruction(&cfg)?,
            );
            print_rows(
                "Experiment 3 — full-node recovery throughput",
                &experiments::exp3_node_recovery(&cfg)?,
            );
        }
        Some("4") => {
            let sweep = [0.5, 1.0, 2.5, 5.0, 10.0];
            for (gbps, rows) in experiments::exp4_bandwidth(&cfg, &sweep)? {
                print_rows(&format!("Experiment 4 — recovery @ {gbps} Gb/s cross"), &rows);
            }
        }
        Some("5") => {
            print_rows("Experiment 5 — decode throughput", &experiments::exp5_decode(&cfg)?)
        }
        Some("6") => {
            let res = experiments::exp6_production(&cfg, 24, 200)?;
            println!("=== Experiment 6 — production workload [{}] ===", cfg.scheme.label());
            for r in &res {
                println!(
                    "  {:<8} normal {:>9.2} ms   degraded {:>9.2} ms",
                    r.family.name(),
                    r.normal_mean_ms,
                    r.degraded_mean_ms
                );
            }
            for r in &res {
                println!("  CDF degraded {}:", r.family.name());
                for (lat, frac) in &r.degraded_cdf {
                    println!("    {lat:>9.3} ms  {frac:>5.2}");
                }
            }
        }
        Some("7") => {
            let fc = fault_sim_config(flags)?;
            let rows = experiments::exp7_faults(&cfg, &fc)?;
            println!(
                "=== Experiment 7 — fault injection [{}] (seed {}, horizon {:.0} h, \
                 warm-up {}) ===",
                cfg.scheme.label(),
                cfg.seed,
                fc.fault.horizon_hours,
                cfg.plan_warmup.name()
            );
            for r in &rows {
                println!("  {:<8} trace digest {:016x}", r.family.name(), r.digest);
                println!(
                    "    events {} (node-fail {}, cluster-fail {})   data-loss stripes {}",
                    r.events, r.node_failures, r.cluster_failures, r.data_loss_stripe_events
                );
                println!(
                    "    repairs {:>4} events / {:>5} blocks   mean {:>9.2} ms   \
                     cross {:>8.1} MiB",
                    r.repair_events,
                    r.repaired_blocks,
                    r.mean_repair_ms,
                    r.cross_bytes as f64 / (1 << 20) as f64
                );
                println!(
                    "    degraded reads {:>3}   mean {:>9.2} ms   prefetched plans {}",
                    r.degraded_reads, r.mean_degraded_ms, r.prefetched_plans
                );
                println!(
                    "    degraded {:>8.1} h   unavailable {:>8.3} h   \
                     stripe-0 degraded {:.4} (markov {:.4})",
                    r.degraded_hours,
                    r.unavailable_hours,
                    r.sim_degraded_frac,
                    r.markov_degraded_frac
                );
                println!(
                    "    MTTDL est {:>10.3e} y   markov {:>10.3e} y",
                    r.mttdl_est_years, r.mttdl_markov_years
                );
            }
        }
        Some("8") => {
            let ec = elastic_config(flags)?;
            let rows = experiments::exp8_elastic(&cfg, &ec)?;
            println!(
                "=== Experiment 8 — elastic topology [{}] (seed {}, +{} nodes, \
                 -{} drains, +{} clusters) ===",
                cfg.scheme.label(),
                cfg.seed,
                ec.add_nodes,
                ec.drain_nodes,
                ec.add_clusters
            );
            for r in &rows {
                println!("  {:<8} scenario digest {:016x}", r.family.name(), r.digest);
                println!(
                    "    events {:>2}   moves {:>5} ({} rebuilt)   migrated {:>8.1} MiB \
                     (cross {:>8.1} MiB)",
                    r.events,
                    r.moves,
                    r.repaired_moves,
                    r.migrated_bytes as f64 / (1 << 20) as f64,
                    r.cross_migration_bytes as f64 / (1 << 20) as f64
                );
                println!(
                    "    migration window {:>9.2} ms   exposure P(failure during move) {:.3e}",
                    r.migration_seconds * 1e3,
                    r.exposure_prob
                );
                println!(
                    "    invariant checks {:>4} passed   post-scale fault events {}   \
                     final topology {} clusters / {} live nodes",
                    r.invariant_checks,
                    r.post_scale_fault_events,
                    r.final_clusters,
                    r.final_live_nodes
                );
                // wall vs. virtual split per event — the baseline exp9's
                // recovery-replay timings are compared against
                println!("    per-event timing (wall / virtual):");
                for (ev, wall_ms, virt_s, moves) in &r.event_timings {
                    println!(
                        "      {:<34} wall {:>8.3} ms   virtual {:>9.2} ms   moves {:>4}",
                        format!("{ev:?}"),
                        wall_ms,
                        virt_s * 1e3,
                        moves
                    );
                }
            }
        }
        Some("9") => {
            let dc = durability_config(flags)?;
            let rows = experiments::exp9_durability(&cfg, &dc)?;
            println!(
                "=== Experiment 9 — durable coordinator [{}] (seed {}, sync-every {}, \
                 snapshot-every {}) ===",
                cfg.scheme.label(),
                cfg.seed,
                dc.wal_sync_every,
                dc.snapshot_every
            );
            for r in &rows {
                println!("  {:<8} oracle digest {:016x}", r.family.name(), r.oracle_digest);
                println!(
                    "    ops {:>3}   wal records {:>4} / {:>8} bytes",
                    r.ops, r.wal_records, r.wal_bytes
                );
                println!(
                    "    crash points {:>4} tested of {:>4}   digest matches {:>4}   \
                     torn tails {:>3}   pending re-plans {:>3}",
                    r.crash_points_tested,
                    r.crash_points_total,
                    r.digest_matches,
                    r.torn_tails,
                    r.pending_replans
                );
                println!(
                    "    decode checks {:>5} passed   byte-exact reconstructions {:>4}",
                    r.decode_checks, r.reconstructed_blocks
                );
                println!(
                    "    mean recover {:>8.3} ms   mean op-tail re-exec {:>8.3} ms",
                    r.mean_recover_ms, r.mean_reexec_ms
                );
                println!(
                    "    snapshot-cadence run: {} manifests written, recovery digest {}",
                    r.snapshot_run_snapshots,
                    if r.snapshot_digest_match { "matches oracle" } else { "MISMATCH" }
                );
            }
        }
        Some("10") => {
            let mc = migration_config(flags)?;
            let rows = experiments::exp10_migration(&cfg, &mc)?;
            println!(
                "=== Experiment 10 — online migration under load [{}] (seed {}, \
                 throttle {} Mb/s burst {} KiB, backoff {}..{} ms × {}) ===",
                cfg.scheme.label(),
                cfg.seed,
                mc.rate_mbps,
                mc.burst_kb,
                mc.backoff_base_ms,
                mc.backoff_cap_ms,
                mc.max_attempts
            );
            for r in &rows {
                println!("  {:<8} oracle digest {:016x}", r.family.name(), r.oracle_digest);
                println!(
                    "    window: peak {:>2} events in flight   trace faults {:>2}   \
                     invariant checks {:>4} passed",
                    r.concurrent_peak, r.trace_faults_applied, r.invariant_checks
                );
                for line in r.stats.render().lines() {
                    println!("    {line}");
                }
                println!(
                    "    crash sweep: {:>3} of {:>3} positions tested   digest matches {:>3}   \
                     mid-wave resumes {:>3}   decode checks {:>5}",
                    r.crash_points_tested,
                    r.crash_points_total,
                    r.digest_matches,
                    r.pending_resumes,
                    r.decode_checks
                );
                println!(
                    "    interference curve ({}):",
                    if r.curve_monotone { "monotone" } else { "NOT MONOTONE" }
                );
                for (mbps, p50, p99) in &r.curve {
                    println!(
                        "      throttle {:>8.1} Mb/s   foreground p50 {:>8.3} ms   \
                         p99 {:>8.3} ms",
                        mbps,
                        p50 * 1e3,
                        p99 * 1e3
                    );
                }
            }
        }
        Some("11") => {
            let sc = scrub_sim_config(flags)?;
            let res = experiments::exp11_scrub(&cfg, &sc)?;
            println!(
                "=== Experiment 11 — latent errors vs background scrub [{}] (seed {}, \
                 horizon {:.0} h, budget {:.0} MiB/h burst {:.0} KiB, {:.0} KiB/node/pass) ===",
                cfg.scheme.label(),
                cfg.seed,
                sc.fault.horizon_hours,
                sc.rate_bytes_per_hour / (1 << 20) as f64,
                sc.burst_bytes / 1024.0,
                sc.node_bytes as f64 / 1024.0
            );
            for r in &res.rows {
                println!(
                    "  {:<8} scrub every {:>6.1} h   sector MTTE {:>6.1} h",
                    r.family.name(),
                    r.interval_hours,
                    r.sector_mtte_hours
                );
                println!(
                    "    injected {:>4}   detected {:>4}   scrubbed {:>8.1} MiB of \
                     {:>8.1} MiB granted",
                    r.injected,
                    r.detected,
                    r.scrubbed_bytes as f64 / (1 << 20) as f64,
                    r.granted_bytes as f64 / (1 << 20) as f64
                );
                println!(
                    "    dwell {:>7.2} h (markov {:>7.2} h)   undetected/node {:>8.5} \
                     (markov {:>8.5})",
                    r.sim_dwell_hours,
                    r.markov_dwell_hours,
                    r.sim_undetected_per_node,
                    r.markov_undetected_per_node
                );
                println!(
                    "    at-risk exposure {:>9.2} block·h   P(loss incl. corruption) {:.3e}",
                    r.at_risk_block_hours, r.loss_fraction_markov
                );
            }
            println!("  sweep digest {:016x}", res.digest);
        }
        _ => anyhow::bail!("experiment must be 1..11"),
    }
    if flags.contains_key("cache-stats") {
        print_plan_cache_stats();
    }
    Ok(())
}

/// `unilrc serve` knobs → [`crate::serve::ServeConfig`].
fn serve_config(flags: &HashMap<String, String>) -> anyhow::Result<crate::serve::ServeConfig> {
    let mut sc = crate::serve::ServeConfig::default();
    // CI binds fixed ports; tests use :0 ephemerals.
    if let Some(v) = flags.get("data-addr") {
        sc.data_addr = v.clone();
    }
    if let Some(v) = flags.get("http-addr") {
        sc.http_addr = v.clone();
    }
    if let Some(v) = flags.get("stripes") {
        sc.stripes = v.parse()?;
    }
    if let Some(v) = flags.get("block-kb") {
        sc.block_size = v.parse::<usize>()? * 1024;
    }
    if let Some(v) = flags.get("seed") {
        sc.seed = v.parse()?;
    }
    if let Some(v) = flags.get("fail-nodes") {
        sc.fail_nodes = v.parse()?;
    }
    if let Some(v) = flags.get("per-tenant") {
        sc.admission.per_tenant = v.parse()?;
    }
    if let Some(v) = flags.get("repair-mbps") {
        sc.admission.repair_rate_bps = v.parse::<f64>()? * 1024.0 * 1024.0 / 8.0;
    }
    if let Some(v) = flags.get("repair-burst-kb") {
        sc.admission.repair_burst = v.parse::<f64>()? * 1024.0;
    }
    if let Some(dir) = flags.get("wal-dir") {
        sc.wal_dir = Some(dir.into());
    }
    anyhow::ensure!(sc.stripes > 0, "--stripes must be at least 1");
    anyhow::ensure!(sc.block_size > 0, "--block-kb must be at least 1");
    anyhow::ensure!(sc.admission.per_tenant > 0, "--per-tenant must be at least 1");
    anyhow::ensure!(sc.admission.repair_rate_bps > 0.0, "--repair-mbps must be positive");
    Ok(sc)
}

/// `unilrc loadgen` knobs: the closed-loop config plus the CI gate
/// assertions (`--assert-p99-ms`, `--expect-redirect`).
fn loadgen_config(
    flags: &HashMap<String, String>,
) -> anyhow::Result<(crate::serve::LoadgenConfig, Option<f64>, bool)> {
    let mut lc = crate::serve::LoadgenConfig::default();
    if let Some(v) = flags.get("data-addr") {
        lc.data_addr = v.clone();
    }
    if let Some(v) = flags.get("http-addr") {
        lc.http_addr = v.clone();
    }
    if let Some(v) = flags.get("sessions") {
        lc.sessions = v.parse()?;
    }
    if let Some(v) = flags.get("duration-s") {
        lc.duration = std::time::Duration::from_secs_f64(v.parse()?);
    }
    if let Some(v) = flags.get("pipeline") {
        lc.pipeline = v.parse()?;
    }
    if let Some(v) = flags.get("seed") {
        lc.seed = v.parse()?;
    }
    if let Some(v) = flags.get("topology-at-s") {
        lc.topology_event_at = Some(std::time::Duration::from_secs_f64(v.parse()?));
    }
    let assert_p99 = flags.get("assert-p99-ms").map(|v| v.parse::<f64>()).transpose()?;
    let expect_redirect = flags.contains_key("expect-redirect");
    anyhow::ensure!(lc.sessions > 0, "--sessions must be at least 1");
    anyhow::ensure!(lc.pipeline > 0, "--pipeline must be at least 1");
    anyhow::ensure!(lc.duration.as_secs_f64() > 0.0, "--duration-s must be positive");
    if let Some(p) = assert_p99 {
        anyhow::ensure!(p > 0.0, "--assert-p99-ms must be positive");
    }
    Ok((lc, assert_p99, expect_redirect))
}

/// `unilrc serve` — boot the serving plane and run until killed.
fn cmd_serve(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let sc = serve_config(flags)?;
    let rt = tokio::runtime::Runtime::new()?;
    rt.block_on(async move {
        let handle = crate::serve::bind(sc).await?;
        println!(
            "serving: data {} · control http://{} (epoch {})",
            handle.data_addr(),
            handle.http_addr(),
            handle.state().epoch.load(std::sync::atomic::Ordering::Acquire)
        );
        handle.wait().await;
        Ok(())
    })
}

/// `unilrc loadgen` — drive a serve instance closed-loop and gate on
/// the protocol invariants (and optionally tail latency).
fn cmd_loadgen(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let (lc, assert_p99, expect_redirect) = loadgen_config(flags)?;
    let r = crate::serve::run_loadgen(&lc).map_err(|e| anyhow::anyhow!(e))?;
    println!("=== loadgen — closed loop, {} sessions × {} deep ===", lc.sessions, lc.pipeline);
    println!("  requests {}   ok {}   repairs {}", r.requests, r.ok, r.repairs);
    println!(
        "  foreground latency p50 {:>8.3} ms   p95 {:>8.3} ms   p99 {:>8.3} ms",
        r.p50_ms, r.p95_ms, r.p99_ms
    );
    println!(
        "  stale redirects {} (unrecovered {})   protocol errors {}   op errors {}   \
         order violations {}",
        r.stale_redirects, r.unrecovered_redirects, r.protocol_errors, r.op_errors,
        r.in_order_violations
    );
    anyhow::ensure!(r.protocol_errors == 0, "{} protocol error(s)", r.protocol_errors);
    anyhow::ensure!(r.op_errors == 0, "{} op error(s)", r.op_errors);
    anyhow::ensure!(
        r.unrecovered_redirects == 0,
        "{} stale-epoch redirect(s) never recovered",
        r.unrecovered_redirects
    );
    anyhow::ensure!(
        r.in_order_violations == 0,
        "{} pipelined response(s) out of order",
        r.in_order_violations
    );
    anyhow::ensure!(r.ok > 0, "loadgen completed zero operations");
    if expect_redirect {
        anyhow::ensure!(
            r.stale_redirects > 0,
            "--expect-redirect: no StaleEpoch was observed during the run"
        );
    }
    if let Some(bound) = assert_p99 {
        anyhow::ensure!(
            r.p99_ms <= bound,
            "foreground p99 {:.3} ms exceeds the {bound:.3} ms bound",
            r.p99_ms
        );
    }
    Ok(())
}

/// Emit golden encode vectors shared with the python test-suite:
/// `alpha z <comma-separated stripe bytes>` per scheme, for the
/// deterministic message `data[j] = (j*31 + 7) mod 256`.
fn cmd_golden(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let mut out = String::new();
    for scheme in Scheme::paper_schemes() {
        let code = scheme.build(CodeFamily::UniLrc);
        let data: Vec<u8> = (0..code.k()).map(|j| ((j * 31 + 7) % 256) as u8).collect();
        let stripe = code.encode_symbols(&data);
        out.push_str(&format!(
            "{} {} {}\n",
            scheme.alpha,
            scheme.z,
            stripe.iter().map(|b| b.to_string()).collect::<Vec<_>>().join(",")
        ));
    }
    match flags.get("out") {
        Some(path) => std::fs::write(path, out)?,
        None => print!("{out}"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flags_parse() {
        let f = parse_flags(&[
            "--scheme".into(),
            "42".into(),
            "--raw".into(),
            "--block-kb".into(),
            "64".into(),
        ]);
        assert_eq!(f["scheme"], "42");
        assert_eq!(f["raw"], "true");
        assert_eq!(f["block-kb"], "64");
    }

    #[test]
    fn analyze_runs() {
        cmd_analyze(&parse_flags(&["--table2".into()])).unwrap();
        cmd_analyze(&parse_flags(&["--fig8".into()])).unwrap();
        cmd_analyze(&parse_flags(&["--table4".into()])).unwrap();
        cmd_analyze(&parse_flags(&["--fig3b".into()])).unwrap();
        cmd_analyze(&parse_flags(&["--fig5".into()])).unwrap();
    }

    #[test]
    fn layout_runs() {
        cmd_layout(&HashMap::new()).unwrap();
    }

    #[test]
    fn engine_runs() {
        cmd_engine(&HashMap::new()).unwrap();
    }

    #[test]
    fn engine_check_probes_tier_availability() {
        // scalar always passes; bogus names and (if any exists on this
        // machine) an unavailable tier must exit non-zero for the CI probe
        cmd_engine(&parse_flags(&["--check".into(), "scalar".into()])).unwrap();
        assert!(cmd_engine(&parse_flags(&["--check".into(), "mmx".into()])).is_err());
        if let Some(k) = Kernel::all().into_iter().find(|k| !k.available()) {
            let f = parse_flags(&["--check".into(), k.name().into()]);
            assert!(cmd_engine(&f).is_err(), "{k} should probe unavailable");
        }
    }

    #[test]
    fn fault_flags_parse_and_override_defaults() {
        let f = parse_flags(&[
            "--horizon-hours".into(),
            "500".into(),
            "--mttf-hours".into(),
            "50".into(),
            "--cluster-mttf-hours".into(),
            "0".into(),
            "--tenants".into(),
            "2".into(),
            "--measure-cap".into(),
            "4".into(),
        ]);
        let fc = fault_sim_config(&f).unwrap();
        assert_eq!(fc.fault.horizon_hours, 500.0);
        assert_eq!(fc.fault.node_mttf_hours, 50.0);
        assert_eq!(fc.fault.cluster_mttf_hours, 0.0);
        assert_eq!(fc.tenants, 2);
        assert_eq!(fc.measure_cap, 4);
        // unset knobs keep their defaults
        let d = experiments::FaultSimConfig::default();
        assert_eq!(fc.fault.node_mttr_hours, d.fault.node_mttr_hours);
        // degenerate knobs are rejected, not panicked on deep in the sim
        assert!(fault_sim_config(&parse_flags(&["--tenants".into(), "0".into()])).is_err());
        assert!(fault_sim_config(&parse_flags(&["--horizon-hours".into(), "0".into()])).is_err());
        assert!(fault_sim_config(&parse_flags(&["--mttr-hours".into(), "0".into()])).is_err());
        // ...but a zero MTTF legitimately disables the class, MTTR moot
        let off =
            parse_flags(&["--mttf-hours".into(), "0".into(), "--mttr-hours".into(), "0".into()]);
        assert!(fault_sim_config(&off).is_ok());
    }

    #[test]
    fn plan_warmup_flag_parses() {
        use crate::experiments::WarmupMode;
        // bare flag keeps the old boolean meaning: trace-driven warm-up
        let cfg = exp_config(&parse_flags(&["--plan-warmup".into()])).unwrap();
        assert_eq!(cfg.plan_warmup, WarmupMode::Trace);
        let learned =
            exp_config(&parse_flags(&["--plan-warmup".into(), "learned".into()])).unwrap();
        assert_eq!(learned.plan_warmup, WarmupMode::Learned);
        let off = exp_config(&HashMap::new()).unwrap();
        assert_eq!(off.plan_warmup, WarmupMode::Off);
        assert!(exp_config(&parse_flags(&["--plan-warmup".into(), "maybe".into()])).is_err());
    }

    #[test]
    fn topology_flag_parses_and_validates() {
        // sized for every S42 family (OLRC chunks need ≥ 11 per cluster)
        let spec = "14, 13,13,12,12,11,11";
        let cfg = exp_config(&parse_flags(&["--topology".into(), spec.into()])).unwrap();
        assert_eq!(cfg.topology, Some(vec![14, 13, 13, 12, 12, 11, 11]));
        // bad shapes error at parse time…
        assert!(exp_config(&parse_flags(&["--topology".into(), "9,x".into()])).is_err());
        assert!(exp_config(&parse_flags(&["--topology".into(), "9,0".into()])).is_err());
        // …and shape-valid but family-infeasible specs error at validation
        // (3 clusters of 3 cannot place any S42 family) instead of
        // panicking inside build_dss
        assert!(exp_config(&parse_flags(&["--topology".into(), "3,3,3".into()])).is_err());
    }

    #[test]
    fn elastic_flags_parse_and_override_defaults() {
        let f = parse_flags(&[
            "--add-nodes".into(),
            "3".into(),
            "--drain-nodes".into(),
            "0".into(),
            "--cluster-nodes".into(),
            "5".into(),
            "--fault-horizon-hours".into(),
            "0".into(),
        ]);
        let ec = elastic_config(&f).unwrap();
        assert_eq!(ec.add_nodes, 3);
        assert_eq!(ec.drain_nodes, 0);
        assert_eq!(ec.cluster_nodes, 5);
        assert_eq!(ec.fault_horizon_hours, 0.0);
        let d = experiments::ElasticConfig::default();
        assert_eq!(ec.add_clusters, d.add_clusters, "unset knobs keep defaults");
        // a scenario with no events at all is rejected
        let none = parse_flags(&[
            "--add-nodes".into(),
            "0".into(),
            "--drain-nodes".into(),
            "0".into(),
            "--add-clusters".into(),
            "0".into(),
        ]);
        assert!(elastic_config(&none).is_err());
    }

    #[test]
    fn durability_flags_parse_and_override_defaults() {
        let f = parse_flags(&[
            "--wal-sync-every".into(),
            "1".into(),
            "--snapshot-every".into(),
            "16".into(),
            "--crash-cap".into(),
            "10".into(),
            "--fault-ops".into(),
            "2".into(),
        ]);
        let dc = durability_config(&f).unwrap();
        assert_eq!(dc.wal_sync_every, 1);
        assert_eq!(dc.snapshot_every, 16);
        assert_eq!(dc.crash_cap, 10);
        assert_eq!(dc.fault_ops, 2);
        // unset knobs keep their defaults
        let d = experiments::DurabilitySimConfig::default();
        assert_eq!(dc.add_nodes, d.add_nodes);
        assert_eq!(dc.drain_nodes, d.drain_nodes);
        // degenerate knobs are rejected up front
        assert!(durability_config(&parse_flags(&["--wal-sync-every".into(), "0".into()]))
            .is_err());
        assert!(durability_config(&parse_flags(&["--snapshot-every".into(), "0".into()]))
            .is_err());
    }

    #[test]
    fn migration_flags_parse_and_override_defaults() {
        let f = parse_flags(&[
            "--migrate-rate-mbps".into(),
            "100".into(),
            "--migrate-burst".into(),
            "256".into(),
            "--backoff-base-ms".into(),
            "5".into(),
            "--max-attempts".into(),
            "3".into(),
            "--fg-reads".into(),
            "16".into(),
        ]);
        let mc = migration_config(&f).unwrap();
        assert_eq!(mc.rate_mbps, 100.0);
        assert_eq!(mc.burst_kb, 256);
        assert_eq!(mc.backoff_base_ms, 5.0);
        assert_eq!(mc.max_attempts, 3);
        assert_eq!(mc.fg_reads, 16);
        // unset knobs keep their defaults
        let d = experiments::MigrationSimConfig::default();
        assert_eq!(mc.backoff_cap_ms, d.backoff_cap_ms);
        assert_eq!(mc.crash_cap, d.crash_cap);
        // degenerate knobs are rejected up front
        assert!(migration_config(&parse_flags(&["--migrate-rate-mbps".into(), "0".into()]))
            .is_err());
        assert!(migration_config(&parse_flags(&["--migrate-burst".into(), "0".into()])).is_err());
        // a cap below the base delay would make backoff regress instantly
        let bad = parse_flags(&[
            "--backoff-base-ms".into(),
            "50".into(),
            "--backoff-cap-ms".into(),
            "10".into(),
        ]);
        assert!(migration_config(&bad).is_err());
        assert!(migration_config(&parse_flags(&["--max-attempts".into(), "0".into()])).is_err());
    }

    #[test]
    fn serve_flags_parse_and_override_defaults() {
        let f = parse_flags(&[
            "--data-addr".into(),
            "127.0.0.1:4700".into(),
            "--stripes".into(),
            "8".into(),
            "--block-kb".into(),
            "32".into(),
            "--fail-nodes".into(),
            "2".into(),
            "--per-tenant".into(),
            "16".into(),
            "--repair-mbps".into(),
            "80".into(),
        ]);
        let sc = serve_config(&f).unwrap();
        assert_eq!(sc.data_addr, "127.0.0.1:4700");
        assert_eq!(sc.stripes, 8);
        assert_eq!(sc.block_size, 32 * 1024);
        assert_eq!(sc.fail_nodes, 2);
        assert_eq!(sc.admission.per_tenant, 16);
        assert!((sc.admission.repair_rate_bps - 80.0 * 1024.0 * 1024.0 / 8.0).abs() < 1e-6);
        // unset knobs keep their defaults
        let d = crate::serve::ServeConfig::default();
        assert_eq!(sc.http_addr, d.http_addr);
        assert_eq!(sc.seed, d.seed);
        assert!(sc.wal_dir.is_none());
        // degenerate knobs are rejected up front
        assert!(serve_config(&parse_flags(&["--stripes".into(), "0".into()])).is_err());
        assert!(serve_config(&parse_flags(&["--per-tenant".into(), "0".into()])).is_err());
        assert!(serve_config(&parse_flags(&["--repair-mbps".into(), "0".into()])).is_err());
    }

    #[test]
    fn loadgen_flags_parse_and_gate_args() {
        let f = parse_flags(&[
            "--sessions".into(),
            "4".into(),
            "--duration-s".into(),
            "2.5".into(),
            "--pipeline".into(),
            "8".into(),
            "--topology-at-s".into(),
            "1".into(),
            "--assert-p99-ms".into(),
            "250".into(),
            "--expect-redirect".into(),
        ]);
        let (lc, p99, redirect) = loadgen_config(&f).unwrap();
        assert_eq!(lc.sessions, 4);
        assert_eq!(lc.pipeline, 8);
        assert!((lc.duration.as_secs_f64() - 2.5).abs() < 1e-9);
        assert_eq!(lc.topology_event_at, Some(std::time::Duration::from_secs(1)));
        assert_eq!(p99, Some(250.0));
        assert!(redirect);
        // steady-state defaults: no event, no latency gate
        let (d, p, r) = loadgen_config(&HashMap::new()).unwrap();
        assert!(d.topology_event_at.is_none());
        assert!(p.is_none());
        assert!(!r);
        // degenerate knobs are rejected up front
        assert!(loadgen_config(&parse_flags(&["--sessions".into(), "0".into()])).is_err());
        assert!(loadgen_config(&parse_flags(&["--duration-s".into(), "0".into()])).is_err());
        assert!(loadgen_config(&parse_flags(&["--assert-p99-ms".into(), "0".into()])).is_err());
    }

    #[test]
    fn bad_gf_kernel_errors() {
        assert!(exp_config(&parse_flags(&["--gf-kernel".into(), "mmx".into()])).is_err());
    }

    #[test]
    fn gf_nt_and_pin_flags_parse() {
        // bad nt grammar is rejected before any engine install
        assert!(exp_config(&parse_flags(&["--gf-nt-kb".into(), "banana".into()])).is_err());
        // boolean flag spellings: bare flag → "true", explicit on/off forms
        assert!(parse_bool_flag("--gf-pin", "true").unwrap());
        assert!(parse_bool_flag("--gf-pin", "1").unwrap());
        assert!(!parse_bool_flag("--gf-pin", "off").unwrap());
        assert!(!parse_bool_flag("--gf-pin", "0").unwrap());
        assert!(parse_bool_flag("--gf-pin", "maybe").is_err());
    }

    #[test]
    fn golden_emits_three_lines() {
        let path = std::env::temp_dir().join(format!("unilrc_golden_{}.txt", std::process::id()));
        let f = parse_flags(&["--out".into(), path.to_str().unwrap().into()]);
        cmd_golden(&f).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.starts_with("1 6 "));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bad_command_errors() {
        assert!(dispatch(&["nope".into()]).is_err());
    }

    #[test]
    fn bad_scheme_errors() {
        assert!(scheme_of(&parse_flags(&["--scheme".into(), "99".into()])).is_err());
    }
}
