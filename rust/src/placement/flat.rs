//! Topology-oblivious round-robin placement — the "no topology locality"
//! strawman used in ablations (what a flat DHT-style DSS would do).

use super::{PlacementStrategy, Topology};
use crate::codes::Code;

#[derive(Debug, Clone, Copy, Default)]
pub struct FlatPlace;

impl PlacementStrategy for FlatPlace {
    fn name(&self) -> &'static str {
        "flat-round-robin"
    }

    fn assign_clusters(&self, code: &Code, topo: &Topology, stripe_idx: usize) -> Vec<usize> {
        let open = topo.open_clusters();
        (0..code.n()).map(|b| open[(b + stripe_idx) % open.len()]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::spec::{CodeFamily, Scheme};

    #[test]
    fn spreads_evenly() {
        let code = Scheme::S42.build(CodeFamily::UniLrc);
        let topo = Topology::new(6, 8);
        let p = FlatPlace.place(&code, &topo, 0);
        for c in 0..6 {
            assert_eq!(p.blocks_in_cluster(c).len(), 7);
        }
    }

    #[test]
    fn repairs_cross_clusters() {
        // the ablation point: flat placement forces cross-cluster repair
        let code = Scheme::S42.build(CodeFamily::UniLrc);
        let topo = Topology::new(6, 8);
        let p = FlatPlace.place(&code, &topo, 0);
        let plan = code.repair_plan(0);
        let home = p.cluster_of[0];
        assert!(plan.sources.iter().any(|&s| p.cluster_of[s] != home));
    }

    #[test]
    fn may_break_cluster_tolerance() {
        // documents *why* flat placement is wrong for wide LRCs: some
        // cluster's loss is unrecoverable.
        let code = Scheme::S42.build(CodeFamily::UniLrc);
        let topo = Topology::new(3, 16);
        let p = FlatPlace.place(&code, &topo, 0);
        // 14 blocks per cluster > n − k = 12 parities ⇒ guaranteed data loss
        let any_bad = (0..3).any(|c| !code.can_decode(&p.blocks_in_cluster(c)));
        assert!(any_bad);
    }
}
