//! Cluster topology and stripe placement (§2.3.2 *topology locality*).
//!
//! A [`Topology`] is a two-tier DSS: clusters of nodes with fast
//! inner-cluster links and an oversubscribed gateway per cluster. Unlike
//! the original frozen `(clusters, nodes_per_cluster)` pair, the topology
//! is *elastic*: clusters may have different sizes, every node carries a
//! lifecycle state ([`NodeState`]), and [`TopologyEvent`]s (scale-out,
//! drain, decommission) mutate it at runtime — the coordinator's
//! migration scheduler ([`crate::coordinator::migrate`]) moves blocks to
//! follow.
//!
//! A [`PlacementStrategy`] maps each block of a stripe to a
//! (cluster, node) pair:
//!
//! * [`unilrc_place::UniLrcPlace`] — the paper's "one local group, one
//!   cluster" deployment (§3.1/Fig 4).
//! * [`ecwide::EcWide`] — the FAST'21 baseline placement used for
//!   ALRC/OLRC/ULRC: pack each local group into the minimum number of
//!   clusters with at most `g+1` stripe blocks per cluster.
//! * [`flat::FlatPlace`] — topology-oblivious round-robin (ablation).
//!
//! All strategies must keep one-cluster-failure tolerance (verified by
//! integration tests: erasing any whole cluster's blocks decodes), and
//! the migration scheduler must preserve it across every move.

pub mod ecwide;
pub mod flat;
pub mod unilrc_place;

pub use ecwide::EcWide;
pub use flat::FlatPlace;
pub use unilrc_place::{UniLrcPlace, UniLrcSpread};

use crate::codes::Code;

/// Lifecycle state of a storage node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeState {
    /// Freshly added by a scale-out event; receives migrated blocks but no
    /// new stripe placements until activated.
    Joining,
    /// Serving — placement target and repair source.
    Active,
    /// Being emptied by the migration scheduler; still readable, no longer
    /// a placement or migration target.
    Draining,
    /// Decommissioned. Never reused; node ids are stable forever.
    Dead,
}

impl NodeState {
    /// Stable one-byte tag for the durability layer (manifest/WAL
    /// encoding). Tags are part of the on-disk format — never renumber.
    pub fn tag(self) -> u8 {
        match self {
            NodeState::Joining => 0,
            NodeState::Active => 1,
            NodeState::Draining => 2,
            NodeState::Dead => 3,
        }
    }

    /// Inverse of [`NodeState::tag`]; `None` for unknown tags (corrupt or
    /// future-version records).
    pub fn from_tag(tag: u8) -> Option<NodeState> {
        match tag {
            0 => Some(NodeState::Joining),
            1 => Some(NodeState::Active),
            2 => Some(NodeState::Draining),
            3 => Some(NodeState::Dead),
            _ => None,
        }
    }
}

/// A topology mutation — the system events of the paper's "frequent
/// system events" scenario family. Applied by
/// [`crate::coordinator::Dss::apply_topology_event`], which also plans and
/// executes the block migration that keeps placement invariants true.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TopologyEvent {
    /// Add one node to an existing cluster (intra-cluster rebalance).
    AddNode { cluster: usize },
    /// Drain a node: move every block off it, then mark it dead.
    DrainNode { node: usize },
    /// Add a whole new cluster of `nodes` nodes (cross-cluster rebalance).
    AddCluster { nodes: usize },
    /// Retire a cluster: relocate every block it hosts, then kill it.
    DecommissionCluster { cluster: usize },
}

/// Two-tier cluster topology with variable-size clusters and per-node
/// lifecycle states. Node ids are stable: adding nodes allocates fresh
/// ids, draining / decommissioning marks ids [`NodeState::Dead`] but never
/// reassigns them — so block maps, fault clocks and network meters keyed
/// by node id survive every topology event.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Topology {
    /// cluster → member node ids in slot order (all lifecycle states).
    members: Vec<Vec<usize>>,
    /// node id → owning cluster.
    cluster_of: Vec<usize>,
    /// node id → lifecycle state.
    states: Vec<NodeState>,
    /// cluster → closed to new placements (decommissioned).
    retired: Vec<bool>,
}

impl Topology {
    /// Uniform topology: `clusters` clusters of `nodes_per_cluster` active
    /// nodes each, numbered cluster-major (the original frozen shape).
    pub fn new(clusters: usize, nodes_per_cluster: usize) -> Topology {
        assert!(clusters > 0 && nodes_per_cluster > 0);
        Self::with_cluster_sizes(&vec![nodes_per_cluster; clusters])
    }

    /// Asymmetric topology from explicit per-cluster sizes
    /// (`--topology 8,8,4,4`).
    pub fn with_cluster_sizes(sizes: &[usize]) -> Topology {
        assert!(!sizes.is_empty() && sizes.iter().all(|&s| s > 0), "clusters must be non-empty");
        let mut members = Vec::with_capacity(sizes.len());
        let mut cluster_of = Vec::new();
        let mut next = 0usize;
        for (c, &s) in sizes.iter().enumerate() {
            members.push((next..next + s).collect());
            cluster_of.extend(std::iter::repeat(c).take(s));
            next += s;
        }
        Topology {
            members,
            states: vec![NodeState::Active; cluster_of.len()],
            cluster_of,
            retired: vec![false; sizes.len()],
        }
    }

    /// Rebuild a topology from its persisted parts (manifest recovery).
    ///
    /// `members` is derived, not stored: every construction path
    /// ([`Topology::with_cluster_sizes`], [`Topology::add_node`],
    /// [`Topology::add_cluster`]) appends fresh (maximal) node ids, so a
    /// cluster's member list is always its owned ids in increasing order —
    /// scanning `cluster_of` reproduces it exactly. Callers must have
    /// validated the parts (see `CoordinatorState::prove_invariants`);
    /// this constructor only asserts basic shape.
    pub fn from_parts(
        cluster_of: Vec<usize>,
        states: Vec<NodeState>,
        retired: Vec<bool>,
    ) -> Topology {
        assert_eq!(cluster_of.len(), states.len(), "one state per node");
        assert!(!retired.is_empty(), "at least one cluster");
        let mut members: Vec<Vec<usize>> = vec![Vec::new(); retired.len()];
        for (node, &c) in cluster_of.iter().enumerate() {
            assert!(c < retired.len(), "cluster id out of range");
            members[c].push(node);
        }
        Topology { members, cluster_of, states, retired }
    }

    /// Number of clusters (including retired ones — cluster ids are stable).
    pub fn clusters(&self) -> usize {
        self.members.len()
    }

    /// Total node ids ever allocated (including dead nodes).
    pub fn total_nodes(&self) -> usize {
        self.cluster_of.len()
    }

    /// Cluster that owns a (global) node id.
    pub fn cluster_of_node(&self, node: usize) -> usize {
        assert!(node < self.total_nodes());
        self.cluster_of[node]
    }

    /// Global node id from (cluster, slot).
    pub fn node_id(&self, cluster: usize, slot: usize) -> usize {
        assert!(cluster < self.clusters() && slot < self.members[cluster].len());
        self.members[cluster][slot]
    }

    /// Node ids of a cluster (every lifecycle state).
    pub fn nodes_of(&self, cluster: usize) -> &[usize] {
        &self.members[cluster]
    }

    /// Member count of a cluster (every lifecycle state).
    pub fn cluster_size(&self, cluster: usize) -> usize {
        self.members[cluster].len()
    }

    /// Largest cluster member count.
    pub fn max_cluster_size(&self) -> usize {
        self.members.iter().map(|m| m.len()).max().unwrap_or(0)
    }

    /// Lifecycle state of a node.
    pub fn state(&self, node: usize) -> NodeState {
        self.states[node]
    }

    pub fn set_state(&mut self, node: usize, state: NodeState) {
        self.states[node] = state;
    }

    /// Node is a valid *placement* target for new stripes.
    pub fn is_active(&self, node: usize) -> bool {
        self.states[node] == NodeState::Active
    }

    /// Node may receive *migrated* blocks (joining nodes take blocks
    /// before they start taking new placements).
    pub fn is_migratable(&self, node: usize) -> bool {
        matches!(self.states[node], NodeState::Active | NodeState::Joining)
    }

    /// Node is not dead — it holds readable data and draws fault clocks.
    pub fn is_live(&self, node: usize) -> bool {
        self.states[node] != NodeState::Dead
    }

    /// Active node ids of a cluster, in slot order.
    pub fn active_nodes_of(&self, cluster: usize) -> Vec<usize> {
        self.members[cluster].iter().copied().filter(|&n| self.is_active(n)).collect()
    }

    /// Migration-target node ids of a cluster, in slot order.
    pub fn migratable_nodes_of(&self, cluster: usize) -> Vec<usize> {
        self.members[cluster].iter().copied().filter(|&n| self.is_migratable(n)).collect()
    }

    /// All live node ids (fault clocks tick exactly for these).
    pub fn live_nodes(&self) -> Vec<usize> {
        (0..self.total_nodes()).filter(|&n| self.is_live(n)).collect()
    }

    /// Clusters open to placement (not retired), in id order.
    pub fn open_clusters(&self) -> Vec<usize> {
        (0..self.clusters()).filter(|&c| !self.retired[c]).collect()
    }

    pub fn is_retired(&self, cluster: usize) -> bool {
        self.retired[cluster]
    }

    /// Close a cluster to placement (decommission).
    pub fn retire_cluster(&mut self, cluster: usize) {
        self.retired[cluster] = true;
    }

    /// Allocate a fresh node id in `cluster`, state [`NodeState::Joining`].
    pub fn add_node(&mut self, cluster: usize) -> usize {
        assert!(cluster < self.clusters() && !self.retired[cluster]);
        let id = self.cluster_of.len();
        self.cluster_of.push(cluster);
        self.states.push(NodeState::Joining);
        self.members[cluster].push(id);
        id
    }

    /// Allocate a fresh cluster of `nodes` joining nodes; returns its id.
    pub fn add_cluster(&mut self, nodes: usize) -> usize {
        assert!(nodes > 0);
        let c = self.members.len();
        self.members.push(Vec::with_capacity(nodes));
        self.retired.push(false);
        for _ in 0..nodes {
            let id = self.cluster_of.len();
            self.cluster_of.push(c);
            self.states.push(NodeState::Joining);
            self.members[c].push(id);
        }
        c
    }
}

/// Where each block of one stripe lives.
#[derive(Debug, Clone)]
pub struct Placement {
    /// Per block: cluster index.
    pub cluster_of: Vec<usize>,
    /// Per block: global node id.
    pub node_of: Vec<usize>,
}

impl Placement {
    /// Blocks hosted in `cluster` (O(n) scan — one-shot analysis helper;
    /// the sim/event hot paths use the precomputed per-cluster index on
    /// [`crate::coordinator::BlockMap`] instead).
    pub fn blocks_in_cluster(&self, cluster: usize) -> Vec<usize> {
        (0..self.cluster_of.len()).filter(|&b| self.cluster_of[b] == cluster).collect()
    }

    /// Number of distinct clusters used (O(n log n) — analysis helper; hot
    /// paths use [`crate::coordinator::BlockMap::clusters_used`]).
    pub fn clusters_used(&self) -> usize {
        let mut c: Vec<usize> = self.cluster_of.clone();
        c.sort_unstable();
        c.dedup();
        c.len()
    }

    /// Histogram of *data* blocks per cluster (for LBNR).
    pub fn data_per_cluster(&self, code: &Code, clusters: usize) -> Vec<usize> {
        let mut h = vec![0usize; clusters];
        for b in 0..code.k() {
            h[self.cluster_of[b]] += 1;
        }
        h
    }

    fn validate(&self, code: &Code, topo: &Topology) {
        assert_eq!(self.cluster_of.len(), code.n());
        assert_eq!(self.node_of.len(), code.n());
        for b in 0..code.n() {
            assert!(self.cluster_of[b] < topo.clusters(), "cluster out of range");
            assert_eq!(
                topo.cluster_of_node(self.node_of[b]),
                self.cluster_of[b],
                "node/cluster mismatch for block {b}"
            );
        }
        // no two blocks of one stripe on the same node
        let mut nodes = self.node_of.clone();
        nodes.sort_unstable();
        nodes.dedup();
        assert_eq!(nodes.len(), code.n(), "two blocks share a node");
    }
}

/// A stripe-placement policy.
pub trait PlacementStrategy {
    /// Name for reports.
    fn name(&self) -> &'static str;

    /// Assign clusters to every block of `code`'s stripe. `stripe_idx`
    /// rotates assignments so consecutive stripes spread load. Strategies
    /// must only use open (non-retired) clusters.
    fn assign_clusters(&self, code: &Code, topo: &Topology, stripe_idx: usize) -> Vec<usize>;

    /// Full placement: clusters via [`Self::assign_clusters`], then node
    /// slots within each cluster's *active* members (rotated by stripe so
    /// full-node recovery parallelizes across surviving nodes).
    fn place(&self, code: &Code, topo: &Topology, stripe_idx: usize) -> Placement {
        let cluster_of = self.assign_clusters(code, topo, stripe_idx);
        let active: Vec<Vec<usize>> =
            (0..topo.clusters()).map(|c| topo.active_nodes_of(c)).collect();
        let mut next_slot = vec![0usize; topo.clusters()];
        let mut node_of = vec![0usize; code.n()];
        for b in 0..code.n() {
            let c = cluster_of[b];
            let slots = &active[c];
            assert!(
                next_slot[c] < slots.len(),
                "{}: cluster {c} overflows its {} active nodes",
                self.name(),
                slots.len()
            );
            let slot = (next_slot[c] + stripe_idx) % slots.len();
            node_of[b] = slots[slot];
            next_slot[c] += 1;
        }
        let p = Placement { cluster_of, node_of };
        p.validate(code, topo);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topology_node_math() {
        let t = Topology::new(6, 8);
        assert_eq!(t.total_nodes(), 48);
        assert_eq!(t.clusters(), 6);
        assert_eq!(t.cluster_of_node(0), 0);
        assert_eq!(t.cluster_of_node(47), 5);
        assert_eq!(t.node_id(2, 3), 19);
        assert_eq!(t.nodes_of(1), &(8..16).collect::<Vec<_>>()[..]);
        assert_eq!(t.cluster_size(1), 8);
    }

    #[test]
    #[should_panic]
    fn node_out_of_range_panics() {
        Topology::new(2, 4).cluster_of_node(8);
    }

    #[test]
    fn asymmetric_clusters() {
        let t = Topology::with_cluster_sizes(&[3, 5, 2]);
        assert_eq!(t.clusters(), 3);
        assert_eq!(t.total_nodes(), 10);
        assert_eq!(t.cluster_size(0), 3);
        assert_eq!(t.cluster_size(1), 5);
        assert_eq!(t.nodes_of(2), &[8, 9]);
        assert_eq!(t.cluster_of_node(7), 1);
        assert_eq!(t.max_cluster_size(), 5);
    }

    #[test]
    fn node_lifecycle_and_scale_out() {
        let mut t = Topology::new(2, 3);
        assert!(t.is_active(0) && t.is_live(0));
        // scale-out: fresh id, joining state, migratable but not placeable
        let n = t.add_node(1);
        assert_eq!(n, 6);
        assert_eq!(t.cluster_of_node(n), 1);
        assert_eq!(t.state(n), NodeState::Joining);
        assert!(t.is_migratable(n) && !t.is_active(n));
        assert_eq!(t.active_nodes_of(1), vec![3, 4, 5]);
        assert_eq!(t.migratable_nodes_of(1), vec![3, 4, 5, 6]);
        t.set_state(n, NodeState::Active);
        assert_eq!(t.active_nodes_of(1), vec![3, 4, 5, 6]);
        // drain: still live (readable) but neither placeable nor migratable
        t.set_state(0, NodeState::Draining);
        assert!(t.is_live(0) && !t.is_active(0) && !t.is_migratable(0));
        assert_eq!(t.active_nodes_of(0), vec![1, 2]);
        t.set_state(0, NodeState::Dead);
        assert!(!t.is_live(0));
        assert!(!t.live_nodes().contains(&0));
        assert_eq!(t.total_nodes(), 7, "dead ids are never reused");
    }

    #[test]
    fn state_tags_round_trip() {
        for s in [NodeState::Joining, NodeState::Active, NodeState::Draining, NodeState::Dead] {
            assert_eq!(NodeState::from_tag(s.tag()), Some(s));
        }
        assert_eq!(NodeState::from_tag(4), None);
    }

    #[test]
    fn from_parts_round_trips_mutated_topology() {
        let mut t = Topology::new(3, 4);
        t.add_node(1);
        t.add_cluster(2);
        t.set_state(0, NodeState::Dead);
        t.set_state(5, NodeState::Draining);
        t.retire_cluster(2);
        let cluster_of: Vec<usize> =
            (0..t.total_nodes()).map(|n| t.cluster_of_node(n)).collect();
        let states: Vec<NodeState> = (0..t.total_nodes()).map(|n| t.state(n)).collect();
        let retired: Vec<bool> = (0..t.clusters()).map(|c| t.is_retired(c)).collect();
        let rebuilt = Topology::from_parts(cluster_of, states, retired);
        assert_eq!(rebuilt, t);
    }

    #[test]
    fn add_and_retire_cluster() {
        let mut t = Topology::new(2, 2);
        let c = t.add_cluster(3);
        assert_eq!(c, 2);
        assert_eq!(t.clusters(), 3);
        assert_eq!(t.nodes_of(2), &[4, 5, 6]);
        assert!(t.nodes_of(2).iter().all(|&n| t.state(n) == NodeState::Joining));
        assert_eq!(t.open_clusters(), vec![0, 1, 2]);
        t.retire_cluster(0);
        assert!(t.is_retired(0));
        assert_eq!(t.open_clusters(), vec![1, 2]);
    }
}
