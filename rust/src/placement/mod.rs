//! Cluster topology and stripe placement (§2.3.2 *topology locality*).
//!
//! A [`Topology`] is a two-tier DSS: `z` clusters of `nodes_per_cluster`
//! nodes each, with fast inner-cluster links and an oversubscribed gateway
//! per cluster. A [`PlacementStrategy`] maps each block of a stripe to a
//! (cluster, node) pair:
//!
//! * [`unilrc_place::UniLrcPlace`] — the paper's "one local group, one
//!   cluster" deployment (§3.1/Fig 4).
//! * [`ecwide::EcWide`] — the FAST'21 baseline placement used for
//!   ALRC/OLRC/ULRC: pack each local group into the minimum number of
//!   clusters with at most `g+1` stripe blocks per cluster.
//! * [`flat::FlatPlace`] — topology-oblivious round-robin (ablation).
//!
//! All strategies must keep one-cluster-failure tolerance (verified by
//! integration tests: erasing any whole cluster's blocks decodes).

pub mod ecwide;
pub mod flat;
pub mod unilrc_place;

pub use ecwide::EcWide;
pub use flat::FlatPlace;
pub use unilrc_place::{UniLrcPlace, UniLrcSpread};

use crate::codes::Code;

/// Two-tier cluster topology.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Topology {
    pub clusters: usize,
    pub nodes_per_cluster: usize,
}

impl Topology {
    pub fn new(clusters: usize, nodes_per_cluster: usize) -> Topology {
        assert!(clusters > 0 && nodes_per_cluster > 0);
        Topology { clusters, nodes_per_cluster }
    }

    pub fn total_nodes(&self) -> usize {
        self.clusters * self.nodes_per_cluster
    }

    /// Cluster that owns a (global) node id.
    pub fn cluster_of_node(&self, node: usize) -> usize {
        assert!(node < self.total_nodes());
        node / self.nodes_per_cluster
    }

    /// Global node id from (cluster, slot).
    pub fn node_id(&self, cluster: usize, slot: usize) -> usize {
        assert!(cluster < self.clusters && slot < self.nodes_per_cluster);
        cluster * self.nodes_per_cluster + slot
    }

    /// Node ids of a cluster.
    pub fn nodes_of(&self, cluster: usize) -> std::ops::Range<usize> {
        cluster * self.nodes_per_cluster..(cluster + 1) * self.nodes_per_cluster
    }
}

/// Where each block of one stripe lives.
#[derive(Debug, Clone)]
pub struct Placement {
    /// Per block: cluster index.
    pub cluster_of: Vec<usize>,
    /// Per block: global node id.
    pub node_of: Vec<usize>,
}

impl Placement {
    /// Blocks hosted in `cluster`.
    pub fn blocks_in_cluster(&self, cluster: usize) -> Vec<usize> {
        (0..self.cluster_of.len()).filter(|&b| self.cluster_of[b] == cluster).collect()
    }

    /// Number of distinct clusters used.
    pub fn clusters_used(&self) -> usize {
        let mut c: Vec<usize> = self.cluster_of.clone();
        c.sort_unstable();
        c.dedup();
        c.len()
    }

    /// Histogram of *data* blocks per cluster (for LBNR).
    pub fn data_per_cluster(&self, code: &Code, clusters: usize) -> Vec<usize> {
        let mut h = vec![0usize; clusters];
        for b in 0..code.k() {
            h[self.cluster_of[b]] += 1;
        }
        h
    }

    fn validate(&self, code: &Code, topo: &Topology) {
        assert_eq!(self.cluster_of.len(), code.n());
        assert_eq!(self.node_of.len(), code.n());
        for b in 0..code.n() {
            assert!(self.cluster_of[b] < topo.clusters, "cluster out of range");
            assert_eq!(
                topo.cluster_of_node(self.node_of[b]),
                self.cluster_of[b],
                "node/cluster mismatch for block {b}"
            );
        }
        // no two blocks of one stripe on the same node
        let mut nodes = self.node_of.clone();
        nodes.sort_unstable();
        nodes.dedup();
        assert_eq!(nodes.len(), code.n(), "two blocks share a node");
    }
}

/// A stripe-placement policy.
pub trait PlacementStrategy {
    /// Name for reports.
    fn name(&self) -> &'static str;

    /// Assign clusters to every block of `code`'s stripe. `stripe_idx`
    /// rotates assignments so consecutive stripes spread load.
    fn assign_clusters(&self, code: &Code, topo: &Topology, stripe_idx: usize) -> Vec<usize>;

    /// Full placement: clusters via [`Self::assign_clusters`], then node
    /// slots within each cluster (rotated by stripe so full-node recovery
    /// parallelizes across surviving nodes).
    fn place(&self, code: &Code, topo: &Topology, stripe_idx: usize) -> Placement {
        let cluster_of = self.assign_clusters(code, topo, stripe_idx);
        let mut next_slot = vec![0usize; topo.clusters];
        let mut node_of = vec![0usize; code.n()];
        for b in 0..code.n() {
            let c = cluster_of[b];
            let slot = (next_slot[c] + stripe_idx) % topo.nodes_per_cluster;
            assert!(
                next_slot[c] < topo.nodes_per_cluster,
                "{}: cluster {c} overflows its {} nodes",
                self.name(),
                topo.nodes_per_cluster
            );
            node_of[b] = topo.node_id(c, slot);
            next_slot[c] += 1;
        }
        let p = Placement { cluster_of, node_of };
        p.validate(code, topo);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topology_node_math() {
        let t = Topology::new(6, 8);
        assert_eq!(t.total_nodes(), 48);
        assert_eq!(t.cluster_of_node(0), 0);
        assert_eq!(t.cluster_of_node(47), 5);
        assert_eq!(t.node_id(2, 3), 19);
        assert_eq!(t.nodes_of(1), 8..16);
    }

    #[test]
    #[should_panic]
    fn node_out_of_range_panics() {
        Topology::new(2, 4).cluster_of_node(8);
    }
}
