//! ECWide placement (Hu et al., FAST'21) — the state-of-the-art
//! topology-aware baseline the paper evaluates ALRC/OLRC/ULRC under (§2.3.2).
//!
//! Core idea (*combined locality*): pack blocks into the minimum number of
//! clusters while tolerating one cluster failure — a cluster may hold at
//! most `g+1` blocks of a stripe, all from the same local group (losing
//! them leaves ≤ g+1 erasures concentrated in one group, which the g
//! globals + that group's surviving structure can repair). Each local group
//! of size `s` therefore spans `⌈s/(g+1)⌉` clusters; blocks outside any
//! group (ALRC/OLRC global parities under exclusive ownership) are packed
//! `g+1` per cluster as their own chunks.
//!
//! The one-cluster-failure invariant is verified code-by-code in
//! integration tests (erase each cluster, assert decodable).

use super::{PlacementStrategy, Topology};
use crate::codes::Code;

/// ECWide-style minimum-cluster packing.
#[derive(Debug, Clone, Copy, Default)]
pub struct EcWide;

impl EcWide {
    /// Split the stripe into cluster-sized chunks (the cluster count is the
    /// chunk count). Exposed for the analysis module.
    pub fn chunks(code: &Code) -> Vec<Vec<usize>> {
        let cap = code.global_parities().len() + 1;
        let mut owned = vec![false; code.n()];
        let mut chunks = Vec::new();
        for grp in code.groups() {
            // exclusive ownership: skip blocks already owned by an earlier
            // (overlapping) group — OLRC's shared globals.
            let members: Vec<usize> =
                grp.members.iter().copied().filter(|&m| !owned[m]).collect();
            for &m in &members {
                owned[m] = true;
            }
            for chunk in members.chunks(cap) {
                chunks.push(chunk.to_vec());
            }
        }
        // ungrouped blocks (ALRC globals): pack together, g+1 per cluster
        let rest: Vec<usize> = (0..code.n()).filter(|&b| !owned[b]).collect();
        for chunk in rest.chunks(cap) {
            chunks.push(chunk.to_vec());
        }
        chunks
    }

    /// Minimum number of clusters ECWide needs for this code.
    pub fn clusters_needed(code: &Code) -> usize {
        Self::chunks(code).len()
    }
}

impl PlacementStrategy for EcWide {
    fn name(&self) -> &'static str {
        "ecwide"
    }

    fn assign_clusters(&self, code: &Code, topo: &Topology, stripe_idx: usize) -> Vec<usize> {
        let chunks = Self::chunks(code);
        let open = topo.open_clusters();
        assert!(
            open.len() >= chunks.len(),
            "ECWide needs {} clusters for {}, topology has {} open",
            chunks.len(),
            code.name(),
            open.len()
        );
        let mut cluster_of = vec![usize::MAX; code.n()];
        for (ci, chunk) in chunks.iter().enumerate() {
            let c = open[(ci + stripe_idx) % open.len()];
            for &b in chunk {
                cluster_of[b] = c;
            }
        }
        cluster_of
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::spec::{CodeFamily, Scheme};

    #[test]
    fn ulrc_42_chunking_matches_fig2() {
        // Fig 2(a): sizes {8,8,8,9,9}, cap g+1=8 ⇒ three 1-cluster groups,
        // two groups split 8+1 ⇒ 7 clusters.
        let code = Scheme::S42.build(CodeFamily::Ulrc);
        let chunks = EcWide::chunks(&code);
        let sizes: Vec<usize> = chunks.iter().map(|c| c.len()).collect();
        assert_eq!(sizes, vec![8, 8, 8, 8, 1, 8, 1]);
        assert_eq!(EcWide::clusters_needed(&code), 7);
    }

    #[test]
    fn alrc_42_chunking() {
        // 6 groups of 6 (≤7 ⇒ one cluster each) + 6 globals in one cluster
        let code = Scheme::S42.build(CodeFamily::Alrc);
        let sizes: Vec<usize> = EcWide::chunks(&code).iter().map(|c| c.len()).collect();
        assert_eq!(sizes, vec![6, 6, 6, 6, 6, 6, 6]);
        assert_eq!(EcWide::clusters_needed(&code), 7);
    }

    #[test]
    fn olrc_42_large_groups_span_clusters() {
        // Limitation: OLRC's 26-member group must span ≥3 clusters (cap 11)
        let code = Scheme::S42.build(CodeFamily::Olrc);
        let chunks = EcWide::chunks(&code);
        assert!(chunks.iter().any(|c| c.len() == 11));
        // every block placed exactly once despite overlapping groups
        let mut all: Vec<usize> = chunks.into_iter().flatten().collect();
        all.sort_unstable();
        assert_eq!(all, (0..42).collect::<Vec<_>>());
    }

    #[test]
    fn placement_covers_all_blocks() {
        for fam in CodeFamily::paper_baselines() {
            let code = Scheme::S42.build(fam);
            let need = EcWide::clusters_needed(&code);
            let topo = Topology::new(need, 16);
            let p = EcWide.place(&code, &topo, 0);
            assert_eq!(p.clusters_used(), need, "{fam:?}");
        }
    }

    #[test]
    fn one_cluster_failure_tolerated_all_families_and_schemes() {
        // the ECWide correctness invariant
        for scheme in Scheme::paper_schemes() {
            for fam in CodeFamily::paper_baselines() {
                let code = scheme.build(fam);
                let need = EcWide::clusters_needed(&code);
                let topo = Topology::new(need, 32);
                let p = EcWide.place(&code, &topo, 0);
                for c in 0..need {
                    let lost = p.blocks_in_cluster(c);
                    assert!(
                        code.can_decode(&lost),
                        "{fam:?} {} cluster {c} loss ({} blocks) unrecoverable",
                        scheme.label(),
                        lost.len()
                    );
                }
            }
        }
    }

    #[test]
    fn rotation_shifts_chunks() {
        let code = Scheme::S42.build(CodeFamily::Ulrc);
        let topo = Topology::new(8, 16);
        let p0 = EcWide.place(&code, &topo, 0);
        let p1 = EcWide.place(&code, &topo, 5);
        assert_ne!(p0.cluster_of, p1.cluster_of);
    }
}
