//! UniLRC's native deployment: one local group → one cluster (§3.1, Fig 4).
//!
//! Every block belongs to exactly one group, every group maps to exactly one
//! cluster, so *all* repairs are cluster-local (zero cross-cluster traffic,
//! Property 2) and the k data blocks are spread `k/z` per cluster
//! (maximum normal-read parallelism, Property 1).

use super::{PlacementStrategy, Topology};
use crate::codes::Code;

/// "One local group, one cluster" placement. Requires the code's groups to
/// partition the stripe (true for UniLRC and ULRC) and `topo.clusters() ≥
/// number of groups`.
#[derive(Debug, Clone, Copy, Default)]
pub struct UniLrcPlace;

impl PlacementStrategy for UniLrcPlace {
    fn name(&self) -> &'static str {
        "one-group-one-cluster"
    }

    fn assign_clusters(&self, code: &Code, topo: &Topology, stripe_idx: usize) -> Vec<usize> {
        let z = code.groups().len();
        assert!(z > 0, "{} requires local groups", self.name());
        let open = topo.open_clusters();
        assert!(
            open.len() >= z,
            "need ≥ {z} open clusters for {}",
            code.name()
        );
        let mut cluster_of = vec![usize::MAX; code.n()];
        for (gi, grp) in code.groups().iter().enumerate() {
            // rotate group→cluster by stripe so stripes spread over clusters
            let c = open[(gi + stripe_idx) % open.len()];
            for &m in &grp.members {
                assert!(
                    cluster_of[m] == usize::MAX || cluster_of[m] == c,
                    "{}: overlapping groups cannot map to clusters",
                    code.name()
                );
                cluster_of[m] = c;
            }
        }
        assert!(
            cluster_of.iter().all(|&c| c != usize::MAX),
            "{}: some block not covered by any group",
            code.name()
        );
        cluster_of
    }
}

/// The §3.3 Discussion deployment for relaxed UniLRC: each local group
/// spans exactly `t` consecutive clusters (members dealt round-robin), so
/// a repair touches `t−1` remote clusters — one aggregated block each.
#[derive(Debug, Clone, Copy)]
pub struct UniLrcSpread {
    pub t: usize,
}

impl PlacementStrategy for UniLrcSpread {
    fn name(&self) -> &'static str {
        "one-group-t-clusters"
    }

    fn assign_clusters(&self, code: &Code, topo: &Topology, stripe_idx: usize) -> Vec<usize> {
        let l = code.groups().len();
        assert!(l > 0, "{} requires local groups", self.name());
        let open = topo.open_clusters();
        assert!(
            open.len() >= l * self.t,
            "need ≥ {} open clusters for {} with t={}",
            l * self.t,
            code.name(),
            self.t
        );
        let mut cluster_of = vec![usize::MAX; code.n()];
        for (gi, grp) in code.groups().iter().enumerate() {
            for (mi, &m) in grp.members.iter().enumerate() {
                let c = open[(gi * self.t + mi % self.t + stripe_idx) % open.len()];
                assert!(cluster_of[m] == usize::MAX, "overlapping groups");
                cluster_of[m] = c;
            }
        }
        assert!(cluster_of.iter().all(|&c| c != usize::MAX), "uncovered block");
        cluster_of
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::spec::{CodeFamily, Scheme};
    use crate::placement::Placement;

    #[test]
    fn unilrc_42_uses_6_clusters_uniformly() {
        let code = Scheme::S42.build(CodeFamily::UniLrc);
        let topo = Topology::new(6, 8);
        let p = UniLrcPlace.place(&code, &topo, 0);
        assert_eq!(p.clusters_used(), 6);
        // 7 blocks per cluster, 5 data per cluster (Property 1)
        for c in 0..6 {
            assert_eq!(p.blocks_in_cluster(c).len(), 7);
        }
        assert_eq!(p.data_per_cluster(&code, 6), vec![5; 6]);
    }

    #[test]
    fn all_repairs_cluster_local() {
        let code = Scheme::S42.build(CodeFamily::UniLrc);
        let topo = Topology::new(6, 8);
        let p = UniLrcPlace.place(&code, &topo, 3);
        for b in 0..code.n() {
            let plan = code.repair_plan(b);
            let home = p.cluster_of[b];
            assert!(
                plan.sources.iter().all(|&s| p.cluster_of[s] == home),
                "block {b} repair crosses clusters"
            );
        }
    }

    #[test]
    fn stripe_rotation_moves_groups() {
        let code = Scheme::S42.build(CodeFamily::UniLrc);
        let topo = Topology::new(6, 8);
        let p0 = UniLrcPlace.place(&code, &topo, 0);
        let p1 = UniLrcPlace.place(&code, &topo, 1);
        assert_ne!(p0.cluster_of, p1.cluster_of);
        // rotation preserves the one-group-one-cluster structure
        assert_eq!(p1.clusters_used(), 6);
    }

    #[test]
    fn works_for_ulrc_partitioned_groups() {
        // ULRC's groups also partition the stripe, so the strategy applies
        // (used in ablations), just with uneven cluster loads.
        let code = Scheme::S42.build(CodeFamily::Ulrc);
        let topo = Topology::new(6, 16);
        let p = UniLrcPlace.place(&code, &topo, 0);
        assert_eq!(p.clusters_used(), 5);
    }

    #[test]
    #[should_panic]
    fn rejects_overlapping_groups() {
        // OLRC groups overlap on globals ⇒ cannot one-group-one-cluster
        let code = Scheme::S42.build(CodeFamily::Olrc);
        let topo = Topology::new(6, 32);
        UniLrcPlace.place(&code, &topo, 0);
    }

    #[test]
    fn spread_placement_cross_traffic_is_t_minus_1() {
        use crate::analysis::metrics::{cross_cost, CrossModel};
        use crate::codes::unilrc::UniLrc;
        let t = 2;
        let code = UniLrc::new_relaxed(1, 6, t);
        let topo = Topology::new(6, 16);
        let p = UniLrcSpread { t }.place(&code, &topo, 0);
        for b in 0..code.n() {
            let agg = cross_cost(&code, &p, b, CrossModel::Aggregated);
            assert_eq!(agg, t - 1, "block {b}: §3.3 claims t−1 cross blocks");
        }
    }

    #[test]
    fn spread_tolerates_one_cluster_failure() {
        use crate::codes::unilrc::UniLrc;
        let code = UniLrc::new_relaxed(1, 6, 2);
        let topo = Topology::new(6, 16);
        let p = UniLrcSpread { t: 2 }.place(&code, &topo, 0);
        for c in 0..6 {
            let lost = p.blocks_in_cluster(c);
            assert!(code.can_decode(&lost), "cluster {c} ({} blocks)", lost.len());
        }
    }

    #[test]
    #[should_panic]
    fn rejects_too_few_clusters() {
        let code = Scheme::S42.build(CodeFamily::UniLrc);
        UniLrcPlace.place(&code, &Topology::new(5, 8), 0);
    }
}
