//! The §6 system experiments (Experiments 1–6), shared by the CLI
//! (`unilrc experiment N`) and the bench harness (`cargo bench`), plus
//! Experiment 7 — the deterministic fault-injection scenario runner that
//! replays seeded failure schedules ([`crate::sim::faults`]) against the
//! prototype and cross-checks the measurements with the closed-form
//! reliability model ([`crate::analysis::markov`]).
//!
//! Each driver builds a DSS per code family on the virtual testbed
//! (DESIGN.md §5) and reports the same quantity the paper's figure plots.

use crate::analysis::markov;
use crate::client::workload::{Workload, WorkloadSpec};
use crate::client::{cdf_points, mean};
use crate::codes::spec::{CodeFamily, Scheme};
use crate::coordinator::{Dss, DssConfig, StripeId};
use crate::placement::{EcWide, PlacementStrategy, Topology, UniLrcPlace};
use crate::prng::Prng;
use crate::runtime::{CodingEngine, NativeCoder, PjrtCoder};
use crate::sim::faults::{digest_mix, DownState, FaultConfig, FaultKind, FaultTrace};
use crate::sim::NetConfig;
use anyhow::Result;
use std::sync::Arc;

/// Experiment configuration (defaults shrink the paper's 1 MB / 40 GB
/// scale to bench-friendly sizes; all knobs are CLI-exposed).
#[derive(Clone)]
pub struct ExpConfig {
    pub scheme: Scheme,
    pub block_size: usize,
    pub stripes: usize,
    pub cross_gbps: f64,
    pub aggregated: bool,
    pub engine: Arc<dyn CodingEngine>,
    pub seed: u64,
    /// Fold measured (real) coding time into the virtual clock. On for the
    /// paper experiments; off for deterministic tests (same seed ⇒ same
    /// virtual latencies regardless of host load or thread counts).
    pub time_compute: bool,
    /// Warm the decode-plan cache with the fault trace's predicted failure
    /// patterns before replay (`--plan-warmup`; experiment 7).
    pub plan_warmup: bool,
}

impl Default for ExpConfig {
    fn default() -> Self {
        ExpConfig {
            scheme: Scheme::S42,
            block_size: 256 * 1024,
            stripes: 4,
            cross_gbps: 1.0,
            aggregated: true,
            engine: Arc::new(NativeCoder),
            seed: 42,
            time_compute: true,
            plan_warmup: false,
        }
    }
}

impl ExpConfig {
    /// Select the PJRT backend (requires `make artifacts`).
    pub fn with_pjrt(mut self) -> Result<Self> {
        self.engine = Arc::new(PjrtCoder::new(None)?);
        Ok(self)
    }
}

/// Build the per-family DSS: UniLRC on its native placement, baselines on
/// ECWide, each with exactly the clusters it needs (§6 Setup).
pub fn build_dss(fam: CodeFamily, cfg: &ExpConfig) -> Dss {
    let code = cfg.scheme.build(fam);
    let (strategy, topo) = strategy_and_topo(fam, &code);
    Dss::new(
        code,
        strategy.as_ref(),
        topo,
        NetConfig::default().with_cross_gbps(cfg.cross_gbps),
        cfg.engine.clone(),
        DssConfig {
            block_size: cfg.block_size,
            aggregated: cfg.aggregated,
            time_compute: cfg.time_compute,
        },
    )
}

/// Placement strategy + a topology sized to its largest per-cluster
/// chunk (plus spare nodes for reconstruction targets).
pub fn strategy_and_topo(
    fam: CodeFamily,
    code: &crate::codes::Code,
) -> (Box<dyn PlacementStrategy>, Topology) {
    match fam {
        CodeFamily::UniLrc => {
            let clusters = code.groups().len();
            let biggest = code.groups().iter().map(|g| g.members.len()).max().unwrap();
            (Box::new(UniLrcPlace), Topology::new(clusters, biggest + 2))
        }
        _ => {
            let chunks = EcWide::chunks(code);
            let biggest = chunks.iter().map(|c| c.len()).max().unwrap();
            (Box::new(EcWide), Topology::new(chunks.len(), biggest + 2))
        }
    }
}

/// One (family, value) result row.
#[derive(Debug, Clone)]
pub struct Row {
    pub family: CodeFamily,
    pub value: f64,
    pub unit: &'static str,
}

fn mib(bytes: usize, secs: f64) -> f64 {
    bytes as f64 / secs / (1 << 20) as f64
}

/// `mean` over possibly-empty measurement sets (0 instead of NaN).
fn mean_or_zero(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        0.0
    } else {
        mean(samples)
    }
}

/// Experiment 1 — normal-read throughput (Fig 10(a)), MiB/s.
pub fn exp1_normal_read(cfg: &ExpConfig) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for fam in CodeFamily::paper_baselines() {
        let mut prng = Prng::new(cfg.seed);
        let mut dss = build_dss(fam, cfg);
        dss.ingest_random_stripes(cfg.stripes, &mut prng)?;
        let mut tputs = Vec::new();
        for s in 0..cfg.stripes {
            let r = dss.normal_read(s)?;
            tputs.push(mib(r.bytes, r.latency));
            dss.quiesce();
        }
        rows.push(Row { family: fam, value: mean(&tputs), unit: "MiB/s" });
    }
    Ok(rows)
}

/// Experiment 2 — degraded-read latency (Fig 10(b)), milliseconds.
pub fn exp2_degraded_read(cfg: &ExpConfig) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for fam in CodeFamily::paper_baselines() {
        let mut prng = Prng::new(cfg.seed);
        let mut dss = build_dss(fam, cfg);
        dss.ingest_random_stripes(1, &mut prng)?;
        let mut lats = Vec::new();
        for target in 0..dss.code.k() {
            let node = dss.metadata().node_of(0, target);
            dss.fail_node(node);
            let r = dss.degraded_read(0, target)?;
            lats.push(r.latency * 1e3);
            dss.heal_node(node);
            dss.quiesce();
        }
        rows.push(Row { family: fam, value: mean(&lats), unit: "ms" });
    }
    Ok(rows)
}

/// Experiment 2b — batched degraded-read burst, milliseconds: fail one
/// node, then request every one of its lost data blocks *at the same
/// instant*. The whole burst's repairs go through the proxy as one batched
/// event (`ProxyCtx::repair_node`), so the engine's worker pool overlaps
/// the per-stripe combines — the multi-stripe shape the §5 evaluation
/// measures.
pub fn exp2_degraded_burst(cfg: &ExpConfig) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for fam in CodeFamily::paper_baselines() {
        let mut prng = Prng::new(cfg.seed);
        let mut dss = build_dss(fam, cfg);
        dss.ingest_random_stripes(cfg.stripes, &mut prng)?;
        let node = dss.metadata().node_of(0, 0);
        dss.fail_node(node);
        let lost: Vec<_> = dss
            .metadata()
            .blocks_on_node(node)
            .into_iter()
            .filter(|&(_, b)| b < dss.code.k())
            .collect();
        anyhow::ensure!(!lost.is_empty(), "failed node hosts no data blocks");
        let r = dss.parallel_read(&lost)?;
        rows.push(Row { family: fam, value: r.latency * 1e3, unit: "ms" });
    }
    Ok(rows)
}

/// Experiment 3a — single-block recovery throughput (Fig 10(c)), MiB/s.
pub fn exp3_reconstruction(cfg: &ExpConfig) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for fam in CodeFamily::paper_baselines() {
        let mut prng = Prng::new(cfg.seed);
        let mut dss = build_dss(fam, cfg);
        dss.ingest_random_stripes(1, &mut prng)?;
        let mut tputs = Vec::new();
        for target in 0..dss.code.n() {
            let node = dss.metadata().node_of(0, target);
            dss.fail_node(node);
            let r = dss.reconstruct(0, target)?;
            tputs.push(mib(r.bytes, r.latency));
            dss.heal_node(node);
            dss.quiesce();
        }
        rows.push(Row { family: fam, value: mean(&tputs), unit: "MiB/s" });
    }
    Ok(rows)
}

/// Experiment 3b — full-node recovery throughput (Fig 10(d)), MiB/s.
pub fn exp3_node_recovery(cfg: &ExpConfig) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for fam in CodeFamily::paper_baselines() {
        let mut prng = Prng::new(cfg.seed);
        let mut dss = build_dss(fam, cfg);
        dss.ingest_random_stripes(cfg.stripes, &mut prng)?;
        let node = dss.metadata().node_of(0, 0);
        dss.fail_node(node);
        let r = dss.recover_node(node)?;
        rows.push(Row { family: fam, value: r.throughput_mib_s(), unit: "MiB/s" });
    }
    Ok(rows)
}

/// Experiment 4 — reconstruction throughput vs cross-cluster bandwidth
/// (Fig 11(a)): (gbps, per-family MiB/s).
pub fn exp4_bandwidth(cfg: &ExpConfig, sweep: &[f64]) -> Result<Vec<(f64, Vec<Row>)>> {
    let mut out = Vec::new();
    for &gbps in sweep {
        let mut c = cfg.clone();
        c.cross_gbps = gbps;
        out.push((gbps, exp3_reconstruction(&c)?));
    }
    Ok(out)
}

/// Experiment 5 — decoding (pure compute) throughput (Fig 11(b)), MiB/s:
/// time the coding-library combine for a single-block repair, no network.
pub fn exp5_decode(cfg: &ExpConfig) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for fam in CodeFamily::paper_baselines() {
        let mut prng = Prng::new(cfg.seed);
        let code = cfg.scheme.build(fam);
        let data: Vec<Vec<u8>> = (0..code.k()).map(|_| prng.bytes(cfg.block_size)).collect();
        let drefs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
        let parities = cfg.engine.encode(&code, &drefs)?;
        let stripe: Vec<&[u8]> =
            drefs.iter().copied().chain(parities.iter().map(|v| v.as_slice())).collect();
        let mut tputs = Vec::new();
        for target in 0..code.n() {
            let plan = code.repair_plan(target);
            let srcs: Vec<&[u8]> = plan.sources.iter().map(|&s| stripe[s]).collect();
            let t = std::time::Instant::now();
            let out = if plan.xor_only() {
                cfg.engine.fold(&srcs)?
            } else {
                cfg.engine.matmul(&[plan.coeffs.clone()], &srcs)?.pop().unwrap()
            };
            let dt = t.elapsed().as_secs_f64();
            anyhow::ensure!(out.as_slice() == stripe[target], "decode mismatch");
            tputs.push(mib(cfg.block_size, dt));
        }
        rows.push(Row { family: fam, value: mean(&tputs), unit: "MiB/s" });
    }
    Ok(rows)
}

/// Experiment 6 — production-workload latency CDFs (Fig 12).
pub struct Exp6Result {
    pub family: CodeFamily,
    pub normal_mean_ms: f64,
    pub degraded_mean_ms: f64,
    pub normal_cdf: Vec<(f64, f64)>,
    pub degraded_cdf: Vec<(f64, f64)>,
}

pub fn exp6_production(
    cfg: &ExpConfig,
    objects: usize,
    requests: usize,
) -> Result<Vec<Exp6Result>> {
    let mut out = Vec::new();
    for fam in CodeFamily::paper_baselines() {
        let mut prng = Prng::new(cfg.seed);
        let mut dss = build_dss(fam, cfg);
        dss.ingest_random_stripes(cfg.stripes, &mut prng)?;
        let wl = Workload::place_fit(&dss, WorkloadSpec::default(), objects, &mut prng);

        // normal reads
        let mut normal = Vec::new();
        for i in 0..requests {
            let obj = prng.gen_range(wl.objects.len());
            let _ = i;
            let r = wl.read_object(&mut dss, obj)?;
            normal.push(r.latency * 1e3);
            dss.quiesce();
        }

        // degrade one node, re-issue
        let node = dss.metadata().node_of(0, 0);
        dss.fail_node(node);
        let mut degraded = Vec::new();
        for _ in 0..requests {
            let obj = prng.gen_range(wl.objects.len());
            let r = wl.read_object(&mut dss, obj)?;
            degraded.push(r.latency * 1e3);
            dss.quiesce();
        }

        out.push(Exp6Result {
            family: fam,
            normal_mean_ms: mean(&normal),
            degraded_mean_ms: mean(&degraded),
            normal_cdf: cdf_points(&normal, 20),
            degraded_cdf: cdf_points(&degraded, 20),
        });
    }
    Ok(out)
}

/// Node-failure tolerance used in the reliability comparisons (Table 4):
/// the scheme's `f` for UniLRC/ALRC/ULRC; OLRC's larger distance bound
/// (`d = n − k − ⌈k/r⌉ + 2`, Theorem 2.3).
pub fn family_tolerance(scheme: Scheme, fam: CodeFamily) -> usize {
    match fam {
        CodeFamily::Olrc => {
            let code = scheme.build(CodeFamily::Olrc);
            let r = code.repair_plan(0).sources.len();
            code.n() - code.k() - code.k().div_ceil(r) + 1
        }
        _ => scheme.f,
    }
}

/// Experiment 7 (fault injection) configuration, on top of [`ExpConfig`].
#[derive(Debug, Clone)]
pub struct FaultSimConfig {
    /// Failure/repair clocks and horizon ([`FaultConfig`]).
    pub fault: FaultConfig,
    /// Co-resident tenants, each drawing its own object-size mix.
    pub tenants: usize,
    /// Objects placed per tenant.
    pub objects_per_tenant: usize,
    /// Objects read per tenant on each measured failure burst.
    pub reads_per_event: usize,
    /// Cap on events that trigger *measured* DSS operations (degraded-read
    /// bursts and batched recoveries). Occupancy statistics — degraded and
    /// unavailable time — always cover the whole trace, so long horizons
    /// stay cheap while the measured sample stays representative.
    pub measure_cap: usize,
}

impl Default for FaultSimConfig {
    fn default() -> Self {
        FaultSimConfig {
            fault: FaultConfig::default(),
            tenants: 3,
            objects_per_tenant: 8,
            reads_per_event: 2,
            measure_cap: 64,
        }
    }
}

/// Per-family summary of one fault-injection run.
#[derive(Debug, Clone)]
pub struct Exp7Result {
    pub family: CodeFamily,
    /// Fingerprint of the trace **and** every measured virtual latency —
    /// the determinism witness (same seed ⇒ same digest, any thread count).
    pub digest: u64,
    pub events: usize,
    pub node_failures: usize,
    pub cluster_failures: usize,
    /// Measured batched recovery events / blocks rebuilt across them.
    pub repair_events: usize,
    pub repaired_blocks: usize,
    pub mean_repair_ms: f64,
    pub cross_bytes: u64,
    /// Measured multi-tenant degraded-read bursts.
    pub degraded_reads: usize,
    pub mean_degraded_ms: f64,
    /// Hours with ≥ 1 failed block in any stripe / with some stripe
    /// unrecoverable, integrated over the whole trace.
    pub degraded_hours: f64,
    pub unavailable_hours: f64,
    /// Stripes that crossed an unrecoverable pattern at a repair event
    /// (data loss under the injected schedule; the virtual store restores
    /// ground truth on heal, modelling an out-of-band backup restore).
    pub data_loss_stripe_events: usize,
    /// Decode plans inserted by `--plan-warmup` (0 when off).
    pub prefetched_plans: usize,
    /// Fraction of time stripe 0 had ≥ 1 failed block, measured vs the
    /// closed-form birth–death steady state (`analysis::markov`).
    pub sim_degraded_frac: f64,
    pub markov_degraded_frac: f64,
    /// MTTDL through the injector's chain, from trace-estimated rates vs
    /// from the configured rates.
    pub mttdl_est_years: f64,
    pub mttdl_markov_years: f64,
}

/// Predicted erasure patterns of a fault trace: for every node that fails
/// (directly or via a cluster event) and every stripe, the blocks that
/// node hosts; for every correlated cluster event and stripe, the whole
/// cluster's blocks. Single-block patterns whose block repairs inside a
/// local group are dropped — that path XORs the group without consulting
/// the plan cache.
pub fn predicted_patterns(dss: &Dss, trace: &FaultTrace) -> Vec<Vec<usize>> {
    let mut patterns: Vec<Vec<usize>> = Vec::new();
    for node in trace.failing_nodes() {
        let mut per_stripe: std::collections::BTreeMap<StripeId, Vec<usize>> = Default::default();
        for (stripe, block) in dss.metadata().blocks_on_node(node) {
            per_stripe.entry(stripe).or_default().push(block);
        }
        patterns.extend(per_stripe.into_values());
    }
    for cluster in trace.failing_clusters() {
        for s in 0..dss.metadata().stripe_count() {
            patterns.push(dss.metadata().placement(s).blocks_in_cluster(cluster));
        }
    }
    for p in &mut patterns {
        p.sort_unstable();
    }
    patterns.retain(|p| match p.as_slice() {
        [] => false,
        [single] => dss.code.group_of(*single).is_none(),
        _ => true,
    });
    patterns.sort();
    patterns.dedup();
    patterns
}

/// Experiment 7 — deterministic fault injection: replay a seeded failure
/// schedule ([`FaultTrace`]) against the virtual-time DSS for each code
/// family, measuring degraded multi-tenant reads at failure bursts,
/// batched recovery at repair events, cross-cluster repair traffic, and
/// data-(un)availability windows; closed-form reliability predictions
/// ride along for the differential check.
///
/// Fully deterministic by construction: compute timing never folds into
/// the virtual clock (regardless of `cfg.time_compute`), so the digest is
/// a pure function of `(scheme, family, seed, config)` — identical across
/// runs, kernels, and worker-thread counts.
pub fn exp7_faults(cfg: &ExpConfig, fcfg: &FaultSimConfig) -> Result<Vec<Exp7Result>> {
    let mut out = Vec::new();
    for fam in CodeFamily::paper_baselines() {
        out.push(exp7_family(fam, cfg, fcfg)?);
    }
    Ok(out)
}

/// Piecewise-constant occupancy integrals accumulated between fault
/// events (and over the tail to the horizon).
#[derive(Default)]
struct Occupancy {
    /// Hours with ≥ 1 failed block in any stripe.
    degraded_hours: f64,
    /// Hours with some stripe's pattern unrecoverable.
    unavailable_hours: f64,
    /// Hours with ≥ 1 failed block in stripe 0 (the Markov comparator).
    s0_degraded_hours: f64,
    /// Σ (down nodes × hours) — the denominator of the μ̂ rate estimate.
    node_down_hours: f64,
}

impl Occupancy {
    fn accrue(&mut self, dss: &Dss, state: &DownState, dt: f64) {
        if dt <= 0.0 || state.down_count() == 0 {
            return;
        }
        let (degraded, unavailable) = dss.availability();
        if degraded {
            self.degraded_hours += dt;
        }
        if unavailable {
            self.unavailable_hours += dt;
        }
        if !dss.failed_blocks(0).is_empty() {
            self.s0_degraded_hours += dt;
        }
        self.node_down_hours += state.down_count() as f64 * dt;
    }
}

fn exp7_family(fam: CodeFamily, cfg: &ExpConfig, fcfg: &FaultSimConfig) -> Result<Exp7Result> {
    let mut det = cfg.clone();
    det.time_compute = false;
    let mut dss = build_dss(fam, &det);
    let mut prng = Prng::new(cfg.seed);
    dss.ingest_random_stripes(cfg.stripes, &mut prng)?;
    let tenants = Workload::place_tenants(&dss, fcfg.tenants, fcfg.objects_per_tenant, &mut prng);

    let trace = FaultTrace::generate(dss.topo, &fcfg.fault, cfg.seed);
    let mut digest = digest_mix(crate::sim::faults::DIGEST_SEED, trace.digest());

    let prefetched_plans = if cfg.plan_warmup {
        let patterns = predicted_patterns(&dss, &trace);
        dss.prefetch_plans(&patterns)
    } else {
        0
    };

    let horizon = fcfg.fault.horizon_hours;
    let n_nodes = dss.topo.total_nodes();
    let mut state = DownState::new(dss.topo);
    let mut t_prev = 0.0f64;
    let mut occ = Occupancy::default();
    let (mut node_failures, mut cluster_failures) = (0usize, 0usize);
    let (mut fail_transitions, mut repair_transitions) = (0usize, 0usize);
    let (mut repair_events, mut repaired_blocks) = (0usize, 0usize);
    let (mut repair_ms, mut degraded_ms) = (Vec::new(), Vec::new());
    let mut cross_bytes = 0u64;
    let mut data_loss_stripe_events = 0usize;
    let mut measured = 0usize;

    for (ei, ev) in trace.events.iter().enumerate() {
        // occupancy since the previous event, under the pre-event state
        occ.accrue(&dss, &state, ev.at_hours - t_prev);
        t_prev = ev.at_hours;

        // ------------------------------------------- apply the event
        match ev.kind {
            FaultKind::NodeFail(_) => node_failures += 1,
            FaultKind::ClusterFail(_) => cluster_failures += 1,
            _ => {}
        }
        let mut failed_now = Vec::new();
        let mut healed_now = Vec::new();
        for (node, down) in state.apply(ev.kind) {
            if down {
                dss.fail_node(node);
                fail_transitions += 1;
                failed_now.push(node);
            } else {
                repair_transitions += 1;
                healed_now.push(node);
            }
        }

        // ------------- failure burst: multi-tenant degraded-read fan-out
        if !failed_now.is_empty() && measured < fcfg.measure_cap {
            let (_, unavail) = dss.availability();
            if !unavail {
                let mut ep = Prng::new(cfg.seed ^ (0xE7E7_0000 + ei as u64));
                let mut blocks: Vec<(StripeId, usize)> = Vec::new();
                for wl in &tenants {
                    let mut cand: Vec<usize> = failed_now
                        .iter()
                        .flat_map(|&node| wl.objects_touching(&dss, node))
                        .collect();
                    cand.sort_unstable();
                    cand.dedup();
                    for _ in 0..fcfg.reads_per_event.min(cand.len()) {
                        let obj = cand.swap_remove(ep.gen_range(cand.len()));
                        blocks.extend(wl.objects[obj].iter().copied());
                    }
                }
                if !blocks.is_empty() {
                    let r = dss.parallel_read(&blocks)?;
                    degraded_ms.push(r.latency * 1e3);
                    digest = digest_mix(digest, r.latency.to_bits());
                    dss.quiesce();
                    measured += 1;
                }
            }
        }

        // -------- repair burst: batched recovery of the returning nodes
        if !healed_now.is_empty() {
            let mut lost: Vec<(StripeId, usize)> = healed_now
                .iter()
                .flat_map(|&node| dss.metadata().blocks_on_node(node))
                .collect();
            lost.sort_unstable();
            let mut lost_stripes = std::collections::BTreeSet::new();
            lost.retain(|&(stripe, _)| {
                if dss.stripe_recoverable(stripe) {
                    true
                } else {
                    lost_stripes.insert(stripe);
                    false
                }
            });
            data_loss_stripe_events += lost_stripes.len();
            if !lost.is_empty() && measured < fcfg.measure_cap {
                let r = dss.recover_blocks(&lost)?;
                repair_events += 1;
                repaired_blocks += r.blocks;
                cross_bytes += r.cross_bytes;
                repair_ms.push(r.seconds * 1e3);
                digest = digest_mix(digest, r.seconds.to_bits());
                digest = digest_mix(digest, r.cross_bytes);
                dss.quiesce();
                measured += 1;
            }
            for &node in &healed_now {
                dss.heal_node(node);
            }
        }
    }
    // tail occupancy from the last event to the horizon
    occ.accrue(&dss, &state, horizon - t_prev);

    // ------------------- closed-form comparison (analysis::markov chain)
    let n = dss.code.n();
    let f_tol = family_tolerance(cfg.scheme, fam);
    let node_clocks_on = fcfg.fault.node_mttf_hours > 0.0 && fcfg.fault.node_mttr_hours > 0.0;
    let (markov_degraded_frac, mttdl_markov_years) = if node_clocks_on {
        let lambda = 1.0 / fcfg.fault.node_mttf_hours;
        let mu = 1.0 / fcfg.fault.node_mttr_hours;
        (
            markov::degraded_fraction(n, lambda, mu),
            markov::mttdl_injected_years(n, f_tol, lambda, mu),
        )
    } else {
        (0.0, f64::INFINITY)
    };
    // rate estimates from the trace (effective per-node transitions)
    let up_hours = n_nodes as f64 * horizon - occ.node_down_hours;
    let have_rates = fail_transitions > 0 && repair_transitions > 0 && occ.node_down_hours > 0.0;
    let mttdl_est_years = if have_rates {
        let lambda_hat = fail_transitions as f64 / up_hours;
        let mu_hat = repair_transitions as f64 / occ.node_down_hours;
        markov::mttdl_injected_years(n, f_tol, lambda_hat, mu_hat)
    } else {
        f64::INFINITY
    };

    Ok(Exp7Result {
        family: fam,
        digest,
        events: trace.events.len(),
        node_failures,
        cluster_failures,
        repair_events,
        repaired_blocks,
        mean_repair_ms: mean_or_zero(&repair_ms),
        cross_bytes,
        degraded_reads: degraded_ms.len(),
        mean_degraded_ms: mean_or_zero(&degraded_ms),
        degraded_hours: occ.degraded_hours,
        unavailable_hours: occ.unavailable_hours,
        data_loss_stripe_events,
        prefetched_plans,
        sim_degraded_frac: occ.s0_degraded_hours / horizon,
        markov_degraded_frac,
        mttdl_est_years,
        mttdl_markov_years,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic test config: `time_compute: false` keeps asserted
    /// latencies pure functions of the virtual network — host load and
    /// worker-thread scheduling can no longer flake the ordering asserts.
    fn tiny() -> ExpConfig {
        ExpConfig { block_size: 16 * 1024, stripes: 2, time_compute: false, ..Default::default() }
    }

    #[test]
    fn exp1_shape() {
        let rows = exp1_normal_read(&tiny()).unwrap();
        assert_eq!(rows.len(), 4);
        let uni = rows.iter().find(|r| r.family == CodeFamily::UniLrc).unwrap().value;
        let olrc = rows.iter().find(|r| r.family == CodeFamily::Olrc).unwrap().value;
        assert!(uni >= olrc * 0.99, "UniLRC {uni} vs OLRC {olrc}");
    }

    #[test]
    fn exp2_burst_runs() {
        let rows = exp2_degraded_burst(&tiny()).unwrap();
        assert_eq!(rows.len(), 4);
        for r in &rows {
            assert!(r.value > 0.0, "{:?}", r.family);
        }
    }

    #[test]
    fn exp2_and_exp3_shapes() {
        let lat = exp2_degraded_read(&tiny()).unwrap();
        let uni = lat.iter().find(|r| r.family == CodeFamily::UniLrc).unwrap().value;
        let olrc = lat.iter().find(|r| r.family == CodeFamily::Olrc).unwrap().value;
        assert!(uni < olrc, "degraded latency: UniLRC {uni} < OLRC {olrc}");

        let rec = exp3_reconstruction(&tiny()).unwrap();
        let uni = rec.iter().find(|r| r.family == CodeFamily::UniLrc).unwrap().value;
        for r in &rec {
            assert!(uni >= r.value * 0.95, "{:?}", r.family);
        }
    }

    #[test]
    fn exp4_unilrc_flat_baselines_climb() {
        // larger blocks so bandwidth (not the fixed RTT) dominates
        let cfg = ExpConfig {
            block_size: 256 * 1024,
            stripes: 2,
            time_compute: false,
            ..Default::default()
        };
        let sweep = exp4_bandwidth(&cfg, &[0.5, 10.0]).unwrap();
        let uni_lo = sweep[0].1.iter().find(|r| r.family == CodeFamily::UniLrc).unwrap().value;
        let uni_hi = sweep[1].1.iter().find(|r| r.family == CodeFamily::UniLrc).unwrap().value;
        let olrc_lo = sweep[0].1.iter().find(|r| r.family == CodeFamily::Olrc).unwrap().value;
        let olrc_hi = sweep[1].1.iter().find(|r| r.family == CodeFamily::Olrc).unwrap().value;
        assert!((uni_hi - uni_lo).abs() / uni_lo < 0.25, "UniLRC flat-ish");
        assert!(olrc_hi > olrc_lo * 1.5, "OLRC climbs with bandwidth: {olrc_lo} -> {olrc_hi}");
    }

    #[test]
    fn exp7_smoke_all_families() {
        let cfg = ExpConfig { block_size: 4 * 1024, stripes: 2, ..tiny() };
        let fcfg = FaultSimConfig {
            fault: FaultConfig {
                node_mttf_hours: 300.0,
                node_mttr_hours: 10.0,
                cluster_mttf_hours: 1_500.0,
                cluster_mttr_hours: 5.0,
                horizon_hours: 600.0,
            },
            tenants: 2,
            objects_per_tenant: 6,
            reads_per_event: 1,
            measure_cap: 8,
        };
        let rows = exp7_faults(&cfg, &fcfg).unwrap();
        assert_eq!(rows.len(), 4);
        for r in &rows {
            assert!(r.events > 0, "{:?}", r.family);
            assert!(r.node_failures > 0, "{:?}", r.family);
            assert!(r.degraded_hours > 0.0, "{:?}", r.family);
            assert!(r.degraded_hours <= fcfg.fault.horizon_hours + 1e-9);
            assert!(r.unavailable_hours <= r.degraded_hours + 1e-9);
            assert!(r.markov_degraded_frac > 0.0 && r.markov_degraded_frac < 1.0);
        }
    }

    #[test]
    fn family_tolerance_matches_table() {
        assert_eq!(family_tolerance(Scheme::S42, CodeFamily::UniLrc), 7);
        assert_eq!(family_tolerance(Scheme::S42, CodeFamily::Alrc), 7);
        assert_eq!(family_tolerance(Scheme::S42, CodeFamily::Olrc), 11);
    }

    #[test]
    fn predicted_patterns_cover_single_node_failures() {
        // S136 keeps this test's cache keys disjoint from every other
        // test in this binary (keys embed the code name), so the
        // `inserted > 0` assert cannot race concurrent demand inserts.
        let cfg = ExpConfig { block_size: 1024, stripes: 2, scheme: Scheme::S136, ..tiny() };
        let mut dss = build_dss(CodeFamily::UniLrc, &cfg);
        let mut p = Prng::new(5);
        dss.ingest_random_stripes(2, &mut p).unwrap();
        let trace = FaultTrace::generate(dss.topo, &FaultConfig::accelerated(), 5);
        let patterns = predicted_patterns(&dss, &trace);
        assert!(!patterns.is_empty());
        for pat in &patterns {
            assert!(!pat.is_empty());
            assert!(pat.windows(2).all(|w| w[0] < w[1]), "sorted dedup {pat:?}");
        }
        // warm-up inserts them and repairs still verify (recover_node
        // checks rebuilt bytes against ground truth internally)
        let inserted = dss.prefetch_plans(&patterns);
        assert!(inserted > 0);
        let node = dss.metadata().node_of(0, 0);
        dss.fail_node(node);
        dss.recover_node(node).unwrap();
        dss.heal_node(node);
    }

    #[test]
    fn exp6_runs() {
        let mut cfg = tiny();
        cfg.stripes = 3;
        let res = exp6_production(&cfg, 10, 8).unwrap();
        assert_eq!(res.len(), 4);
        for r in &res {
            assert!(r.normal_mean_ms > 0.0);
            assert!(r.degraded_mean_ms > 0.0);
        }
    }
}
