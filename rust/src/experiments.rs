//! The §6 system experiments (Experiments 1–6), shared by the CLI
//! (`unilrc experiment N`) and the bench harness (`cargo bench`).
//!
//! Each driver builds a DSS per code family on the virtual testbed
//! (DESIGN.md §5) and reports the same quantity the paper's figure plots.

use crate::client::workload::{Workload, WorkloadSpec};
use crate::client::{cdf_points, mean};
use crate::codes::spec::{CodeFamily, Scheme};
use crate::coordinator::{Dss, DssConfig};
use crate::placement::{EcWide, PlacementStrategy, Topology, UniLrcPlace};
use crate::prng::Prng;
use crate::runtime::{CodingEngine, NativeCoder, PjrtCoder};
use crate::sim::NetConfig;
use anyhow::Result;
use std::sync::Arc;

/// Experiment configuration (defaults shrink the paper's 1 MB / 40 GB
/// scale to bench-friendly sizes; all knobs are CLI-exposed).
#[derive(Clone)]
pub struct ExpConfig {
    pub scheme: Scheme,
    pub block_size: usize,
    pub stripes: usize,
    pub cross_gbps: f64,
    pub aggregated: bool,
    pub engine: Arc<dyn CodingEngine>,
    pub seed: u64,
}

impl Default for ExpConfig {
    fn default() -> Self {
        ExpConfig {
            scheme: Scheme::S42,
            block_size: 256 * 1024,
            stripes: 4,
            cross_gbps: 1.0,
            aggregated: true,
            engine: Arc::new(NativeCoder),
            seed: 42,
        }
    }
}

impl ExpConfig {
    /// Select the PJRT backend (requires `make artifacts`).
    pub fn with_pjrt(mut self) -> Result<Self> {
        self.engine = Arc::new(PjrtCoder::new(None)?);
        Ok(self)
    }
}

/// Build the per-family DSS: UniLRC on its native placement, baselines on
/// ECWide, each with exactly the clusters it needs (§6 Setup).
pub fn build_dss(fam: CodeFamily, cfg: &ExpConfig) -> Dss {
    let code = cfg.scheme.build(fam);
    let (strategy, topo) = strategy_and_topo(fam, &code);
    Dss::new(
        code,
        strategy.as_ref(),
        topo,
        NetConfig::default().with_cross_gbps(cfg.cross_gbps),
        cfg.engine.clone(),
        DssConfig { block_size: cfg.block_size, aggregated: cfg.aggregated, time_compute: true },
    )
}

/// Placement strategy + a topology sized to its largest per-cluster
/// chunk (plus spare nodes for reconstruction targets).
pub fn strategy_and_topo(
    fam: CodeFamily,
    code: &crate::codes::Code,
) -> (Box<dyn PlacementStrategy>, Topology) {
    match fam {
        CodeFamily::UniLrc => {
            let clusters = code.groups().len();
            let biggest = code.groups().iter().map(|g| g.members.len()).max().unwrap();
            (Box::new(UniLrcPlace), Topology::new(clusters, biggest + 2))
        }
        _ => {
            let chunks = EcWide::chunks(code);
            let biggest = chunks.iter().map(|c| c.len()).max().unwrap();
            (Box::new(EcWide), Topology::new(chunks.len(), biggest + 2))
        }
    }
}

/// One (family, value) result row.
#[derive(Debug, Clone)]
pub struct Row {
    pub family: CodeFamily,
    pub value: f64,
    pub unit: &'static str,
}

fn mib(bytes: usize, secs: f64) -> f64 {
    bytes as f64 / secs / (1 << 20) as f64
}

/// Experiment 1 — normal-read throughput (Fig 10(a)), MiB/s.
pub fn exp1_normal_read(cfg: &ExpConfig) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for fam in CodeFamily::paper_baselines() {
        let mut prng = Prng::new(cfg.seed);
        let mut dss = build_dss(fam, cfg);
        dss.ingest_random_stripes(cfg.stripes, &mut prng)?;
        let mut tputs = Vec::new();
        for s in 0..cfg.stripes {
            let r = dss.normal_read(s)?;
            tputs.push(mib(r.bytes, r.latency));
            dss.quiesce();
        }
        rows.push(Row { family: fam, value: mean(&tputs), unit: "MiB/s" });
    }
    Ok(rows)
}

/// Experiment 2 — degraded-read latency (Fig 10(b)), milliseconds.
pub fn exp2_degraded_read(cfg: &ExpConfig) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for fam in CodeFamily::paper_baselines() {
        let mut prng = Prng::new(cfg.seed);
        let mut dss = build_dss(fam, cfg);
        dss.ingest_random_stripes(1, &mut prng)?;
        let mut lats = Vec::new();
        for target in 0..dss.code.k() {
            let node = dss.metadata().node_of(0, target);
            dss.fail_node(node);
            let r = dss.degraded_read(0, target)?;
            lats.push(r.latency * 1e3);
            dss.heal_node(node);
            dss.quiesce();
        }
        rows.push(Row { family: fam, value: mean(&lats), unit: "ms" });
    }
    Ok(rows)
}

/// Experiment 2b — batched degraded-read burst, milliseconds: fail one
/// node, then request every one of its lost data blocks *at the same
/// instant*. The whole burst's repairs go through the proxy as one batched
/// event (`ProxyCtx::repair_node`), so the engine's worker pool overlaps
/// the per-stripe combines — the multi-stripe shape the §5 evaluation
/// measures.
pub fn exp2_degraded_burst(cfg: &ExpConfig) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for fam in CodeFamily::paper_baselines() {
        let mut prng = Prng::new(cfg.seed);
        let mut dss = build_dss(fam, cfg);
        dss.ingest_random_stripes(cfg.stripes, &mut prng)?;
        let node = dss.metadata().node_of(0, 0);
        dss.fail_node(node);
        let lost: Vec<_> = dss
            .metadata()
            .blocks_on_node(node)
            .into_iter()
            .filter(|&(_, b)| b < dss.code.k())
            .collect();
        anyhow::ensure!(!lost.is_empty(), "failed node hosts no data blocks");
        let r = dss.parallel_read(&lost)?;
        rows.push(Row { family: fam, value: r.latency * 1e3, unit: "ms" });
    }
    Ok(rows)
}

/// Experiment 3a — single-block recovery throughput (Fig 10(c)), MiB/s.
pub fn exp3_reconstruction(cfg: &ExpConfig) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for fam in CodeFamily::paper_baselines() {
        let mut prng = Prng::new(cfg.seed);
        let mut dss = build_dss(fam, cfg);
        dss.ingest_random_stripes(1, &mut prng)?;
        let mut tputs = Vec::new();
        for target in 0..dss.code.n() {
            let node = dss.metadata().node_of(0, target);
            dss.fail_node(node);
            let r = dss.reconstruct(0, target)?;
            tputs.push(mib(r.bytes, r.latency));
            dss.heal_node(node);
            dss.quiesce();
        }
        rows.push(Row { family: fam, value: mean(&tputs), unit: "MiB/s" });
    }
    Ok(rows)
}

/// Experiment 3b — full-node recovery throughput (Fig 10(d)), MiB/s.
pub fn exp3_node_recovery(cfg: &ExpConfig) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for fam in CodeFamily::paper_baselines() {
        let mut prng = Prng::new(cfg.seed);
        let mut dss = build_dss(fam, cfg);
        dss.ingest_random_stripes(cfg.stripes, &mut prng)?;
        let node = dss.metadata().node_of(0, 0);
        dss.fail_node(node);
        let r = dss.recover_node(node)?;
        rows.push(Row { family: fam, value: r.throughput_mib_s(), unit: "MiB/s" });
    }
    Ok(rows)
}

/// Experiment 4 — reconstruction throughput vs cross-cluster bandwidth
/// (Fig 11(a)): (gbps, per-family MiB/s).
pub fn exp4_bandwidth(cfg: &ExpConfig, sweep: &[f64]) -> Result<Vec<(f64, Vec<Row>)>> {
    let mut out = Vec::new();
    for &gbps in sweep {
        let mut c = cfg.clone();
        c.cross_gbps = gbps;
        out.push((gbps, exp3_reconstruction(&c)?));
    }
    Ok(out)
}

/// Experiment 5 — decoding (pure compute) throughput (Fig 11(b)), MiB/s:
/// time the coding-library combine for a single-block repair, no network.
pub fn exp5_decode(cfg: &ExpConfig) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for fam in CodeFamily::paper_baselines() {
        let mut prng = Prng::new(cfg.seed);
        let code = cfg.scheme.build(fam);
        let data: Vec<Vec<u8>> = (0..code.k()).map(|_| prng.bytes(cfg.block_size)).collect();
        let drefs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
        let parities = cfg.engine.encode(&code, &drefs)?;
        let stripe: Vec<&[u8]> =
            drefs.iter().copied().chain(parities.iter().map(|v| v.as_slice())).collect();
        let mut tputs = Vec::new();
        for target in 0..code.n() {
            let plan = code.repair_plan(target);
            let srcs: Vec<&[u8]> = plan.sources.iter().map(|&s| stripe[s]).collect();
            let t = std::time::Instant::now();
            let out = if plan.xor_only() {
                cfg.engine.fold(&srcs)?
            } else {
                cfg.engine.matmul(&[plan.coeffs.clone()], &srcs)?.pop().unwrap()
            };
            let dt = t.elapsed().as_secs_f64();
            anyhow::ensure!(out.as_slice() == stripe[target], "decode mismatch");
            tputs.push(mib(cfg.block_size, dt));
        }
        rows.push(Row { family: fam, value: mean(&tputs), unit: "MiB/s" });
    }
    Ok(rows)
}

/// Experiment 6 — production-workload latency CDFs (Fig 12).
pub struct Exp6Result {
    pub family: CodeFamily,
    pub normal_mean_ms: f64,
    pub degraded_mean_ms: f64,
    pub normal_cdf: Vec<(f64, f64)>,
    pub degraded_cdf: Vec<(f64, f64)>,
}

pub fn exp6_production(
    cfg: &ExpConfig,
    objects: usize,
    requests: usize,
) -> Result<Vec<Exp6Result>> {
    let mut out = Vec::new();
    for fam in CodeFamily::paper_baselines() {
        let mut prng = Prng::new(cfg.seed);
        let mut dss = build_dss(fam, cfg);
        dss.ingest_random_stripes(cfg.stripes, &mut prng)?;
        let wl = Workload::place_fit(&dss, WorkloadSpec::default(), objects, &mut prng);

        // normal reads
        let mut normal = Vec::new();
        for i in 0..requests {
            let obj = prng.gen_range(wl.objects.len());
            let _ = i;
            let r = wl.read_object(&mut dss, obj)?;
            normal.push(r.latency * 1e3);
            dss.quiesce();
        }

        // degrade one node, re-issue
        let node = dss.metadata().node_of(0, 0);
        dss.fail_node(node);
        let mut degraded = Vec::new();
        for _ in 0..requests {
            let obj = prng.gen_range(wl.objects.len());
            let r = wl.read_object(&mut dss, obj)?;
            degraded.push(r.latency * 1e3);
            dss.quiesce();
        }

        out.push(Exp6Result {
            family: fam,
            normal_mean_ms: mean(&normal),
            degraded_mean_ms: mean(&degraded),
            normal_cdf: cdf_points(&normal, 20),
            degraded_cdf: cdf_points(&degraded, 20),
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ExpConfig {
        ExpConfig { block_size: 16 * 1024, stripes: 2, ..Default::default() }
    }

    #[test]
    fn exp1_shape() {
        let rows = exp1_normal_read(&tiny()).unwrap();
        assert_eq!(rows.len(), 4);
        let uni = rows.iter().find(|r| r.family == CodeFamily::UniLrc).unwrap().value;
        let olrc = rows.iter().find(|r| r.family == CodeFamily::Olrc).unwrap().value;
        assert!(uni >= olrc * 0.99, "UniLRC {uni} vs OLRC {olrc}");
    }

    #[test]
    fn exp2_burst_runs() {
        let rows = exp2_degraded_burst(&tiny()).unwrap();
        assert_eq!(rows.len(), 4);
        for r in &rows {
            assert!(r.value > 0.0, "{:?}", r.family);
        }
    }

    #[test]
    fn exp2_and_exp3_shapes() {
        let lat = exp2_degraded_read(&tiny()).unwrap();
        let uni = lat.iter().find(|r| r.family == CodeFamily::UniLrc).unwrap().value;
        let olrc = lat.iter().find(|r| r.family == CodeFamily::Olrc).unwrap().value;
        assert!(uni < olrc, "degraded latency: UniLRC {uni} < OLRC {olrc}");

        let rec = exp3_reconstruction(&tiny()).unwrap();
        let uni = rec.iter().find(|r| r.family == CodeFamily::UniLrc).unwrap().value;
        for r in &rec {
            assert!(uni >= r.value * 0.95, "{:?}", r.family);
        }
    }

    #[test]
    fn exp4_unilrc_flat_baselines_climb() {
        // larger blocks so bandwidth (not the fixed RTT) dominates
        let cfg = ExpConfig { block_size: 256 * 1024, stripes: 2, ..Default::default() };
        let sweep = exp4_bandwidth(&cfg, &[0.5, 10.0]).unwrap();
        let uni_lo = sweep[0].1.iter().find(|r| r.family == CodeFamily::UniLrc).unwrap().value;
        let uni_hi = sweep[1].1.iter().find(|r| r.family == CodeFamily::UniLrc).unwrap().value;
        let olrc_lo = sweep[0].1.iter().find(|r| r.family == CodeFamily::Olrc).unwrap().value;
        let olrc_hi = sweep[1].1.iter().find(|r| r.family == CodeFamily::Olrc).unwrap().value;
        assert!((uni_hi - uni_lo).abs() / uni_lo < 0.25, "UniLRC flat-ish");
        assert!(olrc_hi > olrc_lo * 1.5, "OLRC climbs with bandwidth: {olrc_lo} -> {olrc_hi}");
    }

    #[test]
    fn exp6_runs() {
        let mut cfg = tiny();
        cfg.stripes = 3;
        let res = exp6_production(&cfg, 10, 8).unwrap();
        assert_eq!(res.len(), 4);
        for r in &res {
            assert!(r.normal_mean_ms > 0.0);
            assert!(r.degraded_mean_ms > 0.0);
        }
    }
}
